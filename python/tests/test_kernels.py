"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas kernels (interpret=True) must match the pure-jnp oracle in
`compile.kernels.ref` bit-closely across shapes, content distributions and
dtypes. Hypothesis drives the sweeps.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import common
from compile.kernels import audio_pipeline as k_audio
from compile.kernels import image_pipeline as k_image
from compile.kernels import ref

# ---------------------------------------------------------------------------
# image
# ---------------------------------------------------------------------------


def _rand_coeffs(rng, batch):
    s = common.IMG_SRC
    return rng.normal(0.0, 6.0, (batch, s, s, 3)).astype(np.float32)


@pytest.mark.parametrize("batch", [1, 2, 4])
def test_image_pipeline_matches_ref(batch):
    rng = np.random.default_rng(batch)
    coeffs = _rand_coeffs(rng, batch)
    got = np.asarray(k_image.image_pipeline(jnp.asarray(coeffs), batch=batch))
    want = np.stack([np.asarray(ref.image_pipeline(jnp.asarray(c))) for c in coeffs])
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 60.0))
def test_image_pipeline_content_sweep(seed, scale):
    rng = np.random.default_rng(seed)
    s = common.IMG_SRC
    coeffs = (rng.normal(0.0, scale, (1, s, s, 3))).astype(np.float32)
    got = np.asarray(k_image.image_pipeline(jnp.asarray(coeffs), batch=1))[0]
    want = np.asarray(ref.image_pipeline(jnp.asarray(coeffs[0])))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


def test_image_pipeline_output_shape_and_range():
    rng = np.random.default_rng(0)
    coeffs = _rand_coeffs(rng, 2)
    out = np.asarray(k_image.image_pipeline(jnp.asarray(coeffs), batch=2))
    assert out.shape == (2, common.IMG_CROP, common.IMG_CROP, 3)
    # Normalized pixel range is a few units around zero.
    assert np.abs(out).max() < 20.0


def test_decode_dc_only_is_flat():
    s = common.IMG_SRC
    coeffs = np.zeros((s, s, 3), dtype=np.float32)
    coeffs[::8, ::8, :] = 10.0  # DC of each block
    px = np.asarray(ref.decode_blocks(jnp.asarray(coeffs)))
    # Every 8x8 block is constant.
    blk = px[:8, :8, 0]
    assert np.allclose(blk, blk[0, 0], atol=1e-4)
    assert np.allclose(px[0, 0, 0], 10.0 * 8.0 / 8.0 + 128.0, atol=1e-3)


def test_resize_matrix_partition_of_unity():
    for src, dst in [(96, 72), (72, 96), (64, 64)]:
        m = ref.resize_matrix(src, dst)
        np.testing.assert_allclose(m.sum(axis=1), 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# audio
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("len_s", list(common.AUDIO_BUCKETS_S))
def test_audio_pipeline_matches_ref(len_s):
    rng = np.random.default_rng(int(len_s * 10))
    n = int(round(len_s * common.SAMPLE_RATE))
    pcm = rng.normal(0.0, 0.3, (n,)).astype(np.float32)
    got = np.asarray(k_audio.audio_pipeline(jnp.asarray(pcm), len_s=len_s))
    want = np.asarray(ref.audio_pipeline(jnp.asarray(pcm)))
    assert got.shape == want.shape == (common.n_frames(len_s), common.N_MELS)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    f0=st.floats(80.0, 2000.0),
    amp=st.floats(0.01, 1.0),
)
def test_audio_pipeline_tone_sweep(seed, f0, amp):
    n = int(round(2.5 * common.SAMPLE_RATE))
    t = np.arange(n) / common.SAMPLE_RATE
    rng = np.random.default_rng(seed)
    pcm = (amp * np.sin(2 * np.pi * f0 * t) + 0.01 * rng.normal(size=n)).astype(np.float32)
    got = np.asarray(k_audio.audio_pipeline(jnp.asarray(pcm), len_s=2.5))
    want = np.asarray(ref.audio_pipeline(jnp.asarray(pcm)))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_normalized_features_zero_mean_unit_var():
    rng = np.random.default_rng(1)
    pcm = rng.normal(0.0, 0.3, (int(2.5 * common.SAMPLE_RATE),)).astype(np.float32)
    feat = np.asarray(k_audio.audio_pipeline(jnp.asarray(pcm), len_s=2.5))
    np.testing.assert_allclose(feat.mean(axis=0), 0.0, atol=1e-3)
    # std slightly below 1 because of the 1e-2 variance floor:
    # std_out = sqrt(v / (v + 0.01)).
    std = feat.std(axis=0)
    assert (std <= 1.0 + 1e-3).all()
    assert (std >= 0.85).all(), std.min()


def test_spectrogram_peak_at_tone():
    sr = common.SAMPLE_RATE
    f0 = 1000.0
    n = 4096
    pcm = np.sin(2 * np.pi * f0 * np.arange(n) / sr).astype(np.float32)
    spec = np.asarray(ref.power_spectrogram(jnp.asarray(pcm), common.N_FFT, common.HOP))
    mid = spec[spec.shape[0] // 2]
    peak_bin = int(mid.argmax())
    expect = int(round(f0 * common.N_FFT / sr))
    assert abs(peak_bin - expect) <= 1


def test_mel_filterbank_shapes_and_coverage():
    fb = ref.mel_filterbank(common.N_MELS, common.N_FFT, common.SAMPLE_RATE)
    assert fb.shape == (common.N_MELS, common.N_FFT // 2 + 1)
    assert (fb.sum(axis=1) > 0).all()


def test_dtype_bf16_input_promotes_cleanly():
    """Kernels accept bf16 inputs (the MXU-native dtype) and stay finite."""
    rng = np.random.default_rng(3)
    s = common.IMG_SRC
    coeffs = rng.normal(0.0, 6.0, (1, s, s, 3)).astype(np.float32)
    got32 = np.asarray(k_image.image_pipeline(jnp.asarray(coeffs), batch=1))
    got16 = np.asarray(
        k_image.image_pipeline(jnp.asarray(coeffs, dtype=jnp.bfloat16).astype(jnp.float32), batch=1)
    )
    assert np.isfinite(got16).all()
    # bf16 rounding of the input moves outputs only modestly.
    assert np.abs(got16 - got32).max() < 0.35
