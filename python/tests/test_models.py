"""L2 model sanity: shapes, finiteness, determinism, batch consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common
from compile.models import citrinet, conformer, mobilenet, squeezenet, swin
from compile.models.layers import count_params


def _img(b, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, (b, common.IMG_CROP, common.IMG_CROP, 3)).astype(np.float32))


def _mel(b, t, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, (b, t, common.N_MELS)).astype(np.float32))


VISION = [
    ("mobilenet", mobilenet.init, mobilenet.apply),
    ("squeezenet", squeezenet.init, squeezenet.apply),
    ("swin", swin.init, swin.apply),
]


@pytest.mark.parametrize("name,init,apply", VISION)
def test_vision_shapes_and_finiteness(name, init, apply):
    params = init()
    for b in [1, 3]:
        y = np.asarray(apply(params, _img(b)))
        assert y.shape == (b, 1000), name
        assert np.isfinite(y).all(), name
        assert np.abs(y).max() > 1e-6, f"{name}: dead outputs"


@pytest.mark.parametrize("name,init,apply", VISION)
def test_vision_batch_consistency(name, init, apply):
    """Row 0 of a batch-3 run equals a batch-1 run on the same sample."""
    params = init()
    x = _img(3, seed=1)
    y3 = np.asarray(apply(params, x))
    y1 = np.asarray(apply(params, x[:1]))
    np.testing.assert_allclose(y3[0], y1[0], atol=1e-4, rtol=1e-4)


def test_vision_init_deterministic():
    a = mobilenet.init()
    b = mobilenet.init()
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("size", ["small", "default"])
def test_conformer_shapes(size):
    params = conformer.init(size)
    t = common.n_frames(2.5)
    y = np.asarray(conformer.apply(params, _mel(2, t), size))
    # two SAME-padded stride-2 convs: ceil(ceil(t/2)/2)
    t_sub = -(-(-(-t // 2)) // 2)
    assert y.shape == (2, t_sub, conformer.VOCAB)
    assert np.isfinite(y).all()
    # log-softmax rows sum to ~1 in prob space.
    probs = np.exp(y[0, 0])
    assert abs(probs.sum() - 1.0) < 1e-3


def test_conformer_default_larger_than_small():
    small = count_params(conformer.init("small"))
    default = count_params(conformer.init("default"))
    assert default > 2 * small, (small, default)


def test_citrinet_shapes_and_logprobs():
    params = citrinet.init()
    for len_s in [2.5, 5.0]:
        t = common.n_frames(len_s)
        y = np.asarray(citrinet.apply(params, _mel(1, t)))
        assert y.shape == (1, -(-t // 2), citrinet.VOCAB)  # SAME stride-2
        probs = np.exp(y[0, 3])
        assert abs(probs.sum() - 1.0) < 1e-3


def test_swin_shift_changes_output():
    """Shifted-window block (block 1) must see different neighborhoods
    than the unshifted block — permuting a window's content changes the
    logits (sanity that windowing isn't a global op)."""
    params = swin.init()
    x = _img(1, seed=2)
    y = np.asarray(swin.apply(params, x))
    x2 = np.asarray(x).copy()
    x2[0, :8, :8, :] = x2[0, :8, :8, ::-1]  # scramble one patch
    y2 = np.asarray(swin.apply(params, jnp.asarray(x2)))
    assert np.abs(y - y2).max() > 1e-6


def test_param_counts_reasonable():
    # Lite models: big enough to be real compute, small enough for 1-core.
    assert 100_000 < count_params(mobilenet.init()) < 2_000_000
    assert 100_000 < count_params(squeezenet.init()) < 2_000_000
    assert 100_000 < count_params(swin.init()) < 2_000_000
    assert 100_000 < count_params(citrinet.init()) < 2_000_000
    assert 100_000 < count_params(conformer.init("small")) < 3_000_000
    assert 500_000 < count_params(conformer.init("default")) < 10_000_000
