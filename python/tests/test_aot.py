"""AOT path correctness: the HLO-text interchange itself.

Round-trips a jitted function through `aot.to_hlo_text` → the local
xla_client compiler → execution, and compares against direct JAX
execution — the same contract the Rust runtime relies on (text parse must
preserve numerics, constants must not be elided).
"""

import jax
import numpy as np

from compile import aot, common, model


def test_hlo_text_has_no_elided_literals_for_param_models():
    """Every registry entry lowers with constants as parameters, so the
    text must contain zero `constant({...})` markers."""
    entries = [e for e in model.all_entries() if e.key in (
        "kernel/image_pipeline/b1",
        "model/mobilenet/b1",
        "model/citrinet/b1/len2p5",
    )]
    assert len(entries) == 3
    for e in entries:
        const_specs = tuple(jax.ShapeDtypeStruct(c.shape, c.dtype) for c in e.consts)
        lowered = jax.jit(e.fn).lower(*const_specs, *e.example_args)
        text = aot.to_hlo_text(lowered)
        assert "constant({...})" not in text, e.key
        assert "ENTRY" in text or "entry_computation_layout" in text


def test_flops_estimate_scales_with_batch():
    e1 = next(e for e in model.all_entries() if e.key == "model/squeezenet/b1")
    e4 = next(e for e in model.all_entries() if e.key == "model/squeezenet/b4")
    def flops(e):
        const_specs = tuple(jax.ShapeDtypeStruct(c.shape, c.dtype) for c in e.consts)
        return aot.flops_estimate(jax.jit(e.fn).lower(*const_specs, *e.example_args))
    f1, f4 = flops(e1), flops(e4)
    if f1 > 0 and f4 > 0:
        assert 3.0 < f4 / f1 < 5.0


def test_entry_grid_is_complete_and_unique():
    entries = model.all_entries()
    keys = [e.key for e in entries]
    assert len(keys) == len(set(keys)), "duplicate artifact keys"
    n_kernels = 1 + len(common.AUDIO_BUCKETS_S)
    n_vision = 3 * len(common.VISION_BATCHES)
    n_audio = 3 * len(common.AUDIO_BATCHES) * len(common.AUDIO_BUCKETS_S)
    assert len(entries) == n_kernels + n_vision + n_audio


def test_weights_concatenation_layout():
    """write_weights must serialize leaves in registry order, f32 LE."""
    import os
    import tempfile

    consts = [np.arange(4, dtype=np.float32).reshape(2, 2), np.array([7.0], dtype=np.float32)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        shapes = aot.write_weights(consts, path)
        assert shapes == [[2, 2], [1]]
        raw = np.fromfile(path, dtype="<f4")
        np.testing.assert_array_equal(raw, np.array([0, 1, 2, 3, 7], dtype=np.float32))
