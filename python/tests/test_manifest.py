"""Manifest integrity: the build-time contract the Rust runtime consumes.

Skips when `make artifacts` has not run yet.
"""

import json
import os

import numpy as np
import pytest

from compile import common

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_entries():
    m = _manifest()
    keys = {a["key"] for a in m["artifacts"]}
    # 1 image kernel + 4 audio kernels + 3*5 vision + 3*4*4 audio = 68.
    assert len(keys) == 68, len(keys)
    assert "kernel/image_pipeline/b1" in keys
    for len_s in common.AUDIO_BUCKETS_S:
        assert f"kernel/audio_pipeline/len{common.fmt_len(len_s)}" in keys
    for b in common.VISION_BATCHES:
        assert f"model/mobilenet/b{b}" in keys
    for b in common.AUDIO_BATCHES:
        for len_s in common.AUDIO_BUCKETS_S:
            assert f"model/citrinet/b{b}/len{common.fmt_len(len_s)}" in keys


def test_artifact_files_exist_and_nonempty():
    m = _manifest()
    for a in m["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["key"]
        assert os.path.getsize(path) > 500, a["key"]
        # HLO text, parsed by the Rust side, must not elide constants.
        with open(path) as f:
            text = f.read()
        assert "constant({...})" not in text, f"{a['key']} has elided literals"


def test_weight_files_match_declared_shapes():
    m = _manifest()
    seen = {}
    for a in m["artifacts"]:
        wf = a.get("weights_file")
        if not wf:
            continue
        total = sum(int(np.prod(s)) for s in a["weight_shapes"])
        path = os.path.join(ART, wf)
        assert os.path.exists(path), wf
        assert os.path.getsize(path) == total * 4, wf
        # All entries sharing a weights file declare identical shapes.
        if wf in seen:
            assert seen[wf] == a["weight_shapes"], wf
        seen[wf] = a["weight_shapes"]


def test_input_shapes_consistent_with_grid():
    m = _manifest()
    for a in m["artifacts"]:
        if a["key"].startswith("model/") and a["name"] in ("mobilenet", "squeezenet", "swin"):
            assert a["inputs"] == [[a["batch"], common.IMG_CROP, common.IMG_CROP, 3]], a["key"]
            assert a["outputs"] == [[a["batch"], 1000]], a["key"]
        if a["key"].startswith("model/") and a["name"] == "citrinet":
            t = common.n_frames(a["len_s"])
            assert a["inputs"] == [[a["batch"], t, common.N_MELS]], a["key"]


def test_flops_scale_with_batch():
    m = _manifest()
    by_key = {a["key"]: a for a in m["artifacts"]}
    f1 = by_key["model/squeezenet/b1"]["flops_lite"]
    f4 = by_key["model/squeezenet/b4"]["flops_lite"]
    if f1 > 0 and f4 > 0:
        assert 3.0 < f4 / f1 < 5.0, (f1, f4)
