"""AOT compiler: lower every registry entry to HLO text + manifest.json.

Interchange is HLO *text*, NOT `.serialize()`: the image's xla_extension
0.5.1 rejects jax>=0.5's 64-bit-id HloModuleProto, while the text parser
reassigns ids cleanly (see /opt/xla-example/README.md). Lowering uses
`return_tuple=True`; the Rust side unwraps with `Literal::to_tuple`.

Python runs ONLY here (and in pytest). `make artifacts` is incremental on
the stamp file; the rust binary is self-contained afterwards.

Usage: cd python && python -m compile.aot --out ../artifacts [--only PREFIX]
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flops_estimate(lowered) -> float:
    """Analytic FLOPs from XLA's cost analysis (0.0 when unavailable)."""
    try:
        cost = lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost.get("flops", 0.0))
    except Exception:
        return 0.0


def write_weights(consts, path: str) -> list:
    """Concatenate constant operands (f32 little-endian, C order) into a
    side file the Rust runtime feeds as leading parameters. Returns the
    shape list."""
    import numpy as np

    with open(path, "wb") as f:
        for c in consts:
            f.write(np.ascontiguousarray(c, dtype=np.float32).tobytes())
    return [list(c.shape) for c in consts]


def lower_entry(entry: model.Entry, out_dir: str, written_weights: dict) -> dict:
    t0 = time.time()
    const_specs = tuple(
        jax.ShapeDtypeStruct(c.shape, c.dtype) for c in entry.consts
    )
    lowered = jax.jit(entry.fn).lower(*const_specs, *entry.example_args)
    hlo = to_hlo_text(lowered)
    fname = entry.key.replace("/", "_") + ".hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(hlo)
    weight_shapes = []
    if entry.weights_file:
        if entry.weights_file not in written_weights:
            written_weights[entry.weights_file] = write_weights(
                entry.consts, os.path.join(out_dir, entry.weights_file)
            )
        weight_shapes = written_weights[entry.weights_file]
    out_shapes = [
        list(o.shape)
        for o in jax.eval_shape(entry.fn, *const_specs, *entry.example_args)
    ]
    in_shapes = [list(a.shape) for a in entry.example_args]
    rec = {
        "key": entry.key,
        "file": fname,
        "name": entry.name,
        "batch": entry.batch,
        "len_s": entry.len_s,
        "inputs": in_shapes,
        "outputs": out_shapes,
        "weights_file": entry.weights_file,
        "weight_shapes": weight_shapes,
        "flops_lite": flops_estimate(lowered),
        "params_lite": entry.params_lite,
    }
    dt = time.time() - t0
    print(f"  {entry.key:<44} {len(hlo)//1024:>5} KiB  {dt:5.1f}s", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower only keys with this prefix")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = model.all_entries()
    if args.only:
        entries = [e for e in entries if e.key.startswith(args.only)]
    print(f"lowering {len(entries)} artifacts -> {args.out}", flush=True)

    records = []
    written_weights: dict = {}
    for e in entries:
        records.append(lower_entry(e, args.out, written_weights))

    if args.only:
        # Partial relower: merge into the existing manifest by key.
        mpath = os.path.join(args.out, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                old = {a["key"]: a for a in json.load(f)["artifacts"]}
            old.update({r["key"]: r for r in records})
            records = list(old.values())

    manifest = {"version": 1, "artifacts": records}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(records)} artifacts", flush=True)


if __name__ == "__main__":
    sys.exit(main())
