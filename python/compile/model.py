"""L2 registry: every AOT entry (models × batch × length-bucket, plus the
L1 preprocessing kernels) as a (key, fn, const-operands, example-args)
record for aot.py.

Large constants (model weights, DFT bases, resize matrices) are passed as
leading HLO *parameters* rather than closed-over literals: `as_hlo_text`
elides big literals (`constant({...})`) which the Rust-side text parser
would read back as zeros. aot.py stores the constant operands once per
model in a binary weights file that the Rust runtime feeds at execute
time (DESIGN.md §4).

Model parameters use fixed seeds, so `make artifacts` is reproducible.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from .kernels import audio_pipeline as k_audio
from .kernels import image_pipeline as k_image
from .models import citrinet, conformer, mobilenet, squeezenet, swin
from .models.layers import count_params


class Entry:
    """One artifact to lower.

    fn(*consts, *example_args) -> tuple of outputs; `consts` become the
    leading HLO parameters recorded in the shared weights file
    `weights_file` (None when the entry has no constant operands).
    """

    def __init__(self, key, name, batch, len_s, fn, consts, example_args,
                 weights_file=None, params_lite=0):
        self.key = key
        self.name = name
        self.batch = batch
        self.len_s = len_s
        self.fn = fn
        self.consts = consts  # list of np.ndarray (leading parameters)
        self.example_args = example_args
        self.weights_file = weights_file
        self.params_lite = params_lite


def _leaves(params):
    return [np.asarray(l, dtype=np.float32) for l in jax.tree_util.tree_leaves(params)]


def _make_model_fn(apply, treedef, n_leaves):
    def fn(*args):
        leaves, x = args[:n_leaves], args[n_leaves]
        params = jax.tree_util.tree_unflatten(treedef, leaves)
        return (apply(params, x),)

    return fn


def _vision_entries():
    models = [
        ("mobilenet", mobilenet.init(), mobilenet.apply),
        ("squeezenet", squeezenet.init(), squeezenet.apply),
        ("swin", swin.init(), swin.apply),
    ]
    out = []
    crop = common.IMG_CROP
    for name, params, apply in models:
        n_params = count_params(params)
        leaves = _leaves(params)
        treedef = jax.tree_util.tree_structure(params)
        fn = _make_model_fn(apply, treedef, len(leaves))
        wfile = f"weights_{name}.bin"
        for b in common.VISION_BATCHES:
            spec = jax.ShapeDtypeStruct((b, crop, crop, 3), jnp.float32)
            out.append(
                Entry(f"model/{name}/b{b}", name, b, 0.0, fn, leaves, (spec,), wfile, n_params)
            )
    return out


def _audio_entries():
    models = [
        ("conformer_small", conformer.init("small"),
         functools.partial(_apply_conformer, "small")),
        ("conformer_default", conformer.init("default"),
         functools.partial(_apply_conformer, "default")),
        ("citrinet", citrinet.init(), citrinet.apply),
    ]
    out = []
    for name, params, apply in models:
        n_params = count_params(params)
        leaves = _leaves(params)
        treedef = jax.tree_util.tree_structure(params)
        fn = _make_model_fn(apply, treedef, len(leaves))
        wfile = f"weights_{name}.bin"
        for len_s in common.AUDIO_BUCKETS_S:
            t = common.n_frames(len_s)
            for b in common.AUDIO_BATCHES:
                spec = jax.ShapeDtypeStruct((b, t, common.N_MELS), jnp.float32)
                key = f"model/{name}/b{b}/len{common.fmt_len(len_s)}"
                out.append(Entry(key, name, b, len_s, fn, leaves, (spec,), wfile, n_params))
    return out


def _apply_conformer(size, params, x):
    return conformer.apply(params, x, size)


def _image_kernel_fn(q, c, rrows, rcols, norm, x):
    return (k_image.image_pipeline_p(q, c, rrows, rcols, norm, x, batch=x.shape[0]),)


def _make_audio_kernel_fn(len_s):
    def fn(cos_b, sin_b, melt, hann_w, pcm):
        return (k_audio.audio_pipeline_p(cos_b, sin_b, melt, hann_w, pcm, len_s=len_s),)

    return fn


def _kernel_entries():
    out = []
    s = common.IMG_SRC
    spec = jax.ShapeDtypeStruct((1, s, s, 3), jnp.float32)
    out.append(
        Entry(
            "kernel/image_pipeline/b1",
            "image_pipeline",
            1,
            0.0,
            _image_kernel_fn,
            [np.asarray(c, dtype=np.float32) for c in k_image.consts()],
            (spec,),
            "weights_kernel_image.bin",
        )
    )
    audio_consts = [np.asarray(c, dtype=np.float32) for c in k_audio.consts()]
    for len_s in common.AUDIO_BUCKETS_S:
        n = int(round(len_s * common.SAMPLE_RATE))
        spec = jax.ShapeDtypeStruct((n,), jnp.float32)
        out.append(
            Entry(
                f"kernel/audio_pipeline/len{common.fmt_len(len_s)}",
                "audio_pipeline",
                1,
                len_s,
                _make_audio_kernel_fn(len_s),
                audio_consts,
                (spec,),
                "weights_kernel_audio.bin",
            )
        )
    return out


def all_entries():
    """Every artifact to lower, kernels first (cheapest feedback)."""
    return _kernel_entries() + _vision_entries() + _audio_entries()
