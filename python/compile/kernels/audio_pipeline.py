"""L1 Pallas kernels: the audio-preprocessing CUs (paper Fig 11b) on TPU.

Two kernels mirroring PREBA's split-CU design:

  * `mel_kernel` — the "Resample + Mel spectrogram" CU. Frames stream
    through in VMEM-sized blocks; the compute core is three MXU matmuls
    per block (frames @ cosB, frames @ sinB, power @ melT) replacing the
    FPGA FFT butterfly + filter network. This unit PIPELINES across
    requests (Fig 12c) because each frame block is independent.
  * `normalize_kernel` — the "Normalize" CU. Global per-feature mean/var
    over the time axis forces the whole feature map into one program
    invocation — the same all-samples dependency that serializes the
    paper's monolithic CU (Fig 12b) and motivates the split.

`interpret=True`: CPU-PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import common
from . import ref

#: Frames processed per mel-kernel program (VMEM tile height).
FRAME_BLOCK = 64


def _mel_kernel(frames_ref, cos_ref, sin_ref, melt_ref, hann_ref, out_ref):
    """One block of frames -> log-mel rows. All matmuls hit the MXU."""
    frames = frames_ref[...] * hann_ref[...][None, :]
    re = frames @ cos_ref[...]
    im = frames @ sin_ref[...]
    power = re * re + im * im
    out_ref[...] = jnp.log(power @ melt_ref[...] + 1e-3)


def _normalize_kernel(feat_ref, out_ref):
    """Global mean/var normalize over time (needs the FULL input)."""
    feat = feat_ref[...]
    mean = feat.mean(axis=0, keepdims=True)
    var = ((feat - mean) ** 2).mean(axis=0, keepdims=True)
    out_ref[...] = (feat - mean) / jnp.sqrt(var + 1e-2)


def _pad_frames(n_frames: int) -> int:
    """Pad the frame count up to a FRAME_BLOCK multiple for the grid."""
    return ((n_frames + FRAME_BLOCK - 1) // FRAME_BLOCK) * FRAME_BLOCK


def consts():
    """Constant operands in parameter order (see image_pipeline.consts)."""
    cos_b, sin_b = ref.dft_bases(common.N_FFT)
    melt = ref.mel_filterbank(common.N_MELS, common.N_FFT, common.SAMPLE_RATE).T
    return [cos_b, sin_b, melt.copy(), ref.hann(common.N_FFT)]


@functools.partial(jax.jit, static_argnames=("len_s",))
def log_mel_p(cos_b, sin_b, melt, hann_w, pcm, len_s: float):
    """Parameterized mel CU: constants as arguments (AOT path)."""
    n_fft, hop, n_mels = common.N_FFT, common.HOP, common.N_MELS
    n_frames = common.n_frames(len_s)
    padded = _pad_frames(n_frames)
    # Framing (gather) happens in the L2 wrapper; the CU kernel gets the
    # frame matrix (what the FPGA's sample stream becomes after its input
    # FIFO).
    frames = ref.frame_signal(pcm, n_fft, hop)
    frames = jnp.pad(frames, ((0, padded - n_frames), (0, 0)))
    n_bins = n_fft // 2 + 1
    out = pl.pallas_call(
        _mel_kernel,
        out_shape=jax.ShapeDtypeStruct((padded, n_mels), jnp.float32),
        grid=(padded // FRAME_BLOCK,),
        in_specs=[
            pl.BlockSpec((FRAME_BLOCK, n_fft), lambda i: (i, 0)),
            pl.BlockSpec((n_fft, n_bins), lambda i: (0, 0)),
            pl.BlockSpec((n_fft, n_bins), lambda i: (0, 0)),
            pl.BlockSpec((n_bins, n_mels), lambda i: (0, 0)),
            pl.BlockSpec((n_fft,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((FRAME_BLOCK, n_mels), lambda i: (i, 0)),
        interpret=True,
    )(frames, cos_b, sin_b, melt, hann_w)
    return out[:n_frames]


def log_mel(pcm: jnp.ndarray, len_s: float) -> jnp.ndarray:
    """(n,) PCM -> (n_frames, n_mels) log-mel (tests convenience)."""
    cs = [jnp.asarray(c) for c in consts()]
    return log_mel_p(*cs, pcm, len_s=len_s)


@jax.jit
def normalize(feat: jnp.ndarray) -> jnp.ndarray:
    """(n_frames, n_mels) -> normalized, via the Normalize CU kernel."""
    n_frames, n_mels = feat.shape
    return pl.pallas_call(
        _normalize_kernel,
        out_shape=jax.ShapeDtypeStruct((n_frames, n_mels), jnp.float32),
        interpret=True,
    )(feat)


@functools.partial(jax.jit, static_argnames=("len_s",))
def audio_pipeline_p(cos_b, sin_b, melt, hann_w, pcm, len_s: float):
    """Full audio CU chain, parameterized (AOT path)."""
    return normalize(log_mel_p(cos_b, sin_b, melt, hann_w, pcm, len_s=len_s))


def audio_pipeline(pcm: jnp.ndarray, len_s: float) -> jnp.ndarray:
    """Full audio CU chain for one request: mel CU -> normalize CU."""
    cs = [jnp.asarray(c) for c in consts()]
    return audio_pipeline_p(*cs, pcm, len_s=len_s)


def vmem_estimate_kib() -> float:
    """Mel CU per-program VMEM working set (Table 1 / §Perf)."""
    n_fft, n_mels = common.N_FFT, common.N_MELS
    n_bins = n_fft // 2 + 1
    floats = (
        FRAME_BLOCK * n_fft  # frame block
        + 2 * n_fft * n_bins  # DFT bases
        + n_bins * n_mels  # mel matrix
        + 2 * FRAME_BLOCK * n_bins  # re/im
        + FRAME_BLOCK * n_mels  # out
    )
    return floats * 4 / 1024.0
