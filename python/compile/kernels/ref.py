"""Pure-jnp correctness oracle for the preprocessing kernels.

Every function here mirrors, operation for operation, both the Pallas
kernels (which must match under `interpret=True`) and the Rust host
implementations in `rust/src/preprocess/ops.rs` (validated via golden
vectors exported by `tests/test_golden.py`).
"""

import jax.numpy as jnp
import numpy as np

from .. import common

# ---------------------------------------------------------------------------
# image
# ---------------------------------------------------------------------------

_JPEG_BASE_Q = np.array(
    [
        16, 11, 10, 16, 24, 40, 51, 61,
        12, 12, 14, 19, 26, 58, 60, 55,
        14, 13, 16, 24, 40, 57, 69, 56,
        14, 17, 22, 29, 51, 87, 80, 62,
        18, 22, 37, 56, 68, 109, 103, 77,
        24, 35, 55, 64, 81, 104, 113, 92,
        49, 64, 78, 87, 103, 121, 120, 101,
        72, 92, 95, 98, 112, 100, 103, 99,
    ],
    dtype=np.float32,
).reshape(8, 8)


def jpeg_quant_table() -> np.ndarray:
    """Annex-K luma table at quality 75 (scale 50%), floored, min 1."""
    return np.maximum(np.floor(_JPEG_BASE_Q * 50.0 / 100.0), 1.0).astype(np.float32)


def idct8_basis() -> np.ndarray:
    """8x8 IDCT basis C with pixels = C^T @ X @ C."""
    c = np.zeros((8, 8), dtype=np.float32)
    for k in range(8):
        a = np.sqrt(1.0 / 8.0) if k == 0 else np.sqrt(2.0 / 8.0)
        for n in range(8):
            c[k, n] = a * np.cos((np.pi / 8.0) * (n + 0.5) * k)
    return c


def decode_blocks(coeffs: jnp.ndarray) -> jnp.ndarray:
    """Dequantize + per-8x8-block 2-D IDCT + 128 shift.

    coeffs: (H, W, C) with H, W multiples of 8. Returns (H, W, C) pixels.
    """
    h, w, ch = coeffs.shape
    assert h % 8 == 0 and w % 8 == 0
    q = jnp.asarray(jpeg_quant_table())
    c = jnp.asarray(idct8_basis())
    # (by, i, bx, j, ch) -> blocks (by, bx, ch, i, j)
    x = coeffs.reshape(h // 8, 8, w // 8, 8, ch).transpose(0, 2, 4, 1, 3)
    x = x * q[None, None, None, :, :]
    # pixels = C^T X C, batched over (by, bx, ch).
    px = jnp.einsum("ki,bxckj,jl->bxcil", c, x, c)
    px = px + 128.0
    # back to (H, W, C)
    return px.transpose(0, 3, 1, 4, 2).reshape(h, w, ch)


def resize_matrix(src: int, dst: int) -> np.ndarray:
    """Bilinear interpolation matrix (dst, src), half-pixel centers."""
    m = np.zeros((dst, src), dtype=np.float32)
    scale = src / dst
    for d in range(dst):
        pos = (d + 0.5) * scale - 0.5
        lo = np.floor(pos)
        frac = np.float32(pos - lo)
        i0 = int(np.clip(lo, 0, src - 1))
        i1 = int(np.clip(lo + 1, 0, src - 1))
        m[d, i0] += 1.0 - frac
        m[d, i1] += frac
    return m


def resize_bilinear(img: jnp.ndarray, oh: int, ow: int) -> jnp.ndarray:
    """Separable bilinear resize of (H, W, C) via two matmuls."""
    h, w, _ = img.shape
    rm = jnp.asarray(resize_matrix(h, oh))
    cm = jnp.asarray(resize_matrix(w, ow))
    tmp = jnp.einsum("oy,yxc->oxc", rm, img)
    return jnp.einsum("ox,yxc->yoc", cm, tmp)


def center_crop(img: jnp.ndarray, oh: int, ow: int) -> jnp.ndarray:
    h, w, _ = img.shape
    y0 = (h - oh) // 2
    x0 = (w - ow) // 2
    return img[y0 : y0 + oh, x0 : x0 + ow, :]


def normalize_image(img: jnp.ndarray) -> jnp.ndarray:
    mean = jnp.asarray(common.IMAGENET_MEAN, dtype=jnp.float32)
    std = jnp.asarray(common.IMAGENET_STD, dtype=jnp.float32)
    return (img / 255.0 - mean) / std


def image_pipeline(coeffs: jnp.ndarray) -> jnp.ndarray:
    """decode -> resize -> crop -> normalize for one (H, W, C) image."""
    px = decode_blocks(coeffs)
    rs = resize_bilinear(px, common.IMG_RESIZE, common.IMG_RESIZE)
    cr = center_crop(rs, common.IMG_CROP, common.IMG_CROP)
    return normalize_image(cr)


# ---------------------------------------------------------------------------
# audio
# ---------------------------------------------------------------------------


def hann(n: int) -> np.ndarray:
    """Symmetric Hann window (matches the Rust implementation)."""
    if n == 1:
        return np.ones(1, dtype=np.float32)
    i = np.arange(n, dtype=np.float32)
    return (0.5 - 0.5 * np.cos(2.0 * np.pi * i / (n - 1))).astype(np.float32)


def dft_bases(n_fft: int):
    """(cos, -sin) DFT bases of shape (n_fft, n_bins) for matmul DFT."""
    n_bins = n_fft // 2 + 1
    k = np.arange(n_bins)
    n = np.arange(n_fft)
    ang = 2.0 * np.pi * np.outer(n, k) / n_fft
    return np.cos(ang).astype(np.float32), (-np.sin(ang)).astype(np.float32)


def frame_signal(pcm: jnp.ndarray, n_fft: int, hop: int) -> jnp.ndarray:
    """(n,) -> (n_frames, n_fft) frames."""
    n = pcm.shape[0]
    n_frames = 1 + (n - n_fft) // hop
    # jnp.arange lowers to HLO iota; a numpy (n_frames, n_fft) index
    # literal would be elided by the HLO-text printer and read back as
    # zeros on the Rust side.
    idx = jnp.arange(n_frames)[:, None] * hop + jnp.arange(n_fft)[None, :]
    return pcm[idx]


def power_spectrogram(pcm: jnp.ndarray, n_fft: int, hop: int) -> jnp.ndarray:
    """Hann-windowed matmul-DFT power spectrogram: (n_frames, n_bins)."""
    frames = frame_signal(pcm, n_fft, hop) * jnp.asarray(hann(n_fft))[None, :]
    cos_b, sin_b = dft_bases(n_fft)
    re = frames @ jnp.asarray(cos_b)
    im = frames @ jnp.asarray(sin_b)
    return re * re + im * im


def hz_to_mel(hz):
    return 2595.0 * np.log10(1.0 + np.asarray(hz) / 700.0)


def mel_to_hz(mel):
    return 700.0 * (10.0 ** (np.asarray(mel) / 2595.0) - 1.0)


def mel_filterbank(n_mels: int, n_fft: int, sample_rate: float) -> np.ndarray:
    """Triangular mel filterbank (n_mels, n_bins), HTK scale."""
    n_bins = n_fft // 2 + 1
    m_min, m_max = hz_to_mel(0.0), hz_to_mel(sample_rate / 2.0)
    edges = mel_to_hz(np.linspace(m_min, m_max, n_mels + 2))
    bin_hz = np.arange(n_bins) * sample_rate / n_fft
    fb = np.zeros((n_mels, n_bins), dtype=np.float32)
    for m in range(n_mels):
        lo, ctr, hi = edges[m], edges[m + 1], edges[m + 2]
        up = (bin_hz - lo) / (ctr - lo)
        down = (hi - bin_hz) / (hi - ctr)
        fb[m] = np.maximum(0.0, np.minimum(up, down)) * ((bin_hz > lo) & (bin_hz < hi))
    return fb


def log_mel(pcm: jnp.ndarray) -> jnp.ndarray:
    """(n,) PCM -> (n_frames, n_mels) log-mel features."""
    spec = power_spectrogram(pcm, common.N_FFT, common.HOP)
    fb = jnp.asarray(mel_filterbank(common.N_MELS, common.N_FFT, common.SAMPLE_RATE))
    # 1e-3 floor keeps near-silent mel channels numerically stable across
    # the three implementations (Pallas / jnp / Rust) — see DESIGN.md §7.
    return jnp.log(spec @ fb.T + 1e-3)


def normalize_features(feat: jnp.ndarray) -> jnp.ndarray:
    """Global per-feature mean/var normalization over the time axis — the
    full-input-dependency stage (paper Fig 12)."""
    mean = feat.mean(axis=0, keepdims=True)
    var = feat.var(axis=0, keepdims=True)
    # Variance floor (1e-2): degenerate channels are damped, not amplified.
    return (feat - mean) / jnp.sqrt(var + 1e-2)


def audio_pipeline(pcm: jnp.ndarray) -> jnp.ndarray:
    """(n,) 16 kHz PCM -> normalized (n_frames, n_mels). (The resample
    stage is the identity at the native rate; variable-rate resampling is
    exercised by the Rust implementation + cost model.)"""
    return normalize_features(log_mel(pcm))
