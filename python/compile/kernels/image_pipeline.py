"""L1 Pallas kernel: the image-preprocessing CU (paper Fig 11a) on TPU.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA CU
chains four functional units over a streamed image. On TPU we fuse the
whole pipeline into ONE Pallas kernel whose compute core is three MXU
matmul groups:

  * Decode  — dequantize + per-8x8-block 2-D IDCT as `C^T @ X @ C`
              (batched block matmuls; the MXU replaces the FPGA IDCT
              systolic pipeline),
  * Resize  — separable bilinear as two interpolation-matrix matmuls
              (`R_rows @ img @ R_cols^T`; replaces the FPGA line buffer),
  * Crop + Normalize — fused VPU epilogue.

Grid: one program per batch element; the whole (96, 96, 3) image tile
lives in VMEM (~110 KiB in + ~240 KiB working set — comfortably under the
~16 MiB/core budget; see Table 1's VMEM column).

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU lowering is compile-only in this environment.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import common
from . import ref


def _kernel(coeffs_ref, q_ref, c_ref, rrows_ref, rcols_ref, norm_ref, out_ref):
    """One image: (S, S, 3) DCT coeffs -> (CROP, CROP, 3) normalized."""
    s = common.IMG_SRC
    nb = s // 8
    x = coeffs_ref[0]  # (S, S, 3)

    # ---- Decode: dequant + blocked IDCT (MXU) ----
    q = q_ref[...]
    c = c_ref[...]
    blocks = x.reshape(nb, 8, nb, 8, 3).transpose(0, 2, 4, 1, 3)  # (nb,nb,3,8,8)
    blocks = blocks * q[None, None, None, :, :]
    px = jnp.einsum("ki,bxckj,jl->bxcil", c, blocks, c)
    px = px + 128.0
    img = px.transpose(0, 3, 1, 4, 2).reshape(s, s, 3)

    # ---- Resize: two interpolation matmuls (MXU) ----
    rrows = rrows_ref[...]  # (R, S)
    rcols = rcols_ref[...]  # (R, S)
    tmp = jnp.einsum("oy,yxc->oxc", rrows, img)
    rs = jnp.einsum("ox,yxc->yoc", rcols, tmp)

    # ---- Crop + Normalize (VPU epilogue) ----
    r, crop = common.IMG_RESIZE, common.IMG_CROP
    off = (r - crop) // 2
    cr = jax.lax.dynamic_slice(rs, (off, off, 0), (crop, crop, 3))
    mean = norm_ref[0]
    std = norm_ref[1]
    out_ref[0] = (cr / 255.0 - mean) / std


def consts():
    """The kernel's constant operands, in parameter order. AOT passes
    these as runtime parameters (HLO text elides large literals —
    DESIGN.md §4) and records them in the artifact's weights file."""
    s, r = common.IMG_SRC, common.IMG_RESIZE
    norm = np.stack(
        [np.asarray(common.IMAGENET_MEAN), np.asarray(common.IMAGENET_STD)]
    ).astype(np.float32)
    return [
        ref.jpeg_quant_table(),
        ref.idct8_basis(),
        ref.resize_matrix(s, r),
        ref.resize_matrix(s, r),
        norm,
    ]


@functools.partial(jax.jit, static_argnames=("batch",))
def image_pipeline_p(q, c, rrows, rcols, norm, coeffs, batch: int = 1):
    """Parameterized entrypoint: constants as arguments (AOT path)."""
    s, r, crop = common.IMG_SRC, common.IMG_RESIZE, common.IMG_CROP
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((batch, crop, crop, 3), jnp.float32),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, s, s, 3), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((8, 8), lambda b: (0, 0)),
            pl.BlockSpec((8, 8), lambda b: (0, 0)),
            pl.BlockSpec((r, s), lambda b: (0, 0)),
            pl.BlockSpec((r, s), lambda b: (0, 0)),
            pl.BlockSpec((2, 3), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, crop, crop, 3), lambda b: (b, 0, 0, 0)),
        interpret=True,
    )(coeffs, q, c, rrows, rcols, norm)


def image_pipeline(coeffs: jnp.ndarray, batch: int = 1) -> jnp.ndarray:
    """Convenience entrypoint (tests): builds the constants internally.

    coeffs: (B, S, S, 3) -> (B, CROP, CROP, 3) normalized f32.
    """
    cs = [jnp.asarray(c) for c in consts()]
    return image_pipeline_p(*cs, coeffs, batch=batch)


def vmem_estimate_kib() -> float:
    """Per-program VMEM working set (Table 1's VMEM column, §Perf)."""
    s, r, crop = common.IMG_SRC, common.IMG_RESIZE, common.IMG_CROP
    floats = (
        s * s * 3  # coeffs in
        + s * s * 3  # decoded
        + 2 * 64  # bases
        + 2 * r * s  # resize matrices
        + r * s * 3  # row-resized tmp
        + crop * crop * 3  # out
    )
    return floats * 4 / 1024.0
