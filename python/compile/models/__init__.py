"""L2: lite JAX re-implementations of the six paper workloads.

Shape-faithful, width/depth-reduced variants of the models the paper
serves (paper §5: MobileNetV3-Small / SqueezeNet 1.1 / Swin-T from
TorchHub; Conformer small+default / CitriNet from NVIDIA NeMo), sized so a
1-core CPU PJRT client executes them in milliseconds. The MIG service
model uses the full-scale FLOP numbers (rust/src/models/calib.rs); these
lite graphs are what the real driver actually runs (DESIGN.md §4).
"""

from . import citrinet, conformer, mobilenet, squeezenet, swin  # noqa: F401
