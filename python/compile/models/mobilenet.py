"""MobileNetV3-Small (lite): inverted-residual depthwise blocks with SE
and hard-swish, per Howard et al. 2019, at reduced width/depth for the
64x64 lite input."""

import jax.numpy as jnp

from . import layers
from .layers import Init

# (expand, out, kernel, stride, use_se, act)
_BLOCKS = [
    (1, 16, 3, 2, True, "relu"),
    (4, 24, 3, 2, False, "relu"),
    (3, 24, 3, 1, False, "relu"),
    (4, 40, 5, 2, True, "hswish"),
    (6, 40, 5, 1, True, "hswish"),
    (6, 48, 5, 1, True, "hswish"),
]

N_CLASSES = 1000


def init(seed: int = 1):
    ini = Init(seed)
    params = {
        "stem_w": ini.conv(3, 3, 3, 16),
        "stem_s": ini.scale(16),
        "stem_b": ini.bias(16),
        "blocks": [],
        "head_w": ini.conv(1, 1, 48, 288),
        "head_s": ini.scale(288),
        "head_b": ini.bias(288),
        "fc_w": ini.dense(288, N_CLASSES),
        "fc_b": ini.bias(N_CLASSES),
    }
    cin = 16
    for expand, cout, k, _stride, use_se, _act in _BLOCKS:
        ce = cin * expand
        blk = {
            "pw1_w": ini.conv(1, 1, cin, ce),
            "pw1_s": ini.scale(ce),
            "pw1_b": ini.bias(ce),
            "dw_w": ini.conv(k, k, 1, ce),  # depthwise: HWIO with I=1
            "dw_s": ini.scale(ce),
            "dw_b": ini.bias(ce),
            "pw2_w": ini.conv(1, 1, ce, cout),
            "pw2_s": ini.scale(cout),
            "pw2_b": ini.bias(cout),
        }
        if use_se:
            blk["se"] = layers.se_params(ini, ce)
        params["blocks"].append(blk)
        cin = cout
    return params


def apply(params, x):
    """x: (B, 64, 64, 3) -> logits (B, 1000)."""
    x = layers.conv2d(x, params["stem_w"], stride=2)
    x = layers.norm_act(x, params["stem_s"], params["stem_b"], "hswish")
    cin = 16
    for blk, (expand, cout, _k, stride, use_se, act) in zip(params["blocks"], _BLOCKS):
        ce = cin * expand
        y = layers.conv2d(x, blk["pw1_w"])
        y = layers.norm_act(y, blk["pw1_s"], blk["pw1_b"], act)
        y = layers.conv2d(y, blk["dw_w"], stride=stride, groups=ce)
        y = layers.norm_act(y, blk["dw_s"], blk["dw_b"], act)
        if use_se:
            y = layers.se_block(y, blk["se"])
        y = layers.conv2d(y, blk["pw2_w"])
        y = y * blk["pw2_s"] + blk["pw2_b"]
        if stride == 1 and cin == cout:
            y = y + x
        x = y
        cin = cout
    x = layers.conv2d(x, params["head_w"])
    x = layers.norm_act(x, params["head_s"], params["head_b"], "hswish")
    x = layers.global_avg_pool(x)
    return x @ params["fc_w"] + params["fc_b"]
