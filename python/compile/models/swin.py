"""Swin Transformer (lite): patch embedding + windowed multi-head
self-attention blocks with shifted windows, per Liu et al. 2021, reduced
(dim 96, 2 blocks, 4x4 windows on an 8x8 token grid) for the 64x64 input."""

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .layers import Init

PATCH = 8       # 64/8 = 8x8 token grid
DIM = 96
WINDOW = 4      # 4x4 token windows
HEADS = 3
DEPTH = 2
N_CLASSES = 1000


def init(seed: int = 3):
    ini = Init(seed)
    params = {
        "embed_w": ini.dense(PATCH * PATCH * 3, DIM),
        "embed_b": ini.bias(DIM),
        "blocks": [],
        "ln_f_g": ini.scale(DIM),
        "ln_f_b": ini.bias(DIM),
        "fc_w": ini.dense(DIM, N_CLASSES),
        "fc_b": ini.bias(N_CLASSES),
    }
    for _ in range(DEPTH):
        params["blocks"].append(
            {
                "ln1_g": ini.scale(DIM),
                "ln1_b": ini.bias(DIM),
                "attn": layers.mhsa_params(ini, DIM),
                "ln2_g": ini.scale(DIM),
                "ln2_b": ini.bias(DIM),
                "mlp1_w": ini.dense(DIM, 4 * DIM),
                "mlp1_b": ini.bias(4 * DIM),
                "mlp2_w": ini.dense(4 * DIM, DIM),
                "mlp2_b": ini.bias(DIM),
            }
        )
    return params


def _window_partition(x, grid):
    """(B, G, G, C) -> (B * nw, WINDOW*WINDOW, C)."""
    b, g, _, c = x.shape
    nw = g // WINDOW
    x = x.reshape(b, nw, WINDOW, nw, WINDOW, c).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b * nw * nw, WINDOW * WINDOW, c)


def _window_merge(x, b, grid):
    nw = grid // WINDOW
    c = x.shape[-1]
    x = x.reshape(b, nw, nw, WINDOW, WINDOW, c).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, grid, grid, c)


def apply(params, x):
    """x: (B, 64, 64, 3) -> logits (B, 1000)."""
    b = x.shape[0]
    grid = x.shape[1] // PATCH
    # Patch embed.
    x = x.reshape(b, grid, PATCH, grid, PATCH, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, grid, grid, PATCH * PATCH * 3)
    x = x @ params["embed_w"] + params["embed_b"]

    for i, blk in enumerate(params["blocks"]):
        shift = (WINDOW // 2) if (i % 2 == 1) else 0
        y = layers.layer_norm(x, blk["ln1_g"], blk["ln1_b"])
        if shift:
            y = jnp.roll(y, (-shift, -shift), axis=(1, 2))
        w = _window_partition(y, grid)
        w = layers.mhsa(w, blk["attn"], HEADS)
        y = _window_merge(w, b, grid)
        if shift:
            y = jnp.roll(y, (shift, shift), axis=(1, 2))
        x = x + y
        y = layers.layer_norm(x, blk["ln2_g"], blk["ln2_b"])
        y = jax.nn.gelu(y @ blk["mlp1_w"] + blk["mlp1_b"])
        x = x + (y @ blk["mlp2_w"] + blk["mlp2_b"])

    x = layers.layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    x = x.mean(axis=(1, 2))
    return x @ params["fc_w"] + params["fc_b"]
