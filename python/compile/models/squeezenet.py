"""SqueezeNet 1.1 (lite): fire modules (squeeze 1x1 -> expand 1x1 + 3x3),
per Iandola et al. 2016, reduced for the 64x64 lite input."""

import jax
import jax.numpy as jnp

from . import layers
from .layers import Init

# (squeeze, expand) per fire module.
_FIRES = [(16, 64), (16, 64), (32, 128), (32, 128)]

N_CLASSES = 1000


def init(seed: int = 2):
    ini = Init(seed)
    params = {
        "stem_w": ini.conv(3, 3, 3, 32),
        "stem_b": ini.bias(32),
        "fires": [],
        "head_w": ini.conv(1, 1, 256, N_CLASSES),
        "head_b": ini.bias(N_CLASSES),
    }
    cin = 32
    for s, e in _FIRES:
        params["fires"].append(
            {
                "sq_w": ini.conv(1, 1, cin, s),
                "sq_b": ini.bias(s),
                "e1_w": ini.conv(1, 1, s, e),
                "e1_b": ini.bias(e),
                "e3_w": ini.conv(3, 3, s, e),
                "e3_b": ini.bias(e),
            }
        )
        cin = 2 * e
    return params


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
    )


def apply(params, x):
    """x: (B, 64, 64, 3) -> logits (B, 1000)."""
    x = jax.nn.relu(layers.conv2d(x, params["stem_w"], stride=2) + params["stem_b"])
    x = _maxpool(x)
    for i, f in enumerate(params["fires"]):
        s = jax.nn.relu(layers.conv2d(x, f["sq_w"]) + f["sq_b"])
        e1 = jax.nn.relu(layers.conv2d(s, f["e1_w"]) + f["e1_b"])
        e3 = jax.nn.relu(layers.conv2d(s, f["e3_w"]) + f["e3_b"])
        x = jnp.concatenate([e1, e3], axis=-1)
        if i == 1:
            x = _maxpool(x)
    x = jax.nn.relu(layers.conv2d(x, params["head_w"]) + params["head_b"])
    return layers.global_avg_pool(x)
