"""CitriNet ASR encoder (lite), per Majumdar et al. 2021: 1-D depthwise-
separable conv blocks with squeeze-and-excitation, CTC head."""

import jax
import jax.numpy as jnp

from . import layers
from .layers import Init

VOCAB = 128
DIM = 128
KERNELS = [11, 13, 15]  # one block per kernel size


def init(seed: int = 5):
    ini = Init(seed)
    params = {
        "stem_w": ini.conv1d(5, 80, DIM),
        "stem_s": ini.scale(DIM),
        "stem_b": ini.bias(DIM),
        "blocks": [],
        "head_w": ini.conv1d(1, DIM, VOCAB),
        "head_b": ini.bias(VOCAB),
    }
    for k in KERNELS:
        params["blocks"].append(
            {
                "dw_w": ini.conv1d(k, 1, DIM),
                "pw_w": ini.conv1d(1, DIM, DIM),
                "s": ini.scale(DIM),
                "b": ini.bias(DIM),
                "se": layers.se_params(ini, DIM, r=8),
            }
        )
    return params


def apply(params, x):
    """x: (B, T, 80) log-mel -> (B, T//2, VOCAB) log-probs."""
    x = layers.conv1d(x, params["stem_w"], stride=2)
    x = layers.norm_act(x, params["stem_s"], params["stem_b"], "relu")
    for blk in params["blocks"]:
        y = layers.conv1d(x, blk["dw_w"], groups=DIM)
        y = layers.conv1d(y, blk["pw_w"])
        y = layers.norm_act(y, blk["s"], blk["b"], "relu")
        y = layers.se_block(y, blk["se"])
        x = x + y
    x = layers.conv1d(x, params["head_w"]) + params["head_b"]
    return jax.nn.log_softmax(x, axis=-1)
