"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions: NHWC for 2-D convs, (B, T, C) for 1-D; params are nested
dicts of jnp arrays; all models are inference-mode (folded norms: scale +
shift instead of running statistics).
"""

import jax
import jax.numpy as jnp
import numpy as np


class Init:
    """Deterministic parameter initializer (He-normal-ish) with a counter
    so every call site gets a distinct seed."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)

    def conv(self, kh, kw, cin, cout):
        fan_in = kh * kw * cin
        w = self.rng.normal(0.0, np.sqrt(2.0 / fan_in), (kh, kw, cin, cout))
        return jnp.asarray(w, dtype=jnp.float32)

    def conv1d(self, k, cin, cout):
        fan_in = k * cin
        w = self.rng.normal(0.0, np.sqrt(2.0 / fan_in), (k, cin, cout))
        return jnp.asarray(w, dtype=jnp.float32)

    def dense(self, cin, cout):
        w = self.rng.normal(0.0, np.sqrt(2.0 / cin), (cin, cout))
        return jnp.asarray(w, dtype=jnp.float32)

    def bias(self, c):
        return jnp.zeros((c,), dtype=jnp.float32)

    def scale(self, c):
        return jnp.ones((c,), dtype=jnp.float32)


def conv2d(x, w, stride=1, groups=1, padding="SAME"):
    """NHWC conv with HWIO weights."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def conv1d(x, w, stride=1, groups=1, padding="SAME"):
    """(B, T, C) conv with (K, I, O) weights."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride,),
        padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=groups,
    )


def norm_act(x, scale, shift, act="relu"):
    """Folded-BN (scale/shift) + activation."""
    y = x * scale + shift
    if act == "relu":
        return jax.nn.relu(y)
    if act == "hswish":
        return y * jax.nn.relu6(y + 3.0) / 6.0
    if act == "swish":
        return y * jax.nn.sigmoid(y)
    if act == "none":
        return y
    raise ValueError(act)


def layer_norm(x, gamma, beta, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def mhsa(x, params, n_heads):
    """Multi-head self-attention over (B, T, C)."""
    b, t, c = x.shape
    hd = c // n_heads
    q = (x @ params["wq"]).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ params["wv"]).reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)
    att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / np.sqrt(hd), axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, c)
    return y @ params["wo"]


def mhsa_params(init: Init, c: int):
    return {
        "wq": init.dense(c, c),
        "wk": init.dense(c, c),
        "wv": init.dense(c, c),
        "wo": init.dense(c, c),
    }


def global_avg_pool(x):
    """NHWC -> (B, C)."""
    return x.mean(axis=(1, 2))


def se_block(x, params):
    """Squeeze-and-excitation over NHWC (or (B,T,C) if 1-D pooled)."""
    if x.ndim == 4:
        s = x.mean(axis=(1, 2))
    else:
        s = x.mean(axis=1)
    s = jax.nn.relu(s @ params["w1"] + params["b1"])
    s = jax.nn.sigmoid(s @ params["w2"] + params["b2"])
    if x.ndim == 4:
        return x * s[:, None, None, :]
    return x * s[:, None, :]


def se_params(init: Init, c: int, r: int = 4):
    cr = max(1, c // r)
    return {
        "w1": init.dense(c, cr),
        "b1": init.bias(cr),
        "w2": init.dense(cr, c),
        "b2": init.bias(c),
    }


def count_params(tree) -> int:
    """Total scalar count of a param pytree."""
    return int(sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(tree)))
