"""Conformer ASR encoder (lite), per Gulati et al. 2020: conv subsampling
then blocks of [half-FFN, MHSA, conv module, half-FFN, LN]. Two sizes
mirror the paper's Conformer(small)/(default) (NeMo CTC variants)."""

import jax
import jax.numpy as jnp

from . import layers
from .layers import Init

VOCAB = 128

SIZES = {
    # name: (dim, heads, depth, conv kernel)
    "small": (96, 4, 2, 15),
    "default": (176, 4, 4, 15),
}


def init(size: str, seed: int = 4):
    dim, heads, depth, k = SIZES[size]
    ini = Init(seed + hash(size) % 97)
    params = {
        # conv subsampling: two stride-2 1-D convs over time.
        "sub1_w": ini.conv1d(3, 80, dim),
        "sub1_b": ini.bias(dim),
        "sub2_w": ini.conv1d(3, dim, dim),
        "sub2_b": ini.bias(dim),
        "blocks": [],
        "out_w": ini.dense(dim, VOCAB),
        "out_b": ini.bias(VOCAB),
    }
    for _ in range(depth):
        params["blocks"].append(
            {
                "ff1_ln_g": ini.scale(dim),
                "ff1_ln_b": ini.bias(dim),
                "ff1_w1": ini.dense(dim, 4 * dim),
                "ff1_b1": ini.bias(4 * dim),
                "ff1_w2": ini.dense(4 * dim, dim),
                "ff1_b2": ini.bias(dim),
                "att_ln_g": ini.scale(dim),
                "att_ln_b": ini.bias(dim),
                "attn": layers.mhsa_params(ini, dim),
                "conv_ln_g": ini.scale(dim),
                "conv_ln_b": ini.bias(dim),
                "conv_pw1": ini.conv1d(1, dim, 2 * dim),
                "conv_dw": ini.conv1d(k, 1, dim),  # depthwise
                "conv_s": ini.scale(dim),
                "conv_sh": ini.bias(dim),
                "conv_pw2": ini.conv1d(1, dim, dim),
                "ff2_ln_g": ini.scale(dim),
                "ff2_ln_b": ini.bias(dim),
                "ff2_w1": ini.dense(dim, 4 * dim),
                "ff2_b1": ini.bias(4 * dim),
                "ff2_w2": ini.dense(4 * dim, dim),
                "ff2_b2": ini.bias(dim),
                "ln_g": ini.scale(dim),
                "ln_b": ini.bias(dim),
            }
        )
    return params


def apply(params, x, size: str):
    """x: (B, T, 80) log-mel -> (B, T//4, VOCAB) log-probs."""
    dim, heads, _depth, _k = SIZES[size]
    # Subsample 4x.
    x = jax.nn.relu(layers.conv1d(x, params["sub1_w"], stride=2) + params["sub1_b"])
    x = jax.nn.relu(layers.conv1d(x, params["sub2_w"], stride=2) + params["sub2_b"])

    for blk in params["blocks"]:
        # half-step FFN
        y = layers.layer_norm(x, blk["ff1_ln_g"], blk["ff1_ln_b"])
        y = jax.nn.silu(y @ blk["ff1_w1"] + blk["ff1_b1"]) @ blk["ff1_w2"] + blk["ff1_b2"]
        x = x + 0.5 * y
        # MHSA
        y = layers.layer_norm(x, blk["att_ln_g"], blk["att_ln_b"])
        x = x + layers.mhsa(y, blk["attn"], heads)
        # conv module: pointwise GLU -> depthwise -> norm+swish -> pointwise
        y = layers.layer_norm(x, blk["conv_ln_g"], blk["conv_ln_b"])
        y = layers.conv1d(y, blk["conv_pw1"])
        a, b = jnp.split(y, 2, axis=-1)
        y = a * jax.nn.sigmoid(b)
        y = layers.conv1d(y, blk["conv_dw"], groups=dim)
        y = jax.nn.silu(y * blk["conv_s"] + blk["conv_sh"])
        y = layers.conv1d(y, blk["conv_pw2"])
        x = x + y
        # half-step FFN
        y = layers.layer_norm(x, blk["ff2_ln_g"], blk["ff2_ln_b"])
        y = jax.nn.silu(y @ blk["ff2_w1"] + blk["ff2_b1"]) @ blk["ff2_w2"] + blk["ff2_b2"]
        x = x + 0.5 * y
        x = layers.layer_norm(x, blk["ln_g"], blk["ln_b"])

    return jax.nn.log_softmax(x @ params["out_w"] + params["out_b"], axis=-1)
