"""Shared build-time constants.

These pin the preprocessing geometry across all four implementations that
must agree numerically:
  * the Pallas kernels (`kernels/image_pipeline.py`, `kernels/audio_pipeline.py`)
  * the pure-jnp oracle (`kernels/ref.py`)
  * the Rust host implementations (`rust/src/preprocess/ops.rs`)
  * the lite L2 models' input shapes (`models/*`)
"""

# ---- image pipeline (paper Fig 4a) ----------------------------------------
# Source "JPEG" is a quantized-DCT-coefficient image (the entropy-decoded
# representation); decode = dequantize + 8x8 IDCT (DESIGN.md
# §Hardware-Adaptation).
IMG_SRC = 96          # source image side (multiple of 8)
IMG_RESIZE = 72       # bilinear resize target
IMG_CROP = 64         # center-crop side == model input side
IMG_CHANNELS = 3

IMAGENET_MEAN = (0.485, 0.456, 0.406)
IMAGENET_STD = (0.229, 0.224, 0.225)

# ---- audio pipeline (paper Fig 4b) -----------------------------------------
SAMPLE_RATE = 16_000
N_FFT = 512
HOP = 256
N_MELS = 80

# Audio length buckets lowered AOT (paper: 2.5 s windows; the real driver
# pads each request's PCM to its bucket's upper edge).
AUDIO_BUCKETS_S = (2.5, 5.0, 7.5, 10.0)

# ---- AOT batch grids --------------------------------------------------------
VISION_BATCHES = (1, 2, 4, 8, 16)
AUDIO_BATCHES = (1, 2, 4, 8)


def n_frames(len_s: float) -> int:
    """Frames produced by the spectrogram for a bucket length."""
    n = int(round(len_s * SAMPLE_RATE))
    return 1 + (n - N_FFT) // HOP


def fmt_len(len_s: float) -> str:
    """Bucket length -> artifact key fragment (2.5 -> '2p5', 5.0 -> '5')."""
    if abs(len_s - round(len_s)) < 1e-9:
        return str(int(round(len_s)))
    return str(len_s).replace(".", "p")
