//! Bench: regenerate paper Figure 12 (DPU CU pipelining timelines:
//! image pipelined / audio monolithic vs split).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig12::run(&sys);
}
