//! Bench: regenerate paper Figure 20 (power breakdown + energy efficiency;
//! the 3.5x headline).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig20::run(&sys);
}
