//! Bench: regenerate paper Figure 17 (end-to-end throughput, Ideal / DPU /
//! CPU x active servers; the 3.7x headline).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig17::run(&sys);
}
