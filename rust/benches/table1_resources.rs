//! Bench: regenerate paper Table 1 (DPU resource utilization, extended
//! with the TPU Pallas adaptation columns).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::table1::run(&sys);
}
