//! §Perf micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf).
//!
//! Times the coordinator's inner loops in isolation: DES event queue,
//! batcher enqueue/form, service-model evaluation, CPU-pool admission,
//! DPU admission, workload generation, JSON encode, and the host
//! preprocessing ops. `cargo bench --bench perf_hotpath`.

use preba::batching::{BatchPolicy, Bucketizer, DynamicBatcher, QueueParams, Request};
use preba::clock::millis;
use preba::config::{DpuConfig, HardwareConfig, PrebaConfig};
use preba::dpu::Dpu;
use preba::mig::{MigConfig, ServiceModel};
use preba::models::ModelId;
use preba::preprocess::{ops, CpuPool};
use preba::server::{sim_driver, PolicyKind, PreprocMode, SimConfig};
use preba::sim::EventQueue;
use preba::util::bench::time_fn;
use preba::util::json::Json;
use preba::util::Rng;
use preba::workload::QueryGen;

fn main() {
    println!("== L3 hot-path micro-benchmarks ==");

    // DES event queue: schedule+pop cycle.
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut i = 0u64;
    time_fn("sim::EventQueue schedule+pop (64 events)", 1 << 20, || {
        for _ in 0..64 {
            i += 1;
            q.schedule(i, i);
        }
        for _ in 0..64 {
            std::hint::black_box(q.pop());
        }
    })
    .print();

    // Batcher: enqueue + form cycle at knee-sized batches.
    let buckets = Bucketizer::new(2.5, 25.0);
    let policy = BatchPolicy::Static(QueueParams { batch_max: 8, time_queue: millis(5.0) });
    let mut b = DynamicBatcher::new(ModelId::CitriNet, buckets, policy, true);
    let mut t = 0u64;
    let mut rng = Rng::new(1);
    time_fn("batching::enqueue+try_form (8-req batch)", 1 << 20, || {
        for k in 0..8 {
            t += 1000;
            b.enqueue(Request {
                id: t + k,
                model: ModelId::CitriNet,
                arrival: t,
                enqueued: t,
                len_s: rng.f64() * 25.0,
            });
        }
        while std::hint::black_box(b.try_form(t)).is_some() {}
    })
    .print();

    // Service model evaluation.
    let sm = ServiceModel::new(ModelId::ConformerDefault.spec(), 1);
    let mut acc = 0.0;
    time_fn("mig::ServiceModel exec_secs_jittered", 1 << 22, || {
        acc += sm.exec_secs_jittered(4, 10.0, &mut rng);
    })
    .print();
    std::hint::black_box(acc);

    // CPU pool admission.
    let mut pool = CpuPool::new(30, Rng::new(2));
    let mut now = 0u64;
    time_fn("preprocess::CpuPool::admit", 1 << 21, || {
        now += 100_000;
        std::hint::black_box(pool.admit(now, 0.01));
    })
    .print();

    // DPU admission.
    let mut dpu = Dpu::new(&DpuConfig::default(), &HardwareConfig::default());
    let mut now2 = 0u64;
    time_fn("dpu::Dpu::admit (audio, split CUs)", 1 << 21, || {
        now2 += 100_000;
        std::hint::black_box(dpu.admit(now2, ModelId::CitriNet, 5.0));
    })
    .print();

    // Workload generation.
    let mut gen = QueryGen::new(ModelId::CitriNet, 1000.0, Rng::new(3));
    time_fn("workload::QueryGen::next", 1 << 22, || {
        std::hint::black_box(gen.next());
    })
    .print();

    // Host preprocessing ops (the CPU-baseline request cost).
    let mut r2 = Rng::new(4);
    let coeffs = preba::workload::synth_image_coeffs(96, 96, 3, &mut r2);
    time_fn("ops::image_pipeline 96->64 (1 image)", 4096, || {
        std::hint::black_box(ops::image_pipeline(&coeffs, 96, 96, 3, 72, 64));
    })
    .print();
    let pcm = preba::workload::synth_pcm(2.5, &mut r2);
    time_fn("ops::audio_pipeline 2.5s (1 request)", 512, || {
        std::hint::black_box(ops::audio_pipeline(&pcm, 16_000, 512, 256, 80));
    })
    .print();

    // Whole-sim throughput: events/second of the DES driver (the headline
    // §Perf metric — exercises the 4-ary event heap, the in-flight slab,
    // BatchTick dedupe and the pooled batch vectors together).
    let sys = PrebaConfig::new();
    let mk_cfg = || {
        let mut cfg = SimConfig::new(ModelId::CitriNet, MigConfig::Small7, PreprocMode::Dpu);
        cfg.policy = PolicyKind::Dynamic;
        cfg.requests = 2000;
        cfg.rate_qps = cfg.saturating_rate();
        cfg
    };
    let events_per_run = sim_driver::run(&mk_cfg(), &sys).events;
    let stats = time_fn("sim_driver::run 2000 reqs (CitriNet DPU)", 64, || {
        std::hint::black_box(sim_driver::run(&mk_cfg(), &sys));
    });
    stats.print();
    let events_per_sec = events_per_run as f64 / stats.mean_ns * 1e9;
    println!(
        "  -> {} DES events/run, {:.2} M events/s (mean)",
        events_per_run,
        events_per_sec / 1e6
    );

    // Machine-readable output for the CI perf gate: PREBA_BENCH_JSON=<path>
    // writes the gated headline metric (whole-sim DES events/s) plus its
    // inputs; CI assembles this into the BENCH_pr<N>.json artifact and
    // fails the build on a >15% events/s regression vs the committed
    // baseline (benches/perf_baseline.json).
    if let Ok(path) = std::env::var("PREBA_BENCH_JSON") {
        let doc = Json::obj(vec![
            ("bench", Json::str("perf_hotpath")),
            ("events_per_run", Json::num(events_per_run as f64)),
            ("events_per_sec", Json::num(events_per_sec)),
            ("sim_mean_ns", Json::num(stats.mean_ns)),
        ]);
        std::fs::write(&path, doc.to_string_pretty()).expect("write PREBA_BENCH_JSON");
        println!("[bench json written {path}]");
    }

    println!("\n(record before/after numbers in EXPERIMENTS.md §Perf)");
}
