//! §Perf cluster-DES benchmark: events/second of the multi-GPU
//! simulation (`server::cluster`) so fleet-scale serving is tracked from
//! day one, alongside `perf_hotpath`'s single-GPU number.
//!
//! `cargo bench --bench perf_cluster`. The measured configuration is the
//! `cluster` experiment's 4-GPU diurnal fleet on best-fit-decreasing
//! packing with JSQ routing and the online cross-GPU controller enabled —
//! the heaviest code path (routing + per-GPU preproc + rebalancing).
//! A streamed ~1M-arrival trace-day probe (`cluster_1m_trace`) runs
//! first, recording events/s and the process's peak RSS so the
//! arrival-stream seam's bounded-memory claim is gated, not asserted.

use preba::config::PrebaConfig;
use preba::experiments;
use preba::mig::reconfig::planners::{plan_cost, AnnealPlanner, GreedyPlanner, Planner};
use preba::mig::{PackStrategy, ServiceModel, Slice};
use preba::models::ModelId;
use preba::server::cluster::{self, ClusterConfig, ClusterTenant};
use preba::util::bench::time_fn;
use preba::util::json::Json;
use preba::workload::StreamSpec;

/// Peak resident set of this process so far (`VmHWM`), MB. The streamed
/// trace-day probe runs FIRST in `main` so this reflects its footprint.
#[cfg(target_os = "linux")]
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

#[cfg(not(target_os = "linux"))]
fn peak_rss_mb() -> Option<f64> {
    None
}

fn main() {
    experiments::set_fast(true);
    let sys = PrebaConfig::new();

    // §cluster_1m_trace probe: a ~1M-arrival streamed trace day on a
    // 16-GPU fleet — 24 tenants each pulled lazily from a synthetic
    // Azure-shaped StreamSpec, nothing materialized. Runs FIRST so
    // VmHWM is this probe's peak footprint: the gate's RSS ceiling is
    // what proves planet-scale replay stays in bounded memory.
    println!("== streamed trace-day probe (16 GPUs, 24 azure streams, ~1M arrivals) ==");
    let u = ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0);
    let per_tenant_qps = 0.5 * 2.0 * u; // 2 slices at 50% utilization
    let n_tenants = 24;
    let duration_s = 1e6 / (n_tenants as f64 * per_tenant_qps);
    let stream_fleet: Vec<ClusterTenant> = (0..n_tenants)
        .map(|ti| {
            let spec = StreamSpec::azure(0x1A7E ^ ti as u64, duration_s, per_tenant_qps);
            ClusterTenant::new(ModelId::MobileNet, Slice::new(1, 5), 2, per_tenant_qps)
                .with_stream(spec)
                .expect("synthetic source probes")
        })
        .collect();
    let arrivals_1m: usize = stream_fleet.iter().map(|t| t.requests).sum();
    let cfg_1m = ClusterConfig::builder()
        .gpus(16)
        .strategy(PackStrategy::BestFit)
        .tenants(stream_fleet)
        .seed(0x1A7E)
        .build();
    let t0 = std::time::Instant::now();
    let out_1m = cluster::run(&cfg_1m, &sys).expect("valid streamed config");
    let wall_s = t0.elapsed().as_secs_f64();
    let trace_1m_events_per_sec = out_1m.events as f64 / wall_s;
    let trace_1m_peak_rss_mb = peak_rss_mb();
    println!(
        "{arrivals_1m} arrivals over ~{duration_s:.0} s, {} DES events in {wall_s:.2} s -> \
         {:.2} M events/s, peak RSS {}",
        out_1m.events,
        trace_1m_events_per_sec / 1e6,
        match trace_1m_peak_rss_mb {
            Some(mb) => format!("{mb:.0} MB"),
            None => "unavailable (non-Linux)".to_string(),
        }
    );

    println!("\n== cluster-DES benchmark (4 GPUs, diurnal fleet, BFD + JSQ + reconfig) ==");

    let mk_cfg = || {
        ClusterConfig::builder()
            .gpus(4)
            .strategy(PackStrategy::BestFit)
            .tenants(experiments::cluster::diurnal_fleet(4, 4.0))
            .seed(0xBE7C)
            .reconfig(experiments::cluster::policy(&sys))
            .build()
    };
    let probe = cluster::run(&mk_cfg(), &sys).expect("valid cluster config");
    let events_per_run = probe.events;
    let joules_per_query = probe.joules_per_query();
    let cfg = mk_cfg();
    let requests: usize = cfg.tenants.iter().map(|t| t.requests).sum();
    println!(
        "{} tenants, {} requests, {} DES events/run, {:.2} J/query",
        cfg.tenants.len(),
        requests,
        events_per_run,
        joules_per_query
    );

    // Fault-recovery probe: the `faults` experiment's directed failover
    // scenario (GPU crash, never repaired, full recovery stack). Its
    // availability lands in the bench JSON and is gated (a floor, like
    // events/s) once the committed baseline arms cluster_availability_frac.
    let fault_out = cluster::run(&experiments::faults::failover_cfg(true, 4.0, &sys), &sys)
        .expect("valid failover config");
    let availability_frac = fault_out.availability_frac();
    println!(
        "failover probe: availability {:.4}, {} retries, {} hedges, {} timed out",
        availability_frac,
        fault_out.retries.iter().sum::<u64>(),
        fault_out.hedges.iter().sum::<u64>(),
        fault_out.timed_out_total()
    );

    // Interference probe: the `interference` experiment's sizing A/B —
    // a latency-SLA tenant beside saturating neighbor slices, flat vs
    // curve-aware provisioning on identical contended ground truth. The
    // headline is the SLA-violation gap the curves close; it lands in
    // the bench JSON and is gated (a floor) once the committed baseline
    // arms cluster_interference_violation_gap.
    let csys = experiments::interference::curved(&sys);
    let flat_out = cluster::run(&experiments::interference::scenario_cfg(false, 6.0, &csys), &csys)
        .expect("valid flat interference config");
    let aware_out = cluster::run(&experiments::interference::scenario_cfg(true, 6.0, &csys), &csys)
        .expect("valid curve-aware interference config");
    let flat_viol = experiments::interference::main_violation_frac(&flat_out);
    let aware_viol = experiments::interference::main_violation_frac(&aware_out);
    let interference_violation_gap = flat_viol - aware_viol;
    println!(
        "interference probe: main-tenant violations {:.4} flat vs {:.4} curve-aware \
         -> gap {:.4}",
        flat_viol, aware_viol, interference_violation_gap
    );

    // Planner-stack probe: the `optimality` experiment's 64-GPU diurnal
    // rebalance instance, solved by the greedy fast path and the
    // greedy-seeded anneal. Reported: the relative objective gap the
    // anneal closes ((greedy - anneal) / greedy, >= 0 by construction;
    // gated as a floor so the anneal keeps earning its budget) and the
    // greedy planning p99 latency over 100 runs (gated as a CEILING —
    // the fast path must stay controller-tick cheap at fleet scale).
    let own = experiments::optimality::bench_instance(&sys, 64);
    let inst = own.as_instance();
    let greedy_cost = plan_cost(&inst, &GreedyPlanner.plan(&inst));
    let anneal_cost =
        plan_cost(&inst, &AnnealPlanner::budgeted(own.policy.anneal_iters).plan(&inst));
    let planner_gap =
        if greedy_cost > 0.0 { (greedy_cost - anneal_cost) / greedy_cost } else { 0.0 };
    let mut lat_us: Vec<f64> = (0..100)
        .map(|_| {
            let t0 = std::time::Instant::now();
            std::hint::black_box(GreedyPlanner.plan(&inst));
            t0.elapsed().as_secs_f64() * 1e6
        })
        .collect();
    lat_us.sort_by(|a, b| a.total_cmp(b));
    let planner_greedy_p99_us = lat_us[98];
    println!(
        "planner probe: 64-GPU greedy cost {greedy_cost:.2} vs anneal {anneal_cost:.2} \
         -> gap {planner_gap:.4}, greedy p99 {planner_greedy_p99_us:.0} us"
    );

    let stats = time_fn("cluster::run 4-GPU diurnal fleet", 32, || {
        std::hint::black_box(cluster::run(&mk_cfg(), &sys).expect("valid cluster config"));
    });
    stats.print();
    let events_per_sec = events_per_run as f64 / stats.mean_ns * 1e9;
    println!("  -> {:.2} M cluster-DES events/s (mean)", events_per_sec / 1e6);

    // Obs-overhead probe: the SAME diurnal configuration re-run with the
    // observability layer capturing (1 s windows, 1-in-8 spans; outcomes
    // are byte-identical by the neutrality contract, so events_per_run
    // still applies). The fractional slowdown relative to the disabled
    // runs above lands in the bench JSON and is gated as a CEILING once
    // the committed baseline's cluster_obs_overhead_frac is non-null —
    // "always compiled, off by default" must stay cheap even when ON.
    // Runs after the RSS probe, so VmHWM is untouched.
    let mk_obs_cfg = || {
        let mut cfg = mk_cfg();
        cfg.obs = preba::obs::ObsSpec::on(1.0, 8);
        cfg
    };
    let obs_stats = time_fn("cluster::run 4-GPU diurnal fleet + obs", 32, || {
        std::hint::black_box(
            cluster::run(&mk_obs_cfg(), &sys).expect("valid obs cluster config"),
        );
    });
    obs_stats.print();
    let obs_overhead_frac = (obs_stats.mean_ns - stats.mean_ns) / stats.mean_ns;
    println!(
        "  -> {:.2} M events/s with obs capture ({:+.1}% vs disabled)",
        events_per_run as f64 / obs_stats.mean_ns * 1e9 / 1e6,
        obs_overhead_frac * 100.0
    );

    // Machine-readable output for the CI perf artifact
    // (PREBA_BENCH_JSON=<path>); gated once
    // benches/perf_baseline.json's cluster_events_per_sec is non-null.
    if let Ok(path) = std::env::var("PREBA_BENCH_JSON") {
        let doc = Json::obj(vec![
            ("bench", Json::str("perf_cluster")),
            ("events_per_run", Json::num(events_per_run as f64)),
            ("events_per_sec", Json::num(events_per_sec)),
            ("sim_mean_ns", Json::num(stats.mean_ns)),
            // Fleet energy efficiency of the measured configuration —
            // gated (lower is better) once the committed baseline's
            // cluster_joules_per_query is non-null.
            ("joules_per_query", Json::num(joules_per_query)),
            // Availability under the directed crash+recovery scenario —
            // gated (higher is better) once the committed baseline's
            // cluster_availability_frac is non-null.
            ("availability_frac", Json::num(availability_frac)),
            // Streamed ~1M-arrival trace-day probe — events/s gated as a
            // floor via cluster_1m_events_per_sec, peak RSS as a CEILING
            // via cluster_1m_peak_rss_mb (lower is better: the whole
            // point of the arrival-stream seam is bounded memory).
            ("trace_1m_events_per_sec", Json::num(trace_1m_events_per_sec)),
            ("trace_1m_peak_rss_mb", trace_1m_peak_rss_mb.map_or(Json::Null, Json::num)),
            // Main-tenant SLA-violation gap the [curves] layer closes in
            // the interference sizing A/B — gated as a floor (higher is
            // better) once the committed baseline's
            // cluster_interference_violation_gap is non-null.
            ("interference_violation_gap", Json::num(interference_violation_gap)),
            // Planner-stack probe (64-GPU diurnal rebalance instance):
            // the objective gap the anneal closes over greedy (floor,
            // via cluster_planner_gap) and the greedy fast path's
            // planning p99 (CEILING, via cluster_planner_greedy_p99_us).
            ("planner_gap", Json::num(planner_gap)),
            ("planner_greedy_p99_us", Json::num(planner_greedy_p99_us)),
            // Fractional cluster-DES slowdown with obs capture enabled —
            // gated as a CEILING (lower is better) once the committed
            // baseline's cluster_obs_overhead_frac is non-null.
            ("obs_overhead_frac", Json::num(obs_overhead_frac)),
        ]);
        std::fs::write(&path, doc.to_string_pretty()).expect("write PREBA_BENCH_JSON");
        println!("[bench json written {path}]");
    }

    println!("\n(record before/after numbers in EXPERIMENTS.md §Perf)");
}
