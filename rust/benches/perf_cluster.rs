//! §Perf cluster-DES benchmark: events/second of the multi-GPU
//! simulation (`server::cluster`) so fleet-scale serving is tracked from
//! day one, alongside `perf_hotpath`'s single-GPU number.
//!
//! `cargo bench --bench perf_cluster`. The measured configuration is the
//! `cluster` experiment's 4-GPU diurnal fleet on best-fit-decreasing
//! packing with JSQ routing and the online cross-GPU controller enabled —
//! the heaviest code path (routing + per-GPU preproc + rebalancing).

use preba::config::PrebaConfig;
use preba::experiments;
use preba::mig::PackStrategy;
use preba::server::cluster::{self, ClusterConfig};
use preba::util::bench::time_fn;
use preba::util::json::Json;

fn main() {
    experiments::set_fast(true);
    let sys = PrebaConfig::new();
    println!("== cluster-DES benchmark (4 GPUs, diurnal fleet, BFD + JSQ + reconfig) ==");

    let mk_cfg = || {
        let mut cfg = ClusterConfig::new(
            4,
            PackStrategy::BestFit,
            experiments::cluster::diurnal_fleet(4, 4.0),
        );
        cfg.seed = 0xBE7C;
        cfg.reconfig = Some(experiments::cluster::policy(&sys));
        cfg
    };
    let probe = cluster::run(&mk_cfg(), &sys).expect("valid cluster config");
    let events_per_run = probe.events;
    let joules_per_query = probe.joules_per_query();
    let cfg = mk_cfg();
    let requests: usize = cfg.tenants.iter().map(|t| t.requests).sum();
    println!(
        "{} tenants, {} requests, {} DES events/run, {:.2} J/query",
        cfg.tenants.len(),
        requests,
        events_per_run,
        joules_per_query
    );

    // Fault-recovery probe: the `faults` experiment's directed failover
    // scenario (GPU crash, never repaired, full recovery stack). Its
    // availability lands in the bench JSON and is gated (a floor, like
    // events/s) once the committed baseline arms cluster_availability_frac.
    let fault_out = cluster::run(&experiments::faults::failover_cfg(true, 4.0, &sys), &sys)
        .expect("valid failover config");
    let availability_frac = fault_out.availability_frac();
    println!(
        "failover probe: availability {:.4}, {} retries, {} hedges, {} timed out",
        availability_frac,
        fault_out.retries.iter().sum::<u64>(),
        fault_out.hedges.iter().sum::<u64>(),
        fault_out.timed_out_total()
    );

    let stats = time_fn("cluster::run 4-GPU diurnal fleet", 32, || {
        std::hint::black_box(cluster::run(&mk_cfg(), &sys).expect("valid cluster config"));
    });
    stats.print();
    let events_per_sec = events_per_run as f64 / stats.mean_ns * 1e9;
    println!("  -> {:.2} M cluster-DES events/s (mean)", events_per_sec / 1e6);

    // Machine-readable output for the CI perf artifact
    // (PREBA_BENCH_JSON=<path>); gated once
    // benches/perf_baseline.json's cluster_events_per_sec is non-null.
    if let Ok(path) = std::env::var("PREBA_BENCH_JSON") {
        let doc = Json::obj(vec![
            ("bench", Json::str("perf_cluster")),
            ("events_per_run", Json::num(events_per_run as f64)),
            ("events_per_sec", Json::num(events_per_sec)),
            ("sim_mean_ns", Json::num(stats.mean_ns)),
            // Fleet energy efficiency of the measured configuration —
            // gated (lower is better) once the committed baseline's
            // cluster_joules_per_query is non-null.
            ("joules_per_query", Json::num(joules_per_query)),
            // Availability under the directed crash+recovery scenario —
            // gated (higher is better) once the committed baseline's
            // cluster_availability_frac is non-null.
            ("availability_frac", Json::num(availability_frac)),
        ]);
        std::fs::write(&path, doc.to_string_pretty()).expect("write PREBA_BENCH_JSON");
        println!("[bench json written {path}]");
    }

    println!("\n(record before/after numbers in EXPERIMENTS.md §Perf)");
}
