//! Bench: regenerate paper Figure 9 (throughput + CPU utilization vs
//! number of active inference servers).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig09::run(&sys);
}
