//! Bench: regenerate paper Figure 15 (tail latency vs batch at 5/15/25 s;
//! Time_knee ~ 35 ms regardless of length).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig15::run(&sys);
}
