//! Bench: regenerate paper Figure 7 (latency breakdown at iso-throughput,
//! 1g.5gb(7x) vs 7g.40gb(1x)).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig07::run(&sys);
}
