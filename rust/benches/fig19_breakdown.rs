//! Bench: regenerate paper Figure 19 (end-to-end latency breakdown for
//! SqueezeNet and Conformer(default)).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig19::run(&sys);
}
