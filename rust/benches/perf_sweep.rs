//! §Perf sweep-engine benchmark: wall-clock of a figure-scale experiment
//! sweep at 1 worker vs all cores, plus a determinism cross-check.
//!
//! `cargo bench --bench perf_sweep`. Uses `PREBA_FAST` request budgets so
//! a run stays in smoke-test territory; the speedup column is the number
//! that must scale with cores (ISSUE: >= 2x on a 4-core runner).

use std::time::Instant;

use preba::config::PrebaConfig;
use preba::experiments;
use preba::util::bench;
use preba::util::json::Json;

/// The sim-heavy subset used for timing (the full `experiment all` adds
/// only analytic figures beyond these).
const SUITE: [&str; 5] = ["fig9", "fig17", "fig18", "fig22", "abl_traffic"];

fn run_suite(sys: &PrebaConfig) -> String {
    // Capture report output so timing measures compute, not terminal IO;
    // the returned text doubles as the determinism fingerprint.
    let mut all = String::new();
    for id in SUITE {
        let f = experiments::by_id(id).expect("suite id");
        bench::capture_begin();
        f(sys);
        all.push_str(&bench::capture_end());
    }
    all
}

fn main() {
    experiments::set_fast(true);
    let tmp = std::env::temp_dir().join("preba_perf_sweep");
    preba::util::bench::set_results_dir(tmp.to_str().unwrap());
    let sys = PrebaConfig::new();

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("== sweep-engine wall-clock ({} cores available) ==", cores);

    preba::util::par::set_jobs(1);
    let t0 = Instant::now();
    let serial_text = run_suite(&sys);
    let serial = t0.elapsed();
    println!("jobs=1      : {:>8.2} s", serial.as_secs_f64());

    preba::util::par::set_jobs(cores);
    let t0 = Instant::now();
    let parallel_text = run_suite(&sys);
    let parallel = t0.elapsed();
    println!("jobs={:<6} : {:>8.2} s", cores, parallel.as_secs_f64());

    println!(
        "speedup     : {:>8.2}x",
        serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9)
    );
    assert_eq!(
        serial_text, parallel_text,
        "sweep output must be bitwise identical across job counts"
    );
    println!("determinism : report blocks identical at jobs=1 and jobs={cores}");

    // Machine-readable output for the CI bench artifact
    // (PREBA_BENCH_JSON=<path>); the speedup is reported, events/s (from
    // perf_hotpath) is the gated metric.
    if let Ok(path) = std::env::var("PREBA_BENCH_JSON") {
        let doc = Json::obj(vec![
            ("bench", Json::str("perf_sweep")),
            ("cores", Json::num(cores as f64)),
            ("serial_s", Json::num(serial.as_secs_f64())),
            ("parallel_s", Json::num(parallel.as_secs_f64())),
            (
                "speedup",
                Json::num(serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9)),
            ),
        ]);
        std::fs::write(&path, doc.to_string_pretty()).expect("write PREBA_BENCH_JSON");
        println!("[bench json written {path}]");
    }

    println!("\n(record before/after numbers in EXPERIMENTS.md §Perf)");
}
