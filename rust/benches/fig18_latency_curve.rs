//! Bench: regenerate paper Figure 18 (throughput vs tail-latency curves
//! for the three designs).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig18::run(&sys);
}
