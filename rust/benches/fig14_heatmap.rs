//! Bench: regenerate paper Figure 14 (batch x length tail-latency heatmap
//! for Conformer(default), 1g vs 7g).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig14::run(&sys);
}
