//! Bench: regenerate paper Figure 8 (throughput with vs without CPU
//! preprocessing + cores required; CitriNet's 393-core headline).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig08::run(&sys);
}
