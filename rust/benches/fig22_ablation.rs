//! Bench: regenerate paper Figure 22 (ablation: Base / +DPU /
//! +DynamicBatching on the audio models).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig22::run(&sys);
}
