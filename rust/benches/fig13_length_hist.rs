//! Bench: regenerate paper Figure 13 (LibriSpeech length histogram).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig13::run(&sys);
}
