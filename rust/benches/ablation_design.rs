//! Bench: design-choice ablations beyond the paper's figures —
//! adjacent-bucket merging, Time_queue rule, knee_frac sensitivity,
//! traffic shape, and DPU preprocessing granularity (DESIGN.md §8).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::ablation::run_merge(&sys);
    preba::experiments::ablation::run_policy(&sys);
    preba::experiments::ablation::run_traffic(&sys);
    preba::experiments::ablation::run_dpu_granularity(&sys);
}
