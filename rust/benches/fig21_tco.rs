//! Bench: regenerate paper Figure 21 (TCO cost-efficiency; the 3.0x
//! headline).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig21::run(&sys);
}
