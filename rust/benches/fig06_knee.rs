//! Bench: regenerate paper Figure 6 (throughput + tail latency vs batch,
//! Batch_knee markers).
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig06::run(&sys);
}
