//! Bench: regenerate paper Figure 5 (exec throughput + GPU utilization vs
//! batch size, preprocessing disabled). `cargo bench --bench fig05_*`.
fn main() {
    let sys = preba::config::PrebaConfig::new();
    preba::experiments::fig05::run(&sys);
}
