//! Integration: PJRT runtime × AOT artifacts × Rust preprocessing ops.
//!
//! Requires `make artifacts` (skips cleanly when absent so `cargo test`
//! stays green pre-AOT).

use preba::models::Manifest;
use preba::preprocess::ops;
use preba::runtime::Engine;
use preba::util::Rng;
use preba::workload;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if Manifest::exists(dir) {
            return Some(dir.to_string());
        }
    }
    eprintln!("skipping: no artifacts (run `make artifacts`)");
    None
}

#[test]
fn manifest_loads_and_covers_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.len() >= 60, "manifest has {} artifacts", m.len());
    for model in preba::models::ModelId::ALL {
        assert!(
            !m.batches_for(model.name()).is_empty(),
            "no artifacts for {model}"
        );
    }
    assert!(m.get("kernel/image_pipeline/b1").is_some());
    assert!(m.get("kernel/audio_pipeline/len2p5").is_some());
}

#[test]
fn image_kernel_hlo_matches_rust_ops() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(42);
    let coeffs = workload::synth_image_coeffs(96, 96, 3, &mut rng);
    // PJRT path (the Pallas kernel lowered to HLO).
    let outs = engine.execute_f32("kernel/image_pipeline/b1", &[coeffs.clone()]).unwrap();
    // Host-Rust path (the CPU baseline implementation).
    let want = ops::image_pipeline(&coeffs, 96, 96, 3, 72, 64);
    assert_eq!(outs[0].len(), want.len());
    let max_err = outs[0]
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "kernel vs rust ops max err {max_err}");
}

#[test]
fn audio_kernel_hlo_matches_rust_ops() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(43);
    let pcm = workload::synth_pcm(2.5, &mut rng);
    let outs = engine.execute_f32("kernel/audio_pipeline/len2p5", &[pcm.clone()]).unwrap();
    let (want, _, _) = ops::audio_pipeline(&pcm, 16_000, 512, 256, 80);
    assert_eq!(outs[0].len(), want.len());
    let max_err = outs[0]
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 5e-3, "kernel vs rust ops max err {max_err}");
}

#[test]
fn model_execution_produces_finite_nonzero_logits() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(44);
    // Preprocess a real image, run mobilenet b1.
    let coeffs = workload::synth_image_coeffs(96, 96, 3, &mut rng);
    let tensor = ops::image_pipeline(&coeffs, 96, 96, 3, 72, 64);
    let outs = engine.execute_f32("model/mobilenet/b1", &[tensor]).unwrap();
    assert_eq!(outs[0].len(), 1000);
    let l2: f32 = outs[0].iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!(l2.is_finite() && l2 > 1e-3, "logits l2 = {l2}");
}

#[test]
fn audio_model_execution_all_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(45);
    for len in [2.5f64, 5.0] {
        let pcm = workload::synth_pcm(len, &mut rng);
        let key = format!("kernel/audio_pipeline/len{}", if len == 2.5 { "2p5" } else { "5" });
        let feat = engine.execute_f32(&key, &[pcm]).unwrap().remove(0);
        let model_key = format!("model/citrinet/b1/len{}", if len == 2.5 { "2p5" } else { "5" });
        let outs = engine.execute_f32(&model_key, &[feat]).unwrap();
        let l2: f32 = outs[0].iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(l2.is_finite() && l2 > 1e-3, "len {len}: l2 = {l2}");
    }
}

#[test]
fn batch_padding_roundtrip() {
    // Executing a b4 artifact with only 2 real samples: the first two
    // output rows must match the b1 artifact's outputs for those samples.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(46);
    let t1 =
        ops::image_pipeline(&workload::synth_image_coeffs(96, 96, 3, &mut rng), 96, 96, 3, 72, 64);
    let t2 =
        ops::image_pipeline(&workload::synth_image_coeffs(96, 96, 3, &mut rng), 96, 96, 3, 72, 64);
    let single1 = engine.execute_f32("model/squeezenet/b1", &[t1.clone()]).unwrap().remove(0);
    let mut flat = Vec::new();
    flat.extend_from_slice(&t1);
    flat.extend_from_slice(&t2);
    let batched = engine.execute_f32("model/squeezenet/b4", &[flat]).unwrap().remove(0);
    assert_eq!(batched.len(), 4 * 1000);
    let max_err = single1
        .iter()
        .zip(batched[..1000].iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "b1 vs b4[0] max err {max_err}");
}

#[test]
fn pick_batch_padding_logic() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).unwrap();
    assert_eq!(engine.pick_batch("mobilenet", 3), Some(4));
    assert_eq!(engine.pick_batch("mobilenet", 16), Some(16));
    // Beyond the largest lowered batch: falls back to the largest.
    assert_eq!(engine.pick_batch("mobilenet", 99), Some(16));
    assert_eq!(engine.pick_batch("nonexistent", 1), None);
}

#[test]
fn audio_ops_stable_on_degenerate_tone_input() {
    // A pure low-frequency tone leaves high mel channels near-silent; the
    // numeric floors (log +1e-3, variance +1e-2) must keep the output
    // finite and bounded rather than amplifying rounding noise
    // (DESIGN.md §7 — this was a real bug class during bring-up).
    let n = 40_000usize;
    let pcm: Vec<f32> = (0..n).map(|i| (0.01 * i as f32).sin()).collect();
    let (out, nf, nm) = ops::audio_pipeline(&pcm, 16_000, 512, 256, 80);
    assert_eq!((nf, nm), (155, 80));
    assert!(out.iter().all(|v| v.is_finite()));
    assert!(out.iter().all(|v| v.abs() < 50.0));
}
use std::time::Instant;
#[test]
fn time_kernels() {
    let mut engine = Engine::new("artifacts").unwrap();
    let mut rng = Rng::new(1);
    let coeffs = workload::synth_image_coeffs(96, 96, 3, &mut rng);
    engine.execute_f32("kernel/image_pipeline/b1", &[coeffs.clone()]).unwrap();
    let t0 = Instant::now();
    for _ in 0..10 { engine.execute_f32("kernel/image_pipeline/b1", &[coeffs.clone()]).unwrap(); }
    eprintln!("image kernel: {:?}/call", t0.elapsed()/10);
    let pcm = workload::synth_pcm(2.5, &mut rng);
    engine.execute_f32("kernel/audio_pipeline/len2p5", &[pcm.clone()]).unwrap();
    let t0 = Instant::now();
    for _ in 0..5 { engine.execute_f32("kernel/audio_pipeline/len2p5", &[pcm.clone()]).unwrap(); }
    eprintln!("audio kernel: {:?}/call", t0.elapsed()/5);
    let tensor = vec![0.5f32; 64*64*3];
    engine.execute_f32("model/mobilenet/b1", &[tensor.clone()]).unwrap();
    let t0 = Instant::now();
    for _ in 0..10 { engine.execute_f32("model/mobilenet/b1", &[tensor.clone()]).unwrap(); }
    eprintln!("mobilenet b1: {:?}/call", t0.elapsed()/10);
    let t16 = vec![0.5f32; 16*64*64*3];
    engine.execute_f32("model/mobilenet/b16", &[t16.clone()]).unwrap();
    let t0 = Instant::now();
    for _ in 0..10 { engine.execute_f32("model/mobilenet/b16", &[t16.clone()]).unwrap(); }
    eprintln!("mobilenet b16: {:?}/call", t0.elapsed()/10);
}
