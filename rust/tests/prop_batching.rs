//! Property tests: dynamic-batcher invariants (conservation, caps, FIFO,
//! deadline behaviour) under randomized traffic.

use preba::batching::{BatchPolicy, Bucketizer, DynamicBatcher, QueueParams, Request};
use preba::clock::millis;
use preba::models::ModelId;
use preba::prop_assert;
use preba::util::prop;
use preba::util::Rng;

fn random_policy(rng: &mut Rng, n_buckets: usize) -> BatchPolicy {
    if rng.f64() < 0.3 {
        BatchPolicy::Static(QueueParams {
            batch_max: 1 + rng.below(16) as usize,
            time_queue: millis(1.0 + rng.f64() * 30.0),
        })
    } else {
        BatchPolicy::Dynamic {
            per_bucket: (0..n_buckets)
                .map(|_| QueueParams {
                    batch_max: 1 + rng.below(16) as usize,
                    time_queue: millis(1.0 + rng.f64() * 30.0),
                })
                .collect(),
        }
    }
}

fn drive(rng: &mut Rng) -> Result<(), String> {
    let buckets = Bucketizer::new(2.5, 25.0);
    let n_buckets = buckets.n_buckets();
    let policy = random_policy(rng, n_buckets);
    let merge = rng.f64() < 0.5;
    let mut b = DynamicBatcher::new(ModelId::CitriNet, buckets.clone(), policy.clone(), merge);

    let n = 1 + rng.below(200) as usize;
    let mut now = 0u64;
    let mut out_ids: Vec<u64> = Vec::new();
    let mut out_batches = Vec::new();

    for i in 0..n {
        now += rng.below(millis(3.0));
        let len_s = rng.f64() * 25.0;
        b.enqueue(Request {
            id: i as u64,
            model: ModelId::CitriNet,
            arrival: now,
            enqueued: now,
            len_s,
        });
        while let Some((batch, _)) = b.try_form(now) {
            out_ids.extend(batch.requests.iter().map(|r| r.id));
            out_batches.push(batch);
        }
        // Occasionally jump past a deadline.
        if rng.f64() < 0.3 {
            now += millis(40.0);
            while let Some((batch, _)) = b.try_form(now) {
                out_ids.extend(batch.requests.iter().map(|r| r.id));
                out_batches.push(batch);
            }
        }
    }
    // Flush the remainder.
    for batch in b.flush(now + millis(100.0)) {
        out_ids.extend(batch.requests.iter().map(|r| r.id));
        out_batches.push(batch);
    }

    // 1. Conservation: every request released exactly once.
    let mut sorted = out_ids.clone();
    sorted.sort_unstable();
    sorted.dedup();
    prop_assert!(
        sorted.len() == out_ids.len(),
        "duplicate release: {} unique of {}",
        sorted.len(),
        out_ids.len()
    );
    prop_assert!(sorted.len() == n, "lost requests: in {} out {}", n, sorted.len());
    prop_assert!(b.pending() == 0);
    prop_assert!(b.balance() == 0);

    // 2. Caps: a batch never exceeds its own bucket's Batch_max, and a
    //    merged batch never exceeds the longest member's Batch_max when
    //    the longest member came from a longer bucket (the paper's rule).
    for batch in &out_batches {
        prop_assert!(!batch.requests.is_empty());
        let own_cap = policy.params(batch.bucket).batch_max;
        prop_assert!(
            batch.size() <= own_cap,
            "batch {} exceeds own cap {} (bucket {})",
            batch.size(),
            own_cap,
            batch.bucket
        );
        let longest_bucket = buckets.bucket_of(batch.max_len_s);
        if longest_bucket > batch.bucket {
            let longest_cap = policy.params(longest_bucket).batch_max;
            prop_assert!(
                batch.size() <= longest_cap,
                "merged batch {} exceeds longest-member cap {} (buckets {}->{})",
                batch.size(),
                longest_cap,
                batch.bucket,
                longest_bucket
            );
        }
        // 3. max_len_s really is the max member length.
        let max_len = batch.requests.iter().map(|r| r.len_s).fold(0.0, f64::max);
        prop_assert!((max_len - batch.max_len_s).abs() < 1e-12);
    }
    Ok(())
}

#[test]
fn batcher_invariants_hold() {
    prop::check("batcher-invariants", prop::default_cases(), drive);
}

#[test]
fn fifo_order_within_bucket() {
    prop::check("fifo-within-bucket", 64, |rng| {
        let buckets = Bucketizer::new(2.5, 25.0);
        let policy = BatchPolicy::Static(QueueParams {
            batch_max: 1 + rng.below(8) as usize,
            time_queue: millis(5.0),
        });
        // merge=false so releases stay within one bucket.
        let mut b = DynamicBatcher::new(ModelId::CitriNet, buckets, policy, false);
        for i in 0..50u64 {
            b.enqueue(Request {
                id: i,
                model: ModelId::CitriNet,
                arrival: i,
                enqueued: i,
                len_s: (i % 10) as f64 * 2.4,
            });
        }
        let mut last_seen = std::collections::HashMap::new();
        let mut now = 0;
        loop {
            now += millis(10.0);
            let mut any = false;
            while let Some((batch, _)) = b.try_form(now) {
                any = true;
                for r in &batch.requests {
                    let bucket = (r.len_s / 2.5) as usize;
                    if let Some(&prev) = last_seen.get(&bucket) {
                        prop_assert!(r.id > prev, "bucket {bucket}: {} after {}", r.id, prev);
                    }
                    last_seen.insert(bucket, r.id);
                }
            }
            if !any && b.pending() == 0 {
                break;
            }
            prop_assert!(now < millis(10_000.0), "did not drain");
        }
        Ok(())
    });
}

#[test]
fn deadline_is_never_later_than_head_wait() {
    prop::check("deadline-bound", 64, |rng| {
        let buckets = Bucketizer::new(2.5, 25.0);
        let tq = millis(1.0 + rng.f64() * 20.0);
        let policy = BatchPolicy::Static(QueueParams { batch_max: 1000, time_queue: tq });
        let mut b = DynamicBatcher::new(ModelId::CitriNet, buckets, policy, true);
        // Enqueue times are monotone (they are "now" in the server), so
        // every bucket's head is its earliest request.
        let mut first_enq = None;
        let mut t = 0u64;
        for i in 0..(1 + rng.below(20)) {
            t += rng.below(millis(1.0));
            first_enq = Some(first_enq.map_or(t, |f: u64| f.min(t)));
            b.enqueue(Request {
                id: i,
                model: ModelId::CitriNet,
                arrival: t,
                enqueued: t,
                len_s: rng.f64() * 25.0,
            });
        }
        let deadline = b.next_deadline().unwrap();
        prop_assert!(deadline <= first_enq.unwrap() + tq);
        // At the deadline, try_form must release something.
        prop_assert!(b.try_form(deadline).is_some());
        Ok(())
    });
}
