//! Properties of the `[curves]` layer (per-(model, profile, batch)
//! latency/power multipliers + busy-neighbor contention): neutral
//! settings are BYTE-IDENTICAL to the flat model end to end (stats and
//! energy bit-for-bit, at any shard count), interference only ever slows
//! things down and never loses work, the scaled planner helpers degrade
//! exactly to their unscaled twins at scale 1.0, `[curves]` TOML
//! round-trips, and the `interference` experiment is bitwise identical
//! across `--jobs` counts.

use std::process::Command;

use preba::config::{toml, PrebaConfig};
use preba::mig::reconfig::{
    predicted_p95_ms_gpcs, predicted_p95_ms_gpcs_scaled, slices_for_rate, slices_for_rate_scaled,
    TenantSpec,
};
use preba::mig::{MigConfig, PackStrategy, ServiceModel, Slice};
use preba::models::{batch_bucket, ModelId, N_BUCKETS};
use preba::prop_assert;
use preba::server::cluster::{self, ClusterConfig, ClusterOutcome, ClusterTenant};
use preba::server::{sim_driver, PreprocMode, SimConfig};
use preba::util::prop::check;
use preba::util::Rng;

/// A small random fleet: 2 GPUs, 2-3 tenants over mixed slice profiles
/// at sub-saturation load. Shared by the byte-identity properties.
fn random_cluster_cfg(rng: &mut Rng) -> ClusterConfig {
    let horizon_s = 1.5 + rng.f64() * 1.5;
    let models = [ModelId::SwinTransformer, ModelId::MobileNet, ModelId::CitriNet];
    let tenants: Vec<ClusterTenant> = (0..2 + rng.below(2) as usize)
        .map(|i| {
            let model = models[i % models.len()];
            let (slice, per) = if rng.below(2) == 0 {
                (Slice::new(1, 5), ServiceModel::new(model.spec(), 1).plateau_qps(0.0))
            } else {
                (Slice::new(2, 10), ServiceModel::new(model.spec(), 2).plateau_qps(0.0))
            };
            let slices = 2 + rng.below(2) as usize;
            let rate = rng.range_f64(0.35, 0.6) * slices as f64 * per;
            let mut t = ClusterTenant::new(model, slice, slices, rate);
            t.sla_ms = 80.0;
            t.requests = ((rate * horizon_s).ceil() as usize).max(50);
            t
        })
        .collect();
    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(tenants)
        .seed(rng.next_u64())
        .warmup_frac(0.0)
        .build()
}

/// Bitwise outcome fingerprint: every latency/energy float via
/// `to_bits`, plus the raw counters. Two runs that disagree anywhere
/// observable disagree here.
fn fingerprint(out: &ClusterOutcome) -> Vec<u64> {
    let mut f = vec![out.horizon as u64, out.events, out.completed_total()];
    f.extend(out.dropped.iter().copied());
    for (_, stats) in &out.per_tenant {
        f.push(stats.completed);
        f.push(stats.p95_ms().to_bits());
        f.push(stats.mean_ms().to_bits());
        f.push(stats.throughput_qps().to_bits());
    }
    let e = &out.energy;
    for v in [e.gpu_active_j, e.gpu_idle_j, e.cpu_j, e.dpu_j, e.base_j] {
        f.push(v.to_bits());
    }
    f
}

/// Neutral curve settings — disabled, `flat` + zero contention, and
/// `migperf` with every scale at 0 — are all BYTE-identical to the flat
/// model: the curve plumbing must be invisible when the multipliers are
/// 1.0, down to the energy integrals' last bit.
#[test]
fn neutral_curves_are_byte_identical_to_the_flat_model() {
    let base = PrebaConfig::new();
    assert!(!base.curves.enabled);
    let mut flat0 = base.clone();
    flat0.curves.enabled = true;
    flat0.curves.source = "flat".to_string();
    flat0.curves.contention_scale = 0.0;
    let mut mig0 = base.clone();
    mig0.curves.enabled = true;
    mig0.curves.source = "migperf".to_string();
    mig0.curves.lat_scale = 0.0;
    mig0.curves.pow_scale = 0.0;
    mig0.curves.contention_scale = 0.0;
    let variants = [&base, &flat0, &mig0];
    for sys in variants {
        sys.validate().unwrap();
        for m in ModelId::ALL {
            assert!(sys.curves.view(m, 1).is_neutral(), "non-neutral view for {m:?}");
        }
    }
    check("neutral curve byte-identity (cluster)", 8, |rng| {
        let cfg = random_cluster_cfg(rng);
        let outs: Vec<Vec<u64>> = variants
            .iter()
            .map(|sys| fingerprint(&cluster::run(&cfg, sys).expect("valid config")))
            .collect();
        prop_assert!(
            outs[0] == outs[1] && outs[0] == outs[2],
            "neutral curve settings diverged from the flat model"
        );
        Ok(())
    });
    // Same invisibility through the single-server DES path.
    let mut cfg = SimConfig::new(ModelId::SwinTransformer, MigConfig::Small7, PreprocMode::Dpu);
    cfg.requests = 2000;
    cfg.rate_qps = cfg.saturating_rate();
    let outs: Vec<_> = variants.iter().map(|sys| sim_driver::run(&cfg, sys)).collect();
    for o in &outs[1..] {
        assert_eq!(o.horizon, outs[0].horizon);
        assert_eq!(o.stats.p95_ms().to_bits(), outs[0].stats.p95_ms().to_bits());
        assert_eq!(
            o.stats.energy.total_j().to_bits(),
            outs[0].stats.energy.total_j().to_bits(),
            "sim energy diverged under neutral curves"
        );
    }
}

/// Event-heap sharding stays a pure performance knob with interference
/// on: the busy-neighbor count reads sibling groups of the same GPU, and
/// the residency-component partition keeps those in one shard — forcing
/// the single global heap must change nothing.
#[test]
fn sharding_is_invisible_under_interference() {
    let mut sys = PrebaConfig::new();
    sys.curves.enabled = true;
    check("shard invariance with curves on", 6, |rng| {
        let mut cfg = random_cluster_cfg(rng);
        cfg.shards = None; // auto: per residency component
        let auto = cluster::run(&cfg, &sys).expect("valid config");
        cfg.shards = Some(1); // single global heap
        let single = cluster::run(&cfg, &sys).expect("valid config");
        prop_assert!(
            fingerprint(&auto) == fingerprint(&single),
            "sharding changed a curve-aware outcome"
        );
        Ok(())
    });
}

/// Interference is a pure slowdown: with the batch curves flat and only
/// the contention term armed, the same offered load completes the same
/// requests no faster, and the active-energy integral strictly grows
/// (busy neighbors inflate both execution time and draw).
#[test]
fn contention_only_slows_down_and_never_loses_work() {
    let base = PrebaConfig::new();
    let mut contended = base.clone();
    contended.curves.enabled = true;
    contended.curves.source = "flat".to_string(); // isolate the contention term
    check("contention is a pure slowdown", 6, |rng| {
        let cfg = random_cluster_cfg(rng);
        let flat = cluster::run(&cfg, &base).expect("valid config");
        let slow = cluster::run(&cfg, &contended).expect("valid config");
        prop_assert!(
            slow.completed_total() == flat.completed_total(),
            "contention lost work: {} vs {}",
            slow.completed_total(),
            flat.completed_total()
        );
        prop_assert!(
            slow.horizon >= flat.horizon,
            "contention finished earlier: {} vs {}",
            slow.horizon,
            flat.horizon
        );
        // Batch composition may reshuffle slightly under the longer
        // service times, so allow 1% slack — the assertion is about the
        // SIGN of the effect, not its exact magnitude.
        for (i, ((_, s), (_, f))) in slow.per_tenant.iter().zip(&flat.per_tenant).enumerate() {
            prop_assert!(
                s.p95_ms() >= f.p95_ms() * 0.99,
                "tenant {i} p95 improved under contention: {} vs {}",
                s.p95_ms(),
                f.p95_ms()
            );
        }
        prop_assert!(
            slow.energy.gpu_active_j > flat.energy.gpu_active_j,
            "contention did not inflate active energy: {} vs {}",
            slow.energy.gpu_active_j,
            flat.energy.gpu_active_j
        );
        Ok(())
    });
}

/// The curve table itself is sane for every (model, profile): latency
/// multipliers grow with the batch bucket from exactly 1.0, the neighbor
/// penalty is affine and increasing, and `service_scale` is monotone in
/// both arguments.
#[test]
fn curve_views_are_monotone()  {
    let mut sys = PrebaConfig::new();
    sys.curves.enabled = true;
    for m in ModelId::ALL {
        for gpcs in [1usize, 2, 3, 4, 7] {
            let v = sys.curves.view(m, gpcs);
            assert_eq!(v.lat[0], 1.0, "{m:?}/{gpcs}g: smallest bucket must be the 1.0 anchor");
            for b in 1..N_BUCKETS {
                assert!(v.lat[b] >= v.lat[b - 1], "{m:?}/{gpcs}g: lat bucket {b} shrank");
                assert!(v.pow[b] > 0.0 && v.lat[b] > 0.0);
            }
            assert!(v.contention >= 0.0 && v.contention <= 1.0);
            for k in 1..7usize {
                assert!(v.penalty(k) >= v.penalty(k - 1));
                assert!(v.service_scale(64, k) >= v.service_scale(64, k - 1));
                assert!(v.service_scale(64, k) >= v.service_scale(1, k));
            }
        }
        // Bigger slices never suffer MORE contention than smaller ones.
        let cs: Vec<f64> =
            [1usize, 2, 3, 4, 7].iter().map(|&g| sys.curves.view(m, g).contention).collect();
        assert!(cs.windows(2).all(|w| w[1] <= w[0]), "{m:?}: contention not anti-monotone {cs:?}");
    }
    // Batch buckets partition the batch axis in order.
    let mut last = 0;
    for b in [1usize, 2, 3, 8, 9, 32, 33, 256] {
        let bucket = batch_bucket(b);
        assert!(bucket >= last && bucket < N_BUCKETS);
        last = bucket;
    }
}

/// The scaled planner helpers ARE the unscaled ones at scale 1.0 (same
/// bits), and a real service-time scale only ever asks for more slices
/// and predicts a worse p95.
#[test]
fn scaled_planner_degrades_exactly_to_unscaled_at_one() {
    check("scaled planner vs unscaled", 32, |rng| {
        let model = [ModelId::SwinTransformer, ModelId::CitriNet, ModelId::MobileNet]
            [rng.below(3) as usize];
        let spec = TenantSpec::new(model, 20.0 + rng.f64() * 60.0);
        let gpcs = [1usize, 2, 7][rng.below(3) as usize];
        let slices = 1 + rng.below(6) as usize;
        let per = ServiceModel::new(model.spec(), gpcs).plateau_qps(spec.len_s);
        let rate = rng.range_f64(0.2, 0.9) * slices as f64 * per;
        let p1 = predicted_p95_ms_gpcs(&spec, gpcs, slices, rate);
        let p1s = predicted_p95_ms_gpcs_scaled(&spec, gpcs, slices, rate, 1.0);
        prop_assert!(
            p1.to_bits() == p1s.to_bits(),
            "scale 1.0 changed the prediction: {p1} vs {p1s}"
        );
        let scale = 1.0 + rng.f64() * 0.5;
        let ps = predicted_p95_ms_gpcs_scaled(&spec, gpcs, slices, rate, scale);
        prop_assert!(ps >= p1, "scale {scale} predicted better: {ps} vs {p1}");

        let slice = Slice::new(gpcs, 5 * gpcs);
        let util = rng.range_f64(0.5, 0.9);
        let n1 = slices_for_rate(&spec, slice, rate, util);
        let n1s = slices_for_rate_scaled(&spec, slice, rate, util, 1.0);
        prop_assert!(n1 == n1s, "scale 1.0 changed the sizing: {n1} vs {n1s}");
        let ns = slices_for_rate_scaled(&spec, slice, rate, util, scale);
        prop_assert!(ns >= n1, "scale {scale} asked for fewer slices: {ns} vs {n1}");
        Ok(())
    });
}

/// `[curves]` TOML round-trip: every key applies, neutral semantics are
/// reachable from a file, and the validator rejects nonsense with a
/// pointed message instead of simulating garbage.
#[test]
fn curves_toml_round_trips_and_validates() {
    let dir = std::env::temp_dir().join("preba_curves_toml");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("curves.toml");
    std::fs::write(
        &path,
        "[curves]\n\
         enabled = true\n\
         source = \"flat\"\n\
         lat_scale = 0.5\n\
         pow_scale = 0.25\n\
         contention_scale = 2.0\n\
         contention_1g = 0.08\n\
         contention_7g = 0.0\n",
    )
    .unwrap();
    let cfg = PrebaConfig::from_file(path.to_str().unwrap()).unwrap();
    assert!(cfg.curves.enabled);
    assert_eq!(cfg.curves.source, "flat");
    assert_eq!(cfg.curves.lat_scale, 0.5);
    assert_eq!(cfg.curves.pow_scale, 0.25);
    assert_eq!(cfg.curves.contention_scale, 2.0);
    assert_eq!(cfg.curves.contention_1g, 0.08);
    assert_eq!(cfg.curves.contention_7g, 0.0);
    // Untouched keys keep the MIGPerf defaults.
    let defaults = PrebaConfig::new();
    assert_eq!(cfg.curves.contention_2g, defaults.curves.contention_2g);
    // With source = "flat" the batch curves are gone but contention
    // stays: 0.08 * 2.0 per neighbor on 1g.
    let v = cfg.curves.view(ModelId::SwinTransformer, 1);
    assert_eq!(v.lat, [1.0; N_BUCKETS]);
    assert_eq!(v.contention, 0.16);

    for (body, needle) in [
        ("[curves]\nsource = \"vendor\"\n", "curves.source"),
        ("[curves]\nlat_scale = -0.5\n", "curves.lat_scale"),
        ("[curves]\ncontention_scale = -1.0\n", "curves.contention_scale"),
        ("[curves]\ncontention_2g = 1.5\n", "curves.contention_2g"),
    ] {
        let doc = toml::parse(body).unwrap();
        let mut cfg = PrebaConfig::new();
        let err = cfg.apply(&doc).expect_err(body).to_string();
        assert!(err.contains(needle), "error for {body:?} lacks {needle:?}: {err}");
    }
}

fn run_interference(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args(["experiment", "interference", "--jobs", jobs, "--out", out_dir.to_str().unwrap()])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment interference --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_interference_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_interference_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_interference("1", &dir1);
    let stdout4 = run_interference("4", &dir4);
    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );
    let json1 =
        std::fs::read(dir1.join("interference.json")).expect("interference.json at jobs=1");
    let json4 =
        std::fs::read(dir4.join("interference.json")).expect("interference.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}

#[test]
fn cluster_cli_interference_smoke() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--interference"])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --interference failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("best-fit"));
}
