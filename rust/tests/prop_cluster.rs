//! Property tests for the cross-GPU rebalancing planner
//! (`mig::reconfig::plan_cluster_moves`): moves are always legal (donor
//! present, per-GPU capacity held, no tenant starved to zero — all via
//! the shared [`validate_plan`] checker), the migration flag is
//! truthful, in-place reassignment is preferred whenever one exists for
//! the gaining tenant, and migrations clear the amortized-cost bar — an
//! astronomically expensive migration is never emitted.

use preba::mig::reconfig::plan_cluster_moves;
use preba::mig::{validate_plan, GpuClass, ReconfigPolicy, ServiceModel, Slice, TenantSpec};
use preba::models::ModelId;
use preba::prop_assert;
use preba::util::prop::check_default;
use preba::util::Rng;

fn swin(sla_ms: f64) -> TenantSpec {
    TenantSpec::new(ModelId::SwinTransformer, sla_ms)
}

/// Slices a tenant needs at the planner's sizing rule (the contract the
/// planner documents: rate / (plateau × target_util), ceil, min 1).
fn need_of(spec: &TenantSpec, slice: Slice, rate: f64, target_util: f64) -> usize {
    let per_slice = ServiceModel::new(spec.model.spec(), slice.gpcs).plateau_qps(spec.len_s);
    ((rate / (per_slice * target_util).max(1e-9)).ceil() as usize).max(1)
}

struct Scenario {
    tenants: Vec<TenantSpec>,
    slices: Vec<Slice>,
    rates: Vec<f64>,
    alloc: Vec<Vec<usize>>,
}

/// Random cluster state: 2-4 tenants on 1g/2g profiles, 2-4 GPUs filled
/// greedily, rates anywhere from idle to 3× current capacity.
fn random_scenario(rng: &mut Rng) -> Scenario {
    let n_tenants = 2 + rng.below(3) as usize;
    let n_gpus = 2 + rng.below(3) as usize;
    let profiles = [Slice::new(1, 5), Slice::new(2, 10)];
    let slices: Vec<Slice> =
        (0..n_tenants).map(|_| profiles[rng.below(2) as usize]).collect();
    let mut alloc = vec![vec![0usize; n_tenants]; n_gpus];
    for row in alloc.iter_mut() {
        let mut gpcs = 0usize;
        let mut mem = 0usize;
        for _ in 0..8 {
            let t = rng.below(n_tenants as u64) as usize;
            if gpcs + slices[t].gpcs <= 7 && mem + slices[t].mem_gb <= 40 {
                row[t] += 1;
                gpcs += slices[t].gpcs;
                mem += slices[t].mem_gb;
            }
        }
    }
    let tenants: Vec<TenantSpec> = (0..n_tenants).map(|_| swin(25.0)).collect();
    let rates: Vec<f64> = (0..n_tenants)
        .map(|i| {
            let have: usize = alloc.iter().map(|g| g[i]).sum();
            let cap = have.max(1) as f64
                * ServiceModel::new(tenants[i].model.spec(), slices[i].gpcs).plateau_qps(0.0);
            rng.f64() * 3.0 * cap
        })
        .collect();
    Scenario { tenants, slices, rates, alloc }
}

#[test]
fn moves_are_legal_and_in_place_is_preferred() {
    check_default("cluster moves legal + in-place preferred", |rng| {
        let s = random_scenario(rng);
        let policy = ReconfigPolicy::default();
        let moves =
            plan_cluster_moves(&s.tenants, &s.slices, &s.rates, &s.alloc, &policy);

        let t = s.tenants.len();
        let need: Vec<usize> = (0..t)
            .map(|i| need_of(&s.tenants[i], s.slices[i], s.rates[i], policy.target_util))
            .collect();
        let started: Vec<usize> = (0..t).map(|i| s.alloc.iter().map(|g| g[i]).sum()).collect();

        // Atomic legality — donor residency, truthful migration flags,
        // per-GPU capacity after every move, no starvation — is the
        // shared validity contract: replay the plan through it.
        let fleet = vec![GpuClass::A100; s.alloc.len()];
        let failed = vec![false; fleet.len()];
        if let Err(e) = validate_plan(&s.slices, &fleet, &failed, &s.alloc, &moves) {
            prop_assert!(false, "greedy plan failed validation: {e}");
        }

        // Replay each move against an evolving state and re-check the
        // planner-SPECIFIC invariants the shared checker doesn't know:
        // donors donate surplus, gainers close deficits, and a migration
        // is only taken when no in-place reassignment existed.
        let mut state = s.alloc.clone();
        let mut have = started.clone();
        for m in &moves {
            prop_assert!(have[m.from] > need[m.from], "donor had no surplus: {m:?}");
            prop_assert!(have[m.to] < need[m.to], "gainer had no deficit: {m:?}");
            if m.migration {
                // An in-place alternative for this gainer must not exist.
                for (g, row) in state.iter().enumerate() {
                    for (d, &cnt) in row.iter().enumerate() {
                        if d == m.to || cnt == 0 || have[d] <= need[d] || state[g][m.to] == 0 {
                            continue;
                        }
                        let gpc_used: usize =
                            (0..t).map(|i| state[g][i] * s.slices[i].gpcs).sum();
                        let mem_used: usize =
                            (0..t).map(|i| state[g][i] * s.slices[i].mem_gb).sum();
                        let fits = 7 - gpc_used + s.slices[d].gpcs >= s.slices[m.to].gpcs
                            && 40 - mem_used + s.slices[d].mem_gb >= s.slices[m.to].mem_gb;
                        prop_assert!(
                            !fits,
                            "migrated while in-place existed on GPU {g} from {d}: {m:?}"
                        );
                    }
                }
            }
            state[m.gpu][m.from] -= 1;
            state[m.gpu][m.to] += 1;
            have[m.from] -= 1;
            have[m.to] += 1;
        }
        Ok(())
    });
}

#[test]
fn planner_is_deterministic() {
    check_default("cluster planner determinism", |rng| {
        let s = random_scenario(rng);
        let policy = ReconfigPolicy::default();
        let a = plan_cluster_moves(&s.tenants, &s.slices, &s.rates, &s.alloc, &policy);
        let b = plan_cluster_moves(&s.tenants, &s.slices, &s.rates, &s.alloc, &policy);
        prop_assert!(a == b, "moves diverged: {a:?} vs {b:?}");
        Ok(())
    });
}

#[test]
fn migrations_never_clear_an_astronomical_cost_bar() {
    check_default("migration bar", |rng| {
        let s = random_scenario(rng);
        let policy = ReconfigPolicy { migration_s: 1e9, ..Default::default() };
        let moves =
            plan_cluster_moves(&s.tenants, &s.slices, &s.rates, &s.alloc, &policy);
        for m in &moves {
            prop_assert!(
                !m.migration,
                "migration emitted despite an unamortizable cost: {m:?}"
            );
        }
        Ok(())
    });
}

/// The directed version of the cost-bar property: relief that must cross
/// GPUs happens exactly when the amortized win clears the migration bar.
#[test]
fn cross_gpu_relief_is_gated_by_the_bar() {
    let tenants = vec![swin(25.0), swin(25.0)];
    let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
    let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
    // A owns GPU0 and is overloaded 30%; B idles on GPU1.
    let alloc = vec![vec![7, 0], vec![0, 7]];
    let rates = [9.1 * u, 0.1 * u];

    let cheap = ReconfigPolicy { migration_s: 0.2, ..Default::default() };
    let moves = plan_cluster_moves(&tenants, &slices, &rates, &alloc, &cheap);
    assert!(
        moves.iter().any(|m| m.migration),
        "cheap migration should rescue the overloaded tenant: {moves:?}"
    );

    let dear = ReconfigPolicy { migration_s: 1e6, ..Default::default() };
    let moves = plan_cluster_moves(&tenants, &slices, &rates, &alloc, &dear);
    assert!(moves.is_empty(), "unamortizable migration must not be planned: {moves:?}");
}
