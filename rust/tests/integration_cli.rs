//! Integration: the `preba` CLI binary end-to-end (argument parsing,
//! subcommand wiring, human-readable output).

use std::process::Command;

fn preba() -> Command {
    Command::new(env!("CARGO_BIN_EXE_preba"))
}

fn run_ok(args: &[&str]) -> String {
    let out = preba().args(args).output().expect("spawn preba");
    assert!(
        out.status.success(),
        "preba {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_and_list() {
    let help = run_ok(&["--help"]);
    assert!(help.contains("simulate"));
    assert!(help.contains("experiment"));
    let list = run_ok(&["list"]);
    for m in preba::models::ModelId::ALL {
        assert!(list.contains(m.name()), "{m} missing from list");
    }
    assert!(list.contains("1g.5gb(7x)"));
    assert!(list.contains("fig17"));
    assert!(list.contains("abl_traffic"));
}

#[test]
fn simulate_reports_breakdown() {
    let out = run_ok(&[
        "simulate",
        "--model",
        "squeezenet",
        "--mig",
        "1g",
        "--preproc",
        "dpu",
        "--requests",
        "1500",
    ]);
    assert!(out.contains("throughput"), "{out}");
    assert!(out.contains("breakdown"), "{out}");
    assert!(out.contains("gpu util"), "{out}");
}

#[test]
fn profile_prints_knee() {
    let out = run_ok(&["profile", "--model", "mobilenet", "--mig", "1g"]);
    assert!(out.contains("Batch_knee=16"), "{out}");
}

#[test]
fn plan_recommends_partition() {
    let out = run_ok(&["plan", "--model", "mobilenet", "--sla", "50"]);
    assert!(out.contains("recommended: 1g.5gb(7x)"), "{out}");
    // Impossible SLA.
    let out = run_ok(&["plan", "--model", "conformer_default", "--sla", "0.5", "--len", "25"]);
    assert!(out.contains("no partition"), "{out}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = preba().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"), "{err}");
}

#[test]
fn unknown_model_fails_helpfully() {
    let out = preba().args(["simulate", "--model", "resnet"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown model"), "{err}");
    assert!(err.contains("mobilenet"), "should list known models: {err}");
}

#[test]
fn config_file_override_applies() {
    let dir = std::env::temp_dir().join("preba_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.toml");
    std::fs::write(&path, "[workload]\nrequests = 500\n[hardware]\ncpu_cores = 16\n").unwrap();
    let out = run_ok(&[
        "--config",
        path.to_str().unwrap(),
        "simulate",
        "--model",
        "citrinet",
        "--preproc",
        "cpu",
        "--requests",
        "800",
    ]);
    assert!(out.contains("cpu util"), "{out}");
}
