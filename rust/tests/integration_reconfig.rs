//! Integration: the reconfiguration experiment must be bitwise identical
//! at any `--jobs` count — every cell (including the reconfig-enabled
//! multi-tenant runs and their controller decisions) is a pure function
//! of its seed, and the sweep engine merges in job order.

use std::process::Command;

fn run_reconfig(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args([
            "experiment",
            "reconfig",
            "--jobs",
            jobs,
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment reconfig --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_reconfig_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_reconfig_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_reconfig("1", &dir1);
    let stdout4 = run_reconfig("4", &dir4);

    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );

    let json1 = std::fs::read(dir1.join("reconfig.json")).expect("reconfig.json at jobs=1");
    let json4 = std::fs::read(dir4.join("reconfig.json")).expect("reconfig.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}

#[test]
fn reconfig_cli_runs_and_reports_a_timeline() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["reconfig", "--requests", "4000", "--profile", "diurnal"])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba reconfig failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("online"), "{text}");
    assert!(text.contains("reallocations"), "{text}");
}
