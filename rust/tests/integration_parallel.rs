//! Integration: the parallel sweep engine — `preba experiment` must
//! produce bitwise-identical stdout and results JSON at any `--jobs`
//! count, because every simulation cell is seed-deterministic and the
//! pool merges results in job order.

use std::process::Command;

fn run_fig9(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args([
            "experiment",
            "fig9",
            "--jobs",
            jobs,
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment fig9 --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_fig9_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_jobs_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_fig9("1", &dir1);
    let stdout4 = run_fig9("4", &dir4);

    // Human-readable report identical.
    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );

    // Results JSON bitwise identical.
    let json1 = std::fs::read(dir1.join("fig09.json")).expect("fig09.json at jobs=1");
    let json4 = std::fs::read(dir4.join("fig09.json")).expect("fig09.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}

#[test]
fn invalid_jobs_value_is_rejected() {
    for bad in ["0", "-2", "lots"] {
        let out = Command::new(env!("CARGO_BIN_EXE_preba"))
            .args(["experiment", "fig13", "--jobs", bad])
            .output()
            .expect("spawn preba");
        assert!(!out.status.success(), "--jobs {bad} should fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("--jobs"), "{err}");
    }
}
