//! Properties of the observability layer (`obs::*` plus the driver
//! wiring): the accounting-conservation audit under random fault and
//! admission schedules, obs-capture neutrality (enabling recording never
//! perturbs outcomes), obs-disabled determinism across shard layouts and
//! worker counts, byte-determinism of the exported artifacts, trace-event
//! schema sanity, and the `--obs` / `preba report` CLI round trip
//! (including the faults timeline the Perfetto recipe relies on).

use std::process::Command;

use preba::clock::secs;
use preba::config::PrebaConfig;
use preba::fault::{FaultSchedule, FaultSpec};
use preba::mig::{MigConfig, PackStrategy, ServiceModel, Slice};
use preba::models::ModelId;
use preba::obs::{EventMark, ExportInput, Fingerprint, GpuDesc, ObsSpec};
use preba::prop_assert;
use preba::server::cluster::{self, ClusterConfig, ClusterOutcome, ClusterTenant};
use preba::server::{sim_driver, PreprocMode, SimConfig};
use preba::util::json::{parse, Json};
use preba::util::prop::check;
use preba::util::Rng;

/// A small random fleet exercising every accounting path: variable
/// warmup (both exclusion rules), optional admission control, and an
/// optional seeded stochastic fault schedule.
fn random_cfg(rng: &mut Rng, sys: &PrebaConfig) -> ClusterConfig {
    let horizon_s = 2.0 + rng.f64() * 2.0;
    let n_gpus = 2 + rng.below(2) as usize;
    let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
    let tenants: Vec<ClusterTenant> = (0..2)
        .map(|_| {
            let slices = 2 + rng.below(3) as usize;
            let rate = rng.range_f64(0.25, 0.55) * slices as f64 * u;
            let mut t =
                ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), slices, rate);
            t.sla_ms = 50.0;
            t.requests = ((rate * horizon_s).ceil() as usize).max(40);
            t
        })
        .collect();
    let warmup = [0.0, 0.05, 0.1][rng.below(3) as usize];
    let mut cfg = ClusterConfig::builder()
        .gpus(n_gpus)
        .strategy(PackStrategy::BestFit)
        .tenants(tenants)
        .seed(rng.next_u64())
        .warmup_frac(warmup)
        .reconfig(preba::experiments::cluster::policy(sys))
        .admission(rng.below(2) == 0)
        .build();
    if rng.below(2) == 0 {
        let mtbf = rng.range_f64(0.8, 2.5);
        let mttr = rng.range_f64(0.2, 0.8);
        let mut srng = rng.split(0x0B5E);
        let sched = FaultSchedule::stochastic(mtbf, mttr, horizon_s, n_gpus, &mut srng);
        if !sched.is_empty() {
            cfg.faults = Some(if rng.below(2) == 0 {
                FaultSpec::recovering(sched, sys.fault.recovery())
            } else {
                FaultSpec::baseline(sched)
            });
        }
    }
    cfg
}

/// Two full-GPU tenants on two GPUs: disjoint residency components, so
/// `shards` actually shards the event heap (controller-coupled features
/// stay off — they collapse the run to one heap).
fn disjoint_cfg(seed: u64) -> ClusterConfig {
    let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
    let tenants: Vec<ClusterTenant> = (0..2)
        .map(|_| {
            let rate = 0.45 * 7.0 * u;
            let mut t =
                ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 7, rate);
            t.sla_ms = 50.0;
            t.requests = 160;
            t
        })
        .collect();
    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(tenants)
        .seed(seed)
        .build()
}

/// Every outcome field the obs layer could conceivably perturb, as exact
/// bits (floats via `to_bits`): byte-identity is the contract, not
/// approximate equality.
fn outcome_fingerprint(out: &ClusterOutcome) -> Vec<u64> {
    let mut v = vec![
        out.horizon,
        out.events,
        out.completed_total(),
        out.reconfigs,
        out.migrations,
        out.late_admissions,
        out.consolidations,
        out.served_by_failed,
        out.reconfig_aborts,
    ];
    for tally in
        [&out.dropped, &out.deferred, &out.deferred_served, &out.timed_out, &out.retries,
         &out.hedges, &out.served_degraded]
    {
        v.extend(tally.iter().copied());
    }
    for (_, s) in &out.per_tenant {
        v.push(s.completed);
        v.push(s.arrivals);
        v.push(s.warmup_skipped);
        v.push(s.mean_ms().to_bits());
        v.push(s.p95_ms().to_bits());
        v.push(s.throughput_qps().to_bits());
    }
    v.push(out.energy.total_j().to_bits());
    v
}

fn a100_desc() -> GpuDesc {
    GpuDesc { name: "A100".into(), gpcs: 7, gpc_active_w: 43.6, gpc_idle_w: 7.9 }
}

#[test]
fn audit_holds_under_random_fault_and_admission_schedules() {
    let sys = PrebaConfig::new();
    check("obs accounting audit", 32, |rng| {
        let cfg = random_cfg(rng, &sys);
        let out = cluster::run(&cfg, &sys).expect("valid config");
        prop_assert!(out.audit().is_ok(), "audit failed: {:?}", out.audit());
        for (i, t) in cfg.tenants.iter().enumerate() {
            let (_, s) = &out.per_tenant[i];
            let terminal = s.completed + s.dropped + s.timed_out + s.warmup_skipped;
            prop_assert!(
                terminal == s.arrivals && s.arrivals == t.requests as u64,
                "tenant {i}: {} served + {} dropped + {} timed out + {} warmup != \
                 {} arrivals ({} offered)",
                s.completed,
                s.dropped,
                s.timed_out,
                s.warmup_skipped,
                s.arrivals,
                t.requests
            );
            prop_assert!(
                s.deferred_served <= s.deferred && s.deferred <= s.arrivals,
                "tenant {i}: deferred ledger does not nest: served {} <= deferred {} <= \
                 arrivals {}",
                s.deferred_served,
                s.deferred,
                s.arrivals
            );
        }
        Ok(())
    });
}

#[test]
fn obs_capture_never_perturbs_outcomes() {
    let sys = PrebaConfig::new();
    check("obs neutrality", 10, |rng| {
        let cfg = random_cfg(rng, &sys);
        let mut on_cfg = cfg.clone();
        on_cfg.obs = ObsSpec::on(0.25 + rng.f64(), 1 + rng.below(8));
        let off = cluster::run(&cfg, &sys).expect("valid config");
        let on = cluster::run(&on_cfg, &sys).expect("valid config");
        prop_assert!(off.obs.is_none(), "disabled run captured a log");
        prop_assert!(
            outcome_fingerprint(&off) == outcome_fingerprint(&on),
            "enabling obs perturbed the run (seed {:#x})",
            cfg.seed
        );
        // The windowed cells reconcile against the run's own ledger.
        let log = on.obs.as_ref().expect("enabled run must capture a log");
        let (arrivals, served, dropped, timed_out, _) = log.windowed_totals();
        let offered: u64 = cfg.tenants.iter().map(|t| t.requests as u64).sum();
        prop_assert!(arrivals == offered, "windowed arrivals {arrivals} != {offered} offered");
        prop_assert!(
            served == on.completed_total(),
            "windowed served {served} != {} completed",
            on.completed_total()
        );
        let s_drop: u64 = on.per_tenant.iter().map(|(_, s)| s.dropped).sum();
        let s_to: u64 = on.per_tenant.iter().map(|(_, s)| s.timed_out).sum();
        prop_assert!(
            dropped == s_drop && timed_out == s_to,
            "windowed drops/timeouts ({dropped}, {timed_out}) != stats ({s_drop}, {s_to})"
        );
        Ok(())
    });
}

#[test]
fn obs_disabled_runs_are_identical_across_shards_and_jobs() {
    let sys = PrebaConfig::new();
    let mk = |shards: usize| {
        let mut cfg = disjoint_cfg(0xD15C);
        cfg.shards = (shards != 0).then_some(shards);
        cfg
    };
    let serial = cluster::run(&mk(1), &sys).unwrap();
    let auto = cluster::run(&mk(0), &sys).unwrap();
    let wide = preba::util::par::with_jobs(4, || cluster::run(&mk(2), &sys)).unwrap();
    assert!(serial.obs.is_none(), "obs off must not capture a log");
    assert_eq!(outcome_fingerprint(&serial), outcome_fingerprint(&auto));
    assert_eq!(outcome_fingerprint(&serial), outcome_fingerprint(&wide));
    // Same contract on the single-GPU driver: default spec is off, runs
    // are repeatable, and no log is captured.
    let mut scfg = SimConfig::new(ModelId::SwinTransformer, MigConfig::Small7, PreprocMode::Ideal);
    scfg.requests = 400;
    scfg.rate_qps = scfg.saturating_rate() * 0.6;
    scfg.seed = 0x51D0;
    let a = sim_driver::run(&scfg, &sys);
    let b = sim_driver::run(&scfg, &sys);
    assert!(a.obs.is_none() && b.obs.is_none());
    assert_eq!(a.stats.completed, b.stats.completed);
    assert_eq!(a.events, b.events);
    assert_eq!(a.stats.p95_ms().to_bits(), b.stats.p95_ms().to_bits());
}

#[test]
fn obs_enabled_artifacts_are_byte_deterministic_across_shards_and_jobs() {
    let sys = PrebaConfig::new();
    let mk = |shards: usize| {
        let mut cfg = disjoint_cfg(0x0B5E);
        cfg.obs = ObsSpec::on(0.5, 4);
        cfg.shards = (shards != 0).then_some(shards);
        cfg
    };
    let runs = [
        cluster::run(&mk(1), &sys).unwrap(),
        cluster::run(&mk(1), &sys).unwrap(), // identical config, re-run
        preba::util::par::with_jobs(4, || cluster::run(&mk(0), &sys)).unwrap(),
        preba::util::par::with_jobs(4, || cluster::run(&mk(2), &sys)).unwrap(),
    ];
    let mut fp = Fingerprint::new("test");
    fp.push("seed", 0x0B5Eu64);
    let base =
        std::env::temp_dir().join(format!("preba_prop_obs_bytes_{}", std::process::id()));
    let mut all: Vec<Vec<(String, Vec<u8>)>> = Vec::new();
    for (i, out) in runs.iter().enumerate() {
        let dir = base.join(format!("r{i}"));
        let _ = std::fs::remove_dir_all(&dir);
        let log = out.obs.as_ref().expect("obs enabled implies a captured log");
        let input = ExportInput {
            log,
            fp: &fp,
            horizon: out.horizon,
            gpus: vec![a100_desc(), a100_desc()],
            tenants: vec!["swin".into(), "swin".into()],
            marks: vec![],
        };
        let files = preba::obs::export::export(&dir, &input).unwrap();
        all.push(
            files
                .iter()
                .map(|p| {
                    let name = p.file_name().unwrap().to_string_lossy().into_owned();
                    (name, std::fs::read(p).unwrap())
                })
                .collect(),
        );
    }
    std::fs::remove_dir_all(&base).ok();
    for (i, other) in all.iter().enumerate().skip(1) {
        assert_eq!(all[0].len(), other.len());
        for (a, b) in all[0].iter().zip(other) {
            assert_eq!(a.0, b.0);
            assert!(
                a.1 == b.1,
                "artifact {} differs between shard/job layout 0 and {i}",
                a.0
            );
        }
    }
}

#[test]
fn exported_trace_is_schema_sane() {
    let sys = PrebaConfig::new();
    let mut cfg = disjoint_cfg(0x7ACE);
    cfg.obs = ObsSpec::on(0.5, 4);
    let out = cluster::run(&cfg, &sys).unwrap();
    let log = out.obs.as_ref().unwrap();
    let mut fp = Fingerprint::new("cluster");
    fp.push("seed", 0x7ACEu64);
    fp.push("strategy", "best-fit");
    let dir =
        std::env::temp_dir().join(format!("preba_prop_obs_schema_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let input = ExportInput {
        log,
        fp: &fp,
        horizon: out.horizon,
        gpus: vec![a100_desc(), a100_desc()],
        tenants: vec!["swin".into(), "swin".into()],
        marks: vec![EventMark {
            at: secs(1.0),
            gpu: Some(1),
            kind: "crash".into(),
            detail: "injected".into(),
        }],
    };
    preba::obs::export::export(&dir, &input).unwrap();
    // meta.json round-trips the fingerprint mapping.
    let meta = parse(&std::fs::read_to_string(dir.join("meta.json")).unwrap()).unwrap();
    let back = Fingerprint::from_json(meta.req("fingerprint").unwrap()).unwrap();
    assert!(back.same_mapping(&fp), "fingerprint does not round-trip through meta.json");
    // Every JSONL line parses.
    for name in ["windows.jsonl", "spans.jsonl", "events.jsonl"] {
        let text = std::fs::read_to_string(dir.join(name)).unwrap();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            parse(line).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
    // The trace parses whole, timestamps are monotone, async begin/end
    // pairs match, and batches/instants are present.
    let trace = parse(&std::fs::read_to_string(dir.join("trace.json")).unwrap()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let evs = trace.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
    assert!(!evs.is_empty());
    let mut last = f64::MIN;
    for e in &evs {
        let ts = e.req("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last, "trace timestamps are not monotone");
        last = ts;
    }
    let count =
        |ph: &str| evs.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph)).count();
    assert!(count("X") > 0, "no batch rectangles");
    assert!(count("b") > 0, "no sampled request spans");
    assert_eq!(count("b"), count("e"), "unmatched async begin/end pairs");
    assert_eq!(count("i"), 1, "expected exactly the injected crash instant");
    assert!(count("C") > 0, "no counter tracks");
}

#[test]
fn cli_obs_export_and_report_round_trip() {
    let dir = std::env::temp_dir().join(format!("preba_obs_cli_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args([
            "cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--seed", "7",
            "--obs", dir.to_str().unwrap(), "--obs-window", "0.5", "--span-sample", "4",
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --obs failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fingerprint: driver=cluster"), "{text}");
    assert!(text.contains("seed=7"), "{text}");
    assert!(text.contains("obs_window_s=0.500"), "{text}");
    assert!(text.contains("obs:"), "{text}");
    // A single run exports straight into the --obs directory.
    for f in ["meta.json", "windows.jsonl", "spans.jsonl", "events.jsonl", "trace.json"] {
        assert!(dir.join(f).is_file(), "missing artifact {f}");
    }
    let rep = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["report", dir.to_str().unwrap()])
        .output()
        .expect("spawn preba report");
    assert!(
        rep.status.success(),
        "preba report failed:\n{}",
        String::from_utf8_lossy(&rep.stderr)
    );
    let digest = String::from_utf8_lossy(&rep.stdout);
    assert!(digest.contains("driver=cluster"), "{digest}");
    assert!(digest.contains("seed=7"), "{digest}");
    assert!(digest.contains("totals: arrivals"), "{digest}");
    std::fs::remove_dir_all(&dir).ok();
    // An unreadable directory is a clean CLI error, not a panic.
    let bad = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["report", dir.join("nope").to_str().unwrap()])
        .output()
        .expect("spawn preba report");
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("meta.json"));
}

#[test]
fn cli_faults_timeline_shows_crash_detect_repair_instants() {
    let dir = std::env::temp_dir().join(format!("preba_obs_faults_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args([
            "cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--reconfig",
            "--faults", "crash@0.5:g0:0.5", "--obs", dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --faults --obs failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    // The A/B pair lands in per-run sibling subdirectories.
    assert!(dir.join("best-fit-baseline").join("trace.json").is_file());
    let rec = dir.join("best-fit-recovery");
    let meta = parse(&std::fs::read_to_string(rec.join("meta.json")).unwrap()).unwrap();
    let fp = Fingerprint::from_json(meta.req("fingerprint").unwrap()).unwrap();
    assert_eq!(fp.get("recovery"), Some("true"));
    let trace = parse(&std::fs::read_to_string(rec.join("trace.json")).unwrap()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let evs = trace.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
    // The fault lifecycle renders as instants on the crashed GPU's track
    // (pid 0): injection named by fault kind, then detect, then repair.
    let instant_ts = |name: &str| {
        evs.iter()
            .find(|e| {
                e.get("ph").and_then(Json::as_str) == Some("i")
                    && e.get("name").and_then(Json::as_str) == Some(name)
                    && e.get("pid").and_then(Json::as_f64) == Some(0.0)
            })
            .unwrap_or_else(|| panic!("no '{name}' instant on the gpu0 track"))
            .req("ts")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let (crash, detect, repair) = (instant_ts("crash"), instant_ts("detect"), instant_ts("repair"));
    assert!(crash <= detect && detect <= repair, "lifecycle out of order: {crash} {detect} {repair}");
}
