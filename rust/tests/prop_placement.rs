//! Property tests for `mig::placement`: packing never violates per-GPU
//! capacity, conserves the ask list, is deterministic, and
//! best-fit-decreasing dominates first-fit on the divisible-profile
//! family — plus heterogeneous-inventory invariants (every bin caps at
//! its own class, 7g never lands on a 4-GPC class, per-class BFD ≥ FF).
//!
//! Capacity/class-support/legality checks go through the shared
//! [`validate_plan`] checker — the same rules every reconfiguration
//! planner's output must satisfy — by treating each placed instance as
//! a one-instance tenant. Only packing-specific invariants (free-space
//! accounting, ask conservation, strategy dominance) are asserted
//! ad hoc here.

use preba::mig::placement::{pack, pack_fleet, PackStrategy, Packing, SliceAsk};
use preba::mig::{validate_plan, GpuClass, Slice};
use preba::prop_assert;
use preba::util::prop::check_default;
use preba::util::Rng;

/// Every strategy, including the fragmentation-gradient variant.
const STRATEGIES: [PackStrategy; 3] =
    [PackStrategy::FirstFit, PackStrategy::BestFit, PackStrategy::FragGradient];

/// Random ask list over the full legal profile set.
fn random_asks(rng: &mut Rng, profiles: &[Slice]) -> Vec<SliceAsk> {
    let n = 1 + rng.below(12) as usize;
    (0..n)
        .map(|i| {
            let k = rng.below(profiles.len() as u64) as usize;
            SliceAsk { tenant: i % 5, slice: profiles[k] }
        })
        .collect()
}

/// Replay a packing through the planners' shared validity checker: each
/// placed instance becomes its own one-instance tenant, so per-class
/// GPC/memory capacity, class support (no 7g on a 4-GPC class) and
/// profile legality are enforced by the exact rules reconfiguration
/// plans must satisfy.
fn validate_packing(p: &Packing, fleet: &[GpuClass]) -> Result<(), String> {
    let slices: Vec<Slice> = p.placements.iter().map(|(a, _)| a.slice).collect();
    let mut alloc = vec![vec![0usize; slices.len()]; fleet.len()];
    for (k, (_, g)) in p.placements.iter().enumerate() {
        alloc[*g][k] += 1;
    }
    let failed = vec![false; fleet.len()];
    validate_plan(&slices, fleet, &failed, &alloc, &[]).map(|_| ())
}

#[test]
fn packing_never_exceeds_gpu_capacity_and_conserves_asks() {
    check_default("placement capacity+conservation", |rng| {
        let asks = random_asks(rng, &Slice::PROFILES);
        let n_gpus = 1 + rng.below(4) as usize;
        let fleet = vec![GpuClass::A100; n_gpus];
        for strategy in STRATEGIES {
            let p = pack(&asks, n_gpus, strategy);
            // Per-GPU compute/memory budgets and profile legality hold —
            // the shared plan-validity rules.
            if let Err(e) = validate_packing(&p, &fleet) {
                prop_assert!(false, "{strategy:?}: {e}");
            }
            // Free-capacity accounting stays consistent with placements.
            for (g, bin) in p.bins.iter().enumerate() {
                let gpcs: usize = bin.placed.iter().map(|a| a.slice.gpcs).sum();
                let mem: usize = bin.placed.iter().map(|a| a.slice.mem_gb).sum();
                prop_assert!(
                    bin.gpcs_free == 7 - gpcs && bin.mem_free_gb == 40 - mem,
                    "GPU {g} free-capacity accounting drifted ({strategy:?})"
                );
            }
            // Placed + rejected = asked (multiset, by total GPCs and count).
            let placed = p.placements.len() + p.rejected.len();
            prop_assert!(placed == asks.len(), "{} of {} asks accounted", placed, asks.len());
            let asked: usize = asks.iter().map(|a| a.slice.gpcs).sum();
            prop_assert!(p.asked_gpcs() == asked);
            // Every placement is inside the bin it claims.
            for (ask, g) in &p.placements {
                prop_assert!(*g < n_gpus);
                prop_assert!(p.bins[*g].placed.contains(ask));
            }
        }
        Ok(())
    });
}

#[test]
fn packing_is_deterministic_for_a_fixed_seed() {
    check_default("placement determinism", |rng| {
        let asks = random_asks(rng, &Slice::PROFILES);
        let n_gpus = 1 + rng.below(4) as usize;
        for strategy in STRATEGIES {
            let a = pack(&asks, n_gpus, strategy);
            let b = pack(&asks, n_gpus, strategy);
            prop_assert!(a.placements == b.placements, "{strategy:?} placements diverged");
            prop_assert!(a.rejected == b.rejected, "{strategy:?} rejections diverged");
        }
        Ok(())
    });
}

/// On the divisible profile family {1g.5gb, 2g.10gb, 4g.20gb} (each size
/// divides the next; memory is exactly 5 GB/GPC so it never binds before
/// compute), big-first greedy packing is optimal — so best-fit-decreasing
/// must admit at least as much capacity as first-fit and never strand
/// more GPCs behind awkward remainders.
#[test]
fn bfd_dominates_ff_on_divisible_demand() {
    let divisible = [Slice::new(1, 5), Slice::new(2, 10), Slice::new(4, 20)];
    check_default("bfd >= ff (divisible family)", |rng| {
        let asks = random_asks(rng, &divisible);
        let n_gpus = 1 + rng.below(4) as usize;
        let ff = pack(&asks, n_gpus, PackStrategy::FirstFit);
        let bf = pack(&asks, n_gpus, PackStrategy::BestFit);
        prop_assert!(
            bf.admitted_gpcs() >= ff.admitted_gpcs(),
            "bfd admitted {} < ff {} for {asks:?} on {n_gpus} GPUs",
            bf.admitted_gpcs(),
            ff.admitted_gpcs()
        );
        prop_assert!(
            bf.stranded_gpcs() <= ff.stranded_gpcs(),
            "bfd stranded {} > ff {} for {asks:?} on {n_gpus} GPUs",
            bf.stranded_gpcs(),
            ff.stranded_gpcs()
        );
        Ok(())
    });
}

/// Random mixed A100/A30 inventory (1-4 GPUs, at least one of each when
/// size allows).
fn random_fleet(rng: &mut Rng) -> Vec<GpuClass> {
    let n = 1 + rng.below(4) as usize;
    (0..n)
        .map(|_| if rng.below(2) == 0 { GpuClass::A100 } else { GpuClass::A30 })
        .collect()
}

/// Heterogeneous invariants: every bin caps at ITS class (an A30 bin
/// never exceeds 4 GPCs / 24 GB) and no slice lands on a class that
/// cannot host its profile — both via the shared checker — plus
/// per-class free-capacity accounting and ask conservation.
#[test]
fn hetero_packing_respects_every_class() {
    check_default("hetero capacity+conservation", |rng| {
        let asks = random_asks(rng, &Slice::PROFILES);
        let fleet = random_fleet(rng);
        for strategy in STRATEGIES {
            let p = pack_fleet(&asks, &fleet, strategy);
            if let Err(e) = validate_packing(&p, &fleet) {
                prop_assert!(false, "{strategy:?}: {e}");
            }
            for (g, bin) in p.bins.iter().enumerate() {
                let class = fleet[g];
                prop_assert!(bin.class == class, "bin {g} lost its class");
                let gpcs: usize = bin.placed.iter().map(|a| a.slice.gpcs).sum();
                let mem: usize = bin.placed.iter().map(|a| a.slice.mem_gb).sum();
                prop_assert!(
                    bin.gpcs_free == class.gpcs - gpcs && bin.mem_free_gb == class.mem_gb - mem,
                    "GPU {g} free-capacity accounting drifted ({strategy:?})"
                );
            }
            prop_assert!(
                p.placements.len() + p.rejected.len() == asks.len(),
                "asks not conserved ({strategy:?})"
            );
        }
        Ok(())
    });
}

/// 7g.40gb asks over a fleet with A30s: they either sit on an A100 or
/// are rejected — never on the 4-GPC class — and an all-A30 fleet
/// rejects them outright (per-GPU rejection, not a fleet-wide error).
#[test]
fn seven_g_never_lands_on_a_4gpc_class() {
    check_default("7g placement", |rng| {
        let mut asks = random_asks(rng, &Slice::PROFILES);
        asks.push(SliceAsk { tenant: 9, slice: Slice::new(7, 40) });
        let fleet = random_fleet(rng);
        for strategy in STRATEGIES {
            let p = pack_fleet(&asks, &fleet, strategy);
            if let Err(e) = validate_packing(&p, &fleet) {
                prop_assert!(false, "{strategy:?}: {e}");
            }
            for (ask, g) in &p.placements {
                if ask.slice.gpcs == 7 {
                    prop_assert!(
                        fleet[*g] == GpuClass::A100,
                        "7g on {} ({strategy:?})",
                        fleet[*g].name
                    );
                }
            }
            let all_a30: Vec<GpuClass> = vec![GpuClass::A30; fleet.len()];
            let p30 = pack_fleet(&asks, &all_a30, strategy);
            prop_assert!(
                p30.placements.iter().all(|(a, _)| a.slice.gpcs <= 4),
                "an A30-only fleet hosted a big slice ({strategy:?})"
            );
            prop_assert!(
                p30.rejected.iter().any(|a| a.slice.gpcs == 7),
                "the 7g ask vanished ({strategy:?})"
            );
        }
        Ok(())
    });
}

/// Per-class BFD ≥ FF: the divisible-family dominance holds on a
/// homogeneous fleet of EITHER class (for the A30 the family sizes even
/// divide the bin capacity exactly).
#[test]
fn bfd_dominates_ff_per_class_on_divisible_demand() {
    let divisible = [Slice::new(1, 5), Slice::new(2, 10), Slice::new(4, 20)];
    check_default("bfd >= ff per class", |rng| {
        let asks = random_asks(rng, &divisible);
        let n_gpus = 1 + rng.below(4) as usize;
        for class in [GpuClass::A100, GpuClass::A30] {
            let fleet: Vec<GpuClass> = vec![class; n_gpus];
            let ff = pack_fleet(&asks, &fleet, PackStrategy::FirstFit);
            let bf = pack_fleet(&asks, &fleet, PackStrategy::BestFit);
            prop_assert!(
                bf.admitted_gpcs() >= ff.admitted_gpcs(),
                "{}: bfd admitted {} < ff {} for {asks:?} on {n_gpus} GPUs",
                class.name,
                bf.admitted_gpcs(),
                ff.admitted_gpcs()
            );
            prop_assert!(
                bf.stranded_gpcs() <= ff.stranded_gpcs(),
                "{}: bfd stranded {} > ff {} for {asks:?} on {n_gpus} GPUs",
                class.name,
                bf.stranded_gpcs(),
                ff.stranded_gpcs()
            );
        }
        Ok(())
    });
}
