//! Property tests for `mig::placement`: packing never violates per-GPU
//! capacity, conserves the ask list, is deterministic, and
//! best-fit-decreasing dominates first-fit on the divisible-profile
//! family.

use preba::mig::placement::{pack, PackStrategy, SliceAsk};
use preba::mig::Slice;
use preba::prop_assert;
use preba::util::prop::check_default;
use preba::util::Rng;

/// Random ask list over the full legal profile set.
fn random_asks(rng: &mut Rng, profiles: &[Slice]) -> Vec<SliceAsk> {
    let n = 1 + rng.below(12) as usize;
    (0..n)
        .map(|i| {
            let k = rng.below(profiles.len() as u64) as usize;
            SliceAsk { tenant: i % 5, slice: profiles[k] }
        })
        .collect()
}

#[test]
fn packing_never_exceeds_gpu_capacity_and_conserves_asks() {
    check_default("placement capacity+conservation", |rng| {
        let asks = random_asks(rng, &Slice::PROFILES);
        let n_gpus = 1 + rng.below(4) as usize;
        for strategy in [PackStrategy::FirstFit, PackStrategy::BestFit] {
            let p = pack(&asks, n_gpus, strategy);
            // Per-GPU compute and memory budgets hold — no slice overlaps
            // a GPC or a DRAM slice another instance owns.
            for (g, bin) in p.bins.iter().enumerate() {
                let gpcs: usize = bin.placed.iter().map(|a| a.slice.gpcs).sum();
                let mem: usize = bin.placed.iter().map(|a| a.slice.mem_gb).sum();
                prop_assert!(gpcs <= 7, "GPU {g} over GPCs: {gpcs} ({strategy:?})");
                prop_assert!(mem <= 40, "GPU {g} over memory: {mem} ({strategy:?})");
                prop_assert!(
                    bin.gpcs_free == 7 - gpcs && bin.mem_free_gb == 40 - mem,
                    "GPU {g} free-capacity accounting drifted"
                );
            }
            // Placed + rejected = asked (multiset, by total GPCs and count).
            let placed = p.placements.len() + p.rejected.len();
            prop_assert!(placed == asks.len(), "{} of {} asks accounted", placed, asks.len());
            let asked: usize = asks.iter().map(|a| a.slice.gpcs).sum();
            prop_assert!(p.asked_gpcs() == asked);
            // Every placement is inside the bin it claims.
            for (ask, g) in &p.placements {
                prop_assert!(*g < n_gpus);
                prop_assert!(p.bins[*g].placed.contains(ask));
            }
        }
        Ok(())
    });
}

#[test]
fn packing_is_deterministic_for_a_fixed_seed() {
    check_default("placement determinism", |rng| {
        let asks = random_asks(rng, &Slice::PROFILES);
        let n_gpus = 1 + rng.below(4) as usize;
        for strategy in [PackStrategy::FirstFit, PackStrategy::BestFit] {
            let a = pack(&asks, n_gpus, strategy);
            let b = pack(&asks, n_gpus, strategy);
            prop_assert!(a.placements == b.placements, "{strategy:?} placements diverged");
            prop_assert!(a.rejected == b.rejected, "{strategy:?} rejections diverged");
        }
        Ok(())
    });
}

/// On the divisible profile family {1g.5gb, 2g.10gb, 4g.20gb} (each size
/// divides the next; memory is exactly 5 GB/GPC so it never binds before
/// compute), big-first greedy packing is optimal — so best-fit-decreasing
/// must admit at least as much capacity as first-fit and never strand
/// more GPCs behind awkward remainders.
#[test]
fn bfd_dominates_ff_on_divisible_demand() {
    let divisible = [Slice::new(1, 5), Slice::new(2, 10), Slice::new(4, 20)];
    check_default("bfd >= ff (divisible family)", |rng| {
        let asks = random_asks(rng, &divisible);
        let n_gpus = 1 + rng.below(4) as usize;
        let ff = pack(&asks, n_gpus, PackStrategy::FirstFit);
        let bf = pack(&asks, n_gpus, PackStrategy::BestFit);
        prop_assert!(
            bf.admitted_gpcs() >= ff.admitted_gpcs(),
            "bfd admitted {} < ff {} for {asks:?} on {n_gpus} GPUs",
            bf.admitted_gpcs(),
            ff.admitted_gpcs()
        );
        prop_assert!(
            bf.stranded_gpcs() <= ff.stranded_gpcs(),
            "bfd stranded {} > ff {} for {asks:?} on {n_gpus} GPUs",
            bf.stranded_gpcs(),
            ff.stranded_gpcs()
        );
        Ok(())
    });
}
