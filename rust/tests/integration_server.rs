//! Integration: the DES server end-to-end — the paper's qualitative
//! claims as executable assertions (who wins, roughly by how much).

use preba::config::PrebaConfig;
use preba::mig::MigConfig;
use preba::models::ModelId;
use preba::server::{sim_driver, PolicyKind, PreprocMode, SimConfig};

fn saturated(
    model: ModelId,
    mig: MigConfig,
    preproc: PreprocMode,
    policy: PolicyKind,
) -> sim_driver::SimOutcome {
    let mut cfg = SimConfig::new(model, mig, preproc);
    cfg.policy = policy;
    cfg.requests = 6000;
    cfg.rate_qps = cfg.saturating_rate();
    sim_driver::run(&cfg, &PrebaConfig::new())
}

#[test]
fn headline_preba_speedup_over_baseline() {
    // Paper §1: PREBA = 3.7x average throughput over CPU baseline.
    let mut ratios = Vec::new();
    for model in ModelId::ALL {
        let cpu = saturated(model, MigConfig::Small7, PreprocMode::Cpu, PolicyKind::Dynamic).qps();
        let dpu = saturated(model, MigConfig::Small7, PreprocMode::Dpu, PolicyKind::Dynamic).qps();
        assert!(dpu > cpu, "{model}: DPU {dpu} !> CPU {cpu}");
        ratios.push(dpu / cpu);
    }
    let geo = (ratios.iter().map(|r| r.ln()).sum::<f64>() / ratios.len() as f64).exp();
    assert!((2.0..7.0).contains(&geo), "avg speedup {geo} (paper: 3.7x)");
}

#[test]
fn preba_within_10pct_of_ideal_for_most_models() {
    // Paper §6.1: >= 91.6% of Ideal for 5 of 6 models.
    let mut close = 0;
    for model in ModelId::ALL {
        let ideal =
            saturated(model, MigConfig::Small7, PreprocMode::Ideal, PolicyKind::Dynamic).qps();
        let dpu = saturated(model, MigConfig::Small7, PreprocMode::Dpu, PolicyKind::Dynamic).qps();
        if dpu >= 0.85 * ideal {
            close += 1;
        }
    }
    assert!(close >= 5, "only {close}/6 models near Ideal");
}

#[test]
fn small_slices_beat_full_gpu_on_aggregate_throughput() {
    // Paper Fig 5: 1g.5gb(7x) aggregate > 7g.40gb(1x), preproc disabled.
    for model in [ModelId::MobileNet, ModelId::CitriNet] {
        let small =
            saturated(model, MigConfig::Small7, PreprocMode::Ideal, PolicyKind::Dynamic).qps();
        let full =
            saturated(model, MigConfig::Full1, PreprocMode::Ideal, PolicyKind::Dynamic).qps();
        assert!(small > full, "{model}: small {small} !> full {full}");
    }
}

#[test]
fn tail_latency_reduction_vs_baseline_at_moderate_load() {
    // Paper §1: 3.4x tail latency reduction. At a load the baseline can
    // still (barely) sustain, PREBA's p95 must be far lower.
    let model = ModelId::SqueezeNet;
    let mut cfg = SimConfig::new(model, MigConfig::Small7, PreprocMode::Cpu);
    cfg.requests = 6000;
    // Offer what the CPU baseline can achieve at saturation * 0.9.
    let base_cap = saturated(model, MigConfig::Small7, PreprocMode::Cpu, PolicyKind::Dynamic).qps();
    cfg.rate_qps = base_cap * 0.9;
    let sys = PrebaConfig::new();
    let base = sim_driver::run(&cfg, &sys);
    cfg.preproc = PreprocMode::Dpu;
    let preba = sim_driver::run(&cfg, &sys);
    assert!(
        preba.p95_ms() * 2.0 < base.p95_ms(),
        "p95: PREBA {} vs baseline {}",
        preba.p95_ms(),
        base.p95_ms()
    );
}

#[test]
fn medium_partition_lands_between_small_and_full() {
    let model = ModelId::MobileNet;
    let small = saturated(model, MigConfig::Small7, PreprocMode::Ideal, PolicyKind::Dynamic).qps();
    let medium =
        saturated(model, MigConfig::Medium3, PreprocMode::Ideal, PolicyKind::Dynamic).qps();
    let full = saturated(model, MigConfig::Full1, PreprocMode::Ideal, PolicyKind::Dynamic).qps();
    assert!(medium < small, "medium {medium} !< small {small}");
    assert!(medium > full * 0.8, "medium {medium} too far below full {full}");
}

#[test]
fn gpu_utilization_high_when_saturated_ideal() {
    let out = saturated(
        ModelId::SwinTransformer,
        MigConfig::Small7,
        PreprocMode::Ideal,
        PolicyKind::Dynamic,
    );
    assert!(out.gpu_util > 0.7, "gpu util {}", out.gpu_util);
}

#[test]
fn dpu_pcie_usage_reported_and_sane() {
    let out =
        saturated(ModelId::MobileNet, MigConfig::Small7, PreprocMode::Dpu, PolicyKind::Dynamic);
    // Paper §4.2: MobileNet's CPU<->DPU traffic ~6 GB/s << 32 GB/s.
    assert!(out.pcie_gbps > 0.5 && out.pcie_gbps < 32.0, "pcie {}", out.pcie_gbps);
    assert!(out.dpu_util.unwrap() > 0.05);
}
