//! Integration: experiment registry — every figure/table runs through the
//! CLI-facing entry points and produces well-formed JSON.
//!
//! Heavier per-figure shape checks live in each experiment module's unit
//! tests; this suite guards the registry, the fast path, and the JSON
//! contract the results files depend on.

use preba::config::PrebaConfig;
use preba::experiments;

/// One results directory for the whole binary: `set_results_dir` is a
/// process-wide first-caller-wins OnceCell (the replacement for the old
/// `std::env::set_var` idiom, which is UB with parallel test threads), so
/// every test that writes results shares it.
fn results_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("preba_results_integration");
    preba::util::bench::set_results_dir(dir.to_str().unwrap());
    dir
}

#[test]
fn registry_ids_unique_and_resolvable() {
    let mut ids: Vec<&str> = experiments::ALL.iter().map(|(id, _)| *id).collect();
    let n = ids.len();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate experiment ids");
    for (id, _) in experiments::ALL {
        assert!(experiments::by_id(id).is_some(), "{id} not resolvable");
    }
    assert!(experiments::by_id("nope").is_none());
}

#[test]
fn cheap_experiments_produce_data() {
    // The analytic / non-simulation experiments run in milliseconds and
    // must produce non-empty data sections. table1 is exercised by
    // `results_files_written_and_parse_back` instead — both tests share
    // one results directory now, and running table1 here too would race
    // that test's read of table1.json under the parallel harness.
    let _dir = results_dir();
    let sys = PrebaConfig::new();
    for id in ["fig5", "fig6", "fig12", "fig13", "fig14", "fig15"] {
        let f = experiments::by_id(id).unwrap();
        let doc = f(&sys);
        let data = doc.get("data").unwrap().as_obj().unwrap();
        assert!(!data.is_empty(), "{id} produced no data");
    }
}

#[test]
fn results_files_written_and_parse_back() {
    let dir = results_dir();
    let sys = PrebaConfig::new();
    experiments::by_id("table1").unwrap()(&sys);
    let text = std::fs::read_to_string(dir.join("table1.json")).unwrap();
    let parsed = preba::util::json::parse(&text).unwrap();
    assert_eq!(
        parsed.get("title").unwrap().as_str().unwrap(),
        "Table 1: DPU resource utilization (FPGA + TPU adaptation)"
    );
}
