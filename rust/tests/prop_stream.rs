//! Properties of the streaming-arrival seam (`workload::stream`) and the
//! sharded cluster DES: a tenant driven by a lazily-pulled [`StreamSpec`]
//! must produce the byte-identical `ClusterOutcome` to the same tenant
//! driven by an eagerly materialized [`ReplayTrace`]; the chunked
//! CSV/JSON file readers must match the eager loader
//! arrival-for-arrival; and the `preba cluster` CLI must print
//! byte-identical reports at every `--shards` and `--jobs` setting.

use std::process::Command;

use preba::config::PrebaConfig;
use preba::mig::{PackStrategy, ServiceModel, Slice};
use preba::models::ModelId;
use preba::prop_assert;
use preba::server::cluster::{self, ClusterConfig, ClusterOutcome, ClusterTenant};
use preba::util::prop::check;
use preba::util::Rng;
use preba::workload::{Arrival, ArrivalStream, Rescale, ReplayTrace, StreamSpec};

/// Byte-level outcome comparison: event counts, horizon, allocations,
/// integrated energy, and every per-tenant latency statistic down to the
/// f64 bit pattern.
fn same_outcome(a: &ClusterOutcome, b: &ClusterOutcome) -> Result<(), String> {
    prop_assert!(a.events == b.events, "events {} != {}", a.events, b.events);
    prop_assert!(a.horizon == b.horizon, "horizon {} != {}", a.horizon, b.horizon);
    prop_assert!(a.dropped == b.dropped, "dropped {:?} != {:?}", a.dropped, b.dropped);
    prop_assert!(
        a.final_alloc == b.final_alloc,
        "alloc {:?} != {:?}",
        a.final_alloc,
        b.final_alloc
    );
    prop_assert!(
        a.energy.total_j().to_bits() == b.energy.total_j().to_bits(),
        "energy {} J != {} J",
        a.energy.total_j(),
        b.energy.total_j()
    );
    prop_assert!(a.per_tenant.len() == b.per_tenant.len(), "tenant count");
    for (i, ((ma, sa), (mb, sb))) in a.per_tenant.iter().zip(&b.per_tenant).enumerate() {
        prop_assert!(ma == mb, "tenant {i} allocation {ma:?} != {mb:?}");
        prop_assert!(
            sa.completed == sb.completed,
            "tenant {i} completed {} != {}",
            sa.completed,
            sb.completed
        );
        prop_assert!(
            sa.p95_ms().to_bits() == sb.p95_ms().to_bits(),
            "tenant {i} p95 {} != {}",
            sa.p95_ms(),
            sb.p95_ms()
        );
        prop_assert!(
            sa.mean_ms().to_bits() == sb.mean_ms().to_bits(),
            "tenant {i} mean {} != {}",
            sa.mean_ms(),
            sb.mean_ms()
        );
    }
    Ok(())
}

/// The paired cluster configs: identical tenants, one fleet pulling
/// arrivals lazily through [`StreamSpec`]s, the other replaying the
/// equivalent materialized [`ReplayTrace`]s.
fn paired_cfgs(rng: &mut Rng) -> (ClusterConfig, ClusterConfig) {
    let horizon_s = 1.5 + rng.f64() * 1.5;
    let trace_seed = rng.next_u64();
    let cluster_seed = rng.next_u64();
    let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
    let specs: Vec<(usize, f64, u64)> = (0..2)
        .map(|_| {
            let slices = 2 + rng.below(3) as usize;
            let qps = rng.range_f64(0.25, 0.55) * slices as f64 * u;
            (slices, qps, rng.next_u64())
        })
        .collect();
    let max_qps = specs.iter().map(|s| s.1).fold(0.0f64, f64::max);

    let streamed: Vec<ClusterTenant> = specs
        .iter()
        .map(|&(slices, qps, thin_seed)| {
            let spec = StreamSpec::azure(trace_seed, horizon_s, max_qps)
                .fit_duration(horizon_s)
                .thin_to_qps(qps, thin_seed);
            ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), slices, max_qps)
                .with_stream(spec)
                .expect("synthetic source probes")
        })
        .collect();
    let materialized: Vec<ClusterTenant> = specs
        .iter()
        .map(|&(slices, qps, thin_seed)| {
            let trace = ReplayTrace::synth_azure(trace_seed, horizon_s, max_qps)
                .rescaled(Rescale::ToDuration(horizon_s))
                .rescaled(Rescale::Thin { qps, seed: thin_seed });
            ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), slices, max_qps)
                .with_trace(trace)
        })
        .collect();
    let cfg = |tenants| {
        ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::BestFit)
            .tenants(tenants)
            .seed(cluster_seed)
            .build()
    };
    (cfg(streamed), cfg(materialized))
}

#[test]
fn stream_tenants_match_materialized_trace_tenants() {
    let sys = PrebaConfig::new();
    check("stream == materialized", 24, |rng| {
        let (streamed, materialized) = paired_cfgs(rng);
        for (i, (s, m)) in streamed.tenants.iter().zip(&materialized.tenants).enumerate() {
            prop_assert!(
                s.requests == m.requests,
                "tenant {i}: probe saw {} arrivals, trace holds {}",
                s.requests,
                m.requests
            );
            prop_assert!(
                s.rate_qps.to_bits() == m.rate_qps.to_bits(),
                "tenant {i}: probed rate {} != trace rate {}",
                s.rate_qps,
                m.rate_qps
            );
        }
        let a = cluster::run(&streamed, &sys).expect("streamed config runs");
        let b = cluster::run(&materialized, &sys).expect("materialized config runs");
        same_outcome(&a, &b)
    });
}

/// The streamed run must also be shard-invariant: the lazily-injected
/// arrivals land in per-shard heaps exactly as they would in the single
/// global heap.
#[test]
fn streamed_run_is_shard_invariant() {
    let sys = PrebaConfig::new();
    check("streamed sharding", 8, |rng| {
        let (base, _) = paired_cfgs(rng);
        let mut single = base.clone();
        single.shards = Some(1);
        let reference = cluster::run(&single, &sys).expect("single heap runs");
        for shards in [None, Some(2), Some(4)] {
            let mut cfg = base.clone();
            cfg.shards = shards;
            let out = cluster::run(&cfg, &sys).expect("sharded config runs");
            same_outcome(&out, &reference).map_err(|e| format!("shards={shards:?}: {e}"))?;
        }
        Ok(())
    });
}

fn collect(mut s: Box<dyn ArrivalStream>) -> Vec<Arrival> {
    std::iter::from_fn(|| s.next_arrival()).collect()
}

fn assert_same_arrivals(lazy: &[Arrival], eager: &[Arrival], label: &str) {
    assert_eq!(lazy.len(), eager.len(), "{label}: arrival count");
    for (i, (a, b)) in lazy.iter().zip(eager).enumerate() {
        assert_eq!(a.at, b.at, "{label}: arrival {i} timestamp");
        assert_eq!(a.len_s.to_bits(), b.len_s.to_bits(), "{label}: arrival {i} length");
    }
}

/// The chunked CSV/JSON readers and the eager loader parse the same
/// bytes to the same arrivals — with and without the rescale knobs.
#[test]
fn chunked_file_readers_match_eager_load() {
    let dir = std::env::temp_dir().join("preba_prop_stream");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = ReplayTrace::synth_azure(0x57AE, 30.0, 40.0);

    let mut csv = String::from("timestamp_s,source\n# synthetic azure sample\n");
    for t in trace.timestamps_s() {
        csv.push_str(&format!("{t},synth\n"));
    }
    let csv_path = dir.join("sample.csv");
    std::fs::write(&csv_path, &csv).unwrap();

    let json = format!(
        "{{\"arrivals_s\": [{}]}}",
        trace.timestamps_s().iter().map(f64::to_string).collect::<Vec<_>>().join(", ")
    );
    let json_path = dir.join("sample.json");
    std::fs::write(&json_path, &json).unwrap();

    for path in [csv_path.to_str().unwrap(), json_path.to_str().unwrap()] {
        let eager = ReplayTrace::load(path).expect("eager load");
        assert_eq!(eager.len(), trace.len(), "{path}: round-trip length");

        // Raw replay.
        let spec = StreamSpec::file(path);
        assert_eq!(spec.probe().expect("probe").requests, eager.len());
        let lazy = collect(spec.open(ModelId::CitriNet, Rng::new(7)).expect("open"));
        let reference = eager.arrivals(ModelId::CitriNet, &mut Rng::new(7));
        assert_same_arrivals(&lazy, &reference, path);

        // Fitted + thinned replay.
        let spec = StreamSpec::file(path).fit_duration(10.0).thin_to_qps(12.0, 0xF00D);
        let lazy = collect(spec.open(ModelId::CitriNet, Rng::new(8)).expect("open"));
        let rescaled = eager
            .rescaled(Rescale::ToDuration(10.0))
            .rescaled(Rescale::Thin { qps: 12.0, seed: 0xF00D });
        assert_eq!(spec.probe().expect("probe").requests, rescaled.len());
        let reference = rescaled.arrivals(ModelId::CitriNet, &mut Rng::new(8));
        assert_same_arrivals(&lazy, &reference, &format!("{path} (rescaled)"));
    }
}

/// End-to-end CLI determinism: `preba cluster --trace azure` prints the
/// byte-identical report at every `--shards` and `--jobs` setting.
#[test]
fn cluster_cli_identical_across_shards_and_jobs() {
    let run = |shards: &str, jobs: &str| {
        let out = Command::new(env!("CARGO_BIN_EXE_preba"))
            .args([
                "cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--trace",
                "azure", "--shards", shards, "--jobs", jobs,
            ])
            .output()
            .expect("spawn preba");
        assert!(
            out.status.success(),
            "preba cluster --shards {shards} --jobs {jobs} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        out.stdout
    };
    let reference = run("0", "1");
    assert!(!reference.is_empty());
    for (shards, jobs) in [("0", "4"), ("1", "1"), ("1", "4"), ("2", "4"), ("8", "2")] {
        assert_eq!(
            String::from_utf8_lossy(&run(shards, jobs)),
            String::from_utf8_lossy(&reference),
            "--shards {shards} --jobs {jobs} diverged from --shards 0 --jobs 1"
        );
    }
}
