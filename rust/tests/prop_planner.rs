//! Conformance suite for the pluggable rebalance-planner stack
//! (`mig::reconfig::planners`): on every random instance the solver
//! chain is monotone (anneal ≤ greedy, exact ≤ anneal on [`plan_cost`]),
//! the exact solver matches an independent brute-force search over its
//! move universe on tiny instances, every planner is deterministic
//! run-to-run and byte-identical at `--jobs 1` vs `4`, the greedy trait
//! object is the direct heuristic call, the anneal halts within its
//! proposal budget, and every emitted plan replays cleanly through the
//! shared [`validate_plan`] checker.

use preba::mig::reconfig::planners::{
    plan_cost, plan_needs, AnnealPlanner, ExactPlanner, GreedyPlanner, OwnedInstance,
    PlanInstance, Planner, PlannerKind,
};
use preba::mig::reconfig::{plan_cluster_moves_fleet_scaled, ReconfigPolicy};
use preba::mig::{validate_plan, GpuClass, ServiceModel, Slice, SliceMove, TenantSpec};
use preba::models::ModelId;
use preba::prop_assert;
use preba::util::par::run_jobs_on;
use preba::util::prop::check_default;
use preba::util::Rng;
use std::collections::HashMap;

/// Random planning instance: mixed A100/A30 fleet, 1g/2g tenants packed
/// greedily, rates anywhere from idle to 3× current capacity so some
/// draws demand rebalancing and some don't. `max_gpus`/`max_fill` bound
/// the instance size (the brute-force test needs genuinely tiny ones).
fn random_instance(rng: &mut Rng, max_gpus: usize, max_fill: usize) -> OwnedInstance {
    let n_tenants = 2 + rng.below(3) as usize;
    let n_gpus = 1 + rng.below(max_gpus as u64) as usize;
    let profiles = [Slice::new(1, 5), Slice::new(2, 10)];
    let slices: Vec<Slice> =
        (0..n_tenants).map(|_| profiles[rng.below(2) as usize]).collect();
    let fleet: Vec<GpuClass> = (0..n_gpus)
        .map(|_| if rng.below(2) == 0 { GpuClass::A100 } else { GpuClass::A30 })
        .collect();
    let mut alloc = vec![vec![0usize; n_tenants]; n_gpus];
    for (g, row) in alloc.iter_mut().enumerate() {
        let mut gpcs = 0usize;
        let mut mem = 0usize;
        for _ in 0..max_fill {
            let t = rng.below(n_tenants as u64) as usize;
            if fleet[g].supports(&slices[t])
                && gpcs + slices[t].gpcs <= fleet[g].gpcs
                && mem + slices[t].mem_gb <= fleet[g].mem_gb
            {
                row[t] += 1;
                gpcs += slices[t].gpcs;
                mem += slices[t].mem_gb;
            }
        }
    }
    let tenants: Vec<TenantSpec> =
        (0..n_tenants).map(|_| TenantSpec::new(ModelId::SwinTransformer, 25.0)).collect();
    let rates: Vec<f64> = (0..n_tenants)
        .map(|i| {
            let have: usize = alloc.iter().map(|g| g[i]).sum();
            let cap = have.max(1) as f64
                * ServiceModel::new(tenants[i].model.spec(), slices[i].gpcs).plateau_qps(0.0);
            rng.f64() * 3.0 * cap
        })
        .collect();
    let policy = ReconfigPolicy { anneal_iters: 300, ..Default::default() };
    OwnedInstance {
        tenants,
        slices,
        rates,
        alloc,
        fleet,
        policy,
        scales: vec![1.0; n_tenants],
    }
}

/// Every planner's plan for `own`, in [`PlannerKind::ALL`] order.
fn all_plans(own: &OwnedInstance) -> Vec<Vec<SliceMove>> {
    let inst = own.as_instance();
    PlannerKind::ALL.iter().map(|k| k.planner(&own.policy).plan(&inst)).collect()
}

/// The solver chain is monotone on every random instance — anneal never
/// above greedy, exact never above anneal on the plan objective — and
/// every plan replays cleanly through the shared validity checker.
#[test]
fn solver_chain_is_monotone_and_every_plan_is_valid() {
    check_default("anneal <= greedy, exact <= anneal", |rng| {
        let own = random_instance(rng, 4, 5);
        let inst = own.as_instance();
        // A deliberately small node budget: exhaustion returns the
        // incumbent, so the monotone chain must hold even mid-search.
        let exact = ExactPlanner { max_gpus: 16, node_budget: 20_000 };
        let plans = vec![
            GreedyPlanner.plan(&inst),
            AnnealPlanner::budgeted(own.policy.anneal_iters).plan(&inst),
            exact.plan(&inst),
        ];
        let failed = vec![false; own.fleet.len()];
        for (kind, plan) in PlannerKind::ALL.iter().zip(&plans) {
            if let Err(e) = validate_plan(&own.slices, &own.fleet, &failed, &own.alloc, plan) {
                prop_assert!(false, "{} plan failed validation: {e}", kind.label());
            }
        }
        let costs: Vec<f64> = plans.iter().map(|p| plan_cost(&inst, p)).collect();
        let (greedy, anneal, exact) = (costs[0], costs[1], costs[2]);
        prop_assert!(anneal <= greedy + 1e-9, "anneal {anneal} worse than greedy {greedy}");
        prop_assert!(exact <= anneal + 1e-9, "exact {exact} worse than anneal {anneal}");
        Ok(())
    });
}

/// Independent brute force over the exact solver's move universe
/// (donors above their sized need, gainers below): exhaustive
/// depth-first search with per-state move-cost dominance and no bounds,
/// budgets or incumbents. Returns the best reachable [`plan_cost`]
/// (including the empty plan).
fn brute_force_best(inst: &PlanInstance<'_>) -> f64 {
    let t = inst.tenants.len();
    let need = plan_needs(inst);
    let mut best = plan_cost(inst, &[]);
    let mut visited: HashMap<Vec<Vec<usize>>, f64> = HashMap::new();
    visited.insert(inst.alloc.to_vec(), 0.0);
    let mut stack: Vec<(Vec<Vec<usize>>, Vec<SliceMove>, f64)> =
        vec![(inst.alloc.to_vec(), Vec::new(), 0.0)];
    while let Some((state, moves, move_cost)) = stack.pop() {
        let have: Vec<usize> = (0..t).map(|i| state.iter().map(|g| g[i]).sum()).collect();
        for (g, row) in state.iter().enumerate() {
            let gpc_free = inst.fleet[g]
                .gpcs
                .saturating_sub((0..t).map(|i| row[i] * inst.slices[i].gpcs).sum());
            let mem_free = inst.fleet[g]
                .mem_gb
                .saturating_sub((0..t).map(|i| row[i] * inst.slices[i].mem_gb).sum());
            for d in 0..t {
                if have[d] <= need[d] || row[d] == 0 {
                    continue;
                }
                for i in 0..t {
                    if i == d || have[i] >= need[i] {
                        continue;
                    }
                    let (sd, si) = (inst.slices[d], inst.slices[i]);
                    if !(inst.fleet[g].supports(&si)
                        && gpc_free + sd.gpcs >= si.gpcs
                        && mem_free + sd.mem_gb >= si.mem_gb)
                    {
                        continue;
                    }
                    let migration = row[i] == 0;
                    let outage = if migration {
                        inst.policy.migration_s
                    } else {
                        inst.policy.repartition_s
                    };
                    let displaced = inst.rates[d] / have[d].max(1) as f64
                        + inst.rates[i] / (have[i] + 1) as f64;
                    let mc = move_cost + displaced * outage * outage;
                    let mut next = state.clone();
                    next[g][d] -= 1;
                    next[g][i] += 1;
                    if visited.get(&next).is_some_and(|&c| c <= mc) {
                        continue;
                    }
                    visited.insert(next.clone(), mc);
                    let mut ms = moves.clone();
                    ms.push(SliceMove { gpu: g, from: d, to: i, migration });
                    let total = plan_cost(inst, &ms);
                    if total < best {
                        best = total;
                    }
                    stack.push((next, ms, mc));
                }
            }
        }
    }
    best
}

/// On tiny instances (≤ 3 GPUs, lightly filled) the exact solver's cost
/// equals the better of the brute-force optimum over its move universe
/// and the anneal incumbent (the anneal searches a wider swap space, so
/// it may legitimately beat the universe's optimum — the exact plan is
/// then that incumbent).
#[test]
fn exact_matches_brute_force_on_tiny_instances() {
    check_default("exact == min(brute force, anneal)", |rng| {
        let own = random_instance(rng, 3, 3);
        let inst = own.as_instance();
        let exact = ExactPlanner { max_gpus: 16, node_budget: 1_000_000 };
        let exact_cost = plan_cost(&inst, &exact.plan(&inst));
        let anneal_cost =
            plan_cost(&inst, &AnnealPlanner::budgeted(own.policy.anneal_iters).plan(&inst));
        let brute = brute_force_best(&inst);
        let want = brute.min(anneal_cost);
        let tol = 1e-9 * want.abs().max(1.0);
        prop_assert!(
            (exact_cost - want).abs() <= tol,
            "exact {exact_cost} != min(brute {brute}, anneal {anneal_cost})"
        );
        Ok(())
    });
}

/// Every planner is a pure function of its instance: two runs agree
/// move-for-move, on every random instance.
#[test]
fn planners_are_deterministic_run_to_run() {
    check_default("planner determinism", |rng| {
        let own = random_instance(rng, 3, 4);
        let (a, b) = (all_plans(&own), all_plans(&own));
        for (k, kind) in PlannerKind::ALL.iter().enumerate() {
            prop_assert!(
                a[k] == b[k],
                "{} diverged across runs: {:?} vs {:?}",
                kind.label(),
                a[k],
                b[k]
            );
        }
        Ok(())
    });
}

/// Plans are byte-identical whatever the worker count: a serial sweep
/// (`--jobs 1`) and a 4-worker sweep over the same instances produce
/// identical move lists for every planner. The anneal's budget is a
/// proposal count, not wall-clock, so parallelism cannot leak in.
#[test]
fn planners_are_byte_identical_across_jobs() {
    let mut rng = Rng::new(0x01A5_7ACC);
    let instances: Vec<OwnedInstance> =
        (0..6).map(|_| random_instance(&mut rng, 4, 5)).collect();
    let sweep = |jobs: usize| -> Vec<Vec<Vec<SliceMove>>> {
        run_jobs_on(jobs, instances.len(), |i| all_plans(&instances[i]))
    };
    assert_eq!(sweep(1), sweep(4), "plans changed with the worker count");
}

/// The trait seam adds nothing: `GreedyPlanner` through `Box<dyn
/// Planner>` emits exactly what calling the heuristic directly does.
#[test]
fn greedy_through_the_trait_is_the_direct_call() {
    check_default("greedy trait == direct call", |rng| {
        let own = random_instance(rng, 5, 6);
        let via_trait = PlannerKind::Greedy.planner(&own.policy).plan(&own.as_instance());
        let direct = plan_cluster_moves_fleet_scaled(
            &own.tenants,
            &own.slices,
            &own.rates,
            &own.alloc,
            &own.fleet,
            &own.policy,
            &own.scales,
        );
        prop_assert!(via_trait == direct, "trait {via_trait:?} vs direct {direct:?}");
        Ok(())
    });
}

/// The anneal halts within its proposal budget on every instance, and a
/// zero budget degenerates to the greedy plan exactly.
#[test]
fn anneal_respects_its_iteration_budget() {
    check_default("anneal budget", |rng| {
        let own = random_instance(rng, 5, 6);
        let inst = own.as_instance();
        let budget = 1 + rng.below(400) as usize;
        let (moves, used) = AnnealPlanner::budgeted(budget).plan_with_stats(&inst);
        prop_assert!(used <= budget, "spent {used} of {budget} proposals");
        prop_assert!(
            plan_cost(&inst, &moves) <= plan_cost(&inst, &GreedyPlanner.plan(&inst)) + 1e-9,
            "budgeted anneal fell below its greedy seed"
        );
        let (zero, used0) = AnnealPlanner::budgeted(0).plan_with_stats(&inst);
        prop_assert!(used0 == 0, "zero budget spent {used0} proposals");
        prop_assert!(zero == GreedyPlanner.plan(&inst), "zero budget != greedy");
        Ok(())
    });
}
