//! Integration: the cluster experiment must be bitwise identical at any
//! `--jobs` count — every cell (packing DES runs, routing comparison,
//! the reconfig-enabled runs with their controller decisions, and the
//! trace-replay/admission section) is a pure function of its seed, and
//! the sweep engine merges in job order. Plus `preba cluster` CLI smoke
//! tests for `--fleet`, `--trace`, and `--admission`.

use std::process::Command;

fn run_cluster(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args([
            "experiment",
            "cluster",
            "--jobs",
            jobs,
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment cluster --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_cluster_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_cluster_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_cluster("1", &dir1);
    let stdout4 = run_cluster("4", &dir4);

    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );

    let json1 = std::fs::read(dir1.join("cluster.json")).expect("cluster.json at jobs=1");
    let json4 = std::fs::read(dir4.join("cluster.json")).expect("cluster.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}

#[test]
fn cluster_cli_reports_both_packings_and_the_bfd_win() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--gpus", "4", "--horizon", "2"])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("first-fit"), "{text}");
    assert!(text.contains("best-fit"), "{text}");
    assert!(text.contains("stranded"), "{text}");
}

#[test]
fn cluster_cli_hetero_fleet_smoke() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--fleet", "a100x2,a30x2", "--horizon", "2", "--strategy", "bfd"])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --fleet failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("a30"), "{text}");
    assert!(text.contains("4 GPUs"), "{text}");
    // A bogus class is a clean CLI error, not a panic.
    let bad = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--fleet", "h100x8", "--horizon", "1"])
        .output()
        .expect("spawn preba");
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("unknown GPU class"));
}

#[test]
fn cluster_cli_trace_replay_smoke() {
    // The bundled real-style replay fixture (rust/fixtures/) driven
    // through the fleet (rescaled per tenant), plus the synthetic
    // generator.
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/fixtures/azure_sample.csv");
    for trace in [fixture, "azure"] {
        let out = Command::new(env!("CARGO_BIN_EXE_preba"))
            .args([
                "cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--trace",
                trace,
            ])
            .output()
            .expect("spawn preba");
        assert!(
            out.status.success(),
            "preba cluster --trace {trace} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("trace replay"), "{text}");
    }
}

#[test]
fn bundled_azure_fixture_parses_and_has_the_recorded_shape() {
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/fixtures/azure_sample.csv");
    let trace = preba::workload::ReplayTrace::load(fixture).expect("fixture parses");
    assert!(
        (180..=260).contains(&trace.len()),
        "fixture should hold ~200 arrivals, got {}",
        trace.len()
    );
    assert!((55.0..=60.0).contains(&trace.duration_s()), "span {}", trace.duration_s());
    assert!(trace.mean_qps() > 2.0, "mean {}", trace.mean_qps());
}

#[test]
fn cluster_cli_energy_and_consolidation_smoke() {
    // --energy adds the fleet energy columns; --consolidate implies the
    // reconfig controller.
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args([
            "cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--energy",
            "--consolidate",
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --energy --consolidate failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fleet kJ"), "{text}");
    assert!(text.contains("J/query"), "{text}");
    assert!(text.contains("power-downs"), "{text}");
    assert!(text.contains("energy consolidation"), "{text}");
}

#[test]
fn cluster_cli_admission_smoke() {
    // --admission implies the reconfig controller and reports the
    // dropped-vs-deferred split.
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--admission"])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --admission failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("admission control"), "{text}");
    assert!(text.contains("deferred"), "{text}");
    assert!(text.contains("served late"), "{text}");
}

#[test]
fn cluster_cli_online_rebalancing_smoke() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args([
            "cluster",
            "--gpus",
            "2",
            "--horizon",
            "2",
            "--strategy",
            "bfd",
            "--reconfig",
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --reconfig failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rebalances"), "{text}");
    assert!(text.contains("migrations"), "{text}");
}
