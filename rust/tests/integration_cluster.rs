//! Integration: the cluster experiment must be bitwise identical at any
//! `--jobs` count — every cell (packing DES runs, routing comparison, and
//! the reconfig-enabled runs with their controller decisions) is a pure
//! function of its seed, and the sweep engine merges in job order. Plus a
//! `preba cluster` CLI smoke test.

use std::process::Command;

fn run_cluster(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args([
            "experiment",
            "cluster",
            "--jobs",
            jobs,
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment cluster --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_cluster_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_cluster_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_cluster("1", &dir1);
    let stdout4 = run_cluster("4", &dir4);

    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );

    let json1 = std::fs::read(dir1.join("cluster.json")).expect("cluster.json at jobs=1");
    let json4 = std::fs::read(dir4.join("cluster.json")).expect("cluster.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}

#[test]
fn cluster_cli_reports_both_packings_and_the_bfd_win() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--gpus", "4", "--horizon", "2"])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("first-fit"), "{text}");
    assert!(text.contains("best-fit"), "{text}");
    assert!(text.contains("stranded"), "{text}");
}

#[test]
fn cluster_cli_online_rebalancing_smoke() {
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args([
            "cluster",
            "--gpus",
            "2",
            "--horizon",
            "2",
            "--strategy",
            "bfd",
            "--reconfig",
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --reconfig failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rebalances"), "{text}");
    assert!(text.contains("migrations"), "{text}");
}
