//! Properties of the energy subsystem: conservation (the integrated
//! total is exactly the sum of components AND equals ∫power·dt of the
//! component models over the horizon), bitwise determinism of the
//! `energy` experiment across `--jobs` counts, and the consolidation
//! contract — powering GPUs down never increases fleet energy at an
//! equal served count.

use std::process::Command;

use preba::clock::to_secs;
use preba::config::PrebaConfig;
use preba::mig::MigConfig;
use preba::models::ModelId;
use preba::server::{cluster, sim_driver, PreprocMode, SimConfig, SimOutcome};

fn saturated(model: ModelId, preproc: PreprocMode) -> (SimConfig, SimOutcome) {
    let mut cfg = SimConfig::new(model, MigConfig::Small7, preproc);
    cfg.requests = 3000;
    cfg.rate_qps = cfg.saturating_rate();
    let out = sim_driver::run(&cfg, &PrebaConfig::new());
    (cfg, out)
}

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-9)
}

#[test]
fn energy_total_is_the_component_sum() {
    for preproc in [PreprocMode::Ideal, PreprocMode::Cpu, PreprocMode::Dpu] {
        let (_, out) = saturated(ModelId::CitriNet, preproc);
        let e = &out.stats.energy;
        let sum = e.gpu_active_j + e.gpu_idle_j + e.cpu_j + e.dpu_j + e.base_j;
        assert_eq!(sum, e.total_j(), "{preproc:?}");
        assert!(e.total_j() > 0.0);
    }
}

#[test]
fn energy_matches_the_power_integral_over_the_horizon() {
    // Recompute each component's ∫power·dt from the run's OWN reported
    // utilizations and the config constants; the integrated breakdown
    // must agree to float precision (no reconfiguration: the capacity
    // integral reduces to n_vgpus × horizon exactly).
    let sys = PrebaConfig::new();
    let e = &sys.energy;
    let (cfg, out) = saturated(ModelId::SwinTransformer, PreprocMode::Ideal);
    let h_s = to_secs(out.horizon);
    let busy_gpc_s =
        out.gpu_util * cfg.active_servers as f64 * h_s * cfg.mig.gpcs_per_vgpu() as f64;
    let expect_active = e.gpc_active_w * busy_gpc_s;
    let expect_idle =
        e.gpc_idle_w * (sys.hardware.gpcs as f64 * h_s - busy_gpc_s) + e.uncore_w * h_s;
    assert!(
        rel_close(out.stats.energy.gpu_active_j, expect_active, 1e-6),
        "active {} vs ∫ {}",
        out.stats.energy.gpu_active_j,
        expect_active
    );
    assert!(
        rel_close(out.stats.energy.gpu_idle_j, expect_idle, 1e-6),
        "idle {} vs ∫ {}",
        out.stats.energy.gpu_idle_j,
        expect_idle
    );
    // Ideal preprocessing: only the serving reserve is active.
    let reserved = sys.hardware.cpu_reserved_cores as f64;
    let idle_cores = (sys.hardware.cpu_cores as f64 - reserved) * h_s;
    let expect_cpu = e.cpu_core_active_w * reserved * h_s + e.cpu_core_idle_w * idle_cores;
    assert!(rel_close(out.stats.energy.cpu_j, expect_cpu, 1e-6));
    assert_eq!(out.stats.energy.dpu_j, 0.0);
    assert!(rel_close(out.stats.energy.base_j, e.host_base_w * h_s, 1e-9));

    // DPU mode: the FPGA integral follows its reported utilization.
    let (_, out) = saturated(ModelId::CitriNet, PreprocMode::Dpu);
    let h_s = to_secs(out.horizon);
    let u = out.dpu_util.expect("dpu installed");
    let expect_dpu = (e.dpu_idle_w + (e.dpu_active_w - e.dpu_idle_w) * u) * h_s;
    assert!(
        rel_close(out.stats.energy.dpu_j, expect_dpu, 1e-6),
        "dpu {} vs ∫ {}",
        out.stats.energy.dpu_j,
        expect_dpu
    );
}

#[test]
fn consolidation_never_increases_energy_at_equal_served_count() {
    // The shipped overnight scenario, with and without consolidation:
    // same arrivals, same completions, strictly less energy once a GPU
    // powers down — and off-time only ever shortens the idle integral.
    let sys = PrebaConfig::new();
    let horizon_s = 6.0;
    let base =
        cluster::run(&preba::experiments::energy::idle_fleet_cfg(false, horizon_s, &sys), &sys)
            .unwrap();
    let consol =
        cluster::run(&preba::experiments::energy::idle_fleet_cfg(true, horizon_s, &sys), &sys)
            .unwrap();
    assert_eq!(base.consolidations, 0);
    assert_eq!(base.gpu_off_s, 0.0);
    assert!(consol.consolidations >= 1, "low load never consolidated");
    assert!(consol.gpu_off_s > 0.0);
    assert_eq!(
        base.completed_total(),
        consol.completed_total(),
        "consolidation changed the served count"
    );
    assert!(
        consol.energy.total_j() < base.energy.total_j(),
        "consolidation increased energy: {} vs {}",
        consol.energy.total_j(),
        base.energy.total_j()
    );
    // Idle-power elision only: the active-GPC work is conserved (up to
    // batch-formation differences after the relocation's policy rebuild).
    assert!(
        rel_close(consol.energy.gpu_active_j, base.energy.gpu_active_j, 0.15),
        "active work drifted: {} vs {}",
        consol.energy.gpu_active_j,
        base.energy.gpu_active_j
    );
}

fn run_energy(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args(["experiment", "energy", "--jobs", jobs, "--out", out_dir.to_str().unwrap()])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment energy --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_energy_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_energy_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_energy("1", &dir1);
    let stdout4 = run_energy("4", &dir4);
    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );
    let json1 = std::fs::read(dir1.join("energy.json")).expect("energy.json at jobs=1");
    let json4 = std::fs::read(dir4.join("energy.json")).expect("energy.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}
