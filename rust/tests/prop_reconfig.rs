//! Property tests for the online reconfiguration controller: whatever the
//! traffic does, the controller must never thrash (no two committed
//! reconfigurations within the cooldown window), must stay put under
//! steady symmetric load, and must always emit well-formed plans.

use preba::clock::{secs, to_secs, Nanos};
use preba::mig::{MigConfig, Plan, ReconfigController, ReconfigPolicy, TenantSpec};
use preba::models::ModelId;
use preba::util::Rng;

fn tenants(n: usize) -> Vec<TenantSpec> {
    (0..n).map(|_| TenantSpec::new(ModelId::SwinTransformer, 25.0)).collect()
}

fn initial(n: usize) -> Plan {
    // Fair split of the 7 slices.
    let alloc: Vec<usize> = (0..n).map(|i| 7 / n + usize::from(i < 7 % n)).collect();
    Plan { mig: MigConfig::Small7, alloc }
}

/// Drive a controller with per-window arrival counts drawn from `rates`
/// (queries/s per tenant per window) and return the committed events'
/// timestamps.
fn drive(ctrl: &mut ReconfigController, rates: &[Vec<f64>]) -> Vec<Nanos> {
    let window = ctrl.window();
    let mut out = Vec::new();
    let mut now: Nanos = 0;
    for per_tenant in rates {
        now += window;
        for (t, &r) in per_tenant.iter().enumerate() {
            let arrivals = (r * to_secs(window)) as usize;
            for _ in 0..arrivals {
                ctrl.observe_arrival(t);
            }
        }
        if ctrl.tick(now).is_some() {
            out.push(now);
        }
    }
    out
}

#[test]
fn hysteresis_never_thrashes_under_random_rates() {
    // 30 random traffic tapes: whatever happens, two reconfigurations are
    // never closer than the cooldown.
    for seed in 0..30u64 {
        let mut rng = Rng::new(0x4E5E ^ seed);
        let n = 2 + (rng.f64() * 2.0) as usize; // 2..=3 tenants
        let policy = ReconfigPolicy::default();
        let cooldown = secs(policy.cooldown_s);
        let mut ctrl = ReconfigController::new(tenants(n), initial(n), policy);
        let tape: Vec<Vec<f64>> = (0..80)
            .map(|_| (0..n).map(|_| rng.f64() * 2200.0).collect())
            .collect();
        let events = drive(&mut ctrl, &tape);
        for pair in events.windows(2) {
            assert!(
                pair[1] - pair[0] >= cooldown,
                "seed {seed}: reconfigs {} ns apart (cooldown {})",
                pair[1] - pair[0],
                cooldown
            );
        }
        // The controller's own event log agrees.
        assert_eq!(ctrl.events().len(), events.len());
    }
}

#[test]
fn steady_symmetric_load_commits_nothing() {
    let policy = ReconfigPolicy::default();
    let mut ctrl = ReconfigController::new(tenants(2), initial(2), policy);
    let tape: Vec<Vec<f64>> = (0..60).map(|_| vec![400.0, 400.0]).collect();
    let events = drive(&mut ctrl, &tape);
    assert!(events.is_empty(), "thrash on steady load: {events:?}");
}

#[test]
fn plans_are_always_well_formed() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(0xF00D ^ seed);
        let n = 2 + (rng.f64() * 2.0) as usize;
        let mut ctrl =
            ReconfigController::new(tenants(n), initial(n), ReconfigPolicy::default());
        let tape: Vec<Vec<f64>> = (0..60)
            .map(|_| (0..n).map(|_| rng.f64() * 2500.0).collect())
            .collect();
        drive(&mut ctrl, &tape);
        for ev in ctrl.events() {
            assert_eq!(ev.plan.alloc.len(), n, "seed {seed}");
            assert!(ev.plan.alloc.iter().all(|&a| a >= 1), "seed {seed}: {:?}", ev.plan);
            assert_eq!(
                ev.plan.slices(),
                ev.plan.mig.vgpus(),
                "seed {seed}: plan must hand out every slice"
            );
            assert!(ev.predicted_gain_ms > 0.0, "seed {seed}");
        }
    }
}

#[test]
fn controller_is_deterministic() {
    let mk_events = || {
        let mut rng = Rng::new(0xD0);
        let mut ctrl =
            ReconfigController::new(tenants(2), initial(2), ReconfigPolicy::default());
        let tape: Vec<Vec<f64>> =
            (0..50).map(|_| vec![rng.f64() * 2000.0, rng.f64() * 2000.0]).collect();
        drive(&mut ctrl, &tape);
        ctrl.events()
            .iter()
            .map(|e| (e.at, e.plan.clone()))
            .collect::<Vec<_>>()
    };
    assert_eq!(mk_events(), mk_events());
}
