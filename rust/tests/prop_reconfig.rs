//! Property tests for the online reconfiguration controller: whatever the
//! traffic does, the controller must never thrash (no two committed
//! reconfigurations within the cooldown window), must stay put under
//! steady symmetric load, and must always emit well-formed plans. The
//! cluster controller's no-thrash contract is planner-independent — the
//! hysteresis/cooldown/cost gates sit outside the [`Planner`] seam — so
//! it is asserted for every [`PlannerKind`], including across mid-run
//! planner swaps.

use preba::clock::{secs, to_secs, Nanos};
use preba::mig::{
    validate_plan, ClusterReconfigController, MigConfig, Plan, PlannerKind,
    ReconfigController, ReconfigPolicy, Slice, TenantSpec,
};
use preba::models::ModelId;
use preba::util::Rng;

fn tenants(n: usize) -> Vec<TenantSpec> {
    (0..n).map(|_| TenantSpec::new(ModelId::SwinTransformer, 25.0)).collect()
}

fn initial(n: usize) -> Plan {
    // Fair split of the 7 slices.
    let alloc: Vec<usize> = (0..n).map(|i| 7 / n + usize::from(i < 7 % n)).collect();
    Plan { mig: MigConfig::Small7, alloc }
}

/// Drive a controller with per-window arrival counts drawn from `rates`
/// (queries/s per tenant per window) and return the committed events'
/// timestamps.
fn drive(ctrl: &mut ReconfigController, rates: &[Vec<f64>]) -> Vec<Nanos> {
    let window = ctrl.window();
    let mut out = Vec::new();
    let mut now: Nanos = 0;
    for per_tenant in rates {
        now += window;
        for (t, &r) in per_tenant.iter().enumerate() {
            let arrivals = (r * to_secs(window)) as usize;
            for _ in 0..arrivals {
                ctrl.observe_arrival(t);
            }
        }
        if ctrl.tick(now).is_some() {
            out.push(now);
        }
    }
    out
}

#[test]
fn hysteresis_never_thrashes_under_random_rates() {
    // 30 random traffic tapes: whatever happens, two reconfigurations are
    // never closer than the cooldown.
    for seed in 0..30u64 {
        let mut rng = Rng::new(0x4E5E ^ seed);
        let n = 2 + (rng.f64() * 2.0) as usize; // 2..=3 tenants
        let policy = ReconfigPolicy::default();
        let cooldown = secs(policy.cooldown_s);
        let mut ctrl = ReconfigController::new(tenants(n), initial(n), policy);
        let tape: Vec<Vec<f64>> = (0..80)
            .map(|_| (0..n).map(|_| rng.f64() * 2200.0).collect())
            .collect();
        let events = drive(&mut ctrl, &tape);
        for pair in events.windows(2) {
            assert!(
                pair[1] - pair[0] >= cooldown,
                "seed {seed}: reconfigs {} ns apart (cooldown {})",
                pair[1] - pair[0],
                cooldown
            );
        }
        // The controller's own event log agrees.
        assert_eq!(ctrl.events().len(), events.len());
    }
}

#[test]
fn steady_symmetric_load_commits_nothing() {
    let policy = ReconfigPolicy::default();
    let mut ctrl = ReconfigController::new(tenants(2), initial(2), policy);
    let tape: Vec<Vec<f64>> = (0..60).map(|_| vec![400.0, 400.0]).collect();
    let events = drive(&mut ctrl, &tape);
    assert!(events.is_empty(), "thrash on steady load: {events:?}");
}

#[test]
fn plans_are_always_well_formed() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(0xF00D ^ seed);
        let n = 2 + (rng.f64() * 2.0) as usize;
        let mut ctrl =
            ReconfigController::new(tenants(n), initial(n), ReconfigPolicy::default());
        let tape: Vec<Vec<f64>> = (0..60)
            .map(|_| (0..n).map(|_| rng.f64() * 2500.0).collect())
            .collect();
        drive(&mut ctrl, &tape);
        for ev in ctrl.events() {
            assert_eq!(ev.plan.alloc.len(), n, "seed {seed}");
            assert!(ev.plan.alloc.iter().all(|&a| a >= 1), "seed {seed}: {:?}", ev.plan);
            assert_eq!(
                ev.plan.slices(),
                ev.plan.mig.vgpus(),
                "seed {seed}: plan must hand out every slice"
            );
            assert!(ev.predicted_gain_ms > 0.0, "seed {seed}");
        }
    }
}

/// Random cluster start state: 2-3 tenants on 1g/2g profiles over 2-3
/// A100s, filled greedily.
fn cluster_state(rng: &mut Rng) -> (Vec<TenantSpec>, Vec<Slice>, Vec<Vec<usize>>) {
    let n_tenants = 2 + rng.below(2) as usize;
    let n_gpus = 2 + rng.below(2) as usize;
    let profiles = [Slice::new(1, 5), Slice::new(2, 10)];
    let slices: Vec<Slice> =
        (0..n_tenants).map(|_| profiles[rng.below(2) as usize]).collect();
    let mut alloc = vec![vec![0usize; n_tenants]; n_gpus];
    for row in alloc.iter_mut() {
        let mut gpcs = 0usize;
        let mut mem = 0usize;
        for _ in 0..6 {
            let t = rng.below(n_tenants as u64) as usize;
            if gpcs + slices[t].gpcs <= 7 && mem + slices[t].mem_gb <= 40 {
                row[t] += 1;
                gpcs += slices[t].gpcs;
                mem += slices[t].mem_gb;
            }
        }
    }
    (tenants(n_tenants), slices, alloc)
}

/// Drive a cluster controller with per-window arrival counts and return
/// the committed events' timestamps.
fn drive_cluster(ctrl: &mut ClusterReconfigController, tape: &[Vec<f64>]) -> Vec<Nanos> {
    let window = ctrl.window();
    let mut out = Vec::new();
    let mut now: Nanos = 0;
    for per_tenant in tape {
        now += window;
        for (t, &r) in per_tenant.iter().enumerate() {
            let arrivals = (r * to_secs(window)) as usize;
            for _ in 0..arrivals {
                ctrl.observe_arrival(t);
            }
        }
        if ctrl.tick(now).is_some() {
            out.push(now);
        }
    }
    out
}

/// The no-thrash contract survives any choice of planning algorithm:
/// whatever the traffic does, committed rebalances stay at least one
/// cooldown apart under greedy, anneal AND exact planning, and the
/// final allocation mirror replays through the shared validity checker.
#[test]
fn cluster_no_thrash_holds_for_every_planner() {
    for kind in PlannerKind::ALL {
        for seed in 0..8u64 {
            let mut rng = Rng::new(0xC1D0 ^ seed);
            let (t, slices, alloc) = cluster_state(&mut rng);
            let policy =
                ReconfigPolicy { planner: kind, anneal_iters: 300, ..Default::default() };
            let cooldown = secs(policy.cooldown_s);
            let n = t.len();
            let mut ctrl = ClusterReconfigController::new(t, slices.clone(), alloc, policy);
            let tape: Vec<Vec<f64>> = (0..60)
                .map(|_| (0..n).map(|_| rng.f64() * 2200.0).collect())
                .collect();
            let events = drive_cluster(&mut ctrl, &tape);
            for pair in events.windows(2) {
                assert!(
                    pair[1] - pair[0] >= cooldown,
                    "{}: seed {seed}: reconfigs {} ns apart (cooldown {cooldown})",
                    kind.label(),
                    pair[1] - pair[0]
                );
            }
            assert_eq!(ctrl.events().len(), events.len());
            let failed = vec![false; ctrl.fleet().len()];
            if let Err(e) = validate_plan(&slices, ctrl.fleet(), &failed, ctrl.alloc(), &[]) {
                panic!("{}: seed {seed}: end state invalid: {e}", kind.label());
            }
        }
    }
}

/// Swapping the planning algorithm mid-run never violates the cooldown:
/// `set_planner` changes only the solver, so telemetry and cooldown
/// state carry straight across the swap, and the allocation mirror
/// stays valid throughout.
#[test]
fn mid_run_planner_swaps_never_violate_cooldown() {
    let rotation = [PlannerKind::Greedy, PlannerKind::Anneal, PlannerKind::Exact];
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x5A4B ^ seed);
        let (t, slices, alloc) = cluster_state(&mut rng);
        let policy = ReconfigPolicy { anneal_iters: 300, ..Default::default() };
        let cooldown = secs(policy.cooldown_s);
        let n = t.len();
        let mut ctrl = ClusterReconfigController::new(t, slices.clone(), alloc, policy);
        let window = ctrl.window();
        let failed = vec![false; ctrl.fleet().len()];
        let mut now: Nanos = 0;
        let mut events = Vec::new();
        for w in 0..90 {
            // Rotate through all three solvers, swapping mid-flight.
            ctrl.set_planner(rotation[w / 30]);
            now += window;
            for ti in 0..n {
                let arrivals = (rng.f64() * 2200.0 * to_secs(window)) as usize;
                for _ in 0..arrivals {
                    ctrl.observe_arrival(ti);
                }
            }
            if ctrl.tick(now).is_some() {
                events.push(now);
                // Every committed state is valid, not just the last one.
                validate_plan(&slices, ctrl.fleet(), &failed, ctrl.alloc(), &[])
                    .unwrap_or_else(|e| panic!("seed {seed}: invalid after swap: {e}"));
            }
        }
        for pair in events.windows(2) {
            assert!(
                pair[1] - pair[0] >= cooldown,
                "seed {seed}: planner swap broke the cooldown ({} ns apart)",
                pair[1] - pair[0]
            );
        }
    }
}

#[test]
fn controller_is_deterministic() {
    let mk_events = || {
        let mut rng = Rng::new(0xD0);
        let mut ctrl =
            ReconfigController::new(tenants(2), initial(2), ReconfigPolicy::default());
        let tape: Vec<Vec<f64>> =
            (0..50).map(|_| vec![rng.f64() * 2000.0, rng.f64() * 2000.0]).collect();
        drive(&mut ctrl, &tape);
        ctrl.events()
            .iter()
            .map(|e| (e.at, e.plan.clone()))
            .collect::<Vec<_>>()
    };
    assert_eq!(mk_events(), mk_events());
}
