//! Property tests: DES core ordering, CPU pool conservation, DPU
//! monotonicity, service-model structure.

use preba::clock::secs;
use preba::config::{DpuConfig, HardwareConfig};
use preba::dpu::Dpu;
use preba::mig::ServiceModel;
use preba::models::ModelId;
use preba::preprocess::CpuPool;
use preba::prop_assert;
use preba::sim::EventQueue;
use preba::util::prop;
use preba::util::Rng;

#[test]
fn event_queue_pops_in_time_order_fifo_ties() {
    prop::check("event-order", prop::default_cases(), |rng| {
        let mut q: EventQueue<u64> = EventQueue::new();
        let n = 1 + rng.below(500);
        for i in 0..n {
            q.schedule(rng.below(1000), i);
        }
        let mut prev_t = 0;
        let mut seen = 0;
        let mut seq_at_t: std::collections::HashMap<u64, u64> = Default::default();
        while let Some((t, id)) = q.pop() {
            prop_assert!(t >= prev_t, "time went backwards");
            if t == prev_t {
                // FIFO among ties: ids scheduled earlier pop first only
                // when times are equal AND they were inserted earlier.
                if let Some(&prev_id) = seq_at_t.get(&t) {
                    prop_assert!(id > prev_id, "tie not FIFO: {} after {}", id, prev_id);
                }
            }
            seq_at_t.insert(t, id);
            prev_t = t;
            seen += 1;
        }
        prop_assert!(seen == n);
        Ok(())
    });
}

#[test]
fn four_ary_heap_matches_binary_heap_reference() {
    // Pin the 4-ary indexed heap's pop order against a std::BinaryHeap
    // min-ordered reference over random interleaved schedule/pop traces,
    // including past-time scheduling (which clamps to `now`).
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    prop::check("heap-vs-reference", prop::default_cases(), |rng| {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut reference: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
        let mut seq = 0u64;
        let ops = 1 + rng.below(600);
        for _ in 0..ops {
            if reference.is_empty() || rng.f64() < 0.6 {
                // Mix future times with occasional in-the-past times.
                let t = if rng.f64() < 0.15 {
                    rng.below(q.now() + 1)
                } else {
                    q.now() + rng.below(2000)
                };
                seq += 1;
                q.schedule(t, seq);
                reference.push(Reverse((t.max(q.now()), seq)));
            } else {
                let (t, id) = q.pop().expect("queue and reference agree on emptiness");
                let Reverse((rt, rid)) = reference.pop().unwrap();
                prop_assert!(
                    t == rt && id == rid,
                    "pop mismatch: got ({t}, {id}), reference ({rt}, {rid})"
                );
            }
            prop_assert!(q.len() == reference.len());
        }
        while let Some((t, id)) = q.pop() {
            let Reverse((rt, rid)) = reference.pop().unwrap();
            prop_assert!(
                t == rt && id == rid,
                "drain mismatch: got ({t}, {id}), reference ({rt}, {rid})"
            );
        }
        prop_assert!(reference.is_empty());
        Ok(())
    });
}

#[test]
fn cpu_pool_conserves_and_orders_jobs() {
    prop::check("cpu-pool", prop::default_cases(), |rng| {
        let cores = 1 + rng.below(8) as usize;
        let mut pool = CpuPool::new(cores, rng.split(9));
        let n = 1 + rng.below(200);
        let mut now = 0u64;
        let mut dones = Vec::new();
        for _ in 0..n {
            now += rng.below(secs(0.01));
            let (start, done) = pool.admit(now, 0.001 + rng.f64() * 0.02);
            prop_assert!(start >= now, "job started before arrival");
            prop_assert!(done > start, "zero-length job");
            dones.push(done);
        }
        prop_assert!(pool.served == n);
        // Utilization bounded.
        let horizon = *dones.iter().max().unwrap();
        let u = pool.utilization(horizon);
        prop_assert!((0.0..=1.0).contains(&u), "util {u}");
        Ok(())
    });
}

#[test]
fn dpu_completions_monotone_per_stream_and_capacity_bounded() {
    prop::check("dpu-monotone", 64, |rng| {
        let mut cfg = DpuConfig::default();
        cfg.split_audio_cu = rng.f64() < 0.5;
        let mut dpu = Dpu::new(&cfg, &HardwareConfig::default());
        let n = 1 + rng.below(100);
        let mut now = 0u64;
        let mut prev_done = 0u64;
        for _ in 0..n {
            now += rng.below(secs(0.001));
            let model = if rng.f64() < 0.5 { ModelId::MobileNet } else { ModelId::CitriNet };
            let len = 0.1 + rng.f64() * 10.0;
            let done = dpu.admit(now, model, len);
            prop_assert!(done > now, "completion before admit");
            // Same-arrival-order completions per model kind are monotone
            // for the image CU path (FIFO earliest-free).
            if model == ModelId::MobileNet {
                prop_assert!(done >= prev_done || done + secs(0.001) >= prev_done);
                prev_done = done.max(prev_done);
            }
        }
        prop_assert!(dpu.served == n);
        Ok(())
    });
}

#[test]
fn service_model_structure() {
    prop::check("service-model", prop::default_cases(), |rng| {
        let model = ModelId::ALL[rng.below(6) as usize];
        let g = 1 + rng.below(7) as usize;
        let sm = ServiceModel::new(model.spec(), g);
        let len = 1.0 + rng.f64() * 24.0;
        let b1 = 1 + rng.below(128) as usize;
        let b2 = b1 + 1 + rng.below(64) as usize;
        // Latency strictly increases with batch; throughput never drops.
        prop_assert!(sm.exec_secs(b2, len) > sm.exec_secs(b1, len));
        prop_assert!(sm.qps_at(b2, len) >= sm.qps_at(b1, len) * 0.999);
        // Throughput bounded by plateau.
        prop_assert!(sm.qps_at(b2, len) <= sm.plateau_qps(len) * 1.0001);
        // Utilization in (0, 1].
        let u = sm.utilization(b1, len);
        prop_assert!(u > 0.0 && u <= 1.0001, "util {u}");
        Ok(())
    });
}

#[test]
fn sim_driver_conservation_across_random_configs() {
    use preba::config::PrebaConfig;
    use preba::mig::MigConfig;
    use preba::server::{sim_driver, PolicyKind, PreprocMode, SimConfig};
    prop::check("sim-conservation", 24, |rng| {
        let model = ModelId::ALL[rng.below(6) as usize];
        let mig = MigConfig::ALL[rng.below(3) as usize];
        let preproc =
            [PreprocMode::Ideal, PreprocMode::Cpu, PreprocMode::Dpu][rng.below(3) as usize];
        let mut cfg = SimConfig::new(model, mig, preproc);
        cfg.policy = if rng.f64() < 0.5 { PolicyKind::Static } else { PolicyKind::Dynamic };
        cfg.active_servers = 1 + rng.below(mig.vgpus() as u64) as usize;
        cfg.requests = 300 + rng.below(500) as usize;
        cfg.warmup_frac = 0.0;
        cfg.seed = rng.next_u64();
        cfg.rate_qps = cfg.saturating_rate() * (0.2 + rng.f64());
        let out = sim_driver::run(&cfg, &PrebaConfig::new());
        prop_assert!(
            out.stats.completed == cfg.requests as u64,
            "{} of {} completed",
            out.stats.completed,
            cfg.requests
        );
        prop_assert!(out.qps() > 0.0);
        prop_assert!(out.gpu_util <= 1.0);
        Ok(())
    });
}
