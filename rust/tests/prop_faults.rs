//! Properties of the fault subsystem (`fault::*` plus the cluster DES
//! wiring): request conservation under arbitrary seeded fault schedules
//! (every offered request ends in exactly one terminal bucket), the
//! failed-group dispatch gate, recovery never losing the directed
//! failover A/B to the blind baseline at any arrival seed, bitwise
//! determinism of the `faults` experiment across `--jobs` counts, and
//! `preba cluster --faults` CLI smoke.

use std::process::Command;

use preba::clock::to_secs;
use preba::config::PrebaConfig;
use preba::experiments::faults::failover_cfg;
use preba::fault::{FaultSchedule, FaultSpec};
use preba::mig::{PackStrategy, ServiceModel, Slice};
use preba::models::ModelId;
use preba::prop_assert;
use preba::server::cluster::{self, ClusterConfig, ClusterTenant};
use preba::util::prop::check;
use preba::util::Rng;

/// A small random fleet under a random seeded fault schedule (crashes,
/// slice failures, stragglers, preprocessing outages). Warmup 0:
/// conservation must hold over EVERY arrival, not just a trimmed tail.
fn random_faulted_cfg(rng: &mut Rng, sys: &PrebaConfig) -> ClusterConfig {
    let horizon_s = 2.0 + rng.f64() * 2.0;
    let n_gpus = 2 + rng.below(2) as usize;
    let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
    let tenants: Vec<ClusterTenant> = (0..2)
        .map(|_| {
            let slices = 2 + rng.below(3) as usize;
            let rate = rng.range_f64(0.25, 0.55) * slices as f64 * u;
            let mut t =
                ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), slices, rate);
            t.sla_ms = 50.0;
            t.requests = ((rate * horizon_s).ceil() as usize).max(40);
            t
        })
        .collect();
    let mut cfg = ClusterConfig::builder()
        .gpus(n_gpus)
        .strategy(PackStrategy::BestFit)
        .tenants(tenants)
        .seed(rng.next_u64())
        .warmup_frac(0.0)
        .reconfig(preba::experiments::cluster::policy(sys))
        .admission(rng.below(2) == 0)
        .build();
    let mtbf = rng.range_f64(0.6, 2.5);
    let mttr = rng.range_f64(0.2, 0.8);
    let mut srng = rng.split(0xFA17);
    let sched = FaultSchedule::stochastic(mtbf, mttr, horizon_s, n_gpus, &mut srng);
    cfg.faults = Some(if rng.below(2) == 0 {
        FaultSpec::recovering(sched, sys.fault.recovery())
    } else {
        FaultSpec::baseline(sched)
    });
    cfg
}

#[test]
fn every_request_ends_in_exactly_one_terminal_bucket() {
    let sys = PrebaConfig::new();
    check("fault conservation", 48, |rng| {
        let cfg = random_faulted_cfg(rng, &sys);
        let out = cluster::run(&cfg, &sys).expect("valid faulted config");
        for (i, t) in cfg.tenants.iter().enumerate() {
            let (_, stats) = &out.per_tenant[i];
            let total = stats.completed + out.dropped[i] + out.timed_out[i];
            prop_assert!(
                total == t.requests as u64,
                "tenant {i}: {} completed + {} dropped + {} timed out != {} offered",
                stats.completed,
                out.dropped[i],
                out.timed_out[i],
                t.requests
            );
        }
        // The dispatch gate, not the recovery stack, owns this: nothing
        // ever completes on a failed group.
        prop_assert!(
            out.served_by_failed == 0,
            "served {} requests on failed groups",
            out.served_by_failed
        );
        let avail = out.availability_frac();
        prop_assert!((0.0..=1.0).contains(&avail), "availability {avail} out of range");
        Ok(())
    });
}

#[test]
fn faulted_runs_are_deterministic() {
    let sys = PrebaConfig::new();
    check("fault run determinism", 8, |rng| {
        let cfg = random_faulted_cfg(rng, &sys);
        let a = cluster::run(&cfg, &sys).expect("valid faulted config");
        let b = cluster::run(&cfg, &sys).expect("valid faulted config");
        prop_assert!(
            a.completed_total() == b.completed_total()
                && a.timed_out == b.timed_out
                && a.dropped == b.dropped
                && a.retries == b.retries
                && a.hedges == b.hedges
                && a.events == b.events,
            "identical faulted config diverged between runs"
        );
        Ok(())
    });
}

/// The directed failover scenario (GPU crash, never repaired) must never
/// come out WORSE with recovery than without, whatever the arrival seed:
/// the experiment asserts a strict win at its shipped seed, this guards
/// the weaker ordering everywhere else.
#[test]
fn recovery_never_loses_the_failover_ab_at_any_arrival_seed() {
    let sys = PrebaConfig::new();
    check("failover recovery >= baseline", 6, |rng| {
        let seed = rng.next_u64();
        let horizon_s = 5.0;
        let mut base_cfg = failover_cfg(false, horizon_s, &sys);
        let mut rec_cfg = failover_cfg(true, horizon_s, &sys);
        base_cfg.seed = seed;
        rec_cfg.seed = seed;
        let base = cluster::run(&base_cfg, &sys).expect("valid baseline config");
        let rec = cluster::run(&rec_cfg, &sys).expect("valid recovery config");
        prop_assert!(
            rec.availability_frac() >= base.availability_frac(),
            "recovery availability {} < baseline {} at seed {seed:#x}",
            rec.availability_frac(),
            base.availability_frac()
        );
        prop_assert!(
            rec.completed_total() >= base.completed_total(),
            "recovery served {} < baseline {} at seed {seed:#x}",
            rec.completed_total(),
            base.completed_total()
        );
        Ok(())
    });
}

/// A straggler does the SAME work for longer, so a sustained slowdown
/// must strictly inflate the fleet's active-energy integral relative to
/// the fault-free twin at identical load and seed: the DES bills the
/// inflated execution intervals, not the nominal service times.
#[test]
fn slowdown_strictly_inflates_the_active_energy_integral() {
    let sys = PrebaConfig::new();
    check("slowdown energy inflation", 6, |rng| {
        let seed = rng.next_u64();
        let horizon_s = 3.0;
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
        let mk = |faults: Option<FaultSpec>| {
            let rate = 0.5 * 4.0 * u;
            let mut t =
                ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 4, rate);
            t.sla_ms = 500.0;
            t.requests = (rate * horizon_s).ceil() as usize;
            let mut cfg = ClusterConfig::builder()
                .gpus(2)
                .strategy(PackStrategy::BestFit)
                .tenants(vec![t])
                .seed(seed)
                .warmup_frac(0.0)
                .build();
            cfg.faults = faults;
            cfg
        };
        let sched = FaultSchedule::parse("slow@0.2:g0:inf:3.0", 2, horizon_s, seed)
            .expect("parse slowdown spec");
        let clean = cluster::run(&mk(None), &sys).expect("valid clean config");
        let slow = cluster::run(&mk(Some(FaultSpec::baseline(sched))), &sys)
            .expect("valid slowdown config");
        prop_assert!(
            slow.energy.gpu_active_j > clean.energy.gpu_active_j,
            "3x slowdown did not inflate active energy: {} vs {} J at seed {seed:#x}",
            slow.energy.gpu_active_j,
            clean.energy.gpu_active_j
        );
        Ok(())
    });
}

/// Whatever crashes, harvests and retries do to the busy-time integrals,
/// active energy can never exceed the physical ceiling of every GPC on
/// every GPU drawing full active power for the entire horizon. The
/// crash-harvest refund is what keeps the integral under this bound —
/// an in-flight batch killed by a crash must not bill its unexecuted
/// remainder — so this is the conservation property guarding that path.
#[test]
fn active_energy_never_exceeds_the_physical_ceiling() {
    let sys = PrebaConfig::new();
    check("energy physical ceiling", 24, |rng| {
        let cfg = random_faulted_cfg(rng, &sys);
        let out = cluster::run(&cfg, &sys).expect("valid faulted config");
        let horizon_s = to_secs(out.horizon);
        let gpc_s: f64 = cfg.fleet.iter().map(|c| c.gpcs as f64 * horizon_s).sum();
        let ceiling = sys.energy.gpc_active_w * gpc_s;
        prop_assert!(
            out.energy.gpu_active_j <= ceiling * (1.0 + 1e-9),
            "active energy {} J exceeds the {} J all-GPCs-always-on ceiling",
            out.energy.gpu_active_j,
            ceiling
        );
        let e = &out.energy;
        let sum = e.gpu_active_j + e.gpu_idle_j + e.cpu_j + e.dpu_j + e.base_j;
        prop_assert!(
            sum == e.total_j() && sum.is_finite() && e.gpu_active_j >= 0.0 && e.gpu_idle_j >= 0.0,
            "energy breakdown is not a finite non-negative component sum: {e:?}"
        );
        Ok(())
    });
}

fn run_faults_experiment(jobs: &str, out_dir: &std::path::Path) -> Vec<u8> {
    let _ = std::fs::remove_dir_all(out_dir);
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .env("PREBA_FAST", "1")
        .args(["experiment", "faults", "--jobs", jobs, "--out", out_dir.to_str().unwrap()])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba experiment faults --jobs {jobs} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    out.stdout
}

#[test]
fn experiment_faults_identical_at_jobs_1_and_4() {
    let base = std::env::temp_dir().join("preba_faults_determinism");
    let dir1 = base.join("j1");
    let dir4 = base.join("j4");
    let stdout1 = run_faults_experiment("1", &dir1);
    let stdout4 = run_faults_experiment("4", &dir4);
    assert_eq!(
        String::from_utf8_lossy(&stdout1).replace(dir1.to_str().unwrap(), "<out>"),
        String::from_utf8_lossy(&stdout4).replace(dir4.to_str().unwrap(), "<out>"),
        "stdout differs between --jobs 1 and --jobs 4"
    );
    let json1 = std::fs::read(dir1.join("faults.json")).expect("faults.json at jobs=1");
    let json4 = std::fs::read(dir4.join("faults.json")).expect("faults.json at jobs=4");
    assert!(!json1.is_empty());
    assert_eq!(json1, json4, "results JSON differs between --jobs 1 and --jobs 4");
}

#[test]
fn cluster_cli_faults_smoke() {
    // --faults runs each packing twice (baseline vs recovery) and adds
    // the availability columns plus a fault timeline.
    let out = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args([
            "cluster", "--gpus", "2", "--horizon", "2", "--strategy", "bfd", "--reconfig",
            "--faults", "crash@0.5:g0:0.5,slow@1.0:g1:0.5:2.5",
        ])
        .output()
        .expect("spawn preba");
    assert!(
        out.status.success(),
        "preba cluster --faults failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("2 injected faults"), "{text}");
    assert!(text.contains("avail %"), "{text}");
    assert!(text.contains("best-fit/baseline"), "{text}");
    assert!(text.contains("best-fit/recovery"), "{text}");
    assert!(text.contains("crash on gpu0"), "{text}");
    // A malformed spec is a clean CLI error, not a panic.
    let bad = Command::new(env!("CARGO_BIN_EXE_preba"))
        .args(["cluster", "--gpus", "2", "--horizon", "1", "--faults", "melt@1:g0"])
        .output()
        .expect("spawn preba");
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("unknown fault kind"));
}
