//! Image serving: the paper's computer-vision scenario on the real stack.
//!
//! Serves SqueezeNet with BOTH preprocessing paths — the host-Rust CPU
//! baseline (OpenCV-equivalent) and the Pallas-kernel DPU path — and
//! compares their per-stage latency, demonstrating exactly the bottleneck
//! Fig 8/19 describe (here both run on one CPU core, so the comparison is
//! per-request preprocessing cost, not aggregate throughput).
//!
//! Run: `cargo run --release --example image_serving`

use preba::prelude::*;
use preba::runtime::Engine;
use preba::server::real_driver::{serve, RealConfig, RealPreproc};

fn main() -> anyhow::Result<()> {
    let sys = PrebaConfig::new();
    let mut engine = Engine::new(&sys.artifacts_dir)?;

    for (label, preproc) in [
        ("CPU baseline (host Rust ops)", RealPreproc::HostRust),
        ("PREBA DPU (Pallas kernel on PJRT)", RealPreproc::DpuPallas),
    ] {
        let mut cfg = RealConfig::new(ModelId::SqueezeNet, preproc);
        cfg.requests = 60;
        cfg.rate_qps = 40.0;
        cfg.seed = 11;
        let out = serve(&cfg, &sys, &mut engine)?;
        let (pre, bat, _disp, exec) = out.stats.breakdown_ms();
        println!("\n== {label} ==");
        println!(
            "  {} reqs | {:.1} QPS | p95 {:.2} ms | preproc {:.2} ms | batch {:.2} ms | exec {:.2} ms",
            out.stats.completed,
            out.stats.throughput_qps(),
            out.stats.p95_ms(),
            pre,
            bat,
            exec
        );
        anyhow::ensure!(out.output_l2 > 0.0 && out.output_l2.is_finite());
    }
    Ok(())
}
