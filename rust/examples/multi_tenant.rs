//! Multi-tenant MIG serving: MobileNet + CitriNet colocated on one
//! 1g.5gb(7x) A100 (3 + 4 vGPUs), demonstrating that the SHARED host CPU
//! couples tenants through preprocessing — CitriNet's demand starves
//! MobileNet even though their vGPUs are isolated — and that PREBA's DPU
//! restores the isolation MIG promised (DES study; the multi-tenant
//! version of the paper's headline).
//!
//! Run: `cargo run --release --example multi_tenant`

use preba::mig::ServiceModel;
use preba::prelude::*;
use preba::server::multi::{run, MultiConfig, Tenant};
use preba::util::table::{num, Table};

fn main() -> anyhow::Result<()> {
    let sys = PrebaConfig::new();
    let mob_rate = 3.0 * ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0) * 0.5;
    let cit_rate = 4.0 * ServiceModel::new(ModelId::CitriNet.spec(), 1).plateau_qps(10.0) * 0.55;
    println!(
        "tenants: MobileNet 3 vGPUs @ {:.0} QPS | CitriNet 4 vGPUs @ {:.0} QPS\n",
        mob_rate, cit_rate
    );

    let mut t = Table::new(&["preproc", "tenant", "QPS", "p95 ms", "preproc ms", "exec ms"]);
    for preproc in [PreprocMode::Cpu, PreprocMode::Dpu] {
        let cfg = MultiConfig {
            mig: MigConfig::Small7,
            tenants: vec![
                Tenant::new(ModelId::MobileNet, 3, mob_rate),
                Tenant::new(ModelId::CitriNet, 4, cit_rate),
            ],
            preproc,
            policy: PolicyKind::Dynamic,
            requests: 12_000,
            seed: 99,
            warmup_frac: 0.1,
            reconfig: None,
        };
        let out = run(&cfg, &sys)?;
        for (model, stats) in &out.per_tenant {
            let (pre, _bat, _disp, exec) = stats.breakdown_ms();
            t.row(&[
                preproc.label().to_string(),
                model.display().to_string(),
                num(stats.throughput_qps()),
                num(stats.p95_ms()),
                num(pre),
                num(exec),
            ]);
        }
        if preproc == PreprocMode::Cpu {
            println!("shared CPU pool utilization: {:.0}%", 100.0 * out.cpu_util);
        }
    }
    t.print();
    println!("\nCPU preprocessing couples the tenants (MobileNet's p95 blows up under CitriNet's demand);");
    println!("the DPU restores per-tenant isolation.");
    Ok(())
}
