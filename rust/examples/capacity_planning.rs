//! Capacity planning: use the library's calibrated models to answer the
//! AIaaS operator's question — which MIG partition + batching policy
//! sustains a target workload within an SLA, and at what cost?
//!
//! Two levels:
//! 1. **One GPU** — sweeps the three paper partitions × both batching
//!    policies for a given model and SLA, reporting SLA-bounded
//!    throughput, energy efficiency, and TCO (the paper's §6 metrics).
//! 2. **A cluster** — packs the diurnal tenant fleet onto N A100s
//!    first-fit vs best-fit-decreasing and runs the multi-GPU DES
//!    (`server::cluster`), so the packing decision is priced in stranded
//!    GPCs and fleet tail latency, not just an analytic count.
//!
//! Run: `cargo run --release --example capacity_planning [-- model sla_ms n_gpus]`

use preba::energy::{PowerModel, TcoModel};
use preba::experiments::support;
use preba::prelude::*;
use preba::server::cluster;
use preba::util::table::{num, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .first()
        .and_then(|s| ModelId::parse(s))
        .unwrap_or(ModelId::ConformerDefault);
    let sla_ms: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100.0);
    let n_gpus: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let sys = PrebaConfig::new();
    let pm = PowerModel::new(&sys.power);
    let tco = TcoModel::new(&sys.tco);

    println!("capacity plan for {} under p95 <= {sla_ms} ms (PREBA DPU preprocessing)", model.display());
    let mut t = Table::new(&[
        "partition", "policy", "QPS @SLA", "p95 ms", "QPS/W", "Mqueries/$",
    ]);
    let mut best: Option<(f64, String)> = None;
    for mig in MigConfig::ALL {
        for policy in [PolicyKind::Static, PolicyKind::Dynamic] {
            let (qps, p95) = support::max_qps_under_sla(
                model, mig, PreprocMode::Dpu, policy, sla_ms, 4000, &sys,
            );
            // Power at that operating point (approximate utilizations).
            let gpu_util = 0.85;
            let power = pm.power(0.2, gpu_util, Some(0.5));
            let eff = pm.qpj(qps, &power);
            let cost = tco.evaluate(qps, &power, true).queries_per_usd / 1e6;
            let label = format!("{} + {:?}", mig.name(), policy);
            if best.as_ref().map(|(b, _)| qps > *b).unwrap_or(true) {
                best = Some((qps, label.clone()));
            }
            t.row(&[
                mig.name().to_string(),
                format!("{policy:?}"),
                num(qps),
                num(p95),
                num(eff),
                num(cost),
            ]);
        }
    }
    t.print();
    let (qps, label) = best.unwrap();
    println!("\nrecommended: {label} ({qps:.0} QPS within SLA)");

    // ---- Cluster level: how should the fleet be packed? ----
    println!(
        "\ncluster plan: diurnal tenant fleet on {n_gpus} A100s, first-fit vs \
         best-fit-decreasing"
    );
    let mut t = Table::new(&[
        "packing", "admitted GPCs", "stranded %", "worst p95 ms", "worst p99 ms", "viol %",
    ]);
    for strategy in [PackStrategy::FirstFit, PackStrategy::BestFit] {
        let tenants = preba::experiments::cluster::diurnal_fleet(n_gpus, 6.0);
        let cfg = ClusterConfig::builder()
            .gpus(n_gpus)
            .strategy(strategy)
            .tenants(tenants)
            .build();
        let out = cluster::run(&cfg, &sys)?;
        t.row(&[
            strategy.label().to_string(),
            out.packing.admitted_gpcs().to_string(),
            num(out.packing.fragmentation() * 100.0),
            num(out.worst_p95_ms()),
            num(out.worst_p99_ms()),
            num(out.max_violation_frac(&cfg.tenants) * 100.0),
        ]);
    }
    t.print();
    println!("\n(best-fit-decreasing should admit more capacity with fewer stranded GPCs)");
    Ok(())
}
