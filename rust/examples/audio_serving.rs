//! Audio serving: variable-length inputs through PREBA's bucketed dynamic
//! batcher on the real stack (paper Fig 16 in action).
//!
//! CitriNet requests with LibriSpeech-shaped lengths are bucketized into
//! 2.5 s windows, preprocessed by the Pallas audio kernels (mel CU +
//! normalize CU), batched per bucket with per-bucket Batch_max, and
//! executed on the length-bucketed model artifacts.
//!
//! Run: `cargo run --release --example audio_serving`

use preba::prelude::*;
use preba::runtime::Engine;
use preba::server::real_driver::{serve, RealConfig, RealPreproc};

fn main() -> anyhow::Result<()> {
    let sys = PrebaConfig::new();
    let mut engine = Engine::new(&sys.artifacts_dir)?;

    let mut cfg = RealConfig::new(ModelId::CitriNet, RealPreproc::DpuPallas);
    cfg.requests = 30;
    cfg.rate_qps = 10.0;
    cfg.max_audio_s = 10.0; // buckets 2.5 / 5 / 7.5 / 10 s are lowered

    println!("serving {} variable-length audio requests...", cfg.requests);
    let out = serve(&cfg, &sys, &mut engine)?;

    let (pre, bat, disp, exec) = out.stats.breakdown_ms();
    println!("completed   : {}", out.stats.completed);
    println!("throughput  : {:.1} QPS", out.stats.throughput_qps());
    println!("p95         : {:.2} ms", out.stats.p95_ms());
    println!("breakdown   : preproc {pre:.2} | batching {bat:.2} | queue {disp:.2} | exec {exec:.2} ms");
    println!("mean batch  : {:.2} over {} batches", out.stats.batch_sizes.mean(), out.executed_batches);
    anyhow::ensure!(out.output_l2 > 0.0 && out.output_l2.is_finite());
    println!("log-prob L2 : {:.3}", out.output_l2);
    Ok(())
}
