//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §End-to-end).
//!
//! Proves all three layers compose on a real workload: for each of the
//! six paper models, serve a batch of requests through the COMPLETE
//! pipeline — synthetic raw inputs → preprocessing (Pallas kernel
//! artifacts on the CPU PJRT client) → PREBA's dynamic batcher → lite-
//! model execution from the AOT HLO artifacts — and report throughput,
//! tail latency and the per-stage breakdown. Also cross-checks the DPU
//! (Pallas) preprocessing path against the host-Rust baseline
//! numerically on live traffic.
//!
//! Run: `cargo run --release --example e2e_pipeline` (after `make artifacts`)

use preba::prelude::*;
use preba::runtime::Engine;
use preba::server::real_driver::{serve, RealConfig, RealPreproc};
use preba::util::table::{num, Table};

fn main() -> anyhow::Result<()> {
    let sys = PrebaConfig::new();
    let mut engine = Engine::new(&sys.artifacts_dir)?;
    println!("PJRT platform: {} | artifacts: {}", engine.platform(), engine.manifest().len());

    let mut t = Table::new(&[
        "model", "reqs", "QPS", "p95 ms", "preproc ms", "batch ms", "exec ms", "mean batch", "out L2",
    ]);
    for model in ModelId::ALL {
        let mut cfg = RealConfig::new(model, RealPreproc::DpuPallas);
        cfg.requests = 50;
        // Offered load scaled to what one CPU core sustains for each lite
        // model (conformer_default's 10 s-bucket batches run ~300 ms).
        cfg.rate_qps = match model {
            ModelId::ConformerDefault => 2.5,
            m if m.kind() == preba::models::ModelKind::Audio => 8.0,
            _ => 40.0,
        };
        cfg.seed = 7;
        let out = serve(&cfg, &sys, &mut engine)?;
        let (pre, bat, _disp, exec) = out.stats.breakdown_ms();
        anyhow::ensure!(out.output_l2.is_finite() && out.output_l2 > 0.0, "{model}: dead output");
        t.row(&[
            model.display().to_string(),
            out.stats.completed.to_string(),
            num(out.stats.throughput_qps()),
            num(out.stats.p95_ms()),
            num(pre),
            num(bat),
            num(exec),
            num(out.stats.batch_sizes.mean()),
            num(out.output_l2),
        ]);
    }
    println!();
    t.print();
    println!("\nall six models served end-to-end through Pallas preprocessing + dynamic batching + HLO execution.");
    Ok(())
}
