//! Quickstart: the smallest end-to-end PREBA run.
//!
//! Loads the AOT artifacts (`make artifacts` first), serves 40 MobileNet
//! requests through the real pipeline — Pallas-kernel preprocessing on
//! PJRT, dynamic batching, model execution — and prints the latency
//! breakdown.
//!
//! Run: `cargo run --release --example quickstart`

use preba::prelude::*;
use preba::runtime::Engine;
use preba::server::real_driver::{serve, RealConfig, RealPreproc};

fn main() -> anyhow::Result<()> {
    let sys = PrebaConfig::new();
    let mut engine = Engine::new(&sys.artifacts_dir)?;
    println!("PJRT platform: {}", engine.platform());

    let mut cfg = RealConfig::new(ModelId::MobileNet, RealPreproc::DpuPallas);
    cfg.requests = 40;
    cfg.rate_qps = 30.0;

    println!("serving {} requests of {}...", cfg.requests, cfg.model.display());
    let out = serve(&cfg, &sys, &mut engine)?;

    let (pre, bat, disp, exec) = out.stats.breakdown_ms();
    println!("\ncompleted     : {}", out.stats.completed);
    println!("throughput    : {:.1} QPS", out.stats.throughput_qps());
    println!("p95 latency   : {:.2} ms", out.stats.p95_ms());
    println!("breakdown     : preproc {pre:.2} | batching {bat:.2} | queue {disp:.2} | exec {exec:.2} ms");
    println!("batches       : {} (mean size {:.2})", out.executed_batches, out.stats.batch_sizes.mean());
    println!("output L2     : {:.3} (finite, non-zero => full stack is live)", out.output_l2);
    anyhow::ensure!(out.output_l2.is_finite() && out.output_l2 > 0.0);
    Ok(())
}
