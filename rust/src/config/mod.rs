//! Typed configuration for the PREBA server and simulator.
//!
//! Every calibration constant of the reproduction lives here with its
//! provenance documented (paper section / public datasheet / derived), and
//! can be overridden from a TOML file (`preba --config path.toml ...`).

pub mod toml;

use crate::clock::{millis, Nanos};

/// Host + accelerator hardware description (paper §5 "Hardware").
#[derive(Debug, Clone)]
pub struct HardwareConfig {
    /// Physical CPU cores on the host (AMD EPYC 7502: 32).
    pub cpu_cores: usize,
    /// Cores the serving stack itself consumes (load balancing, kernel
    /// launching — paper §3.3 "the host CPU is already busy").
    pub cpu_reserved_cores: usize,
    /// PCIe gen4 x16 effective bandwidth, GB/s (paper §4.2: 32 GB/s).
    pub pcie_gbps: f64,
    /// One-way PCIe transfer fixed latency (paper: "tens of microseconds").
    pub pcie_latency: Nanos,
    /// Number of GPCs in the A100 (7).
    pub gpcs: usize,
    /// Peak dense fp16/tensor throughput of a 1-GPC slice, TFLOP/s.
    /// A100 = 312 TFLOPS tensor-fp16 over 7 GPCs ≈ 44.6 per GPC.
    pub tflops_per_gpc: f64,
    /// HBM bandwidth of a 1-GPC (1g.5gb) slice, GB/s. A100 = 1555 GB/s
    /// over 8 slices; 1g.5gb gets 1 slice ≈ 194 GB/s.
    pub hbm_gbps_per_slice: f64,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        HardwareConfig {
            cpu_cores: 32,
            cpu_reserved_cores: 2,
            pcie_gbps: 32.0,
            pcie_latency: crate::clock::micros(20.0),
            gpcs: 7,
            tflops_per_gpc: 44.6,
            hbm_gbps_per_slice: 194.0,
        }
    }
}

/// Power model constants (paper §6.2, public TDPs).
#[derive(Debug, Clone)]
pub struct PowerConfig {
    /// EPYC 7502 TDP, W.
    pub cpu_tdp_w: f64,
    /// CPU idle floor as a fraction of TDP.
    pub cpu_idle_frac: f64,
    /// A100 SXM/PCIe TDP, W.
    pub gpu_tdp_w: f64,
    /// GPU idle floor fraction (MIG slices powered but idle).
    pub gpu_idle_frac: f64,
    /// Alveo U55C max power, W (Xilinx datasheet: 115 W card, ~75 typical).
    pub fpga_w: f64,
    /// FPGA idle fraction.
    pub fpga_idle_frac: f64,
    /// Rest-of-server (DRAM, fans, NIC) constant draw, W.
    pub server_base_w: f64,
}

impl Default for PowerConfig {
    fn default() -> Self {
        PowerConfig {
            cpu_tdp_w: 180.0,
            cpu_idle_frac: 0.35,
            gpu_tdp_w: 400.0,
            gpu_idle_frac: 0.20,
            fpga_w: 75.0,
            fpga_idle_frac: 0.30,
            server_base_w: 120.0,
        }
    }
}

/// Component-energy integrator constants (`energy::EnergyModel`,
/// `[energy]` in TOML). Calibrated so a fully-active / fully-idle GPU
/// lands on the same envelope as [`PowerConfig`]'s TDP × idle-fraction
/// figures: A100 = 45 + 7×50 = 395 W active, 45 + 7×5 = 80 W idle
/// (PowerConfig: 400 / 80 W); host = 32 cores × 5.7 = 182.4 W active,
/// 32 × 2.0 = 64 W idle (PowerConfig: 180 / 63 W). The per-GPC split is
/// what lets the DES integrate energy through MIG geometry changes and
/// elide idle power for consolidation-powered-down GPUs (MIGPerf shows
/// slice energy is geometry-dependent, not a constant per-GPC figure).
#[derive(Debug, Clone)]
pub struct EnergyConfig {
    /// A100: one GPC executing a batch, W.
    pub gpc_active_w: f64,
    /// A100: one powered-but-idle GPC, W.
    pub gpc_idle_w: f64,
    /// A100: uncore/HBM floor of a powered-on GPU, W.
    pub uncore_w: f64,
    /// A30-style class: active GPC, W (165 W TDP over 4 GPCs + uncore).
    pub a30_gpc_active_w: f64,
    /// A30-style class: idle GPC, W.
    pub a30_gpc_idle_w: f64,
    /// A30-style class: uncore/HBM floor, W.
    pub a30_uncore_w: f64,
    /// One busy host core (preprocessing or serving reserve), W.
    pub cpu_core_active_w: f64,
    /// One idle host core, W.
    pub cpu_core_idle_w: f64,
    /// FPGA DPU fully busy, W (Alveo U55C ~75 W typical).
    pub dpu_active_w: f64,
    /// FPGA DPU idle, W (clocks never gate fully off).
    pub dpu_idle_w: f64,
    /// Host base draw (DRAM, fans, NIC), W — matches
    /// [`PowerConfig::server_base_w`].
    pub host_base_w: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        EnergyConfig {
            gpc_active_w: 50.0,
            gpc_idle_w: 5.0,
            uncore_w: 45.0,
            a30_gpc_active_w: 32.5,
            a30_gpc_idle_w: 4.0,
            a30_uncore_w: 35.0,
            cpu_core_active_w: 5.7,
            cpu_core_idle_w: 2.0,
            dpu_active_w: 75.0,
            dpu_idle_w: 22.5,
            host_base_w: 120.0,
        }
    }
}

/// TCO model constants (paper §6.3).
#[derive(Debug, Clone)]
pub struct TcoConfig {
    /// Server node CAPEX, USD (SuperMicro 2U AMD [82]).
    pub server_usd: f64,
    /// A100 CAPEX, USD [7].
    pub gpu_usd: f64,
    /// Alveo U55C CAPEX, USD [90].
    pub fpga_usd: f64,
    /// Depreciation horizon, years (paper: 3).
    pub years: f64,
    /// Electricity, USD per kWh (paper: $0.139).
    pub usd_per_kwh: f64,
}

impl Default for TcoConfig {
    fn default() -> Self {
        TcoConfig {
            server_usd: 8000.0,
            gpu_usd: 14000.0,
            fpga_usd: 4500.0,
            years: 3.0,
            usd_per_kwh: 0.139,
        }
    }
}

/// Batching-system configuration (paper §4.3).
#[derive(Debug, Clone)]
pub struct BatchingConfig {
    /// Throughput fraction of plateau that defines `Batch_knee` in the
    /// offline profiler (knee = smallest batch reaching this fraction).
    pub knee_frac: f64,
    /// Audio bucket window, seconds (paper: 2.5 s windows).
    pub bucket_window_s: f64,
    /// Maximum audio length, seconds (LibriSpeech tail, Fig 13: ~25 s).
    pub max_audio_s: f64,
    /// Static-baseline `Batch_max` (ablation "Base" configuration).
    pub static_batch_max: usize,
    /// Static-baseline `Time_queue`.
    pub static_time_queue: Nanos,
    /// Enable adjacent-bucket merging (paper §4.3 last paragraph).
    pub merge_adjacent: bool,
    /// Override the `Time_queue = Time_knee / n_vGPUs` divisor (ablation
    /// of the paper's rule; `None` = use the vGPU count).
    pub time_queue_divisor: Option<f64>,
}

impl Default for BatchingConfig {
    fn default() -> Self {
        BatchingConfig {
            knee_frac: 0.90,
            bucket_window_s: 2.5,
            max_audio_s: 25.0,
            static_batch_max: 32,
            static_time_queue: millis(50.0),
            merge_adjacent: true,
            time_queue_divisor: None,
        }
    }
}

/// DPU (FPGA preprocessing accelerator) configuration (paper §4.2, §5).
#[derive(Debug, Clone)]
pub struct DpuConfig {
    /// Image-pipeline CUs instantiated (Table 1: image CU uses ~45% LUT,
    /// so 2 fit; throughput scales with CU count).
    pub image_cus: usize,
    /// Audio Resample+Mel CUs (split design, Fig 11b).
    pub audio_mel_cus: usize,
    /// Audio Normalize CUs (split design, Fig 11b).
    pub audio_norm_cus: usize,
    /// Use the split-CU audio design (false = monolithic CU, Fig 12b).
    pub split_audio_cu: bool,
    /// Host->CU command/doorbell overhead per invocation.
    pub cu_dispatch_overhead: Nanos,
}

impl Default for DpuConfig {
    fn default() -> Self {
        DpuConfig {
            image_cus: 2,
            audio_mel_cus: 2,
            audio_norm_cus: 1,
            split_audio_cu: true,
            cu_dispatch_overhead: crate::clock::micros(15.0),
        }
    }
}

/// Cluster-serving defaults (`preba cluster`, `server::cluster`).
#[derive(Debug, Clone)]
pub struct ClusterDefaults {
    /// GPUs in the inventory the CLI simulates by default (all A100 when
    /// no `--fleet`/`fleet` spec is given).
    pub gpus: usize,
    /// Default fleet spec (`a100x4`, `a100x2,a30x2`, ...); empty = a
    /// homogeneous A100 pool of `gpus`.
    pub fleet: String,
    /// A100-preset compute capacity, GPCs (datasheet: 7).
    pub a100_gpcs: usize,
    /// A100-preset memory capacity, GB (A100-40GB).
    pub a100_mem_gb: usize,
    /// A30-style-preset compute capacity, GPCs (datasheet: 4).
    pub a30_gpcs: usize,
    /// A30-style-preset memory capacity, GB (A30: 24).
    pub a30_mem_gb: usize,
    /// Default simulated horizon per run, seconds (per-tenant request
    /// budgets are sized as rate × horizon).
    pub horizon_s: f64,
    /// Cross-GPU tenant-migration outage fed into
    /// [`crate::mig::ReconfigPolicy::migration_s`], seconds. ≫ the
    /// in-place repartition outage: a migration ships model weights and
    /// restarts the server on a GPU the tenant was not resident on.
    pub migration_s: f64,
    /// In-place repartition outage, seconds.
    pub repartition_s: f64,
    /// Event-heap shard count fed into
    /// [`crate::server::cluster::ClusterConfig::shards`]: 0 = auto (one
    /// shard per connected component of the tenant↔GPU residency
    /// graph), 1 = force a single global heap, n = merge components
    /// round-robin into at most n shards. Outcomes are byte-identical
    /// at every setting.
    pub shards: usize,
}

impl Default for ClusterDefaults {
    fn default() -> Self {
        ClusterDefaults {
            gpus: 4,
            fleet: String::new(),
            a100_gpcs: crate::mig::GpuClass::A100.gpcs,
            a100_mem_gb: crate::mig::GpuClass::A100.mem_gb,
            a30_gpcs: crate::mig::GpuClass::A30.gpcs,
            a30_mem_gb: crate::mig::GpuClass::A30.mem_gb,
            horizon_s: 10.0,
            migration_s: 0.3,
            repartition_s: 0.1,
            shards: 0,
        }
    }
}

impl ClusterDefaults {
    /// Resolve a class label against these (possibly TOML-overridden)
    /// preset capacities.
    pub fn class(&self, name: &str) -> Option<crate::mig::GpuClass> {
        match name {
            "a100" | "A100" => Some(crate::mig::GpuClass {
                name: "a100",
                gpcs: self.a100_gpcs,
                mem_gb: self.a100_mem_gb,
            }),
            "a30" | "A30" => Some(crate::mig::GpuClass {
                name: "a30",
                gpcs: self.a30_gpcs,
                mem_gb: self.a30_mem_gb,
            }),
            _ => None,
        }
    }

    /// Parse a `a100x4,a30x2` fleet spec with these preset capacities.
    pub fn parse_fleet(&self, spec: &str) -> anyhow::Result<Vec<crate::mig::GpuClass>> {
        crate::mig::partition::parse_fleet_with(spec, |name| self.class(name))
    }

    /// The inventory the CLI should simulate: the configured `fleet` spec
    /// when set, else `gpus` A100s.
    pub fn default_fleet(&self) -> anyhow::Result<Vec<crate::mig::GpuClass>> {
        if self.fleet.trim().is_empty() {
            let a100 = self.class("a100").expect("a100 preset");
            Ok(vec![a100; self.gpus])
        } else {
            self.parse_fleet(&self.fleet)
        }
    }
}

/// Fault-injection & recovery defaults (`[fault]` in TOML; the
/// `preba cluster --faults SPEC` flag overrides `spec`). The schedule
/// grammar is [`crate::fault::FaultSchedule::parse`]; the recovery knobs
/// mirror [`crate::fault::RecoveryPolicy`].
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Default fault spec string; empty = no faults injected.
    /// Examples: `"crash@2:g1:3"`, `"mtbf:25,mttr:1"`,
    /// `"slice@1:g0:0.5,slow@2:g1:2:1.8"`.
    pub spec: String,
    /// Mean time between failures for `mtbf:`-only specs, seconds.
    pub mtbf_s: f64,
    /// Mean time to repair for stochastic schedules, seconds.
    pub mttr_s: f64,
    /// Health-check detection latency, seconds.
    pub detect_s: f64,
    /// Client request timeout, ms.
    pub timeout_ms: f64,
    /// Retry budget per request.
    pub retries: u32,
    /// Exponential backoff base, ms.
    pub backoff_ms: f64,
    /// Hedge delay, ms; 0 disables hedged requests.
    pub hedge_ms: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            spec: String::new(),
            mtbf_s: 25.0,
            mttr_s: 1.0,
            detect_s: 0.2,
            timeout_ms: 250.0,
            retries: 3,
            backoff_ms: 50.0,
            hedge_ms: 0.0,
        }
    }
}

impl FaultConfig {
    /// The recovery policy these knobs describe.
    pub fn recovery(&self) -> crate::fault::RecoveryPolicy {
        crate::fault::RecoveryPolicy {
            detect_s: self.detect_s,
            timeout_s: self.timeout_ms / 1000.0,
            max_retries: self.retries,
            backoff_s: self.backoff_ms / 1000.0,
            hedge_s: self.hedge_ms / 1000.0,
        }
    }
}

/// Per-(model, profile, batch) performance/energy curve configuration
/// (`[curves]` in TOML). Disabled by default: the flat affine service
/// model and flat per-GPC watts apply, bit-identical to earlier releases.
/// When enabled, [`crate::models::calib::migperf_curve`]'s
/// MIGPerf-calibrated multipliers scale execution time and active power
/// per batch bucket, and the per-profile contention coefficients inflate
/// both per busy neighbor slice at dispatch (uncore interference).
#[derive(Debug, Clone)]
pub struct CurvesConfig {
    /// Master switch; `false` = flat model (byte-identical outputs).
    pub enabled: bool,
    /// Curve table: `"migperf"` (calibrated defaults) or `"flat"` (all
    /// multipliers 1.0 — isolates the contention term).
    pub source: String,
    /// Scales the latency correction `(lat_mult - 1)`; 1.0 = table as-is,
    /// 0.0 = no latency correction.
    pub lat_scale: f64,
    /// Scales the active-power correction `(pow_mult - 1)`.
    pub pow_scale: f64,
    /// Scales every per-profile contention coefficient; 0.0 disables
    /// interference while keeping the batch curves.
    pub contention_scale: f64,
    /// Per-profile contention coefficients: fractional execution-time and
    /// active-power inflation per busy neighbor slice. Defaults are the
    /// MIGPerf-calibrated values from `models::calib`.
    pub contention_1g: f64,
    pub contention_2g: f64,
    pub contention_3g: f64,
    pub contention_4g: f64,
    pub contention_7g: f64,
}

impl Default for CurvesConfig {
    fn default() -> Self {
        use crate::models::calib::migperf_contention;
        CurvesConfig {
            enabled: false,
            source: "migperf".to_string(),
            lat_scale: 1.0,
            pow_scale: 1.0,
            contention_scale: 1.0,
            contention_1g: migperf_contention(1),
            contention_2g: migperf_contention(2),
            contention_3g: migperf_contention(3),
            contention_4g: migperf_contention(4),
            contention_7g: migperf_contention(7),
        }
    }
}

impl CurvesConfig {
    /// Configured contention coefficient for a `gpcs`-GPC profile
    /// (before `contention_scale`). Profiles without a dedicated knob
    /// (5g/6g don't exist in the MIG lineup) fall back to the table.
    fn contention_raw(&self, gpcs: usize) -> f64 {
        match gpcs {
            0 | 1 => self.contention_1g,
            2 => self.contention_2g,
            3 => self.contention_3g,
            4 => self.contention_4g,
            7.. => self.contention_7g,
            _ => crate::models::calib::migperf_contention(gpcs),
        }
    }

    /// Resolve the curve row for one (model, slice geometry). Returns
    /// [`crate::models::CurveView::NEUTRAL`] when disabled, so dispatch
    /// paths can hold the view unconditionally.
    pub fn view(&self, model: crate::models::ModelId, gpcs: usize) -> crate::models::CurveView {
        use crate::models::CurveView;
        if !self.enabled {
            return CurveView::NEUTRAL;
        }
        let mut v = CurveView::NEUTRAL;
        if self.source != "flat" {
            let row = crate::models::calib::migperf_curve(model, gpcs);
            for (b, pt) in row.iter().enumerate() {
                v.lat[b] = 1.0 + (pt.lat_mult - 1.0) * self.lat_scale;
                v.pow[b] = 1.0 + (pt.pow_mult - 1.0) * self.pow_scale;
            }
        }
        v.contention = self.contention_raw(gpcs) * self.contention_scale;
        v
    }
}

/// Observability configuration (`[obs]` in TOML; `--obs DIR`,
/// `--obs-window`, `--span-sample` override per run). Off by default —
/// disabled runs are byte-identical to an unobserved build (the
/// [`crate::obs`] neutrality contract).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Master switch (also flipped on by `--obs DIR`).
    pub enabled: bool,
    /// Artifact directory exported runs write into.
    pub out_dir: String,
    /// Time-series window width, seconds.
    pub window_s: f64,
    /// Span sampling period: request `idx` is sampled iff
    /// `idx % span_sample == 0`.
    pub span_sample: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: false, out_dir: "obs".to_string(), window_s: 1.0, span_sample: 8 }
    }
}

impl ObsConfig {
    /// Resolve to the driver-side recording spec.
    pub fn spec(&self) -> crate::obs::ObsSpec {
        if self.enabled {
            crate::obs::ObsSpec::on(self.window_s, self.span_sample as u64)
        } else {
            crate::obs::ObsSpec::default()
        }
    }
}

/// Workload-generation configuration (paper §5 "Input query modeling").
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// RNG seed for the whole experiment.
    pub seed: u64,
    /// Requests to simulate per measurement run.
    pub requests: usize,
    /// Warmup fraction excluded from statistics.
    pub warmup_frac: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { seed: 0x9E3779B97F4A7C15, requests: 20_000, warmup_frac: 0.1 }
    }
}

/// Reconfiguration-planner defaults (`[reconfig]` in TOML; the
/// `preba cluster --planner` flag overrides `planner`). These feed the
/// planner-selection fields of [`crate::mig::ReconfigPolicy`].
#[derive(Debug, Clone)]
pub struct ReconfigDefaults {
    /// Planning algorithm: `greedy` (fast path), `anneal` (budgeted
    /// simulated annealing seeded from greedy), or `exact`
    /// (branch-and-bound, small fleets; larger fleets fall back to
    /// anneal).
    pub planner: String,
    /// Proposal budget per planning call for the `anneal` planner.
    pub anneal_iters: usize,
}

impl Default for ReconfigDefaults {
    fn default() -> Self {
        ReconfigDefaults { planner: "greedy".to_string(), anneal_iters: 2_000 }
    }
}

impl ReconfigDefaults {
    /// Resolve the configured planner name to a [`crate::mig::PlannerKind`].
    pub fn planner_kind(&self) -> anyhow::Result<crate::mig::PlannerKind> {
        crate::mig::PlannerKind::parse(&self.planner).ok_or_else(|| {
            anyhow::anyhow!(
                "reconfig.planner must be 'greedy', 'anneal' or 'exact', got '{}'",
                self.planner
            )
        })
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct PrebaConfig {
    pub hardware: HardwareConfig,
    pub power: PowerConfig,
    pub energy: EnergyConfig,
    pub tco: TcoConfig,
    pub batching: BatchingConfig,
    pub dpu: DpuConfig,
    pub cluster: ClusterDefaults,
    pub reconfig: ReconfigDefaults,
    pub fault: FaultConfig,
    pub curves: CurvesConfig,
    pub obs: ObsConfig,
    pub workload: WorkloadConfig,
    /// Directory holding AOT artifacts + manifest.json.
    pub artifacts_dir: String,
}

impl PrebaConfig {
    /// Built-in defaults (paper testbed).
    pub fn new() -> Self {
        PrebaConfig { artifacts_dir: "artifacts".to_string(), ..Default::default() }
    }

    /// Load defaults then apply overrides from a TOML file.
    pub fn from_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config '{path}': {e}"))?;
        let doc = toml::parse(&text)?;
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed TOML doc on top of the current values.
    pub fn apply(&mut self, doc: &toml::Doc) -> anyhow::Result<()> {
        let h = &mut self.hardware;
        h.cpu_cores = doc.i64_or("hardware.cpu_cores", h.cpu_cores as i64) as usize;
        h.cpu_reserved_cores =
            doc.i64_or("hardware.cpu_reserved_cores", h.cpu_reserved_cores as i64) as usize;
        h.pcie_gbps = doc.f64_or("hardware.pcie_gbps", h.pcie_gbps);
        h.gpcs = doc.i64_or("hardware.gpcs", h.gpcs as i64) as usize;
        h.tflops_per_gpc = doc.f64_or("hardware.tflops_per_gpc", h.tflops_per_gpc);
        h.hbm_gbps_per_slice = doc.f64_or("hardware.hbm_gbps_per_slice", h.hbm_gbps_per_slice);

        let p = &mut self.power;
        p.cpu_tdp_w = doc.f64_or("power.cpu_tdp_w", p.cpu_tdp_w);
        p.gpu_tdp_w = doc.f64_or("power.gpu_tdp_w", p.gpu_tdp_w);
        p.fpga_w = doc.f64_or("power.fpga_w", p.fpga_w);
        p.server_base_w = doc.f64_or("power.server_base_w", p.server_base_w);

        let e = &mut self.energy;
        e.gpc_active_w = doc.f64_or("energy.gpc_active_w", e.gpc_active_w);
        e.gpc_idle_w = doc.f64_or("energy.gpc_idle_w", e.gpc_idle_w);
        e.uncore_w = doc.f64_or("energy.uncore_w", e.uncore_w);
        e.a30_gpc_active_w = doc.f64_or("energy.a30_gpc_active_w", e.a30_gpc_active_w);
        e.a30_gpc_idle_w = doc.f64_or("energy.a30_gpc_idle_w", e.a30_gpc_idle_w);
        e.a30_uncore_w = doc.f64_or("energy.a30_uncore_w", e.a30_uncore_w);
        e.cpu_core_active_w = doc.f64_or("energy.cpu_core_active_w", e.cpu_core_active_w);
        e.cpu_core_idle_w = doc.f64_or("energy.cpu_core_idle_w", e.cpu_core_idle_w);
        e.dpu_active_w = doc.f64_or("energy.dpu_active_w", e.dpu_active_w);
        e.dpu_idle_w = doc.f64_or("energy.dpu_idle_w", e.dpu_idle_w);
        e.host_base_w = doc.f64_or("energy.host_base_w", e.host_base_w);

        let t = &mut self.tco;
        t.server_usd = doc.f64_or("tco.server_usd", t.server_usd);
        t.gpu_usd = doc.f64_or("tco.gpu_usd", t.gpu_usd);
        t.fpga_usd = doc.f64_or("tco.fpga_usd", t.fpga_usd);
        t.years = doc.f64_or("tco.years", t.years);
        t.usd_per_kwh = doc.f64_or("tco.usd_per_kwh", t.usd_per_kwh);

        let b = &mut self.batching;
        b.knee_frac = doc.f64_or("batching.knee_frac", b.knee_frac);
        b.bucket_window_s = doc.f64_or("batching.bucket_window_s", b.bucket_window_s);
        b.max_audio_s = doc.f64_or("batching.max_audio_s", b.max_audio_s);
        b.static_batch_max =
            doc.i64_or("batching.static_batch_max", b.static_batch_max as i64) as usize;
        b.merge_adjacent = doc.bool_or("batching.merge_adjacent", b.merge_adjacent);

        let d = &mut self.dpu;
        d.image_cus = doc.i64_or("dpu.image_cus", d.image_cus as i64) as usize;
        d.audio_mel_cus = doc.i64_or("dpu.audio_mel_cus", d.audio_mel_cus as i64) as usize;
        d.audio_norm_cus = doc.i64_or("dpu.audio_norm_cus", d.audio_norm_cus as i64) as usize;
        d.split_audio_cu = doc.bool_or("dpu.split_audio_cu", d.split_audio_cu);

        let c = &mut self.cluster;
        c.gpus = doc.i64_or("cluster.gpus", c.gpus as i64) as usize;
        if let Some(v) = doc.get("cluster.fleet").and_then(toml::Value::as_str) {
            c.fleet = v.to_string();
        }
        c.a100_gpcs = doc.i64_or("cluster.a100_gpcs", c.a100_gpcs as i64) as usize;
        c.a100_mem_gb = doc.i64_or("cluster.a100_mem_gb", c.a100_mem_gb as i64) as usize;
        c.a30_gpcs = doc.i64_or("cluster.a30_gpcs", c.a30_gpcs as i64) as usize;
        c.a30_mem_gb = doc.i64_or("cluster.a30_mem_gb", c.a30_mem_gb as i64) as usize;
        c.horizon_s = doc.f64_or("cluster.horizon_s", c.horizon_s);
        c.migration_s = doc.f64_or("cluster.migration_s", c.migration_s);
        c.repartition_s = doc.f64_or("cluster.repartition_s", c.repartition_s);
        c.shards = doc.i64_or("cluster.shards", c.shards as i64) as usize;

        let r = &mut self.reconfig;
        if let Some(v) = doc.get("reconfig.planner").and_then(toml::Value::as_str) {
            r.planner = v.to_string();
        }
        r.anneal_iters = doc.i64_or("reconfig.anneal_iters", r.anneal_iters as i64) as usize;

        let f = &mut self.fault;
        if let Some(v) = doc.get("fault.spec").and_then(toml::Value::as_str) {
            f.spec = v.to_string();
        }
        f.mtbf_s = doc.f64_or("fault.mtbf_s", f.mtbf_s);
        f.mttr_s = doc.f64_or("fault.mttr_s", f.mttr_s);
        f.detect_s = doc.f64_or("fault.detect_s", f.detect_s);
        f.timeout_ms = doc.f64_or("fault.timeout_ms", f.timeout_ms);
        f.retries = doc.i64_or("fault.retries", i64::from(f.retries)) as u32;
        f.backoff_ms = doc.f64_or("fault.backoff_ms", f.backoff_ms);
        f.hedge_ms = doc.f64_or("fault.hedge_ms", f.hedge_ms);

        let cv = &mut self.curves;
        cv.enabled = doc.bool_or("curves.enabled", cv.enabled);
        if let Some(v) = doc.get("curves.source").and_then(toml::Value::as_str) {
            cv.source = v.to_string();
        }
        cv.lat_scale = doc.f64_or("curves.lat_scale", cv.lat_scale);
        cv.pow_scale = doc.f64_or("curves.pow_scale", cv.pow_scale);
        cv.contention_scale = doc.f64_or("curves.contention_scale", cv.contention_scale);
        cv.contention_1g = doc.f64_or("curves.contention_1g", cv.contention_1g);
        cv.contention_2g = doc.f64_or("curves.contention_2g", cv.contention_2g);
        cv.contention_3g = doc.f64_or("curves.contention_3g", cv.contention_3g);
        cv.contention_4g = doc.f64_or("curves.contention_4g", cv.contention_4g);
        cv.contention_7g = doc.f64_or("curves.contention_7g", cv.contention_7g);

        let o = &mut self.obs;
        o.enabled = doc.bool_or("obs.enabled", o.enabled);
        if let Some(v) = doc.get("obs.out_dir").and_then(toml::Value::as_str) {
            o.out_dir = v.to_string();
        }
        o.window_s = doc.f64_or("obs.window_s", o.window_s);
        o.span_sample = doc.i64_or("obs.span_sample", o.span_sample as i64) as usize;

        let w = &mut self.workload;
        w.seed = doc.i64_or("workload.seed", w.seed as i64) as u64;
        w.requests = doc.i64_or("workload.requests", w.requests as i64) as usize;
        w.warmup_frac = doc.f64_or("workload.warmup_frac", w.warmup_frac);

        if let Some(v) = doc.get("artifacts_dir").and_then(toml::Value::as_str) {
            self.artifacts_dir = v.to_string();
        }
        self.validate()
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.hardware.cpu_cores > self.hardware.cpu_reserved_cores,
            "cpu_cores must exceed cpu_reserved_cores");
        anyhow::ensure!(self.hardware.gpcs >= 1 && self.hardware.gpcs <= 8, "gpcs out of range");
        anyhow::ensure!(
            (0.5..1.0).contains(&self.batching.knee_frac),
            "knee_frac must be in [0.5,1)"
        );
        anyhow::ensure!(self.batching.bucket_window_s > 0.0, "bucket_window_s must be positive");
        anyhow::ensure!(self.workload.warmup_frac < 0.9, "warmup_frac too large");
        anyhow::ensure!(self.dpu.image_cus >= 1, "need at least one image CU");
        anyhow::ensure!(self.cluster.gpus >= 1, "cluster needs at least one GPU");
        anyhow::ensure!(
            self.cluster.a100_gpcs >= 1 && self.cluster.a30_gpcs >= 1,
            "GPU class presets need at least one GPC"
        );
        anyhow::ensure!(
            self.cluster.a100_mem_gb >= 1 && self.cluster.a30_mem_gb >= 1,
            "GPU class presets need memory"
        );
        self.cluster.default_fleet().map_err(|e| anyhow::anyhow!("cluster.fleet: {e}"))?;
        self.reconfig.planner_kind()?;
        let e = &self.energy;
        for (name, active, idle) in [
            ("energy.gpc", e.gpc_active_w, e.gpc_idle_w),
            ("energy.a30_gpc", e.a30_gpc_active_w, e.a30_gpc_idle_w),
            ("energy.cpu_core", e.cpu_core_active_w, e.cpu_core_idle_w),
            ("energy.dpu", e.dpu_active_w, e.dpu_idle_w),
        ] {
            anyhow::ensure!(
                active >= idle && idle >= 0.0,
                "{name}: active watts must be >= idle watts >= 0"
            );
        }
        anyhow::ensure!(
            e.uncore_w >= 0.0 && e.a30_uncore_w >= 0.0 && e.host_base_w >= 0.0,
            "energy floors must be non-negative"
        );
        anyhow::ensure!(self.cluster.horizon_s > 0.0, "cluster horizon must be positive");
        anyhow::ensure!(
            self.cluster.migration_s >= self.cluster.repartition_s,
            "migration must cost at least a repartition"
        );
        anyhow::ensure!(
            self.fault.mtbf_s > 0.0 && self.fault.mttr_s > 0.0,
            "fault mtbf_s/mttr_s must be positive"
        );
        self.fault.recovery().validate().map_err(|e| anyhow::anyhow!("[fault]: {e}"))?;
        let cv = &self.curves;
        anyhow::ensure!(
            cv.source == "migperf" || cv.source == "flat",
            "curves.source must be 'migperf' or 'flat', got '{}'",
            cv.source
        );
        for (name, v) in [
            ("curves.lat_scale", cv.lat_scale),
            ("curves.pow_scale", cv.pow_scale),
            ("curves.contention_scale", cv.contention_scale),
        ] {
            anyhow::ensure!(v.is_finite() && v >= 0.0, "{name} must be finite and >= 0");
        }
        for (name, c) in [
            ("curves.contention_1g", cv.contention_1g),
            ("curves.contention_2g", cv.contention_2g),
            ("curves.contention_3g", cv.contention_3g),
            ("curves.contention_4g", cv.contention_4g),
            ("curves.contention_7g", cv.contention_7g),
        ] {
            anyhow::ensure!(
                (0.0..=1.0).contains(&c),
                "{name} must be in [0, 1] (fractional inflation per neighbor)"
            );
        }
        let o = &self.obs;
        anyhow::ensure!(
            o.window_s.is_finite() && o.window_s > 0.0,
            "obs.window_s must be finite and positive"
        );
        anyhow::ensure!(o.span_sample >= 1, "obs.span_sample must be >= 1");
        anyhow::ensure!(!o.out_dir.is_empty(), "obs.out_dir must be non-empty");
        // Every resolved multiplier must stay positive, whatever the scales.
        for m in crate::models::ModelId::ALL {
            for gpcs in [1usize, 2, 3, 4, 7] {
                let v = cv.view(m, gpcs);
                for b in 0..crate::models::N_BUCKETS {
                    anyhow::ensure!(
                        v.lat[b] > 0.0 && v.pow[b] > 0.0,
                        "curves: resolved multiplier for {m} on {gpcs}g bucket {b} \
                         is non-positive (check lat_scale/pow_scale)"
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        PrebaConfig::new().validate().unwrap();
    }

    #[test]
    fn apply_overrides() {
        let doc = toml::parse(
            r#"
            [hardware]
            cpu_cores = 64
            [batching]
            knee_frac = 0.85
            merge_adjacent = false
            [workload]
            requests = 500
            artifacts_dir_unused = 1
            "#,
        )
        .unwrap();
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.hardware.cpu_cores, 64);
        assert_eq!(cfg.batching.knee_frac, 0.85);
        assert!(!cfg.batching.merge_adjacent);
        assert_eq!(cfg.workload.requests, 500);
        // untouched default survives
        assert_eq!(cfg.power.gpu_tdp_w, 400.0);
    }

    #[test]
    fn obs_section_applies_and_validates() {
        let cfg = PrebaConfig::new();
        assert!(!cfg.obs.enabled, "obs is off by default");
        assert!(!cfg.obs.spec().enabled, "default spec is the neutral one");

        let doc = toml::parse(
            r#"
            [obs]
            enabled = true
            out_dir = "obs_out"
            window_s = 0.25
            span_sample = 4
            "#,
        )
        .unwrap();
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc).unwrap();
        assert!(cfg.obs.enabled);
        assert_eq!(cfg.obs.out_dir, "obs_out");
        let spec = cfg.obs.spec();
        assert!(spec.enabled);
        assert_eq!(spec.window_ns, crate::clock::secs(0.25));
        assert_eq!(spec.span_sample, 4);

        let mut bad = PrebaConfig::new();
        bad.obs.window_s = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = PrebaConfig::new();
        bad.obs.span_sample = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fleet_presets_resolve_and_override() {
        let defaults = ClusterDefaults::default();
        let fleet = defaults.parse_fleet("a100x2,a30").unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[0], crate::mig::GpuClass::A100);
        assert_eq!(fleet[2], crate::mig::GpuClass::A30);
        assert_eq!(defaults.default_fleet().unwrap().len(), defaults.gpus);

        let doc = toml::parse(
            r#"
            [cluster]
            fleet = "a30x2"
            a30_mem_gb = 32
            "#,
        )
        .unwrap();
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc).unwrap();
        let fleet = cfg.cluster.default_fleet().unwrap();
        assert_eq!(fleet.len(), 2);
        assert_eq!(fleet[0].name, "a30");
        assert_eq!(fleet[0].mem_gb, 32, "preset override ignored");

        let mut bad = PrebaConfig::new();
        bad.cluster.fleet = "h100x8".into();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn reconfig_planner_overrides_apply_and_validate() {
        let doc = toml::parse(
            r#"
            [reconfig]
            planner = "anneal"
            anneal_iters = 500
            "#,
        )
        .unwrap();
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.reconfig.planner, "anneal");
        assert_eq!(cfg.reconfig.anneal_iters, 500);
        assert_eq!(cfg.reconfig.planner_kind().unwrap(), crate::mig::PlannerKind::Anneal);
        // Default stays the pre-planner-stack fast path.
        assert_eq!(
            PrebaConfig::new().reconfig.planner_kind().unwrap(),
            crate::mig::PlannerKind::Greedy
        );

        let mut bad = PrebaConfig::new();
        bad.reconfig.planner = "milp".into();
        assert!(bad.validate().is_err(), "unknown planner must be rejected");
    }

    #[test]
    fn energy_overrides_apply_and_validate() {
        let doc = toml::parse(
            r#"
            [energy]
            gpc_active_w = 60.0
            uncore_w = 50.0
            host_base_w = 100.0
            "#,
        )
        .unwrap();
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.energy.gpc_active_w, 60.0);
        assert_eq!(cfg.energy.uncore_w, 50.0);
        assert_eq!(cfg.energy.host_base_w, 100.0);
        // untouched default survives
        assert_eq!(cfg.energy.dpu_active_w, 75.0);

        let mut bad = PrebaConfig::new();
        bad.energy.gpc_idle_w = bad.energy.gpc_active_w + 1.0;
        assert!(bad.validate().is_err(), "idle above active must be rejected");
        let mut bad2 = PrebaConfig::new();
        bad2.energy.uncore_w = -1.0;
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn fault_overrides_apply_and_validate() {
        let doc = toml::parse(
            r#"
            [fault]
            spec = "crash@2:g1:3"
            detect_s = 0.5
            timeout_ms = 100.0
            retries = 1
            hedge_ms = 30.0
            "#,
        )
        .unwrap();
        let mut cfg = PrebaConfig::new();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.fault.spec, "crash@2:g1:3");
        let pol = cfg.fault.recovery();
        assert_eq!(pol.detect_s, 0.5);
        assert_eq!(pol.timeout_s, 0.1);
        assert_eq!(pol.max_retries, 1);
        assert_eq!(pol.hedge_s, 0.03);
        // untouched default survives
        assert_eq!(cfg.fault.mtbf_s, 25.0);

        let mut bad = PrebaConfig::new();
        bad.fault.mtbf_s = 0.0;
        assert!(bad.validate().is_err());
        let mut bad2 = PrebaConfig::new();
        bad2.fault.timeout_ms = -5.0;
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = PrebaConfig::new();
        cfg.batching.knee_frac = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg2 = PrebaConfig::new();
        cfg2.hardware.cpu_reserved_cores = 99;
        assert!(cfg2.validate().is_err());
    }
}
