//! TOML-subset parser (in lieu of the `toml` crate, absent offline).
//!
//! Supports what PREBA config files use: `[section]` / `[a.b]` tables,
//! `key = value` with string / integer / float / boolean / homogeneous
//! array values, `#` comments, and bare or quoted keys. No inline tables,
//! no multi-line strings, no datetimes.

use std::collections::BTreeMap;

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path -> value, e.g. `"mig.peak_tflops"`.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All keys under a section prefix (e.g. `"preprocess.cpu_ms"`).
    pub fn section(&self, prefix: &str) -> Vec<(&str, &Value)> {
        let pfx = format!("{prefix}.");
        self.entries
            .iter()
            .filter(|(k, _)| k.starts_with(&pfx))
            .map(|(k, v)| (&k[pfx.len()..], v))
            .collect()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> anyhow::Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                anyhow::bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
        } else if let Some((k, v)) = line.split_once('=') {
            let key = unquote_key(k.trim());
            let full = if section.is_empty() { key } else { format!("{section}.{key}") };
            let value = parse_value(v.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            if doc.entries.insert(full.clone(), value).is_some() {
                anyhow::bail!("line {}: duplicate key '{full}'", lineno + 1);
            }
        } else {
            anyhow::bail!("line {}: expected 'key = value' or '[section]'", lineno + 1);
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote_key(k: &str) -> String {
    k.trim_matches('"').to_string()
}

fn parse_value(v: &str) -> anyhow::Result<Value> {
    if v.is_empty() {
        anyhow::bail!("empty value");
    }
    if let Some(body) = v.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = v.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            for item in split_top_level(trimmed) {
                items.push(parse_value(item.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    // Number: int if it parses as i64 and has no '.', 'e'.
    let is_floaty = v.contains('.') || v.contains('e') || v.contains('E');
    if !is_floaty {
        if let Ok(i) = v.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(x) = v.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(x));
    }
    anyhow::bail!("cannot parse value '{v}'")
}

/// Split an array body on commas that are not inside strings (nested
/// arrays are not supported — config arrays are flat).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            top = 1
            [server]
            name = "preba"   # trailing comment
            cores = 32
            util = 0.9
            enabled = true
            sizes = [1, 2, 4]
            [mig.a100]
            tflops = 19.5
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("top", 0), 1);
        assert_eq!(doc.str_or("server.name", ""), "preba");
        assert_eq!(doc.i64_or("server.cores", 0), 32);
        assert_eq!(doc.f64_or("server.util", 0.0), 0.9);
        assert!(doc.bool_or("server.enabled", false));
        let arr = doc.get("server.sizes").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(doc.f64_or("mig.a100.tflops", 0.0), 19.5);
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = parse("x = 5").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 5.0);
    }

    #[test]
    fn section_listing() {
        let doc = parse("[p]\na = 1\nb = 2\n[q]\nc = 3").unwrap();
        let keys: Vec<_> = doc.section("p").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["a", "b"]);
    }

    #[test]
    fn errors() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("bare").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("s = \"open").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }
}
