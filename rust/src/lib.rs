//! # PREBA — Multi-Instance GPU inference serving, reproduced end-to-end
//!
//! Rust + JAX + Pallas reproduction of *"PREBA: A Hardware/Software
//! Co-Design for Multi-Instance GPU based AI Inference Servers"*
//! (Yeo, Kim, Choi, Rhu — 2024).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas preprocessing kernels (the paper's FPGA DPU,
//!   re-architected for the TPU MXU; `python/compile/kernels/`), AOT-lowered
//!   to HLO text.
//! * **L2** — the six paper workloads (MobileNetV3 / SqueezeNet /
//!   Swin-Transformer / Conformer ×2 / CitriNet) written in JAX
//!   (`python/compile/models/`), AOT-lowered per (model, batch,
//!   audio-length bucket).
//! * **L3** — this crate: request router, MIG partition + vGPU service
//!   model, CPU-preprocessing pool, DPU scheduler, the dynamic batching
//!   system, metrics/power/TCO, and both a discrete-event driver (paper
//!   figures) and a real-PJRT driver (end-to-end execution of the lowered
//!   HLO on the CPU PJRT client).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + `manifest.json` once, and the `preba` binary is
//! self-contained afterwards.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod batching;
pub mod cli;
pub mod clock;
pub mod config;
pub mod dpu;
pub mod experiments;
pub mod metrics;
pub mod mig;
pub mod models;
pub mod preprocess;
pub mod profiler;
pub mod rt;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
