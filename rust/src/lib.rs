//! # PREBA — Multi-Instance GPU inference serving, reproduced end-to-end
//!
//! Rust + JAX + Pallas reproduction of *"PREBA: A Hardware/Software
//! Co-Design for Multi-Instance GPU based AI Inference Servers"*
//! (Yeo, Kim, Choi, Rhu — 2024).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas preprocessing kernels (the paper's FPGA DPU,
//!   re-architected for the TPU MXU; `python/compile/kernels/`), AOT-lowered
//!   to HLO text.
//! * **L2** — the six paper workloads (MobileNetV3 / SqueezeNet /
//!   Swin-Transformer / Conformer ×2 / CitriNet) written in JAX
//!   (`python/compile/models/`), AOT-lowered per (model, batch,
//!   audio-length bucket).
//! * **L3** — this crate: request router, MIG partition + vGPU service
//!   model, CPU-preprocessing pool, DPU scheduler, the dynamic batching
//!   system, metrics/power/TCO, and both a discrete-event driver (paper
//!   figures) and a real-PJRT driver (end-to-end execution of the lowered
//!   HLO on the CPU PJRT client).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + `manifest.json` once, and the `preba` binary is
//! self-contained afterwards.
//!
//! ## Module map (bottom-up)
//!
//! | layer | modules | role |
//! |---|---|---|
//! | core | [`clock`], [`util`], [`sim`] | virtual time, RNG/stats/JSON/job pool, 4-ary event heap |
//! | models | [`models`], [`mig`], [`profiler`] | workload specs, MIG geometry + service model + packing/reconfig planners |
//! | serving | [`batching`], [`preprocess`], [`dpu`], [`workload`] | dynamic batching, CPU-pool/DPU preprocessing, arrival synthesis + trace replay |
//! | drivers | [`server`], [`fault`] | DES drivers (single GPU, multi-tenant, multi-GPU cluster) + the real-PJRT driver, fault injection/recovery for the fleet |
//! | surface | [`experiments`], [`metrics`], [`obs`], [`energy`], [`config`], [`cli`], [`rt`], [`runtime`], [`prelude`] | figure regeneration, power/energy/TCO accounting, run observability (windowed series, sampled spans, Perfetto export), TOML config, CLI plumbing, PJRT runtime, one-line imports |
//!
//! `ARCHITECTURE.md` walks the same map in prose — including the
//! drain → outage → restart reconfiguration lifecycle and the
//! determinism contract; `EXPERIMENTS.md` has the per-experiment notes
//! and paper-vs-measured results.
//!
//! A five-line taste of the analytic layer (everything below the DES is
//! callable as a library):
//!
//! ```
//! use preba::mig::placement::{pack, SliceAsk};
//! use preba::mig::{PackStrategy, Slice};
//!
//! // Three 4g.20gb asks onto two A100s: one per GPU fits, the third is
//! // rejected (7 - 4 = 3 GPCs left on each).
//! let asks = vec![SliceAsk { tenant: 0, slice: Slice::new(4, 20) }; 3];
//! let packing = pack(&asks, 2, PackStrategy::BestFit);
//! assert_eq!(packing.placements.len(), 2);
//! assert_eq!(packing.rejected.len(), 1);
//! ```

pub mod batching;
pub mod cli;
pub mod clock;
pub mod config;
pub mod dpu;
pub mod energy;
pub mod experiments;
pub mod fault;
pub mod metrics;
pub mod mig;
pub mod models;
pub mod obs;
pub mod prelude;
pub mod preprocess;
pub mod profiler;
pub mod rt;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
