//! Component power models (paper §6.2, Fig 20).
//!
//! Two granularities share this module:
//!
//! * [`PowerModel`] — the utilization-weighted snapshot model the paper's
//!   Fig 20/21 arithmetic uses: component power = idle floor +
//!   (TDP − idle) × utilization. The paper's observations this must
//!   reproduce: PREBA cuts CPU power ~35.4% on average (preprocessing off
//!   the host); PREBA *raises* GPU power (~2.8× for audio) because
//!   utilization rises; the DPU adds FPGA power but net energy-efficiency
//!   improves ~3.5×.
//! * [`EnergyModel`] — the component *integrator* the DES drivers use:
//!   per-GPC active/idle watts plus a GPU uncore/HBM floor (presets per
//!   [`GpuClass`], TOML-overridable under `[energy]`), per-host-core CPU
//!   power, the FPGA DPU, and a constant host base draw. Its default
//!   constants are calibrated so that a fully-utilized / fully-idle A100
//!   lands on the same ~400 W / ~80 W envelope as [`PowerModel`]'s TDP ×
//!   idle-fraction defaults — the two models agree at the endpoints and
//!   differ only in what they can resolve (per-GPC occupancy, powered-off
//!   GPUs).

use crate::config::{EnergyConfig, PowerConfig};
use crate::mig::GpuClass;

/// Per-component and total watts.
#[derive(Debug, Clone, Copy, Default)]
pub struct PowerBreakdown {
    pub cpu_w: f64,
    pub gpu_w: f64,
    pub fpga_w: f64,
    pub base_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.cpu_w + self.gpu_w + self.fpga_w + self.base_w
    }
}

/// Utilization-weighted power model.
#[derive(Debug, Clone)]
pub struct PowerModel {
    cfg: PowerConfig,
}

impl PowerModel {
    pub fn new(cfg: &PowerConfig) -> PowerModel {
        PowerModel { cfg: cfg.clone() }
    }

    /// System power given component utilizations in [0,1].
    ///
    /// * `cpu_util` — host cores busy fraction (preprocessing + serving).
    /// * `gpu_util` — mean vGPU utilization × fraction of GPCs active.
    /// * `fpga_util` — `None` when no DPU is installed (baseline).
    pub fn power(&self, cpu_util: f64, gpu_util: f64, fpga_util: Option<f64>) -> PowerBreakdown {
        let c = &self.cfg;
        let scale = |tdp: f64, idle_frac: f64, u: f64| {
            tdp * (idle_frac + (1.0 - idle_frac) * u.clamp(0.0, 1.0))
        };
        PowerBreakdown {
            cpu_w: scale(c.cpu_tdp_w, c.cpu_idle_frac, cpu_util),
            gpu_w: scale(c.gpu_tdp_w, c.gpu_idle_frac, gpu_util),
            fpga_w: fpga_util.map_or(0.0, |u| scale(c.fpga_w, c.fpga_idle_frac, u)),
            base_w: c.server_base_w,
        }
    }

    /// Energy efficiency: queries per joule (= QPS / W).
    pub fn qpj(&self, qps: f64, breakdown: &PowerBreakdown) -> f64 {
        if breakdown.total() <= 0.0 {
            0.0
        } else {
            qps / breakdown.total()
        }
    }
}

/// Per-component energy integrated over a simulation run, joules.
///
/// Conservation invariant (pinned by `tests/prop_energy.rs`): the total
/// is exactly the sum of the components, and each component equals the
/// ∫power·dt of its model over the horizon.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Active-GPC energy (GPCs executing batches).
    pub gpu_active_j: f64,
    /// Idle-GPC + GPU uncore/HBM energy of powered-on GPUs. A powered-
    /// down GPU contributes nothing here (idle-power elision).
    pub gpu_idle_j: f64,
    /// Host CPU cores (preprocessing pool busy time + serving reserve
    /// active, remaining cores at the idle floor).
    pub cpu_j: f64,
    /// FPGA DPU energy (0 when no DPU is installed).
    pub dpu_j: f64,
    /// Host base draw (DRAM, fans, NIC).
    pub base_j: f64,
}

impl EnergyBreakdown {
    /// Total integrated energy, joules.
    pub fn total_j(&self) -> f64 {
        self.gpu_active_j + self.gpu_idle_j + self.cpu_j + self.dpu_j + self.base_j
    }

    /// Component-wise accumulation (fleet totals from per-GPU parts).
    pub fn accumulate(&mut self, other: &EnergyBreakdown) {
        self.gpu_active_j += other.gpu_active_j;
        self.gpu_idle_j += other.gpu_idle_j;
        self.cpu_j += other.cpu_j;
        self.dpu_j += other.dpu_j;
        self.base_j += other.base_j;
    }
}

/// One GPU class's power parameters (per-GPC + uncore).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuPowerParams {
    /// Watts of one GPC executing a batch.
    pub gpc_active_w: f64,
    /// Watts of one powered-but-idle GPC.
    pub gpc_idle_w: f64,
    /// Uncore/HBM/NVLink floor of a powered-on GPU, W.
    pub uncore_w: f64,
}

/// Component energy integrator over DES busy-time integrals.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    cfg: EnergyConfig,
}

impl EnergyModel {
    pub fn new(cfg: &EnergyConfig) -> EnergyModel {
        EnergyModel { cfg: cfg.clone() }
    }

    /// Per-GPC/uncore parameters for a GPU class. Classes resolve by
    /// name (`a100` / `a30`); unknown classes fall back to the A100
    /// preset (the conservative choice — never under-reports energy for
    /// a bigger part).
    pub fn gpu_params(&self, class: &GpuClass) -> GpuPowerParams {
        let c = &self.cfg;
        match class.name {
            "a30" => GpuPowerParams {
                gpc_active_w: c.a30_gpc_active_w,
                gpc_idle_w: c.a30_gpc_idle_w,
                uncore_w: c.a30_uncore_w,
            },
            _ => GpuPowerParams {
                gpc_active_w: c.gpc_active_w,
                gpc_idle_w: c.gpc_idle_w,
                uncore_w: c.uncore_w,
            },
        }
    }

    /// Integrate one GPU: `busy_gpc_s` GPC-seconds spent executing and
    /// `on_s` seconds powered on, over the class's total GPC count.
    /// Returns `(active_j, idle_j)`; `idle_j` covers idle GPCs plus the
    /// uncore floor for the powered-on interval only.
    pub fn gpu_energy(&self, class: &GpuClass, busy_gpc_s: f64, on_s: f64) -> (f64, f64) {
        self.gpu_energy_weighted(class, busy_gpc_s, busy_gpc_s, on_s)
    }

    /// [`Self::gpu_energy`] with a curve-weighted active integral: the
    /// per-(model, profile, batch) power multipliers and the interference
    /// penalty scale each batch's GPC-time contribution, so the dispatch
    /// paths accumulate `weighted_busy_gpc_s = Σ exec · pow_mult · penalty`
    /// alongside the unweighted `busy_gpc_s`. Active energy integrates the
    /// weighted time; the idle complement still uses *wall-clock* busy time
    /// (a GPC drawing 1.1× active watts is not idle for -0.1× seconds).
    /// With all multipliers at 1.0 the two integrals are equal and this is
    /// bit-identical to `gpu_energy`.
    pub fn gpu_energy_weighted(
        &self,
        class: &GpuClass,
        busy_gpc_s: f64,
        weighted_busy_gpc_s: f64,
        on_s: f64,
    ) -> (f64, f64) {
        let p = self.gpu_params(class);
        let idle_gpc_s = (class.gpcs as f64 * on_s - busy_gpc_s).max(0.0);
        (p.gpc_active_w * weighted_busy_gpc_s, p.gpc_idle_w * idle_gpc_s + p.uncore_w * on_s)
    }

    /// Host CPU energy: `active_core_s` core-seconds busy (preprocessing
    /// pool + serving reserve) out of `total_core_s` provisioned.
    pub fn cpu_energy(&self, active_core_s: f64, total_core_s: f64) -> f64 {
        let active = active_core_s.clamp(0.0, total_core_s);
        self.cfg.cpu_core_active_w * active
            + self.cfg.cpu_core_idle_w * (total_core_s - active)
    }

    /// DPU energy over `horizon_s` at mean CU utilization `util` (linear
    /// idle→active; the FPGA's clock never gates fully off).
    pub fn dpu_energy(&self, util: f64, horizon_s: f64) -> f64 {
        let u = util.clamp(0.0, 1.0);
        (self.cfg.dpu_idle_w + (self.cfg.dpu_active_w - self.cfg.dpu_idle_w) * u) * horizon_s
    }

    /// Host base draw over `horizon_s`.
    pub fn base_energy(&self, horizon_s: f64) -> f64 {
        self.cfg.host_base_w * horizon_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PowerModel {
        PowerModel::new(&PowerConfig::default())
    }

    #[test]
    fn idle_floor_and_tdp_cap() {
        let m = model();
        let idle = m.power(0.0, 0.0, Some(0.0));
        assert!((idle.cpu_w - 180.0 * 0.35).abs() < 1e-9);
        assert!((idle.gpu_w - 400.0 * 0.20).abs() < 1e-9);
        let full = m.power(1.0, 1.0, Some(1.0));
        assert_eq!(full.cpu_w, 180.0);
        assert_eq!(full.gpu_w, 400.0);
        assert_eq!(full.fpga_w, 75.0);
        // clamps
        let over = m.power(5.0, 5.0, Some(5.0));
        assert_eq!(over.total(), full.total());
    }

    #[test]
    fn no_fpga_means_zero_fpga_power() {
        let m = model();
        assert_eq!(m.power(0.5, 0.5, None).fpga_w, 0.0);
    }

    #[test]
    fn preba_direction_of_change() {
        // Baseline: CPU pinned ~90%, GPU starved (~25% util).
        // PREBA: CPU light (~20%), GPU busy (~85%), FPGA on.
        let m = model();
        let base = m.power(0.90, 0.25, None);
        let preba = m.power(0.20, 0.85, Some(0.6));
        assert!(preba.cpu_w < base.cpu_w * 0.75, "CPU power should drop >25%");
        assert!(preba.gpu_w > base.gpu_w * 1.5, "GPU power should rise");
        // Efficiency: PREBA at ~4x the throughput wins despite more watts.
        let eff_base = m.qpj(1000.0, &base);
        let eff_preba = m.qpj(3700.0, &preba);
        assert!(eff_preba / eff_base > 2.0, "ratio={}", eff_preba / eff_base);
    }

    #[test]
    fn qpj_zero_guard() {
        let m = model();
        let bd = PowerBreakdown::default();
        assert_eq!(m.qpj(100.0, &bd), 0.0);
    }

    #[test]
    fn energy_model_endpoints_match_the_snapshot_model() {
        // The integrator's A100 defaults must land on the same envelope
        // as PowerModel's TDP × idle-fraction: ~400 W fully active,
        // ~80 W fully idle (within a few percent).
        let em = EnergyModel::new(&EnergyConfig::default());
        let a100 = GpuClass::A100;
        let (act, idle) = em.gpu_energy(&a100, 7.0, 1.0); // 1 s all-busy
        assert!(((act + idle) - 400.0).abs() < 12.0, "full={}", act + idle);
        let (act0, idle0) = em.gpu_energy(&a100, 0.0, 1.0);
        assert_eq!(act0, 0.0);
        assert!((idle0 - 80.0).abs() < 4.0, "idle={idle0}");
        // 32 cores fully busy ~ 180 W; fully idle ~ 63 W.
        assert!((em.cpu_energy(32.0, 32.0) - 180.0).abs() < 6.0);
        assert!((em.cpu_energy(0.0, 32.0) - 63.0).abs() < 3.0);
        // DPU matches the Alveo envelope.
        assert_eq!(em.dpu_energy(1.0, 1.0), 75.0);
        assert!((em.dpu_energy(0.0, 1.0) - 22.5).abs() < 1e-9);
    }

    #[test]
    fn powered_off_gpu_pays_nothing() {
        let em = EnergyModel::new(&EnergyConfig::default());
        let (act, idle) = em.gpu_energy(&GpuClass::A100, 0.0, 0.0);
        assert_eq!((act, idle), (0.0, 0.0));
        // Half the horizon off: idle energy exactly halves.
        let (_, idle_full) = em.gpu_energy(&GpuClass::A100, 0.0, 2.0);
        let (_, idle_half) = em.gpu_energy(&GpuClass::A100, 0.0, 1.0);
        assert!((idle_full - 2.0 * idle_half).abs() < 1e-9);
    }

    #[test]
    fn a30_params_are_smaller_than_a100() {
        let em = EnergyModel::new(&EnergyConfig::default());
        let a100 = em.gpu_params(&GpuClass::A100);
        let a30 = em.gpu_params(&GpuClass::A30);
        assert!(a30.uncore_w < a100.uncore_w);
        let full_a30 = a30.uncore_w + 4.0 * a30.gpc_active_w;
        let full_a100 = a100.uncore_w + 7.0 * a100.gpc_active_w;
        assert!(full_a30 < 0.5 * full_a100, "a30 {full_a30} vs a100 {full_a100}");
    }

    #[test]
    fn breakdown_conserves_and_accumulates() {
        let mut a = EnergyBreakdown {
            gpu_active_j: 1.0,
            gpu_idle_j: 2.0,
            cpu_j: 3.0,
            dpu_j: 4.0,
            base_j: 5.0,
        };
        assert_eq!(a.total_j(), 15.0);
        let b = a;
        a.accumulate(&b);
        assert_eq!(a.total_j(), 30.0);
        assert_eq!(a.cpu_j, 6.0);
    }
}
