//! Energy & cost accounting: component power models, DES-integrated
//! energy, and the TCO fold onto queries-per-dollar.
//!
//! PREBA's two economic headline claims — **3.5× energy-efficiency** and
//! **3.0× cost-efficiency** (paper §6.2/§6.3, Figs 20/21) — are
//! properties of *integrated* power, not of a point-in-time utilization
//! snapshot. MIGPerf further shows MIG slice energy behavior is workload-
//! and geometry-dependent, so this subsystem makes energy a first-class
//! simulated quantity the schedulers can optimize:
//!
//! * [`model`] — the component power models. [`PowerModel`] is the
//!   utilization-weighted snapshot model Figs 20/21 are built on
//!   (CPU/GPU/FPGA TDP × idle-floor scaling). [`EnergyModel`] is the
//!   finer-grained integrator the DES drivers use: **per-GPC**
//!   active/idle watts plus a GPU uncore/HBM floor (with presets per
//!   [`crate::mig::GpuClass`]), per-host-core CPU power, the FPGA DPU,
//!   and a host base draw — all overridable from TOML under `[energy]`
//!   ([`crate::config::EnergyConfig`]).
//! * DES integration — `server::sim_driver` and `server::cluster`
//!   accumulate busy GPC-time through the same capacity-integral
//!   machinery that tracks `gpu_util` (folding across geometry changes),
//!   and surface an [`EnergyBreakdown`] via
//!   [`crate::metrics::RunStats::energy_j`] /
//!   `joules_per_query` / `perf_per_watt` and
//!   `ClusterOutcome::energy`. A cluster GPU a consolidation decision
//!   powered down stops paying its idle + uncore power (idle-power
//!   elision) for exactly the powered-off interval.
//! * [`tco`] — capex presets + integrated energy folded into
//!   queries-per-dollar over the depreciation horizon
//!   ([`TcoModel::evaluate_watts`] takes the DES's mean measured power
//!   directly).
//!
//! The energy-aware *policy* consuming all this lives in
//! [`crate::mig::reconfig`]: `ClusterReconfigController` with
//! `ReconfigPolicy::consolidate` drains lightly-loaded GPUs under
//! sustained low load and powers them down, with hysteresis so it never
//! fights the rate-driven planner. `preba experiment energy` measures
//! the whole loop.

pub mod model;
pub mod tco;

pub use model::{EnergyBreakdown, EnergyModel, GpuPowerParams, PowerBreakdown, PowerModel};
pub use tco::{TcoModel, TcoReport};
