//! Cost-efficiency / TCO model (paper §6.3, Fig 21).
//!
//! `cost_efficiency = Throughput × time / (CAPEX + OPEX)` where CAPEX is
//! the hardware purchase (server node, GPU, optionally FPGA), `time` is
//! the 3-year depreciation horizon, and OPEX is the electricity for the
//! measured power draw over that horizon.

use crate::config::TcoConfig;

use super::model::PowerBreakdown;

/// TCO calculator.
#[derive(Debug, Clone)]
pub struct TcoModel {
    cfg: TcoConfig,
}

/// One design point's cost summary.
#[derive(Debug, Clone, Copy)]
pub struct TcoReport {
    pub capex_usd: f64,
    pub opex_usd: f64,
    /// Queries served over the horizon.
    pub queries: f64,
    /// Queries per dollar (the paper's cost-efficiency metric).
    pub queries_per_usd: f64,
}

impl TcoModel {
    pub fn new(cfg: &TcoConfig) -> TcoModel {
        TcoModel { cfg: cfg.clone() }
    }

    /// Evaluate a design point sustaining `qps` at `power` draw.
    /// `with_fpga` adds the DPU's CAPEX.
    pub fn evaluate(&self, qps: f64, power: &PowerBreakdown, with_fpga: bool) -> TcoReport {
        self.evaluate_watts(qps, power.total(), with_fpga)
    }

    /// [`TcoModel::evaluate`] from a bare mean power draw — the entry
    /// point for DES-integrated energy: pass
    /// `energy_j / horizon_s` as `total_w` and the measured goodput as
    /// `qps`, and the depreciation-horizon extrapolation is identical to
    /// the snapshot model's.
    pub fn evaluate_watts(&self, qps: f64, total_w: f64, with_fpga: bool) -> TcoReport {
        let c = &self.cfg;
        let capex = c.server_usd + c.gpu_usd + if with_fpga { c.fpga_usd } else { 0.0 };
        let hours = c.years * 365.25 * 24.0;
        let opex = total_w / 1000.0 * hours * c.usd_per_kwh;
        let queries = qps * hours * 3600.0;
        let total = capex + opex;
        TcoReport {
            capex_usd: capex,
            opex_usd: opex,
            queries,
            queries_per_usd: if total > 0.0 { queries / total } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn power(total_w: f64) -> PowerBreakdown {
        PowerBreakdown { cpu_w: total_w, gpu_w: 0.0, fpga_w: 0.0, base_w: 0.0 }
    }

    #[test]
    fn capex_includes_fpga_only_for_preba() {
        let m = TcoModel::new(&TcoConfig::default());
        let a = m.evaluate(100.0, &power(500.0), false);
        let b = m.evaluate(100.0, &power(500.0), true);
        assert_eq!(b.capex_usd - a.capex_usd, 4500.0);
    }

    #[test]
    fn opex_matches_hand_calc() {
        let cfg = TcoConfig { years: 1.0, usd_per_kwh: 0.10, ..Default::default() };
        let m = TcoModel::new(&cfg);
        let r = m.evaluate(1.0, &power(1000.0), false);
        // 1 kW for 1 year at $0.10/kWh = 8766 hours * 0.1 = $876.6
        assert!((r.opex_usd - 876.6).abs() < 0.1, "opex={}", r.opex_usd);
    }

    #[test]
    fn higher_qps_wins_despite_fpga_capex() {
        // The paper's 3.0x cost-efficiency: PREBA's throughput gain
        // dominates the DPU's CAPEX + power.
        let m = TcoModel::new(&TcoConfig::default());
        let base = m.evaluate(1000.0, &power(600.0), false);
        let preba = m.evaluate(3700.0, &power(800.0), true);
        let ratio = preba.queries_per_usd / base.queries_per_usd;
        assert!(ratio > 2.0 && ratio < 5.0, "ratio={ratio}");
    }

    #[test]
    fn evaluate_watts_matches_breakdown_path() {
        let m = TcoModel::new(&TcoConfig::default());
        let a = m.evaluate(500.0, &power(700.0), true);
        let b = m.evaluate_watts(500.0, 700.0, true);
        assert_eq!(a.capex_usd, b.capex_usd);
        assert_eq!(a.opex_usd, b.opex_usd);
        assert_eq!(a.queries_per_usd, b.queries_per_usd);
    }

    #[test]
    fn zero_total_guard() {
        let cfg = TcoConfig {
            server_usd: 0.0,
            gpu_usd: 0.0,
            fpga_usd: 0.0,
            years: 0.0,
            usd_per_kwh: 0.0,
        };
        let m = TcoModel::new(&cfg);
        let r = m.evaluate(10.0, &power(0.0), false);
        assert_eq!(r.queries_per_usd, 0.0);
    }
}
