//! Fault schedules: what breaks, where, when, and for how long.
//!
//! A schedule is an explicit, time-sorted `(t, gpu, kind, duration)`
//! list. It can be built three ways, all deterministic:
//!
//! * [`FaultSchedule::scripted`] — hand-written event lists (tests, the
//!   `faults` experiment's directed scenarios).
//! * [`FaultSchedule::stochastic`] — per-GPU alternating-renewal
//!   up/down processes with exponential MTBF/MTTR, drawn from a seeded
//!   [`Rng`] split per GPU so the schedule is invariant to fleet
//!   iteration order.
//! * [`FaultSchedule::parse`] — the `--faults` / `[fault] spec` grammar:
//!   comma-separated entries `kind@t:gN[:dur[:factor]]`, e.g.
//!   `crash@2.5:g1:1.0,slow@4:g0:2:3.0`, plus `mtbf:M[,mttr:R]` to mix
//!   in a stochastic background.

use crate::util::Rng;

/// The failure modes the cluster DES can inject.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Whole-GPU crash: every slice on the GPU stops executing, in-flight
    /// batches are lost, and the GPU draws no power until repair.
    GpuCrash,
    /// One MIG slice fails: the fullest group on the GPU loses its
    /// earliest-free slice until repair.
    SliceFail,
    /// The GPU's host preprocessing resources (CPU pool / DPU) go down;
    /// requests admitted during the outage wait it out.
    PreprocOutage,
    /// Straggler: service times on the GPU are multiplied by `factor`
    /// for the duration; completions count as served-degraded.
    Slowdown { factor: f64 },
    /// The next repartition/migration plan at or after the fault instant
    /// aborts mid-drain and rolls back (the drained slice returns to its
    /// donor after paying the drain + repartition outage).
    ReconfigAbort,
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::GpuCrash => "crash",
            FaultKind::SliceFail => "slice",
            FaultKind::PreprocOutage => "preproc",
            FaultKind::Slowdown { .. } => "slow",
            FaultKind::ReconfigAbort => "abort",
        }
    }
}

/// One scheduled fault: `(t, target, kind, duration)`.
#[derive(Debug, Clone, Copy)]
pub struct FaultEvent {
    pub at_s: f64,
    pub gpu: usize,
    pub kind: FaultKind,
    /// Repair arrives this long after injection (0 for the instantaneous
    /// [`FaultKind::ReconfigAbort`]). `f64::INFINITY` means the unit never
    /// comes back: no repair event is scheduled, and only recovery (or the
    /// end of the run) resolves whatever the fault stranded. The spec
    /// grammar spells it `inf`, e.g. `crash@2:g1:inf`.
    pub duration_s: f64,
}

/// A deterministic fault schedule, sorted by injection time.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    pub events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// An explicit event list (sorted on construction; ties keep their
    /// given order).
    pub fn scripted(mut events: Vec<FaultEvent>) -> FaultSchedule {
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FaultSchedule { events }
    }

    /// Per-GPU alternating-renewal fault process: up-times are
    /// exponential with mean `mtbf_s`, down-times exponential with mean
    /// `mttr_s` (floored at 1% of the mean so a repair is never
    /// instantaneous). Kinds are drawn 40% crash / 30% slice /
    /// 20% slowdown (factor 1.5–3.5) / 10% preprocessing outage.
    /// Each GPU draws from its own [`Rng::split`] stream, so the
    /// schedule does not depend on how many faults other GPUs see.
    pub fn stochastic(
        mtbf_s: f64,
        mttr_s: f64,
        horizon_s: f64,
        n_gpus: usize,
        rng: &mut Rng,
    ) -> FaultSchedule {
        assert!(mtbf_s > 0.0 && mttr_s > 0.0 && horizon_s > 0.0, "non-positive MTBF/MTTR");
        let mut events = Vec::new();
        for g in 0..n_gpus {
            let mut r = rng.split(0xFA17_0000 + g as u64);
            let mut t = r.exp(1.0 / mtbf_s);
            while t < horizon_s {
                let duration_s = r.exp(1.0 / mttr_s).max(0.01 * mttr_s);
                let kind = match r.below(10) {
                    0..=3 => FaultKind::GpuCrash,
                    4..=6 => FaultKind::SliceFail,
                    7..=8 => FaultKind::Slowdown { factor: 1.5 + 2.0 * r.f64() },
                    _ => FaultKind::PreprocOutage,
                };
                events.push(FaultEvent { at_s: t, gpu: g, kind, duration_s });
                t += duration_s + r.exp(1.0 / mtbf_s);
            }
        }
        FaultSchedule::scripted(events)
    }

    /// Parse a `--faults` spec string. Grammar (comma-separated):
    ///
    /// * `crash@T:gN[:DUR]` — GPU `N` crashes at `T` s for `DUR` s (1.0)
    /// * `slice@T:gN[:DUR]` — one slice on GPU `N` fails
    /// * `preproc@T:gN[:DUR]` — GPU `N`'s preprocessing is out
    /// * `slow@T:gN[:DUR[:FACTOR]]` — service ×`FACTOR` (2.0) for `DUR` s
    /// * `abort@T:gN` — the next reconfig plan at/after `T` aborts
    /// * `mtbf:M` / `mttr:R` — add a stochastic background over the
    ///   horizon (MTTR defaults to `M/10`), seeded from `seed`
    ///
    /// A GPU target is `gN` or a bare index. `DUR` may be `inf` for a
    /// permanent fault that is never repaired.
    pub fn parse(
        spec: &str,
        n_gpus: usize,
        horizon_s: f64,
        seed: u64,
    ) -> anyhow::Result<FaultSchedule> {
        let mut events = Vec::new();
        let (mut mtbf, mut mttr) = (None, None);
        for ent in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            if let Some(v) = ent.strip_prefix("mtbf:") {
                mtbf = Some(parse_num(v, ent, "MTBF")?);
                continue;
            }
            if let Some(v) = ent.strip_prefix("mttr:") {
                mttr = Some(parse_num(v, ent, "MTTR")?);
                continue;
            }
            let (kind_s, rest) = ent.split_once('@').ok_or_else(|| {
                anyhow::anyhow!(
                    "fault entry '{ent}': expected kind@t:gN[:dur[:factor]], \
                     mtbf:M, or mttr:R"
                )
            })?;
            let parts: Vec<&str> = rest.split(':').collect();
            anyhow::ensure!(parts.len() >= 2, "fault entry '{ent}': missing target GPU");
            let at_s = parse_num(parts[0], ent, "time")?;
            let gpu = parse_gpu(parts[1], ent)?;
            let num_at = |i: usize, what: &str, default: f64| -> anyhow::Result<f64> {
                match parts.get(i) {
                    None => Ok(default),
                    Some(s) => parse_num(s, ent, what),
                }
            };
            let (kind, duration_s) = match kind_s {
                "crash" => (FaultKind::GpuCrash, num_at(2, "duration", 1.0)?),
                "slice" => (FaultKind::SliceFail, num_at(2, "duration", 1.0)?),
                "preproc" => (FaultKind::PreprocOutage, num_at(2, "duration", 1.0)?),
                "slow" => (
                    FaultKind::Slowdown { factor: num_at(3, "factor", 2.0)? },
                    num_at(2, "duration", 1.0)?,
                ),
                "abort" => (FaultKind::ReconfigAbort, 0.0),
                other => anyhow::bail!(
                    "unknown fault kind '{other}' in '{ent}' \
                     (crash|slice|preproc|slow|abort)"
                ),
            };
            events.push(FaultEvent { at_s, gpu, kind, duration_s });
        }
        if let Some(m) = mtbf {
            let r = mttr.unwrap_or(m / 10.0);
            let mut rng = Rng::new(seed ^ 0xFA17_C0DE);
            events.extend(FaultSchedule::stochastic(m, r, horizon_s, n_gpus, &mut rng).events);
        } else {
            anyhow::ensure!(mttr.is_none(), "mttr: given without mtbf:");
        }
        let sched = FaultSchedule::scripted(events);
        sched.validate(n_gpus)?;
        Ok(sched)
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn validate(&self, n_gpus: usize) -> anyhow::Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            anyhow::ensure!(
                e.at_s.is_finite() && e.at_s >= 0.0,
                "fault {i}: bad injection time {}",
                e.at_s
            );
            // Infinity is legal (permanent fault, never repaired); NaN and
            // negatives are not.
            anyhow::ensure!(
                !e.duration_s.is_nan() && e.duration_s >= 0.0,
                "fault {i}: bad duration {}",
                e.duration_s
            );
            anyhow::ensure!(
                e.gpu < n_gpus,
                "fault {i}: GPU g{} outside the {n_gpus}-GPU fleet",
                e.gpu
            );
            if let FaultKind::Slowdown { factor } = e.kind {
                anyhow::ensure!(
                    factor.is_finite() && factor >= 1.0,
                    "fault {i}: slowdown factor {factor} must be >= 1"
                );
            }
            if !matches!(e.kind, FaultKind::ReconfigAbort) {
                anyhow::ensure!(e.duration_s > 0.0, "fault {i}: zero-length outage");
            }
        }
        Ok(())
    }
}

fn parse_num(s: &str, ent: &str, what: &str) -> anyhow::Result<f64> {
    s.trim()
        .parse::<f64>()
        .map_err(|_| anyhow::anyhow!("fault entry '{ent}': bad {what} '{s}'"))
}

fn parse_gpu(s: &str, ent: &str) -> anyhow::Result<usize> {
    let digits = s.strip_prefix('g').unwrap_or(s);
    digits
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("fault entry '{ent}': bad GPU target '{s}' (use gN)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_every_kind_and_sorts() {
        let s = FaultSchedule::parse(
            "slow@4:g0:2:3.0, crash@2.5:g1:1.0, abort@5:g1, slice@1:0:0.5, preproc@3:g0",
            2,
            10.0,
            7,
        )
        .unwrap();
        assert_eq!(s.len(), 5);
        assert!(s.events.windows(2).all(|w| w[0].at_s <= w[1].at_s), "unsorted");
        assert_eq!(s.events[0].kind, FaultKind::SliceFail);
        assert_eq!(s.events[0].gpu, 0, "bare GPU index accepted");
        assert_eq!(s.events[1].kind, FaultKind::GpuCrash);
        assert!(matches!(s.events[4].kind, FaultKind::ReconfigAbort));
        assert_eq!(s.events[2].duration_s, 1.0, "preproc default duration");
        assert!(matches!(s.events[3].kind, FaultKind::Slowdown { factor } if factor == 3.0));
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "crash@2.5",             // no target
            "crash@x:g0",            // bad time
            "crash@1:g9:1.0",        // GPU outside fleet
            "melt@1:g0:1.0",         // unknown kind
            "slow@1:g0:1.0:0.5",     // factor < 1
            "crash@1:g0:0",          // zero-length outage
            "mttr:0.5",              // mttr without mtbf
            "crash",                 // no @
        ] {
            assert!(FaultSchedule::parse(bad, 2, 10.0, 7).is_err(), "accepted '{bad}'");
        }
        assert!(FaultSchedule::parse("", 2, 10.0, 7).unwrap().is_empty());
    }

    #[test]
    fn infinite_duration_means_permanent_fault() {
        let s = FaultSchedule::parse("crash@2:g1:inf", 2, 10.0, 7).unwrap();
        assert_eq!(s.len(), 1);
        assert!(s.events[0].duration_s.is_infinite());
        assert!(s.validate(2).is_ok(), "inf duration must validate");
        assert!(FaultSchedule::parse("crash@2:g1:nan", 2, 10.0, 7).is_err());
        assert!(FaultSchedule::parse("crash@2:g1:-1", 2, 10.0, 7).is_err());
    }

    #[test]
    fn stochastic_is_seeded_and_respects_the_horizon() {
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        let a = FaultSchedule::stochastic(2.0, 0.5, 30.0, 3, &mut r1);
        let b = FaultSchedule::stochastic(2.0, 0.5, 30.0, 3, &mut r2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.gpu, y.gpu);
        }
        assert!(!a.is_empty(), "30 s at MTBF 2 s should fault");
        assert!(a.events.iter().all(|e| e.at_s < 30.0 && e.duration_s > 0.0));
        assert!(a.validate(3).is_ok());
        let mut r3 = Rng::new(12);
        let c = FaultSchedule::stochastic(2.0, 0.5, 30.0, 3, &mut r3);
        assert!(
            a.len() != c.len()
                || a.events.iter().zip(&c.events).any(|(x, y)| x.at_s != y.at_s),
            "seed ignored"
        );
    }

    #[test]
    fn parse_mixes_scripted_and_stochastic() {
        let s = FaultSchedule::parse("crash@1:g0:2,mtbf:3,mttr:0.5", 2, 20.0, 9).unwrap();
        assert!(s.len() > 1, "stochastic background missing");
        assert!(s.events.iter().any(|e| e.at_s == 1.0 && e.gpu == 0));
    }
}
