//! Recovery policy: how the fleet fights back after an injected fault.
//!
//! Recovery in `server::cluster` is layered, mirroring production
//! serving stacks:
//!
//! 1. **Detection** — a crashed group keeps receiving traffic for
//!    `detect_s` (the health-check interval); only then does the router
//!    learn, flush the dead group's queue to surviving replicas, and
//!    hand the controller the lost capacity.
//! 2. **Timeout + retry** — requests lost in-flight are noticed by the
//!    client `timeout_s` after the crash and re-submitted with
//!    exponential backoff, up to `max_retries`; an exhausted budget is a
//!    timed-out request (terminal, counted separately from drops).
//! 3. **Hedging** (optional) — a request unanswered after `hedge_s`
//!    whose routed group has silently failed is re-issued to a second
//!    replica; the first completion wins, the loser is discarded.
//! 4. **Failover re-packing** — capacity the crash destroyed re-enters
//!    the controller's pending-ask queue and is re-admitted through
//!    `try_admit` onto surviving (or repaired) GPUs, paying the
//!    migration outage like any late admission.
//!
//! Degradation is graceful by construction: when surviving capacity
//! cannot carry the load, the existing admission queues (weighted
//! round-robin drain) shed the overflow rather than collapsing.

/// Knobs for the recovery layers (all deterministic; no RNG involved).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPolicy {
    /// Health-check latency, seconds: how long a crashed group keeps
    /// receiving new traffic before the router learns.
    pub detect_s: f64,
    /// Client-side request timeout, seconds: a request lost in a crash
    /// is noticed (retried, or given up on) this long after the fault.
    pub timeout_s: f64,
    /// Retry budget per request; 0 disables retries entirely.
    pub max_retries: u32,
    /// Exponential backoff base, seconds: retry `k` (0-based) waits
    /// `backoff_s * 2^k` after its timeout fires.
    pub backoff_s: f64,
    /// Hedged requests: when > 0, a request unanswered after this many
    /// seconds whose routed group has failed is re-issued to a second
    /// replica. 0 disables hedging.
    pub hedge_s: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy {
            detect_s: 0.2,
            timeout_s: 0.25,
            max_retries: 3,
            backoff_s: 0.05,
            hedge_s: 0.0,
        }
    }
}

impl RecoveryPolicy {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.detect_s.is_finite() && self.detect_s >= 0.0,
            "detection latency must be >= 0, got {}",
            self.detect_s
        );
        anyhow::ensure!(
            self.timeout_s.is_finite() && self.timeout_s > 0.0,
            "request timeout must be > 0, got {}",
            self.timeout_s
        );
        anyhow::ensure!(
            self.backoff_s.is_finite() && self.backoff_s >= 0.0,
            "retry backoff must be >= 0, got {}",
            self.backoff_s
        );
        anyhow::ensure!(
            self.hedge_s.is_finite() && self.hedge_s >= 0.0,
            "hedge delay must be >= 0, got {}",
            self.hedge_s
        );
        Ok(())
    }

    /// Backoff before retry `attempt` (0-based), seconds. The exponent
    /// is clamped so a deep budget cannot overflow into infinity.
    pub fn backoff_delay_s(&self, attempt: u32) -> f64 {
        self.backoff_s * f64::from(1u32 << attempt.min(16))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_saturates() {
        let p = RecoveryPolicy { backoff_s: 0.05, ..Default::default() };
        assert!((p.backoff_delay_s(0) - 0.05).abs() < 1e-12);
        assert!((p.backoff_delay_s(1) - 0.10).abs() < 1e-12);
        assert!((p.backoff_delay_s(3) - 0.40).abs() < 1e-12);
        assert!(p.backoff_delay_s(1000).is_finite(), "exponent must clamp");
    }

    #[test]
    fn validation_rejects_degenerate_knobs() {
        assert!(RecoveryPolicy::default().validate().is_ok());
        assert!(RecoveryPolicy { detect_s: -0.1, ..Default::default() }.validate().is_err());
        assert!(RecoveryPolicy { timeout_s: 0.0, ..Default::default() }.validate().is_err());
        assert!(
            RecoveryPolicy { backoff_s: f64::NAN, ..Default::default() }.validate().is_err()
        );
        assert!(RecoveryPolicy { hedge_s: -1.0, ..Default::default() }.validate().is_err());
    }
}
