//! Fault injection & failure recovery for the cluster DES.
//!
//! The fleet simulated by `server::cluster` was a fair-weather machine:
//! no GPU, slice, or preprocessing unit ever failed, so the reconfig
//! planner, admission control, and consolidation had never been
//! exercised under loss of capacity. Real MIG serving systems must
//! survive exactly this — MIG-Serving (arXiv:2109.11067) frames
//! reconfiguration as rescheduling under a changing machine set, and
//! ParvaGPU (arXiv:2409.14447) targets cloud scales where unit failures
//! are routine.
//!
//! Two halves:
//!
//! * [`inject`] — deterministic fault schedules: explicit
//!   `(t, target, kind, duration)` event lists, a `--faults` spec-string
//!   grammar, and stochastic MTBF/MTTR generation seeded via
//!   [`crate::util::Rng`] (so `--jobs N` sweeps stay byte-identical).
//! * [`recover`] — the recovery policy: detection latency, per-request
//!   timeout + retry with exponential backoff, optional hedged requests,
//!   and failover re-packing through the reconfig controller's
//!   `try_admit` seam.
//!
//! The DES wiring lives in `server::cluster`: a [`FaultSpec`] on
//! `ClusterConfig::faults` turns faults on; `recovery: None` is the
//! no-recovery baseline the `faults` experiment compares against.

pub mod inject;
pub mod recover;

pub use inject::{FaultEvent, FaultKind, FaultSchedule};
pub use recover::RecoveryPolicy;

/// What a cluster run should break, and whether the fleet fights back.
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    pub schedule: FaultSchedule,
    /// `None` = the no-recovery baseline: faults strike but nothing is
    /// detected, retried, re-routed, or re-packed — lost requests time
    /// out and blind routing keeps feeding dead groups until repair.
    pub recovery: Option<RecoveryPolicy>,
}

impl FaultSpec {
    /// Scripted faults with recovery enabled at the given policy.
    pub fn recovering(schedule: FaultSchedule, recovery: RecoveryPolicy) -> FaultSpec {
        FaultSpec { schedule, recovery: Some(recovery) }
    }

    /// The same schedule with recovery stripped (the A/B baseline).
    pub fn baseline(schedule: FaultSchedule) -> FaultSpec {
        FaultSpec { schedule, recovery: None }
    }

    pub fn validate(&self, n_gpus: usize) -> anyhow::Result<()> {
        self.schedule.validate(n_gpus)?;
        if let Some(r) = &self.recovery {
            r.validate()?;
        }
        Ok(())
    }
}

/// One injected fault's observed lifecycle — drives the CLI timeline and
/// the MTTR aggregate on `ClusterOutcome`.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub at_s: f64,
    pub gpu: usize,
    pub kind: FaultKind,
    /// When the health check noticed (recovery runs only; crashes).
    pub detected_s: Option<f64>,
    /// When the unit came back. `None` = still down at the horizon.
    pub repaired_s: Option<f64>,
    /// The fault landed on a unit already down and was ignored.
    pub skipped: bool,
}

impl FaultRecord {
    /// Observed time-to-repair, seconds.
    pub fn ttr_s(&self) -> Option<f64> {
        self.repaired_s.map(|r| r - self.at_s)
    }
}

/// Mean time-to-repair over the records whose repair completed, seconds
/// (0 when nothing was repaired). [`FaultKind::ReconfigAbort`] records are
/// excluded: an abort's "repair" stamp is the instant its arm was
/// consumed, not a unit coming back from downtime.
pub fn mttr_s(records: &[FaultRecord]) -> f64 {
    let reps: Vec<f64> = records
        .iter()
        .filter(|r| !matches!(r.kind, FaultKind::ReconfigAbort))
        .filter_map(FaultRecord::ttr_s)
        .collect();
    if reps.is_empty() {
        0.0
    } else {
        reps.iter().sum::<f64>() / reps.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mttr_averages_completed_repairs_only() {
        let rec = |at, rep| FaultRecord {
            at_s: at,
            gpu: 0,
            kind: FaultKind::GpuCrash,
            detected_s: None,
            repaired_s: rep,
            skipped: false,
        };
        assert_eq!(mttr_s(&[]), 0.0);
        let recs = [rec(1.0, Some(2.0)), rec(5.0, Some(8.0)), rec(9.0, None)];
        assert!((mttr_s(&recs) - 2.0).abs() < 1e-12);
        let abort = FaultRecord {
            at_s: 0.0,
            gpu: 0,
            kind: FaultKind::ReconfigAbort,
            detected_s: None,
            repaired_s: Some(100.0),
            skipped: false,
        };
        let mixed = [recs[0].clone(), recs[1].clone(), abort];
        assert!((mttr_s(&mixed) - 2.0).abs() < 1e-12, "aborts are not repairs");
    }

    #[test]
    fn spec_validation_composes_schedule_and_policy() {
        let sched = FaultSchedule::parse("crash@1:g0:0.5", 2, 10.0, 7).unwrap();
        assert!(FaultSpec::baseline(sched.clone()).validate(2).is_ok());
        assert!(FaultSpec::baseline(sched.clone()).validate(1).is_err(), "gpu out of fleet");
        let bad = RecoveryPolicy { timeout_s: -1.0, ..Default::default() };
        assert!(FaultSpec::recovering(sched, bad).validate(2).is_err());
    }
}
