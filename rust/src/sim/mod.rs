//! Discrete-event simulation core.
//!
//! The paper's figures are statistical properties of a queueing system
//! (arrivals → preprocessing → batching → vGPU execution). On this
//! single-core CI box we regenerate them with a deterministic DES under a
//! [`crate::clock::VirtualClock`]; the identical coordinator code also runs
//! under the real-PJRT driver (`server::real_driver`) for end-to-end
//! validation.
//!
//! Design: a 4-ary implicit min-heap event queue of `(time, seq, Event)`.
//! `seq` breaks ties FIFO so runs are bit-reproducible. The 4-ary layout
//! halves the tree depth of a binary heap and keeps all four children of a
//! node in one cache line's worth of entries, which measurably cuts the
//! schedule/pop cost that dominates the whole-sim hot path. The event type
//! is generic: the concrete server simulation (`server::sim_driver`)
//! defines its own event enum and owns all component state, which keeps the
//! borrow checker out of the way (no `Rc<RefCell<dyn Actor>>` web).

use crate::clock::Nanos;

/// Heap branching factor. 4 keeps sift-down comparisons sequential in
/// memory; measured faster than 2 (deeper tree) and 8 (more compares).
const ARITY: usize = 4;

/// An entry in the event queue.
struct Scheduled<E> {
    at: Nanos,
    seq: u64,
    ev: E,
}

impl<E> Scheduled<E> {
    /// Min-heap key: earliest time first, FIFO (insertion seq) among ties.
    #[inline]
    fn key(&self) -> (Nanos, u64) {
        (self.at, self.seq)
    }
}

/// Event queue with virtual time, backed by a 4-ary implicit heap.
pub struct EventQueue<E> {
    heap: Vec<Scheduled<E>>,
    seq: u64,
    now: Nanos,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: Vec::new(), seq: 0, now: 0, processed: 0 }
    }

    /// Pre-size the heap for a known event population (e.g. all arrivals).
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: Vec::with_capacity(cap), seq: 0, now: 0, processed: 0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Nanos {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past clamps
    /// to `now` (events fire immediately, in FIFO order).
    pub fn schedule(&mut self, at: Nanos, ev: E) {
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Scheduled { at, seq: self.seq, ev });
        self.sift_up(self.heap.len() - 1);
    }

    /// Schedule `ev` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: Nanos, ev: E) {
        self.schedule(self.now.saturating_add(delay), ev);
    }

    /// Pop the next event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(Nanos, E)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let s = self.heap.pop().expect("non-empty heap");
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        debug_assert!(s.at >= self.now);
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.ev))
    }

    /// Time of the next scheduled event, if any.
    pub fn peek_time(&self) -> Option<Nanos> {
        self.heap.first().map(|s| s.at)
    }

    /// Advance virtual time to `at` without popping (never moves time
    /// backwards). Used by drivers that inject externally-sourced events
    /// (lazy arrival streams) between heap pops: the injected event's
    /// timestamp becomes `now` so subsequent `schedule` calls clamp
    /// correctly.
    pub fn advance_to(&mut self, at: Nanos) {
        debug_assert!(
            self.peek_time().is_none_or(|t| at <= t),
            "advance_to({at}) past the next scheduled event"
        );
        self.now = self.now.max(at);
    }

    #[inline]
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / ARITY;
            if self.heap[i].key() < self.heap[parent].key() {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    #[inline]
    fn sift_down(&mut self, mut i: usize) {
        let len = self.heap.len();
        loop {
            let first_child = ARITY * i + 1;
            if first_child >= len {
                break;
            }
            let mut smallest = i;
            let end = (first_child + ARITY).min(len);
            for c in first_child..end {
                if self.heap[c].key() < self.heap[smallest].key() {
                    smallest = c;
                }
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// Drive a simulation to completion: repeatedly pop events and hand them to
/// `step` together with the queue (so handlers can schedule more). Stops
/// when the queue drains, `step` returns `false`, or `max_events` fires
/// (runaway guard).
pub fn run<E, F: FnMut(Nanos, E, &mut EventQueue<E>) -> bool>(
    q: &mut EventQueue<E>,
    max_events: u64,
    mut step: F,
) -> u64 {
    let mut n = 0;
    while let Some((t, ev)) = q.pop() {
        n += 1;
        if !step(t, ev, q) || n >= max_events {
            break;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.schedule(20, "b");
        q.schedule(10, "a1");
        q.schedule(10, "a2");
        q.schedule(30, "c");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![(10, "a1"), (10, "a2"), (20, "b"), (30, "c")]
        );
    }

    #[test]
    fn clamps_past_scheduling() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(100, 1);
        q.pop();
        assert_eq!(q.now(), 100);
        q.schedule(50, 2); // in the past -> fires now
        assert_eq!(q.pop(), Some((100, 2)));
    }

    #[test]
    fn run_drives_cascade() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule(0, 0);
        let mut fired = Vec::new();
        run(&mut q, 1000, |t, ev, q| {
            fired.push((t, ev));
            if ev < 4 {
                q.schedule_in(10, ev + 1);
            }
            true
        });
        assert_eq!(fired, vec![(0, 0), (10, 1), (20, 2), (30, 3), (40, 4)]);
    }

    #[test]
    fn run_respects_max_events() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(0, 0);
        let n = run(&mut q, 5, |_, _, q| {
            q.schedule_in(1, 0); // infinite cascade
            true
        });
        assert_eq!(n, 5);
    }

    #[test]
    fn sift_paths_cover_deep_heaps() {
        // Enough entries for several 4-ary levels, descending insert order
        // (every insert sifts to the root) then ascending pops (every pop
        // sifts down the full depth).
        let mut q: EventQueue<u64> = EventQueue::new();
        let n = 1000u64;
        for i in (0..n).rev() {
            q.schedule(i, i);
        }
        assert_eq!(q.len(), n as usize);
        assert_eq!(q.peek_time(), Some(0));
        for expect in 0..n {
            assert_eq!(q.pop(), Some((expect, expect)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_schedule_pop_keeps_order() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule(5, 50);
        q.schedule(1, 10);
        assert_eq!(q.pop(), Some((1, 10)));
        q.schedule(3, 30);
        q.schedule(2, 20);
        assert_eq!(q.pop(), Some((2, 20)));
        assert_eq!(q.pop(), Some((3, 30)));
        assert_eq!(q.pop(), Some((5, 50)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.processed(), 4);
    }
}
