//! Discrete-event simulation core.
//!
//! The paper's figures are statistical properties of a queueing system
//! (arrivals → preprocessing → batching → vGPU execution). On this
//! single-core CI box we regenerate them with a deterministic DES under a
//! [`crate::clock::VirtualClock`]; the identical coordinator code also runs
//! under the real-PJRT driver (`server::real_driver`) for end-to-end
//! validation.
//!
//! Design: a binary-heap event queue of `(time, seq, Event)`. `seq` breaks
//! ties FIFO so runs are bit-reproducible. The event type is generic: the
//! concrete server simulation (`server::sim_driver`) defines its own event
//! enum and owns all component state, which keeps the borrow checker out of
//! the way (no `Rc<RefCell<dyn Actor>>` web).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::clock::Nanos;

/// An entry in the event queue.
struct Scheduled<E> {
    at: Nanos,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Event queue with virtual time.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: Nanos,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0, processed: 0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Nanos {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past clamps
    /// to `now` (events fire immediately, in FIFO order).
    pub fn schedule(&mut self, at: Nanos, ev: E) {
        let at = at.max(self.now);
        self.seq += 1;
        self.heap.push(Scheduled { at, seq: self.seq, ev });
    }

    /// Schedule `ev` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: Nanos, ev: E) {
        self.schedule(self.now.saturating_add(delay), ev);
    }

    /// Pop the next event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(Nanos, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.at >= self.now);
        self.now = s.at;
        self.processed += 1;
        Some((s.at, s.ev))
    }

    /// Time of the next scheduled event, if any.
    pub fn peek_time(&self) -> Option<Nanos> {
        self.heap.peek().map(|s| s.at)
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// Drive a simulation to completion: repeatedly pop events and hand them to
/// `step` together with the queue (so handlers can schedule more). Stops
/// when the queue drains, `step` returns `false`, or `max_events` fires
/// (runaway guard).
pub fn run<E, F: FnMut(Nanos, E, &mut EventQueue<E>) -> bool>(
    q: &mut EventQueue<E>,
    max_events: u64,
    mut step: F,
) -> u64 {
    let mut n = 0;
    while let Some((t, ev)) = q.pop() {
        n += 1;
        if !step(t, ev, q) || n >= max_events {
            break;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.schedule(20, "b");
        q.schedule(10, "a1");
        q.schedule(10, "a2");
        q.schedule(30, "c");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![(10, "a1"), (10, "a2"), (20, "b"), (30, "c")]
        );
    }

    #[test]
    fn clamps_past_scheduling() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(100, 1);
        q.pop();
        assert_eq!(q.now(), 100);
        q.schedule(50, 2); // in the past -> fires now
        assert_eq!(q.pop(), Some((100, 2)));
    }

    #[test]
    fn run_drives_cascade() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.schedule(0, 0);
        let mut fired = Vec::new();
        run(&mut q, 1000, |t, ev, q| {
            fired.push((t, ev));
            if ev < 4 {
                q.schedule_in(10, ev + 1);
            }
            true
        });
        assert_eq!(fired, vec![(0, 0), (10, 1), (20, 2), (30, 3), (40, 4)]);
    }

    #[test]
    fn run_respects_max_events() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(0, 0);
        let n = run(&mut q, 5, |_, _, q| {
            q.schedule_in(1, 0); // infinite cascade
            true
        });
        assert_eq!(n, 5);
    }
}
