//! Small in-tree substrates.
//!
//! The build environment is offline with a fixed crate cache that lacks
//! `rand`, `serde`, `proptest` and `criterion`; everything those would
//! provide is implemented here (DESIGN.md §4, "Offline-dependency note").

pub mod bench;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use stats::{percentile, Histogram, Summary};

/// Format a nanosecond duration as a human-readable string.
pub fn fmt_nanos(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Round a float to `d` decimal places (for stable report output).
pub fn round_to(x: f64, d: u32) -> f64 {
    let p = 10f64.powi(d as i32);
    (x * p).round() / p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_nanos_ranges() {
        assert_eq!(fmt_nanos(12), "12 ns");
        assert_eq!(fmt_nanos(1_500), "1.500 us");
        assert_eq!(fmt_nanos(2_500_000), "2.500 ms");
        assert_eq!(fmt_nanos(3_000_000_000), "3.000 s");
    }

    #[test]
    fn round_to_places() {
        assert_eq!(round_to(3.14159, 2), 3.14);
        assert_eq!(round_to(2.5, 0), 3.0);
    }
}
