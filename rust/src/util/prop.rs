//! Micro property-testing harness (in lieu of `proptest`, absent offline).
//!
//! Runs a closure over many seeded random cases; on failure it re-runs a
//! simple shrink loop over the failing seed's integer parameters where the
//! generator supports it. Generators draw from [`crate::util::Rng`], so a
//! failing case is reproducible from the printed seed.

use crate::util::rng::Rng;

/// Number of cases per property (override with `PREBA_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PREBA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(128)
}

/// Run `body` for `cases` seeded RNGs; panics with the failing seed.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, body: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default case count.
pub fn check_default<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, body: F) {
    check(name, default_cases(), body)
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("x*0==0", 64, |rng| {
            let x = rng.below(1000) as i64;
            if x * 0 == 0 {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failing_seed() {
        check("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn macro_forms() {
        check("macro", 16, |rng| {
            let a = rng.below(10);
            prop_assert!(a < 10);
            prop_assert!(a < 10, "a={} out of range", a);
            Ok(())
        });
    }
}
