//! Criterion-free benchmark harness for `harness = false` bench targets.
//!
//! Two roles:
//!  1. Micro-benchmarks (`time_fn`) for L3 hot-path profiling (§Perf):
//!     warmup + timed iterations, reporting mean/p50/p95 per iteration.
//!  2. Experiment benches (`Reporter`): each `benches/figNN_*.rs` binary
//!     regenerates one paper figure/table and prints the same rows/series
//!     the paper reports, plus machine-readable JSON next to it.

use std::time::Instant;

/// Result of a micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            crate::util::fmt_nanos(self.mean_ns as u64),
            crate::util::fmt_nanos(self.p50_ns as u64),
            crate::util::fmt_nanos(self.p95_ns as u64),
            crate::util::fmt_nanos(self.min_ns as u64),
        );
    }
}

/// Time `f` with automatic iteration-count calibration (targets ~0.5 s of
/// measurement, capped at `max_iters`). Returns per-iteration stats.
pub fn time_fn<F: FnMut()>(name: &str, max_iters: u64, mut f: F) -> BenchStats {
    // Warmup + calibration: run until 50 ms or 16 iters.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_iters < 16 && warm_start.elapsed().as_millis() < 50 {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
    let target_iters = ((0.5e9 / per_iter.max(1.0)) as u64).clamp(8, max_iters);

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchStats {
        name: name.to_string(),
        iters: target_iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p95_ns: crate::util::stats::percentile(&samples, 95.0),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Pretty table + JSON reporter used by the figure benches.
pub struct Reporter {
    title: String,
    sections: Vec<(String, Vec<String>)>,
    json: Vec<(String, crate::util::json::Json)>,
}

impl Reporter {
    pub fn new(title: &str) -> Self {
        println!("\n==== {title} ====");
        Reporter { title: title.to_string(), sections: Vec::new(), json: Vec::new() }
    }

    /// Start a named section (e.g. one sub-plot of a figure).
    pub fn section(&mut self, name: &str) {
        println!("\n-- {name}");
        self.sections.push((name.to_string(), Vec::new()));
    }

    /// Emit one already-formatted row.
    pub fn row(&mut self, line: &str) {
        println!("{line}");
        if let Some((_, rows)) = self.sections.last_mut() {
            rows.push(line.to_string());
        }
    }

    /// Attach machine-readable data for this figure.
    pub fn data(&mut self, key: &str, value: crate::util::json::Json) {
        self.json.push((key.to_string(), value));
    }

    /// Write `results/<slug>.json` if the `PREBA_RESULTS_DIR` env var (or
    /// `results/` default) is writable; always returns the JSON document.
    pub fn finish(self, slug: &str) -> crate::util::json::Json {
        use crate::util::json::Json;
        let doc = Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "data",
                Json::Obj(self.json.into_iter().collect()),
            ),
        ]);
        let dir = std::env::var("PREBA_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = format!("{dir}/{slug}.json");
            if std::fs::write(&path, doc.to_string_pretty()).is_ok() {
                println!("\n[written {path}]");
            }
        }
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let mut x = 0u64;
        let stats = time_fn("noop-ish", 64, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(stats.iters >= 8);
        assert!(stats.mean_ns >= 0.0);
        assert!(stats.p95_ns >= stats.min_ns);
    }

    #[test]
    fn reporter_collects_json() {
        let mut r = Reporter::new("test");
        r.section("s");
        r.row("row1");
        r.data("k", crate::util::json::Json::num(1.0));
        let doc = r.finish("_test_reporter");
        assert_eq!(doc.get("data").unwrap().get("k").unwrap().as_f64(), Some(1.0));
    }
}
