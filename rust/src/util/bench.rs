//! Criterion-free benchmark harness for `harness = false` bench targets.
//!
//! Two roles:
//!  1. Micro-benchmarks (`time_fn`) for L3 hot-path profiling (§Perf):
//!     warmup + timed iterations, reporting mean/p50/p95 per iteration.
//!  2. Experiment benches (`Reporter`): each `benches/figNN_*.rs` binary
//!     regenerates one paper figure/table and prints the same rows/series
//!     the paper reports, plus machine-readable JSON next to it.

use std::cell::RefCell;
use std::io::Write as _;
use std::time::Instant;

/// Result of a micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            crate::util::fmt_nanos(self.mean_ns as u64),
            crate::util::fmt_nanos(self.p50_ns as u64),
            crate::util::fmt_nanos(self.p95_ns as u64),
            crate::util::fmt_nanos(self.min_ns as u64),
        );
    }
}

/// Time `f` with automatic iteration-count calibration (targets ~0.5 s of
/// measurement, capped at `max_iters`). Returns per-iteration stats.
pub fn time_fn<F: FnMut()>(name: &str, max_iters: u64, mut f: F) -> BenchStats {
    // Warmup + calibration: run until 50 ms or 16 iters.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_iters < 16 && warm_start.elapsed().as_millis() < 50 {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
    let target_iters = ((0.5e9 / per_iter.max(1.0)) as u64).clamp(8, max_iters);

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchStats {
        name: name.to_string(),
        iters: target_iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p95_ns: crate::util::stats::percentile(&samples, 95.0),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Where `results/*.json` land. Programmatic callers (the CLI's `--out`,
/// integration tests, benches) inject it through [`set_results_dir`];
/// absent that, the first `finish` samples `PREBA_RESULTS_DIR` (default
/// `results`). Injection replaces the old `std::env::set_var` idiom,
/// which is UB on glibc with parallel test threads.
static RESULTS_DIR: once_cell::sync::OnceCell<String> = once_cell::sync::OnceCell::new();

/// Choose the results directory programmatically. First caller wins (and
/// an earlier `Reporter::finish` wins over both); thread-safe.
pub fn set_results_dir(dir: &str) {
    let _ = RESULTS_DIR.set(dir.to_string());
}

fn results_dir() -> &'static str {
    RESULTS_DIR
        .get_or_init(|| std::env::var("PREBA_RESULTS_DIR").unwrap_or_else(|_| "results".into()))
}

thread_local! {
    /// When set, `Reporter::finish` appends its rendered block here instead
    /// of printing — the parallel `experiment all` runner captures each
    /// experiment's output on its worker thread and prints the blocks in
    /// job order, so stdout is bitwise identical to a serial run.
    static CAPTURE: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Start capturing `Reporter` output on this thread.
pub fn capture_begin() {
    CAPTURE.with(|c| *c.borrow_mut() = Some(String::new()));
}

/// Stop capturing and return everything reporters emitted since
/// [`capture_begin`]. Returns an empty string if capture was never started.
pub fn capture_end() -> String {
    CAPTURE.with(|c| c.borrow_mut().take().unwrap_or_default())
}

/// Route a finished report block to the thread's capture buffer, or stdout.
fn emit_block(text: &str) {
    let captured = CAPTURE.with(|c| match c.borrow_mut().as_mut() {
        Some(buf) => {
            buf.push_str(text);
            true
        }
        None => false,
    });
    if !captured {
        // `print!` (not a raw stdout write) so the test harness can
        // capture report output; one call keeps the block contiguous.
        print!("{text}");
        let _ = std::io::stdout().flush();
    }
}

/// True when this thread is capturing reporter output.
fn capture_active() -> bool {
    CAPTURE.with(|c| c.borrow().is_some())
}

/// Pretty table + JSON reporter used by the figure benches.
///
/// Without an active capture (plain single-experiment runs, benches),
/// lines print incrementally as the experiment progresses. Under a
/// capture (the parallel `experiment all` runner), lines are buffered and
/// handed to the capture as one contiguous block in [`Reporter::finish`],
/// so concurrent experiments never interleave their reports.
pub struct Reporter {
    title: String,
    /// True when output is being collected for the thread's capture
    /// buffer instead of printed as it is produced.
    buffered: bool,
    lines: Vec<String>,
    sections: Vec<(String, Vec<String>)>,
    json: Vec<(String, crate::util::json::Json)>,
}

impl Reporter {
    pub fn new(title: &str) -> Self {
        let mut r = Reporter {
            title: title.to_string(),
            buffered: capture_active(),
            lines: Vec::new(),
            sections: Vec::new(),
            json: Vec::new(),
        };
        r.push(format!("\n==== {title} ===="));
        r
    }

    /// Buffer or print one output line, per the capture mode.
    fn push(&mut self, line: String) {
        if self.buffered {
            self.lines.push(line);
        } else {
            println!("{line}");
        }
    }

    /// Start a named section (e.g. one sub-plot of a figure).
    pub fn section(&mut self, name: &str) {
        self.push(format!("\n-- {name}"));
        self.sections.push((name.to_string(), Vec::new()));
    }

    /// Emit one already-formatted row.
    pub fn row(&mut self, line: &str) {
        self.push(line.to_string());
        if let Some((_, rows)) = self.sections.last_mut() {
            rows.push(line.to_string());
        }
    }

    /// Attach machine-readable data for this figure.
    pub fn data(&mut self, key: &str, value: crate::util::json::Json) {
        self.json.push((key.to_string(), value));
    }

    /// Write `results/<slug>.json` if the configured results directory
    /// ([`set_results_dir`], or `PREBA_RESULTS_DIR`, or `results/`) is
    /// writable, flush any buffered report block, and return the JSON
    /// document.
    pub fn finish(mut self, slug: &str) -> crate::util::json::Json {
        use crate::util::json::Json;
        let doc = Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "data",
                Json::Obj(self.json.into_iter().collect()),
            ),
        ]);
        let dir = results_dir();
        if std::fs::create_dir_all(dir).is_ok() {
            let path = format!("{dir}/{slug}.json");
            if std::fs::write(&path, doc.to_string_pretty()).is_ok() {
                self.push(format!("\n[written {path}]"));
            }
        }
        if self.buffered {
            let mut text = String::new();
            for line in &self.lines {
                text.push_str(line);
                text.push('\n');
            }
            emit_block(&text);
        }
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let mut x = 0u64;
        let stats = time_fn("noop-ish", 64, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(stats.iters >= 8);
        assert!(stats.mean_ns >= 0.0);
        assert!(stats.p95_ns >= stats.min_ns);
    }

    #[test]
    fn reporter_collects_json() {
        let mut r = Reporter::new("test");
        r.section("s");
        r.row("row1");
        r.data("k", crate::util::json::Json::num(1.0));
        let doc = r.finish("_test_reporter");
        assert_eq!(doc.get("data").unwrap().get("k").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn capture_collects_report_blocks_in_order() {
        capture_begin();
        let mut r = Reporter::new("captured");
        r.section("sec");
        r.row("alpha");
        r.finish("_test_capture_a");
        let mut r2 = Reporter::new("captured2");
        r2.row("beta");
        r2.finish("_test_capture_b");
        let text = capture_end();
        assert!(text.contains("==== captured ===="), "{text}");
        assert!(text.contains("alpha"));
        assert!(text.contains("beta"));
        assert!(
            text.find("alpha").unwrap() < text.find("beta").unwrap(),
            "blocks out of order"
        );
        // Capture is consumed.
        assert_eq!(capture_end(), "");
    }
}
