//! Latency/throughput statistics: summaries, percentiles, histograms.
//!
//! All tail-latency numbers in the paper are 95%-ile; [`Summary::p95`] is
//! the primary consumer-facing value. Percentiles use the nearest-rank
//! method over the exact sample set (sample counts here are small enough
//! that a sketch is unnecessary).

/// Exact percentile (nearest-rank) of an unsorted slice; `q` in `[0,100]`.
/// Returns `0.0` on an empty slice.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Streaming sample collector with summary statistics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sum += x;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile(&self.samples, q)
    }

    /// Fraction of samples strictly above `x` (SLA-violation accounting).
    /// Returns 0 on an empty summary.
    pub fn frac_above(&self, x: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().filter(|&&s| s > x).count() as f64 / self.samples.len() as f64
    }

    /// 95th percentile — the paper's tail-latency metric.
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Fixed-bin histogram (used for the LibriSpeech length histogram, Fig 13,
/// and utilization timelines).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    /// `nbins` equal-width bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], under: 0, over: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let w = (self.hi - self.lo) / self.bins.len() as f64;
            let i = (((x - self.lo) / w) as usize).min(self.bins.len() - 1);
            self.bins[i] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    /// (bin_center, count) pairs for report output.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + w * (i as f64 + 0.5), c))
            .collect()
    }

    /// Fraction of in-range mass at or below `x`.
    pub fn cdf(&self, x: f64) -> f64 {
        let total: u64 = self.bins.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            if self.lo + w * (i as f64 + 1.0) <= x {
                acc += c;
            }
        }
        acc as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 95.0), 95.0);
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
    }

    #[test]
    fn percentile_empty() {
        assert_eq!(percentile(&[], 95.0), 0.0);
    }

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for i in 1..=10 {
            s.add(i as f64);
        }
        assert_eq!(s.count(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.p50(), 5.0);
    }

    #[test]
    fn summary_stddev() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.stddev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.bins(), &[1u64; 10][..]);
        assert_eq!(h.total(), 12);
        assert!((h.cdf(5.0) - 0.5).abs() < 1e-12);
    }
}
