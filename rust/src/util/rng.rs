//! Deterministic pseudo-random numbers (SplitMix64 core).
//!
//! The registry cache has no `rand` crate; every stochastic component in
//! the simulator (Poisson arrivals, length sampling, service-time jitter)
//! draws from this generator so experiment runs are reproducible from a
//! single seed.

/// SplitMix64 PRNG (Steele et al., "Fast splittable pseudorandom number
/// generators"). Passes BigCrush when used as a 64-bit generator; more
/// than adequate for workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Split off an independent stream (used to give each simulation
    /// component its own stream so component insertion order does not
    /// perturb other components' draws).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64();
        Rng::new(s ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`). Used for Poisson
    /// inter-arrival gaps.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
