//! Fixed-width text table formatting for experiment reports.

/// Column-aligned text table. Collect rows, then render with every column
/// padded to its widest cell.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as lines (header, separator, rows).
    pub fn render(&self) -> Vec<String> {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = Vec::with_capacity(self.rows.len() + 2);
        out.push(fmt_row(&self.header));
        out.push(width.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            out.push(fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        for line in self.render() {
            println!("{line}");
        }
    }
}

/// Format a float compactly for table cells.
pub fn num(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer", "22"]);
        let lines = t.render();
        assert_eq!(lines.len(), 4);
        // All lines the same width.
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w));
    }

    #[test]
    fn num_formats() {
        assert_eq!(num(0.0), "0");
        assert_eq!(num(1234.6), "1235");
        assert_eq!(num(12.34), "12.3");
        assert_eq!(num(1.2345), "1.234");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
