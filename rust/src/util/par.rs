//! Scoped work-stealing job pool for the experiment sweeps.
//!
//! The paper's evaluation is a grid of *independent* simulations (figures ×
//! models × MIG configs × load points), each deterministic given its seed.
//! `run_jobs` fans an indexed job list out over worker threads that pull
//! indices from a shared atomic counter (work stealing at job granularity),
//! then merges results **in job order** — so every caller's output is
//! bitwise identical to a serial run regardless of worker count or
//! scheduling.
//!
//! Worker count comes from `--jobs N` / `PREBA_JOBS`, defaulting to the
//! machine's available parallelism. Jobs run on `std::thread::scope`
//! threads, so borrowed captures (`&PrebaConfig`, parameter slices) work
//! without `Arc`.
//!
//! ```
//! use preba::util::par::run_jobs_on;
//!
//! // Results always come back in job order, whatever the worker count.
//! let serial = run_jobs_on(1, 8, |i| i * i);
//! let parallel = run_jobs_on(4, 8, |i| i * i);
//! assert_eq!(serial, parallel);
//! assert_eq!(serial, (0..8).map(|i| i * i).collect::<Vec<_>>());
//! ```

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker-count override; 0 = unset (fall back to `PREBA_JOBS` / core
/// count). An atomic rather than an env write: the CLI's `--jobs` and the
/// benches inject it through [`set_jobs`], because `std::env::set_var`
/// racing `getenv` across threads is UB on glibc — and `perf_sweep`
/// legitimately switches worker counts mid-process.
static JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the worker count programmatically (clamped to >= 1). Overrides
/// `PREBA_JOBS`; may be called repeatedly.
pub fn set_jobs(n: usize) {
    JOBS_OVERRIDE.store(n.max(1), Ordering::Relaxed);
}

thread_local! {
    /// True while this thread is a pool worker. Nested `run_jobs` calls
    /// (an experiment's inner sweep running inside the parallel
    /// `experiment all` runner) then execute inline instead of spawning a
    /// second full-width pool — otherwise `all` would oversubscribe the
    /// CPU with ~jobs² simulation threads.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };

    /// Scoped worker-count pin for this thread; 0 = unset. Takes
    /// precedence over [`set_jobs`] so determinism tests can compare a
    /// serial against a parallel run without racing the process-global
    /// override from concurrently running tests.
    static JOBS_TLS: Cell<usize> = const { Cell::new(0) };
}

/// Run `f` with the worker count pinned to `n` (>= 1) on the calling
/// thread only, restoring the previous pin afterwards (also on panic).
/// `run_jobs` resolves its worker count on the calling thread, so the pin
/// covers every fan-out `f` performs directly.
pub fn with_jobs<T>(n: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            JOBS_TLS.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(JOBS_TLS.with(|c| c.replace(n.max(1))));
    f()
}

/// Resolve the worker count: the thread's [`with_jobs`] pin first, then
/// the [`set_jobs`] override (the CLI's `--jobs N`), then `PREBA_JOBS` if
/// set (and >= 1), otherwise the number of available cores.
pub fn jobs() -> usize {
    let pinned = JOBS_TLS.with(Cell::get);
    if pinned != 0 {
        return pinned;
    }
    match JOBS_OVERRIDE.load(Ordering::Relaxed) {
        0 => parse_jobs(std::env::var("PREBA_JOBS").ok().as_deref()),
        n => n,
    }
}

/// Pure half of [`jobs`]: interpret an optional `PREBA_JOBS` value. Split
/// out so tests never have to mutate the process environment (setenv
/// racing getenv across parallel lib tests is UB on glibc).
fn parse_jobs(v: Option<&str>) -> usize {
    if let Some(v) = v {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `n` indexed jobs on the configured number of workers and return
/// their results in job order. See [`run_jobs_on`].
pub fn run_jobs<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_jobs_on(jobs(), n, f)
}

/// Run `n` indexed jobs on `workers` threads. Jobs are pulled from a shared
/// counter so a slow cell never blocks the rest of the grid; results are
/// merged in index order. With `workers <= 1` (or a single job) everything
/// runs inline on the caller's thread — the serial and parallel paths
/// produce identical results because each job is a pure function of its
/// index.
///
/// Panics in a job are propagated to the caller after all workers stop.
pub fn run_jobs_on<T, F>(workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = if IN_POOL.with(Cell::get) { 1 } else { workers.max(1).min(n) };
    if workers == 1 {
        return (0..n).map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    IN_POOL.with(|p| p.set(true));
                    let mut out: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(i)));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(part) => part,
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    });

    // Merge in job order.
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    for part in parts {
        for (i, v) in part {
            debug_assert!(slots[i].is_none(), "job {i} ran twice");
            slots[i] = Some(v);
        }
    }
    slots.into_iter().map(|o| o.expect("job result missing")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_in_job_order() {
        for workers in [1, 2, 4, 9] {
            let out = run_jobs_on(workers, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counters: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        run_jobs_on(4, 64, |i| counters[i].fetch_add(1, Ordering::SeqCst));
        for (i, c) in counters.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "job {i}");
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(run_jobs_on(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(run_jobs_on(4, 1, |i| i + 7), vec![7]);
    }

    #[test]
    fn matches_serial_with_uneven_costs() {
        // Jobs with wildly different costs still merge in order.
        let serial = run_jobs_on(1, 20, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 % 7) * 10_000 {
                acc = acc.wrapping_add(k);
            }
            (i, acc)
        });
        let parallel = run_jobs_on(3, 20, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 % 7) * 10_000 {
                acc = acc.wrapping_add(k);
            }
            (i, acc)
        });
        assert_eq!(serial, parallel);
    }

    #[test]
    fn nested_pools_run_inline_with_correct_results() {
        // An inner run_jobs on a pool worker must not spawn a second
        // full-width pool, and must still merge in job order.
        let out = run_jobs_on(4, 6, |i| {
            let inner = run_jobs_on(4, 5, move |j| i * 10 + j);
            assert_eq!(inner, (0..5).map(|j| i * 10 + j).collect::<Vec<_>>());
            inner.iter().sum::<usize>()
        });
        assert_eq!(out, (0..6).map(|i| 5 * (i * 10) + 10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "job boom")]
    fn worker_panics_propagate() {
        run_jobs_on(2, 8, |i| {
            if i == 5 {
                panic!("job boom");
            }
            i
        });
    }

    #[test]
    fn with_jobs_pins_and_restores() {
        let before = jobs();
        let inside = with_jobs(3, || {
            assert_eq!(jobs(), 3);
            with_jobs(1, jobs)
        });
        assert_eq!(inside, 1);
        assert_eq!(jobs(), before);
    }

    #[test]
    fn jobs_value_parsing() {
        assert_eq!(parse_jobs(Some("3")), 3);
        assert_eq!(parse_jobs(Some(" 5 ")), 5);
        assert!(parse_jobs(Some("not-a-number")) >= 1);
        assert!(parse_jobs(Some("0")) >= 1);
        assert!(parse_jobs(None) >= 1);
    }
}
