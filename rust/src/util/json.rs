//! Minimal JSON value type with writer + parser.
//!
//! Replaces `serde_json` (absent from the offline crate cache). Used to
//! read `artifacts/manifest.json` written by `python/compile/aot.py` and to
//! emit experiment results. Supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (the manifest is plain ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that fails loudly with the missing key's name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}' in JSON object"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- writer ----------------------------------------------------------

    /// Pretty-printed with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Compact (no-whitespace) encoding via `Display` — `doc.to_string()`
/// keeps working through the blanket `ToString`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parser ----------------------------------------------------------------

/// Parse a JSON document. Errors carry byte offsets.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        // Re-parse our own output.
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
        let pretty = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo"));
    }
}
