//! Windowed time-series recorder with deterministic shard merge.
//!
//! [`ObsLog`] is the buffer a DES driver (or one shard of the cluster DES)
//! fills while it runs. Every recording method is a no-op when the spec is
//! disabled, never draws driver RNG, and never schedules events — the
//! neutrality contract in [`crate::obs`]. Keys are **global** ids (the
//! cluster's `run_inner` maps local shard indices through its `ShardCtx`
//! before recording), so merging shard buffers is pure concatenation plus a
//! deterministic sort — byte-identical output at any `--shards`/`--jobs`.

use std::collections::BTreeMap;

use super::span::{flag, BatchSeg, Route, Served, Span, SpanOutcome};
use super::ObsSpec;
use crate::clock::{to_millis, Nanos};
use crate::metrics::LatencyParts;

/// 64-bucket log2(ns) latency histogram: bounded, mergeable, and exact
/// enough for per-window tails (bucket b covers `[2^b, 2^(b+1))` ns; the
/// quantile reports the bucket's upper edge).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatHist {
    buckets: [u64; 64],
}

impl Default for LatHist {
    fn default() -> Self {
        LatHist { buckets: [0; 64] }
    }
}

impl LatHist {
    #[inline]
    fn bucket(ns: Nanos) -> usize {
        (63 - ns.max(1).leading_zeros() as usize).min(62)
    }

    pub fn add(&mut self, ns: Nanos) {
        self.buckets[Self::bucket(ns)] += 1;
    }

    pub fn merge(&mut self, other: &LatHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Upper edge of the bucket holding the q-quantile, in ms (0 if empty).
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return to_millis(1u64 << (b + 1));
            }
        }
        0.0
    }
}

/// One (window, tenant) cell. `arrivals` counts every arrival in the
/// window (warmup included — the offered-load curve); the outcome columns
/// count only what `RunStats` counts, so `Σ served == stats.completed`
/// and likewise for drops/timeouts/defers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantCell {
    pub arrivals: u64,
    pub served: u64,
    pub dropped: u64,
    pub timed_out: u64,
    pub deferred: u64,
    /// Σ end-to-end latency of served requests (for the window mean).
    pub sum_ns: u128,
    pub max_ns: Nanos,
    pub hist: LatHist,
}

impl TenantCell {
    pub fn mean_ms(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            to_millis((self.sum_ns / self.served as u128) as Nanos)
        }
    }

    pub fn p95_ms(&self) -> f64 {
        self.hist.quantile_ms(0.95)
    }

    fn merge(&mut self, other: &TenantCell) {
        self.arrivals += other.arrivals;
        self.served += other.served;
        self.dropped += other.dropped;
        self.timed_out += other.timed_out;
        self.deferred += other.deferred;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.hist.merge(&other.hist);
    }
}

/// One (window, GPU, tenant) serving-group gauge cell: queue depth and
/// in-flight batches sampled at dispatch/completion edges, plus the number
/// of batches dispatched in the window.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GroupCell {
    /// Gauge samples taken (divisor for the averages).
    pub samples: u64,
    pub queue_sum: u64,
    pub queue_max: u64,
    pub in_flight_sum: u64,
    pub in_flight_max: u64,
    pub batches: u64,
}

impl GroupCell {
    pub fn queue_avg(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.queue_sum as f64 / self.samples as f64
        }
    }

    pub fn in_flight_avg(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.in_flight_sum as f64 / self.samples as f64
        }
    }

    fn merge(&mut self, other: &GroupCell) {
        self.samples += other.samples;
        self.queue_sum += other.queue_sum;
        self.queue_max = self.queue_max.max(other.queue_max);
        self.in_flight_sum += other.in_flight_sum;
        self.in_flight_max = self.in_flight_max.max(other.in_flight_max);
        self.batches += other.batches;
    }
}

/// The recorder. One per driver run (or per shard, merged at `finalize`).
#[derive(Debug, Clone, Default)]
pub struct ObsLog {
    pub spec: ObsSpec,
    /// (window, tenant) → counters. BTreeMap: deterministic iteration.
    pub tenant_cells: BTreeMap<(u64, usize), TenantCell>,
    /// (window, gpu, tenant) → gauges.
    pub group_cells: BTreeMap<(u64, usize, usize), GroupCell>,
    /// Sampled request spans (sorted at merge/seal).
    pub spans: Vec<Span>,
    /// Batch execution segments (sorted at merge/seal).
    pub segs: Vec<BatchSeg>,
    /// Pre-terminal modifier bits for *sampled* requests only, keyed
    /// (tenant, idx); folded into the span at its terminal and dropped.
    flags: BTreeMap<(usize, usize), u8>,
}

impl ObsLog {
    pub fn new(spec: ObsSpec) -> Self {
        ObsLog { spec, ..Default::default() }
    }

    /// A disabled recorder: every call below is a no-op.
    pub fn off() -> Self {
        Self::default()
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.spec.enabled
    }

    #[inline]
    fn sampled(&self, idx: usize) -> bool {
        idx as u64 % self.spec.span_sample.max(1) == 0
    }

    fn tcell(&mut self, at: Nanos, tenant: usize) -> &mut TenantCell {
        let w = self.spec.window(at);
        self.tenant_cells.entry((w, tenant)).or_default()
    }

    /// One request arrived (warmup or not — this is the offered load).
    pub fn on_arrival(&mut self, at: Nanos, tenant: usize) {
        if !self.enabled() {
            return;
        }
        self.tcell(at, tenant).arrivals += 1;
    }

    /// One request served. `counted` mirrors the driver's warmup rule.
    pub fn on_served(&mut self, s: Served) {
        if !self.enabled() {
            return;
        }
        if s.counted {
            let cell = self.tcell(s.done, s.tenant);
            cell.served += 1;
            let e2e = s.parts.total();
            cell.sum_ns += e2e as u128;
            cell.max_ns = cell.max_ns.max(e2e);
            cell.hist.add(e2e);
        }
        if self.sampled(s.idx) {
            let mut flags = self.flags.remove(&(s.tenant, s.idx)).unwrap_or(0);
            if s.degraded {
                flags |= flag::DEGRADED;
            }
            if s.deferred {
                flags |= flag::DEFERRED;
            }
            if !s.counted {
                flags |= flag::WARMUP;
            }
            self.spans.push(Span {
                tenant: s.tenant,
                idx: s.idx,
                arrival: s.arrival,
                end: s.done,
                parts: s.parts,
                route: Some(Route {
                    gpu: s.gpu,
                    slice: s.slice,
                    batch: s.batch,
                    batch_size: s.batch_size,
                }),
                outcome: SpanOutcome::Served,
                flags,
            });
        }
    }

    fn on_terminal(
        &mut self,
        at: Nanos,
        tenant: usize,
        idx: usize,
        arrival: Nanos,
        deferred: bool,
        counted: bool,
        outcome: SpanOutcome,
    ) {
        if !self.enabled() {
            return;
        }
        if counted {
            let cell = self.tcell(at, tenant);
            match outcome {
                SpanOutcome::Dropped => cell.dropped += 1,
                SpanOutcome::TimedOut => cell.timed_out += 1,
                SpanOutcome::Served => unreachable!("served has its own path"),
            }
        }
        if self.sampled(idx) {
            let mut flags = self.flags.remove(&(tenant, idx)).unwrap_or(0);
            if deferred {
                flags |= flag::DEFERRED;
            }
            if !counted {
                flags |= flag::WARMUP;
            }
            self.spans.push(Span {
                tenant,
                idx,
                arrival,
                end: at,
                parts: LatencyParts::default(),
                route: None,
                outcome,
                flags,
            });
        }
    }

    /// One request dropped by admission (terminal).
    pub fn on_dropped(
        &mut self,
        at: Nanos,
        tenant: usize,
        idx: usize,
        arrival: Nanos,
        deferred: bool,
        counted: bool,
    ) {
        self.on_terminal(at, tenant, idx, arrival, deferred, counted, SpanOutcome::Dropped);
    }

    /// One request lost to a fault (terminal).
    pub fn on_timed_out(
        &mut self,
        at: Nanos,
        tenant: usize,
        idx: usize,
        arrival: Nanos,
        deferred: bool,
        counted: bool,
    ) {
        self.on_terminal(at, tenant, idx, arrival, deferred, counted, SpanOutcome::TimedOut);
    }

    /// One request newly parked in an admission queue.
    pub fn on_deferred(&mut self, at: Nanos, tenant: usize, idx: usize, counted: bool) {
        if !self.enabled() {
            return;
        }
        if counted {
            self.tcell(at, tenant).deferred += 1;
        }
        if self.sampled(idx) {
            *self.flags.entry((tenant, idx)).or_default() |= flag::DEFERRED;
        }
    }

    /// A crash-recovery retry attempt was issued for (tenant, idx).
    pub fn mark_retry(&mut self, tenant: usize, idx: usize) {
        if self.enabled() && self.sampled(idx) {
            *self.flags.entry((tenant, idx)).or_default() |= flag::RETRIED;
        }
    }

    /// A hedged duplicate was issued for (tenant, idx).
    pub fn mark_hedge(&mut self, tenant: usize, idx: usize) {
        if self.enabled() && self.sampled(idx) {
            *self.flags.entry((tenant, idx)).or_default() |= flag::HEDGED;
        }
    }

    /// One batch finished (or was crash-harvested) on a slice.
    pub fn on_batch(&mut self, seg: BatchSeg) {
        if !self.enabled() {
            return;
        }
        let w = self.spec.window(seg.start);
        self.group_cells.entry((w, seg.gpu, seg.tenant)).or_default().batches += 1;
        self.segs.push(seg);
    }

    /// Sample a serving group's queue depth / in-flight gauge.
    pub fn on_queue(&mut self, at: Nanos, gpu: usize, tenant: usize, queue: usize, in_flight: usize) {
        if !self.enabled() {
            return;
        }
        let w = self.spec.window(at);
        let cell = self.group_cells.entry((w, gpu, tenant)).or_default();
        cell.samples += 1;
        cell.queue_sum += queue as u64;
        cell.queue_max = cell.queue_max.max(queue as u64);
        cell.in_flight_sum += in_flight as u64;
        cell.in_flight_max = cell.in_flight_max.max(in_flight as u64);
    }

    /// Merge shard-local buffers into one log, deterministically: cells
    /// add (shard keys are disjoint anyway, but adding is robust), span
    /// and segment vectors concatenate in the order given, then sort on
    /// total keys — the result is independent of shard layout.
    pub fn merge(spec: ObsSpec, parts: impl IntoIterator<Item = ObsLog>) -> ObsLog {
        let mut out = ObsLog::new(spec);
        for part in parts {
            for (k, v) in &part.tenant_cells {
                out.tenant_cells.entry(*k).or_default().merge(v);
            }
            for (k, v) in &part.group_cells {
                out.group_cells.entry(*k).or_default().merge(v);
            }
            out.spans.extend(part.spans);
            out.segs.extend(part.segs);
        }
        out.seal();
        out
    }

    /// Sort the event vectors on total keys: every request reaches exactly
    /// one terminal, so (tenant, idx) orders spans totally; (gpu, tenant)
    /// names one serving group and `seq` orders its dispatches.
    pub fn seal(&mut self) {
        self.spans.sort_by_key(|s| (s.tenant, s.idx));
        self.segs.sort_by_key(|b| (b.gpu, b.tenant, b.seq, b.slice));
        self.flags.clear();
    }

    /// Σ served over every window cell (must equal the run's
    /// `stats.completed` — pinned by the reconciliation property test).
    pub fn windowed_served_total(&self) -> u64 {
        self.tenant_cells.values().map(|c| c.served).sum()
    }

    /// Σ (arrivals, served, dropped, timed_out, deferred) over all cells.
    pub fn windowed_totals(&self) -> (u64, u64, u64, u64, u64) {
        let mut t = (0, 0, 0, 0, 0);
        for c in self.tenant_cells.values() {
            t.0 += c.arrivals;
            t.1 += c.served;
            t.2 += c.dropped;
            t.3 += c.timed_out;
            t.4 += c.deferred;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{millis, secs};

    fn served(tenant: usize, idx: usize, at: Nanos, e2e: Nanos) -> Served {
        Served {
            tenant,
            idx,
            arrival: at.saturating_sub(e2e),
            done: at,
            parts: LatencyParts { execution: e2e, ..Default::default() },
            gpu: 0,
            slice: 0,
            batch: 0,
            batch_size: 1,
            degraded: false,
            deferred: false,
            counted: true,
        }
    }

    #[test]
    fn disabled_log_records_nothing() {
        let mut log = ObsLog::off();
        log.on_arrival(0, 0);
        log.on_served(served(0, 0, millis(5.0), millis(5.0)));
        log.on_dropped(0, 0, 1, 0, false, true);
        log.on_queue(0, 0, 0, 3, 1);
        log.mark_retry(0, 0);
        assert!(log.tenant_cells.is_empty());
        assert!(log.group_cells.is_empty());
        assert!(log.spans.is_empty());
        assert!(log.segs.is_empty());
    }

    #[test]
    fn cells_bucket_by_window_and_reconcile() {
        let spec = ObsSpec::on(1.0, 1);
        let mut log = ObsLog::new(spec);
        for i in 0..10 {
            let at = secs(0.3 * i as f64);
            log.on_arrival(at, 0);
            log.on_served(served(0, i, at + millis(4.0), millis(4.0)));
        }
        let (arr, srv, _, _, _) = log.windowed_totals();
        assert_eq!((arr, srv), (10, 10));
        assert_eq!(log.windowed_served_total(), 10);
        assert!(log.tenant_cells.len() > 1, "multiple windows populated");
        let c = log.tenant_cells.get(&(0, 0)).unwrap();
        assert!(c.mean_ms() > 3.9 && c.mean_ms() < 4.1);
        assert!(c.p95_ms() >= 4.0, "upper-edge quantile bounds the true p95");
    }

    #[test]
    fn warmup_served_is_flagged_not_counted() {
        let spec = ObsSpec::on(1.0, 1);
        let mut log = ObsLog::new(spec);
        let mut s = served(0, 0, millis(5.0), millis(5.0));
        s.counted = false;
        log.on_served(s);
        assert_eq!(log.windowed_served_total(), 0);
        assert_eq!(log.spans.len(), 1);
        assert_ne!(log.spans[0].flags & flag::WARMUP, 0);
    }

    #[test]
    fn sampling_is_by_index() {
        let spec = ObsSpec::on(1.0, 4);
        let mut log = ObsLog::new(spec);
        for i in 0..16 {
            log.on_served(served(0, i, millis(5.0), millis(1.0)));
        }
        assert_eq!(log.spans.len(), 4);
        assert!(log.spans.iter().all(|s| s.idx % 4 == 0));
        assert_eq!(log.windowed_served_total(), 16, "cells see every request");
    }

    #[test]
    fn flags_fold_into_terminal_span() {
        let spec = ObsSpec::on(1.0, 1);
        let mut log = ObsLog::new(spec);
        log.mark_retry(0, 3);
        log.mark_hedge(0, 3);
        log.on_timed_out(millis(9.0), 0, 3, millis(1.0), true, true);
        assert_eq!(log.spans.len(), 1);
        let s = &log.spans[0];
        assert_eq!(s.outcome, SpanOutcome::TimedOut);
        assert_ne!(s.flags & flag::RETRIED, 0);
        assert_ne!(s.flags & flag::HEDGED, 0);
        assert_ne!(s.flags & flag::DEFERRED, 0);
        assert!(s.route.is_none());
        let (_, _, _, to, _) = log.windowed_totals();
        assert_eq!(to, 1);
    }

    #[test]
    fn merge_is_order_independent() {
        let spec = ObsSpec::on(1.0, 1);
        let mk = |tenants: &[usize]| {
            let mut log = ObsLog::new(spec);
            for &t in tenants {
                log.on_arrival(millis(t as f64), t);
                log.on_served(served(t, t, millis(10.0 + t as f64), millis(2.0)));
                log.on_batch(BatchSeg {
                    gpu: t,
                    slice: 0,
                    tenant: t,
                    seq: 0,
                    start: millis(1.0),
                    end: millis(2.0),
                    size: 1,
                    gpcs: 1,
                    pw: 1.0,
                    harvested: false,
                });
            }
            log
        };
        let a = ObsLog::merge(spec, vec![mk(&[0, 2]), mk(&[1, 3])]);
        let b = ObsLog::merge(spec, vec![mk(&[1, 3]), mk(&[0, 2])]);
        assert_eq!(a.spans, b.spans);
        assert_eq!(a.segs, b.segs);
        assert_eq!(a.windowed_totals(), b.windowed_totals());
    }

    #[test]
    fn lathist_quantiles_bound_from_above() {
        let mut h = LatHist::default();
        for _ in 0..99 {
            h.add(millis(1.0));
        }
        h.add(millis(100.0));
        assert!(h.quantile_ms(0.5) >= 1.0 && h.quantile_ms(0.5) < 3.0);
        assert!(h.quantile_ms(1.0) >= 100.0);
        assert_eq!(LatHist::default().quantile_ms(0.95), 0.0);
    }
}
