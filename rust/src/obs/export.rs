//! Artifact writers: JSONL metric dumps + a Chrome trace-event timeline.
//!
//! `export` writes five files into the output directory:
//!
//! * `meta.json`    — the run [`Fingerprint`] + artifact manifest.
//! * `windows.jsonl`— per-window rows: tenant counters (arrivals / served /
//!   drops / timeouts / defers, mean / p95 / max latency), per-GPU busy-GPC
//!   utilization and estimated power draw (rastered from the batch
//!   segments, the same integrand the energy model uses), and per-(GPU,
//!   tenant) queue-depth gauges.
//! * `spans.jsonl`  — the sampled request spans.
//! * `events.jsonl` — reconfig / consolidation / fault / repair marks.
//! * `trace.json`   — Chrome trace-event JSON, loadable in
//!   `ui.perfetto.dev`: GPUs are processes, slices are threads, batches are
//!   complete (`X`) events, sampled requests are async (`b`/`e`) pairs,
//!   fleet events are instants (`i`), and per-window busy-GPC / power /
//!   queue curves are counters (`C`).
//!
//! Every writer iterates sorted containers and emits through
//! [`crate::util::json::Json`] (BTreeMap-ordered keys), so the bytes are a
//! pure function of the recorded data — deterministic across runs, shard
//! layouts and worker counts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::series::ObsLog;
use super::span::{flag, Span, SpanOutcome};
use super::Fingerprint;
use crate::clock::{to_secs, Nanos};
use crate::util::json::Json;

/// Per-GPU description the exporter needs: display name, GPC count (the
/// utilization denominator) and the energy model's per-GPC watts (the
/// power raster).
#[derive(Debug, Clone)]
pub struct GpuDesc {
    pub name: String,
    pub gpcs: usize,
    pub gpc_active_w: f64,
    pub gpc_idle_w: f64,
}

/// One fleet-lifecycle event: reconfig plan/commit, consolidation,
/// crash / detect / repair. `gpu: None` marks fleet-scope events.
#[derive(Debug, Clone)]
pub struct EventMark {
    pub at: Nanos,
    pub gpu: Option<usize>,
    pub kind: String,
    pub detail: String,
}

/// Everything `export` consumes. The drivers never do IO — the CLI builds
/// this from a run outcome and hands it over.
#[derive(Debug, Clone)]
pub struct ExportInput<'a> {
    pub log: &'a ObsLog,
    pub fp: &'a Fingerprint,
    pub horizon: Nanos,
    pub gpus: Vec<GpuDesc>,
    /// Tenant display names, indexed by global tenant id.
    pub tenants: Vec<String>,
    pub marks: Vec<EventMark>,
}

const TRACE_FILE: &str = "trace.json";
const FILES: [&str; 5] = ["meta.json", "windows.jsonl", "spans.jsonl", "events.jsonl", TRACE_FILE];

/// Write all artifacts into `dir` (created if missing); returns the paths.
pub fn export(dir: &Path, input: &ExportInput) -> anyhow::Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let texts = [
        meta_text(input),
        windows_text(input),
        spans_text(input),
        events_text(input),
        trace_text(input),
    ];
    let mut out = Vec::new();
    for (name, text) in FILES.iter().zip(texts) {
        let path = dir.join(name);
        std::fs::write(&path, text)?;
        out.push(path);
    }
    Ok(out)
}

fn meta_text(input: &ExportInput) -> String {
    let doc = Json::obj(vec![
        ("format", Json::num(1.0)),
        ("fingerprint", input.fp.json()),
        ("window_s", Json::num(to_secs(input.log.spec.window_ns))),
        ("span_sample", Json::num(input.log.spec.span_sample as f64)),
        ("horizon_s", Json::num(to_secs(input.horizon))),
        ("gpus", Json::arr(input.gpus.iter().map(|g| Json::str(&g.name)))),
        ("tenants", Json::arr(input.tenants.iter().map(|t| Json::str(t)))),
        ("files", Json::arr(FILES.iter().map(|f| Json::str(f)))),
    ]);
    let mut s = doc.to_string_pretty();
    s.push('\n');
    s
}

fn header_line(input: &ExportInput) -> String {
    Json::obj(vec![
        ("kind", Json::str("meta")),
        ("fingerprint", input.fp.json()),
        ("window_s", Json::num(to_secs(input.log.spec.window_ns))),
    ])
    .to_string()
}

/// Per-(window, gpu) → (busy GPC·s, pw-weighted busy GPC·s), rastered from
/// the batch segments by splitting each segment across the windows it
/// overlaps — the discrete form of the energy model's busy-GPC integral.
fn gpu_raster(input: &ExportInput) -> BTreeMap<(u64, usize), (f64, f64)> {
    let win = input.log.spec.window_ns.max(1);
    let mut raster: BTreeMap<(u64, usize), (f64, f64)> = BTreeMap::new();
    for seg in &input.log.segs {
        let (mut t, end) = (seg.start, seg.end.max(seg.start));
        while t < end {
            let w = t / win;
            let stop = ((w + 1) * win).min(end);
            let dur_s = to_secs(stop - t) * seg.gpcs as f64;
            let cell = raster.entry((w, seg.gpu)).or_insert((0.0, 0.0));
            cell.0 += dur_s;
            cell.1 += dur_s * seg.pw;
            t = stop;
        }
    }
    raster
}

/// Mean power over a window for one GPU: idle floor on every GPC plus the
/// active increment on the (pw-weighted) busy fraction.
fn window_power_w(g: &GpuDesc, weighted_gpc_s: f64, win_s: f64) -> f64 {
    g.gpcs as f64 * g.gpc_idle_w + (g.gpc_active_w - g.gpc_idle_w) * weighted_gpc_s / win_s
}

fn windows_text(input: &ExportInput) -> String {
    let log = input.log;
    let win_s = to_secs(log.spec.window_ns.max(1));
    let mut out = header_line(input);
    out.push('\n');
    for ((w, tenant), c) in &log.tenant_cells {
        let line = Json::obj(vec![
            ("kind", Json::str("tenant")),
            ("window", Json::num(*w as f64)),
            ("t0_s", Json::num(*w as f64 * win_s)),
            ("tenant", Json::num(*tenant as f64)),
            ("model", Json::str(tenant_name(input, *tenant))),
            ("arrivals", Json::num(c.arrivals as f64)),
            ("served", Json::num(c.served as f64)),
            ("dropped", Json::num(c.dropped as f64)),
            ("timed_out", Json::num(c.timed_out as f64)),
            ("deferred", Json::num(c.deferred as f64)),
            ("mean_ms", Json::num(c.mean_ms())),
            ("p95_ms", Json::num(c.p95_ms())),
            ("max_ms", Json::num(to_secs(c.max_ns) * 1e3)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    for ((w, gpu), (busy, weighted)) in &gpu_raster(input) {
        let Some(g) = input.gpus.get(*gpu) else { continue };
        // The last window may be partial: clamp the utilization
        // denominator to the simulated horizon.
        let span_s =
            (to_secs(input.horizon) - *w as f64 * win_s).clamp(f64::MIN_POSITIVE, win_s);
        let util = busy / (g.gpcs as f64 * span_s);
        let line = Json::obj(vec![
            ("kind", Json::str("gpu")),
            ("window", Json::num(*w as f64)),
            ("t0_s", Json::num(*w as f64 * win_s)),
            ("gpu", Json::num(*gpu as f64)),
            ("class", Json::str(&g.name)),
            ("busy_gpc_s", Json::num(*busy)),
            ("util", Json::num(util.min(1.0))),
            ("power_w", Json::num(window_power_w(g, *weighted, win_s))),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    for ((w, gpu, tenant), c) in &log.group_cells {
        let line = Json::obj(vec![
            ("kind", Json::str("group")),
            ("window", Json::num(*w as f64)),
            ("t0_s", Json::num(*w as f64 * win_s)),
            ("gpu", Json::num(*gpu as f64)),
            ("tenant", Json::num(*tenant as f64)),
            ("queue_avg", Json::num(c.queue_avg())),
            ("queue_max", Json::num(c.queue_max as f64)),
            ("in_flight_avg", Json::num(c.in_flight_avg())),
            ("in_flight_max", Json::num(c.in_flight_max as f64)),
            ("batches", Json::num(c.batches as f64)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

fn tenant_name<'a>(input: &'a ExportInput, tenant: usize) -> &'a str {
    input.tenants.get(tenant).map(String::as_str).unwrap_or("?")
}

fn span_flags(s: &Span) -> Json {
    let names: [(&str, u8); 5] = [
        ("deferred", flag::DEFERRED),
        ("retried", flag::RETRIED),
        ("hedged", flag::HEDGED),
        ("degraded", flag::DEGRADED),
        ("warmup", flag::WARMUP),
    ];
    Json::arr(names.iter().filter(|(_, b)| s.flags & b != 0).map(|(n, _)| Json::str(n)))
}

fn spans_text(input: &ExportInput) -> String {
    let mut out = header_line(input);
    out.push('\n');
    for s in &input.log.spans {
        let mut pairs = vec![
            ("tenant", Json::num(s.tenant as f64)),
            ("model", Json::str(tenant_name(input, s.tenant))),
            ("idx", Json::num(s.idx as f64)),
            ("arrival_s", Json::num(to_secs(s.arrival))),
            ("end_s", Json::num(to_secs(s.end))),
            ("outcome", Json::str(s.outcome.label())),
            ("flags", span_flags(s)),
        ];
        if s.outcome == SpanOutcome::Served {
            pairs.push(("preprocess_ms", Json::num(to_secs(s.parts.preprocess) * 1e3)));
            pairs.push(("batching_ms", Json::num(to_secs(s.parts.batching) * 1e3)));
            pairs.push(("dispatch_ms", Json::num(to_secs(s.parts.dispatch_wait) * 1e3)));
            pairs.push(("execution_ms", Json::num(to_secs(s.parts.execution) * 1e3)));
            pairs.push(("e2e_ms", Json::num(to_secs(s.parts.total()) * 1e3)));
        }
        if let Some(r) = &s.route {
            pairs.push(("gpu", Json::num(r.gpu as f64)));
            pairs.push(("slice", Json::num(r.slice as f64)));
            pairs.push(("batch", Json::num(r.batch as f64)));
            pairs.push(("batch_size", Json::num(r.batch_size as f64)));
        }
        out.push_str(&Json::obj(pairs).to_string());
        out.push('\n');
    }
    out
}

fn sorted_marks(input: &ExportInput) -> Vec<&EventMark> {
    let mut marks: Vec<&EventMark> = input.marks.iter().collect();
    marks.sort_by(|a, b| {
        (a.at, &a.kind, a.gpu, &a.detail).cmp(&(b.at, &b.kind, b.gpu, &b.detail))
    });
    marks
}

fn events_text(input: &ExportInput) -> String {
    let mut out = header_line(input);
    out.push('\n');
    for m in sorted_marks(input) {
        let line = Json::obj(vec![
            ("at_s", Json::num(to_secs(m.at))),
            ("gpu", m.gpu.map_or(Json::Null, |g| Json::num(g as f64))),
            ("kind", Json::str(&m.kind)),
            ("detail", Json::str(&m.detail)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

fn us(t: Nanos) -> f64 {
    t as f64 / 1e3
}

/// Chrome trace-event JSON (the "JSON Array Format" with an object
/// envelope). Process ids are GPU indices; one extra process holds
/// fleet-scope instants and counters.
fn trace_text(input: &ExportInput) -> String {
    let log = input.log;
    let fleet_pid = input.gpus.len();
    let win_s = to_secs(log.spec.window_ns.max(1));
    let mut events: Vec<(f64, Json)> = Vec::new();
    let mut meta =
        |name: &str, pid: usize, tid: Option<usize>, value: &str, events: &mut Vec<(f64, Json)>| {
            let mut pairs = vec![
                ("name", Json::str(name)),
                ("ph", Json::str("M")),
                ("pid", Json::num(pid as f64)),
                ("ts", Json::num(0.0)),
                ("args", Json::obj(vec![("name", Json::str(value))])),
            ];
            if let Some(tid) = tid {
                pairs.push(("tid", Json::num(tid as f64)));
            }
            events.push((0.0, Json::obj(pairs)));
        };
    for (g, desc) in input.gpus.iter().enumerate() {
        meta("process_name", g, None, &format!("GPU{g} ({})", desc.name), &mut events);
    }
    meta("process_name", fleet_pid, None, "fleet", &mut events);
    // Thread (slice) names: every slice that ever executed a batch.
    let mut slices: Vec<(usize, usize)> = log.segs.iter().map(|s| (s.gpu, s.slice)).collect();
    slices.sort_unstable();
    slices.dedup();
    for (gpu, slice) in slices {
        meta("thread_name", gpu, Some(slice + 1), &format!("slice {slice}"), &mut events);
    }

    // Batch execution rectangles: complete (X) events on (GPU, slice).
    for seg in &log.segs {
        let name = format!(
            "{} x{}{}",
            tenant_name(input, seg.tenant),
            seg.size,
            if seg.harvested { " (harvested)" } else { "" }
        );
        events.push((
            us(seg.start),
            Json::obj(vec![
                ("name", Json::str(&name)),
                ("cat", Json::str("batch")),
                ("ph", Json::str("X")),
                ("pid", Json::num(seg.gpu as f64)),
                ("tid", Json::num(seg.slice as f64 + 1.0)),
                ("ts", Json::num(us(seg.start))),
                ("dur", Json::num(us(seg.end.max(seg.start)) - us(seg.start))),
                (
                    "args",
                    Json::obj(vec![
                        ("tenant", Json::num(seg.tenant as f64)),
                        ("seq", Json::num(seg.seq as f64)),
                        ("gpcs", Json::num(seg.gpcs as f64)),
                        ("pw", Json::num(seg.pw)),
                        ("harvested", Json::Bool(seg.harvested)),
                    ]),
                ),
            ]),
        ));
    }

    // Sampled served requests: async begin/end pairs on their GPU's
    // process, keyed by a per-request id so overlaps render correctly.
    for s in &log.spans {
        let Some(r) = &s.route else { continue };
        let id = format!("t{}:r{}", s.tenant, s.idx);
        let name = format!("{} req {}", tenant_name(input, s.tenant), s.idx);
        let begin = Json::obj(vec![
            ("name", Json::str(&name)),
            ("cat", Json::str("request")),
            ("ph", Json::str("b")),
            ("id", Json::str(&id)),
            ("pid", Json::num(r.gpu as f64)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(us(s.arrival))),
            (
                "args",
                Json::obj(vec![
                    ("outcome", Json::str(s.outcome.label())),
                    ("flags", span_flags(s)),
                    ("preprocess_ms", Json::num(to_secs(s.parts.preprocess) * 1e3)),
                    ("batching_ms", Json::num(to_secs(s.parts.batching) * 1e3)),
                    ("dispatch_ms", Json::num(to_secs(s.parts.dispatch_wait) * 1e3)),
                    ("execution_ms", Json::num(to_secs(s.parts.execution) * 1e3)),
                    ("batch", Json::num(r.batch as f64)),
                    ("slice", Json::num(r.slice as f64)),
                ]),
            ),
        ]);
        let end = Json::obj(vec![
            ("name", Json::str(&name)),
            ("cat", Json::str("request")),
            ("ph", Json::str("e")),
            ("id", Json::str(&id)),
            ("pid", Json::num(r.gpu as f64)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(us(s.end))),
        ]);
        events.push((us(s.arrival), begin));
        events.push((us(s.end), end));
    }

    // Fleet lifecycle instants: process-scoped on their GPU's track,
    // global otherwise (crash → detect → repair land on the failed GPU).
    for m in sorted_marks(input) {
        let (pid, scope) = match m.gpu {
            Some(g) => (g, "p"),
            None => (fleet_pid, "g"),
        };
        events.push((
            us(m.at),
            Json::obj(vec![
                ("name", Json::str(&m.kind)),
                ("cat", Json::str("event")),
                ("ph", Json::str("i")),
                ("s", Json::str(scope)),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(us(m.at))),
                ("args", Json::obj(vec![("detail", Json::str(&m.detail))])),
            ]),
        ));
    }

    // Per-window counter tracks: busy GPCs + power per GPU, fleet power.
    let mut fleet_power: BTreeMap<u64, f64> = BTreeMap::new();
    for ((w, gpu), (busy, weighted)) in &gpu_raster(input) {
        let Some(g) = input.gpus.get(*gpu) else { continue };
        let ts = *w as f64 * win_s * 1e6;
        let power = window_power_w(g, *weighted, win_s);
        *fleet_power.entry(*w).or_default() += power;
        for (name, value) in [("busy_gpc", busy / win_s), ("power_w", power)] {
            events.push((
                ts,
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("ph", Json::str("C")),
                    ("pid", Json::num(*gpu as f64)),
                    ("tid", Json::num(0.0)),
                    ("ts", Json::num(ts)),
                    ("args", Json::obj(vec![(name, Json::num(value))])),
                ]),
            ));
        }
    }
    for (w, power) in fleet_power {
        let ts = w as f64 * win_s * 1e6;
        events.push((
            ts,
            Json::obj(vec![
                ("name", Json::str("fleet_power_w")),
                ("ph", Json::str("C")),
                ("pid", Json::num(fleet_pid as f64)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(ts)),
                ("args", Json::obj(vec![("fleet_power_w", Json::num(power))])),
            ]),
        ));
    }

    // Monotone timestamps (stable: construction order breaks ties).
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let doc = Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("otherData", input.fp.json()),
        ("traceEvents", Json::arr(events.into_iter().map(|(_, e)| e))),
    ]);
    let mut s = doc.to_string_pretty();
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{millis, secs};
    use crate::metrics::LatencyParts;
    use crate::obs::span::{BatchSeg, Served};
    use crate::obs::ObsSpec;

    fn sample_input(log: &ObsLog, fp: &Fingerprint) -> Vec<String> {
        let input = ExportInput {
            log,
            fp,
            horizon: secs(2.0),
            gpus: vec![GpuDesc {
                name: "a100".into(),
                gpcs: 7,
                gpc_active_w: 50.0,
                gpc_idle_w: 5.0,
            }],
            tenants: vec!["swin".into()],
            marks: vec![
                EventMark { at: secs(1.0), gpu: Some(0), kind: "crash".into(), detail: "g0".into() },
                EventMark { at: secs(1.2), gpu: None, kind: "reconfig".into(), detail: "".into() },
            ],
        };
        vec![
            meta_text(&input),
            windows_text(&input),
            spans_text(&input),
            events_text(&input),
            trace_text(&input),
        ]
    }

    fn sample_log() -> ObsLog {
        let spec = ObsSpec::on(1.0, 1);
        let mut log = ObsLog::new(spec);
        log.on_arrival(millis(100.0), 0);
        log.on_served(Served {
            tenant: 0,
            idx: 0,
            arrival: millis(100.0),
            done: millis(140.0),
            parts: LatencyParts { execution: millis(40.0), ..Default::default() },
            gpu: 0,
            slice: 2,
            batch: 0,
            batch_size: 4,
            degraded: false,
            deferred: false,
            counted: true,
        });
        log.on_batch(BatchSeg {
            gpu: 0,
            slice: 2,
            tenant: 0,
            seq: 0,
            start: millis(100.0),
            end: millis(140.0),
            size: 4,
            gpcs: 1,
            pw: 1.0,
            harvested: false,
        });
        log.on_queue(millis(100.0), 0, 0, 3, 1);
        log.seal();
        log
    }

    #[test]
    fn export_texts_are_valid_and_deterministic() {
        let log = sample_log();
        let mut fp = Fingerprint::new("test");
        fp.push("seed", 7);
        let a = sample_input(&log, &fp);
        let b = sample_input(&log, &fp);
        assert_eq!(a, b, "same log ⇒ identical bytes");
        // Every JSONL line parses; trace + meta parse whole.
        for text in [&a[1], &a[2], &a[3]] {
            for line in text.lines() {
                crate::util::json::parse(line).unwrap();
            }
        }
        let meta = crate::util::json::parse(&a[0]).unwrap();
        assert!(Fingerprint::from_json(meta.req("fingerprint").unwrap()).unwrap().same_mapping(&fp));
        let trace = crate::util::json::parse(&a[4]).unwrap();
        let evs = trace.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
        assert!(!evs.is_empty());
        let mut last = f64::MIN;
        for e in &evs {
            let ts = e.req("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last, "trace timestamps are monotone");
            last = ts;
        }
        // One X batch, one matched b/e request pair, two instants.
        let count =
            |ph: &str| evs.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph)).count();
        assert_eq!(count("X"), 1);
        assert_eq!(count("b"), count("e"));
        assert_eq!(count("b"), 1);
        assert_eq!(count("i"), 2);
        assert!(count("C") >= 2);
    }

    #[test]
    fn raster_splits_segments_across_windows() {
        let spec = ObsSpec::on(1.0, 1);
        let mut log = ObsLog::new(spec);
        log.on_batch(BatchSeg {
            gpu: 0,
            slice: 0,
            tenant: 0,
            seq: 0,
            start: millis(500.0),
            end: millis(1500.0),
            size: 1,
            gpcs: 2,
            pw: 1.0,
            harvested: false,
        });
        let fp = Fingerprint::new("test");
        let input = ExportInput {
            log: &log,
            fp: &fp,
            horizon: secs(2.0),
            gpus: vec![GpuDesc {
                name: "a100".into(),
                gpcs: 7,
                gpc_active_w: 50.0,
                gpc_idle_w: 5.0,
            }],
            tenants: vec!["t".into()],
            marks: vec![],
        };
        let raster = gpu_raster(&input);
        let w0 = raster.get(&(0, 0)).unwrap();
        let w1 = raster.get(&(1, 0)).unwrap();
        assert!((w0.0 - 1.0).abs() < 1e-9, "0.5 s × 2 GPCs in window 0");
        assert!((w1.0 - 1.0).abs() < 1e-9, "0.5 s × 2 GPCs in window 1");
    }
}
