//! Sampled per-request spans and per-batch execution segments.
//!
//! A [`Span`] is the time-resolved twin of one `RunStats::record` call: the
//! full `LatencyParts` pipeline (preprocess → batching → dispatch_wait →
//! execution) plus route (GPU / slice / batch) and outcome. Spans are
//! sampled deterministically 1-in-N by request index — never by RNG — so
//! recording cannot perturb the simulation.
//!
//! A [`BatchSeg`] is one batch's occupancy of one slice: the timeline
//! rectangles the Perfetto export draws, and the raster the per-window
//! busy-GPC utilization and power curves integrate.

use crate::clock::Nanos;
use crate::metrics::LatencyParts;

/// How a request's life ended. Deferral, retries, hedging and degraded
/// service are *modifiers* on the way to one of these terminals and are
/// carried in [`Span::flags`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Served to completion (possibly late, degraded, or via a retry).
    Served,
    /// Turned away by admission control and never served.
    Dropped,
    /// Lost to an injected fault (retry budget exhausted / no recovery).
    TimedOut,
}

impl SpanOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            SpanOutcome::Served => "served",
            SpanOutcome::Dropped => "dropped",
            SpanOutcome::TimedOut => "timed_out",
        }
    }
}

/// Bit flags qualifying a span's journey (see [`Span::flags`]).
pub mod flag {
    /// Waited in an admission queue before (maybe) being served.
    pub const DEFERRED: u8 = 1 << 0;
    /// At least one crash-recovery retry attempt was issued for it.
    pub const RETRIED: u8 = 1 << 1;
    /// A hedged duplicate was issued to a second replica.
    pub const HEDGED: u8 = 1 << 2;
    /// Served on a slowdown-degraded GPU.
    pub const DEGRADED: u8 = 1 << 3;
    /// Finished inside the driver's warmup and is excluded from
    /// `RunStats` aggregates (still shown on timelines).
    pub const WARMUP: u8 = 1 << 4;
}

/// Where a served request actually ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    /// Global GPU index.
    pub gpu: usize,
    /// Slice (vGPU slot) index on that GPU.
    pub slice: usize,
    /// Per-(GPU, tenant) batch sequence number ([`BatchSeg::seq`]).
    pub batch: u64,
    /// Size of the batch it rode in.
    pub batch_size: usize,
}

/// One sampled request, arrival to terminal state.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Global tenant index.
    pub tenant: usize,
    /// Request index within the tenant's arrival sequence.
    pub idx: usize,
    pub arrival: Nanos,
    /// Completion / drop / timeout instant.
    pub end: Nanos,
    /// Pipeline breakdown; zeroed for requests that never executed.
    pub parts: LatencyParts,
    /// `None` for requests that never reached a slice.
    pub route: Option<Route>,
    pub outcome: SpanOutcome,
    /// OR of [`flag`] bits.
    pub flags: u8,
}

/// Everything the recorder needs about one served request (bundled so the
/// call sites stay readable and clippy stays quiet about arity).
#[derive(Debug, Clone, Copy)]
pub struct Served {
    pub tenant: usize,
    pub idx: usize,
    pub arrival: Nanos,
    pub done: Nanos,
    pub parts: LatencyParts,
    pub gpu: usize,
    pub slice: usize,
    pub batch: u64,
    pub batch_size: usize,
    pub degraded: bool,
    pub deferred: bool,
    /// Whether this completion is counted in `RunStats` (post-warmup by
    /// the driver's completion-order rule). Warmup completions still get
    /// spans (flagged [`flag::WARMUP`]) but stay out of the window cells.
    pub counted: bool,
}

/// One batch's occupancy of one slice: `[start, end)` on `(gpu, slice)`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchSeg {
    /// Global GPU index.
    pub gpu: usize,
    /// Slice (vGPU slot) index on that GPU.
    pub slice: usize,
    /// Global tenant index.
    pub tenant: usize,
    /// Dispatch sequence number within this (GPU, tenant) serving group —
    /// with `(gpu, tenant)` it is a total key, which the shard merge's
    /// deterministic sort relies on.
    pub seq: u64,
    pub start: Nanos,
    pub end: Nanos,
    /// Requests in the batch.
    pub size: usize,
    /// GPCs the executing slice holds (raster weight for busy-GPC curves).
    pub gpcs: usize,
    /// Interference power weight in effect at dispatch (1.0 = neutral).
    pub pw: f64,
    /// True when a crash harvested the batch before completion: `end` is
    /// the crash instant, not the modeled completion.
    pub harvested: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_labels() {
        assert_eq!(SpanOutcome::Served.label(), "served");
        assert_eq!(SpanOutcome::Dropped.label(), "dropped");
        assert_eq!(SpanOutcome::TimedOut.label(), "timed_out");
    }

    #[test]
    fn flags_are_distinct_bits() {
        let all = [flag::DEFERRED, flag::RETRIED, flag::HEDGED, flag::DEGRADED, flag::WARMUP];
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_eq!(a & b, 0);
            }
        }
    }
}
