//! Run observability: windowed time-series, sampled request spans, and
//! Perfetto-loadable fleet timelines.
//!
//! The DES drivers report end-of-run aggregates ([`crate::metrics::RunStats`]),
//! which is the right interface for experiments but hides *dynamics*: a
//! reconfig oscillation, a fault-recovery stall, or a batching pathology is
//! invisible unless some aggregate happens to shadow it. This module is the
//! seam that makes those visible without perturbing the simulation:
//!
//! * [`series`] — an [`ObsLog`] recorder aggregating counters/gauges into
//!   fixed `window_ns` buckets (per-tenant arrivals/served/drops + latency
//!   histogram, per-(GPU, tenant) queue-depth gauges), with shard-local
//!   buffers merged deterministically in shard order at `finalize`.
//! * [`span`] — deterministic 1-in-N sampled per-request [`Span`]s carrying
//!   the full `LatencyParts` pipeline plus route and outcome, and per-batch
//!   execution segments ([`BatchSeg`]) for the timeline.
//! * [`export`] — JSONL metric dumps plus a Chrome trace-event JSON timeline
//!   (GPUs are processes, slices are threads, batches are complete events,
//!   reconfig/consolidation/fault events are instants) that loads directly
//!   in `ui.perfetto.dev`.
//! * [`report`] — the `preba report` subcommand: a run digest (phase
//!   breakdown, top-k worst windows, event log) rendered from the exported
//!   artifacts.
//!
//! **Neutrality contract** (the PR 8 discipline): the layer is always
//! compiled but off by default, and with `ObsSpec::enabled == false` every
//! recording call returns before touching any state — runs are BYTE-identical
//! to an unobserved build. When enabled, recording never consumes driver RNG
//! state, never schedules events, and keys every record by global ids, so
//! outcomes stay byte-identical and the exported artifacts are deterministic
//! across `--shards` and `--jobs`.

pub mod export;
pub mod report;
pub mod series;
pub mod span;

pub use export::{EventMark, ExportInput, GpuDesc};
pub use series::{GroupCell, ObsLog, TenantCell};
pub use span::{BatchSeg, Route, Served, Span, SpanOutcome};

use crate::clock::{secs, Nanos};
use crate::util::json::Json;

/// Recording knobs carried by both DES driver configs. `Default` is
/// disabled: a driver with a default spec behaves byte-identically to one
/// built before this module existed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObsSpec {
    /// Master switch. Off ⇒ every recorder call is a no-op.
    pub enabled: bool,
    /// Time-series bucket width.
    pub window_ns: Nanos,
    /// Span sampling: request `idx` is sampled iff `idx % span_sample == 0`
    /// (deterministic — no RNG draw, so sampling cannot perturb the run).
    pub span_sample: u64,
}

impl Default for ObsSpec {
    fn default() -> Self {
        ObsSpec { enabled: false, window_ns: secs(1.0), span_sample: 8 }
    }
}

impl ObsSpec {
    /// An enabled spec with the given bucket width and sampling period.
    pub fn on(window_s: f64, span_sample: u64) -> Self {
        ObsSpec {
            enabled: true,
            window_ns: secs(window_s.max(1e-3)),
            span_sample: span_sample.max(1),
        }
    }

    /// Window index for a timestamp.
    #[inline]
    pub fn window(&self, t: Nanos) -> u64 {
        t / self.window_ns.max(1)
    }
}

/// The resolved-config fingerprint embedded in every CLI run banner and
/// every exported obs artifact, so a timeline is self-describing: seed,
/// planner, strategy, shards, curves on/off, fault spec, obs knobs.
///
/// Pairs keep insertion order for the human-readable [`Fingerprint::line`];
/// the JSON form sorts keys (BTreeMap) — both are deterministic. The
/// fingerprint deliberately excludes `--jobs`: worker count never changes
/// results, and run banners are byte-compared across job counts in tests.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Fingerprint {
    pairs: Vec<(String, String)>,
}

impl Fingerprint {
    pub fn new(driver: &str) -> Self {
        let mut fp = Fingerprint::default();
        fp.push("driver", driver);
        fp.push("crate", env!("CARGO_PKG_VERSION"));
        fp
    }

    pub fn push(&mut self, key: &str, value: impl std::fmt::Display) {
        self.pairs.push((key.to_string(), value.to_string()));
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// One-line `k=v` form for run banners and JSONL headers.
    pub fn line(&self) -> String {
        let body: Vec<String> = self.pairs.iter().map(|(k, v)| format!("{k}={v}")).collect();
        format!("fingerprint: {}", body.join(" "))
    }

    /// JSON object form (string values; keys sorted by the writer).
    pub fn json(&self) -> Json {
        Json::Obj(self.pairs.iter().map(|(k, v)| (k.clone(), Json::str(v))).collect())
    }

    /// Rebuild from the JSON object form (key order is the writer's sorted
    /// order — equality with the original is on the key→value *mapping*).
    pub fn from_json(doc: &Json) -> anyhow::Result<Self> {
        let obj = doc.as_obj().ok_or_else(|| anyhow::anyhow!("fingerprint is not an object"))?;
        let mut fp = Fingerprint::default();
        for (k, v) in obj {
            let s = v.as_str().ok_or_else(|| anyhow::anyhow!("fingerprint['{k}'] not a string"))?;
            fp.push(k, s);
        }
        Ok(fp)
    }

    /// Key→value equality regardless of pair order (JSON round-trips sort).
    pub fn same_mapping(&self, other: &Fingerprint) -> bool {
        let norm = |fp: &Fingerprint| {
            let mut v = fp.pairs.clone();
            v.sort();
            v
        };
        norm(self) == norm(other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_disabled() {
        let spec = ObsSpec::default();
        assert!(!spec.enabled);
        assert_eq!(spec.window_ns, secs(1.0));
        assert!(spec.span_sample >= 1);
    }

    #[test]
    fn windows_partition_time() {
        let spec = ObsSpec::on(0.5, 4);
        assert_eq!(spec.window(0), 0);
        assert_eq!(spec.window(secs(0.49)), 0);
        assert_eq!(spec.window(secs(0.5)), 1);
        assert_eq!(spec.window(secs(2.6)), 5);
    }

    #[test]
    fn fingerprint_round_trips_through_json() {
        let mut fp = Fingerprint::new("cluster");
        fp.push("seed", 0xC1A0u64);
        fp.push("strategy", "bfd");
        fp.push("shards", "auto");
        let back = Fingerprint::from_json(&fp.json()).unwrap();
        assert!(fp.same_mapping(&back));
        assert_eq!(back.get("seed").unwrap(), format!("{}", 0xC1A0u64));
        assert!(fp.line().contains("driver=cluster"));
        assert!(fp.line().contains("strategy=bfd"));
    }
}
