//! `preba report DIR` — a run digest rendered from exported obs artifacts.
//!
//! Reads the files [`crate::obs::export`] wrote (`meta.json`,
//! `windows.jsonl`, `spans.jsonl`, `events.jsonl`) and prints: the run's
//! [`Fingerprint`] (the round-trip the reproducibility smoke test pins),
//! totals reconciled from the window cells, the sampled-span phase
//! breakdown, the top-k worst windows by p95, and the fleet event log.

use std::path::Path;

use super::Fingerprint;
use crate::util::json::{parse, Json};
use crate::util::table::{num, Table};

/// How many worst windows the digest lists.
const TOP_K: usize = 5;

/// Render the digest to stdout.
pub fn report(dir: &Path) -> anyhow::Result<()> {
    print!("{}", render(dir)?);
    Ok(())
}

fn read_jsonl(path: &Path) -> anyhow::Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| parse(l).map_err(|e| anyhow::anyhow!("{}: {e}", path.display())))
        .collect()
}

fn f(doc: &Json, key: &str) -> f64 {
    doc.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

fn s<'a>(doc: &'a Json, key: &str) -> &'a str {
    doc.get(key).and_then(Json::as_str).unwrap_or("")
}

/// Render the digest as a string (separated from [`report`] for tests).
pub fn render(dir: &Path) -> anyhow::Result<String> {
    let meta = parse(
        &std::fs::read_to_string(dir.join("meta.json"))
            .map_err(|e| anyhow::anyhow!("cannot read {}/meta.json: {e}", dir.display()))?,
    )?;
    let fp = Fingerprint::from_json(meta.req("fingerprint")?)?;
    let windows = read_jsonl(&dir.join("windows.jsonl"))?;
    let spans = read_jsonl(&dir.join("spans.jsonl"))?;
    let events = read_jsonl(&dir.join("events.jsonl"))?;

    let mut out = String::new();
    out.push_str(&format!("run digest from {}\n", dir.display()));
    out.push_str(&fp.line());
    out.push('\n');

    // ---- totals reconciled from the window cells -----------------------
    let tenant_rows: Vec<&Json> =
        windows.iter().filter(|r| s(r, "kind") == "tenant").collect();
    let total = |key: &str| tenant_rows.iter().map(|r| f(r, key)).sum::<f64>();
    out.push_str(&format!(
        "\nwindows: {} cells over {:.1} s (window {} s)\n",
        tenant_rows.len(),
        f(&meta, "horizon_s"),
        f(&meta, "window_s"),
    ));
    out.push_str(&format!(
        "totals: arrivals {} | served {} | dropped {} | timed out {} | deferred {}\n",
        total("arrivals"),
        total("served"),
        total("dropped"),
        total("timed_out"),
        total("deferred"),
    ));

    // ---- phase breakdown from the sampled served spans -----------------
    let served: Vec<&Json> =
        spans.iter().filter(|r| s(r, "outcome") == "served").collect();
    if !served.is_empty() {
        let mean = |key: &str| {
            served.iter().map(|r| f(r, key)).sum::<f64>() / served.len() as f64
        };
        out.push_str(&format!(
            "\nphase breakdown ({} sampled served spans):\n  preprocess {:.2} ms | batching {:.2} ms | queue {:.2} ms | execute {:.2} ms | e2e {:.2} ms\n",
            served.len(),
            mean("preprocess_ms"),
            mean("batching_ms"),
            mean("dispatch_ms"),
            mean("execution_ms"),
            mean("e2e_ms"),
        ));
    }

    // ---- top-k worst windows by p95 ------------------------------------
    let mut worst: Vec<&&Json> = tenant_rows.iter().filter(|r| f(r, "served") > 0.0).collect();
    worst.sort_by(|a, b| {
        f(b, "p95_ms")
            .total_cmp(&f(a, "p95_ms"))
            .then(f(a, "window").total_cmp(&f(b, "window")))
            .then(f(a, "tenant").total_cmp(&f(b, "tenant")))
    });
    if !worst.is_empty() {
        out.push_str(&format!("\nworst {} windows by p95:\n", TOP_K.min(worst.len())));
        let mut t = Table::new(&["t0 s", "model", "served", "p95 ms", "mean ms", "drops"]);
        for r in worst.iter().take(TOP_K) {
            t.row(&[
                num(f(r, "t0_s")),
                s(r, "model").to_string(),
                num(f(r, "served")),
                num(f(r, "p95_ms")),
                num(f(r, "mean_ms")),
                num(f(r, "dropped") + f(r, "timed_out")),
            ]);
        }
        for line in t.render() {
            out.push_str(&line);
            out.push('\n');
        }
    }

    // ---- fleet event log -----------------------------------------------
    let marks: Vec<&Json> = events.iter().filter(|r| !s(r, "kind").is_empty()).collect();
    if marks.is_empty() {
        out.push_str("\nno fleet events recorded\n");
    } else {
        out.push_str(&format!("\nfleet events ({}):\n", marks.len()));
        for m in marks {
            let gpu = m
                .get("gpu")
                .and_then(Json::as_f64)
                .map_or("fleet".to_string(), |g| format!("gpu{g}"));
            let detail = s(m, "detail");
            out.push_str(&format!(
                "  t={:.2}s {} [{}]{}{}\n",
                f(m, "at_s"),
                s(m, "kind"),
                gpu,
                if detail.is_empty() { "" } else { " " },
                detail,
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{millis, secs};
    use crate::metrics::LatencyParts;
    use crate::obs::export::{export, EventMark, ExportInput, GpuDesc};
    use crate::obs::span::Served;
    use crate::obs::{ObsLog, ObsSpec};

    #[test]
    fn report_round_trips_the_fingerprint() {
        let spec = ObsSpec::on(1.0, 1);
        let mut log = ObsLog::new(spec);
        log.on_arrival(millis(10.0), 0);
        log.on_served(Served {
            tenant: 0,
            idx: 0,
            arrival: millis(10.0),
            done: millis(30.0),
            parts: LatencyParts { execution: millis(20.0), ..Default::default() },
            gpu: 0,
            slice: 0,
            batch: 0,
            batch_size: 1,
            degraded: false,
            deferred: false,
            counted: true,
        });
        log.seal();
        let mut fp = Fingerprint::new("cluster");
        fp.push("seed", 0xAB5EEDu64);
        fp.push("strategy", "bfd");
        let dir = std::env::temp_dir().join(format!("preba_obs_report_{}", std::process::id()));
        let input = ExportInput {
            log: &log,
            fp: &fp,
            horizon: secs(1.0),
            gpus: vec![GpuDesc {
                name: "a100".into(),
                gpcs: 7,
                gpc_active_w: 50.0,
                gpc_idle_w: 5.0,
            }],
            tenants: vec!["swin".into()],
            marks: vec![EventMark {
                at: millis(500.0),
                gpu: Some(0),
                kind: "crash".into(),
                detail: "injected".into(),
            }],
        };
        export(&dir, &input).unwrap();
        let text = render(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(text.contains(&fp.line()), "digest embeds the fingerprint line");
        assert!(text.contains(&format!("seed={}", 0xAB5EEDu64)));
        assert!(text.contains("crash"), "event log lists the fault");
        assert!(text.contains("served 1"), "totals reconcile");
    }
}
