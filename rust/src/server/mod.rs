//! The PREBA inference server (L3 coordinator).
//!
//! Architecture (paper Fig 3 / Fig 10): frontend receives queries →
//! preprocessing (host CPU pool, or PREBA's DPU, or "Ideal" = free) →
//! dynamic batching queues → per-vGPU execution workers.
//!
//! Two drivers share this coordinator logic:
//! * [`sim_driver`] — discrete-event simulation under a virtual clock with
//!   the calibrated MIG service model; regenerates the paper's figures.
//! * [`real_driver`] — threads + the PJRT runtime executing the AOT
//!   Pallas/JAX artifacts for real (examples & end-to-end validation).
//!
//! Above the single GPU, [`multi`] colocates tenants on one partition and
//! [`cluster`] runs one DES over a multi-GPU inventory (packing-based
//! placement over possibly heterogeneous GPU classes, cross-GPU routing,
//! online rebalancing, admission control, and recorded-trace replay).

pub mod cluster;
pub mod multi;
pub mod real_driver;
pub mod sim_driver;

pub use cluster::{ClusterConfig, ClusterConfigBuilder, ClusterOutcome, ClusterTenant, Routing};
pub use sim_driver::{PreprocMode, SimConfig, SimOutcome};

/// Which batching policy the server uses (ablation axis, Fig 22).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Fixed Batch_max/Time_queue, one queue (baseline batcher).
    Static,
    /// PREBA: profiled per-bucket Batch_knee + Time_knee/n policy.
    Dynamic,
}
