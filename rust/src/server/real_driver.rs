//! Real-execution serving driver: the full PREBA pipeline with *actual*
//! compute on the PJRT CPU client.
//!
//! * Frontend thread: paced Poisson arrivals, synthesizes raw inputs
//!   (quantized-DCT images / PCM audio), ships them over a bounded
//!   channel (backpressure).
//! * Server thread (owns the PJRT [`Engine`]): preprocessing — either the
//!   host-Rust pipelines (`preprocess::ops`, the paper's CPU baseline) or
//!   the AOT Pallas kernel artifacts (the DPU path) — then PREBA's
//!   `DynamicBatcher`, then model execution on the lite JAX artifacts.
//!
//! Python never runs here; everything executes from `artifacts/*.hlo.txt`.
//! On this 1-core box the MIG partition is emulated by the batching policy
//! (knees of the 1g slice) while execution itself is serialized — the
//! *figures* come from the DES driver; this driver proves the three layers
//! compose and feeds EXPERIMENTS.md's end-to-end run.

use crate::batching::{BatchPolicy, Bucketizer, DynamicBatcher, Request};
use crate::clock::{Clock, Nanos, RealClock};
use crate::config::PrebaConfig;
use crate::metrics::{LatencyParts, RunStats};
use crate::mig::{MigConfig, ServiceModel};
use crate::models::{ModelId, ModelKind};
use crate::preprocess::ops;
use crate::rt;
use crate::runtime::Engine;
use crate::util::Rng;
use crate::workload::{self, QueryGen};

/// Preprocessing implementation for the real driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RealPreproc {
    /// Host Rust pipelines (the paper's CPU baseline).
    HostRust,
    /// AOT Pallas kernel artifacts on PJRT (the DPU path).
    DpuPallas,
}

/// Raw-input request shipped from the frontend.
struct RawRequest {
    id: u64,
    arrival: Nanos,
    len_s: f64,
    data: Vec<f32>,
}

/// Run parameters.
#[derive(Debug, Clone)]
pub struct RealConfig {
    pub model: ModelId,
    pub preproc: RealPreproc,
    pub rate_qps: f64,
    pub requests: usize,
    pub seed: u64,
    /// Cap audio lengths so only lowered buckets are exercised.
    pub max_audio_s: f64,
}

impl RealConfig {
    pub fn new(model: ModelId, preproc: RealPreproc) -> RealConfig {
        RealConfig { model, preproc, rate_qps: 20.0, requests: 100, seed: 7, max_audio_s: 10.0 }
    }
}

/// Outcome of a real serving run.
pub struct RealOutcome {
    pub stats: RunStats,
    pub executed_batches: u64,
    pub platform: String,
    /// Output checksum (finiteness witness for EXPERIMENTS.md).
    pub output_l2: f64,
}

/// Source-image side length for vision synthesis (DCT coefficient input).
pub const IMG_SRC: usize = 96;

/// Serve `cfg.requests` requests end-to-end; blocks until drained.
pub fn serve(
    cfg: &RealConfig,
    sys: &PrebaConfig,
    engine: &mut Engine,
) -> anyhow::Result<RealOutcome> {
    let spec = cfg.model.spec();
    // ONE clock for frontend + server: two epochs would silently shift
    // the arrival timestamps by the warm-up duration.
    let clock = std::sync::Arc::new(RealClock::new());

    // Policy: the 1g-slice dynamic policy (PREBA on 1g.5gb(7x)), with
    // Batch_max clamped to the largest lowered artifact batch.
    let buckets = match cfg.model.kind() {
        ModelKind::Vision => Bucketizer::fixed(),
        ModelKind::Audio => Bucketizer::new(sys.batching.bucket_window_s, cfg.max_audio_s),
    };
    let sm = ServiceModel::new(spec, MigConfig::Small7.gpcs_per_vgpu());
    let policy = clamp_policy(
        BatchPolicy::dynamic_from_model(spec, &sm, &buckets, MigConfig::Small7.vgpus()),
        engine,
        cfg.model,
    );
    let mut batcher =
        DynamicBatcher::new(cfg.model, buckets.clone(), policy, sys.batching.merge_adjacent);

    // Warm-up: compile every artifact this run can touch and execute each
    // once with zeros, so PJRT compilation happens at server startup (as
    // in any production server) and not on the first requests.
    warmup(cfg, engine)?;

    // Frontend thread.
    let (tx, rx) = rt::channel::<RawRequest>(256);
    let fe_cfg = cfg.clone();
    let mut pool = rt::WorkerPool::new();
    let fe_clock = clock.clone();
    pool.spawn("frontend", move || {
        let mut rng = Rng::new(fe_cfg.seed);
        let mut qgen = QueryGen::new(fe_cfg.model, fe_cfg.rate_qps, rng.split(1));
        for i in 0..fe_cfg.requests {
            let a = qgen.next();
            let len_s = a.len_s.min(fe_cfg.max_audio_s).max(0.0);
            // Pace to the arrival schedule.
            let now = fe_clock.now();
            if a.at > now {
                std::thread::sleep(std::time::Duration::from_nanos(a.at - now));
            }
            let data = match fe_cfg.model.kind() {
                ModelKind::Vision => workload::synth_image_coeffs(IMG_SRC, IMG_SRC, 3, &mut rng),
                ModelKind::Audio => workload::synth_pcm(len_s, &mut rng),
            };
            let req = RawRequest { id: i as u64, arrival: fe_clock.now(), len_s, data };
            if tx.send(req).is_err() {
                return;
            }
        }
    });

    // Server loop (owns the engine).
    let mut stats = RunStats::new();
    let mut executed_batches = 0u64;
    let mut output_l2 = 0f64;
    let mut received = 0usize;
    let mut preproc_done_at: Vec<Nanos> = vec![0; cfg.requests];
    let mut arrivals: Vec<Nanos> = vec![0; cfg.requests];
    let mut tensors: Vec<Option<Vec<f32>>> = (0..cfg.requests).map(|_| None).collect();

    let drain = |batcher: &mut DynamicBatcher,
                     engine: &mut Engine,
                     now_fn: &dyn Fn() -> Nanos,
                     stats: &mut RunStats,
                     tensors: &mut Vec<Option<Vec<f32>>>,
                     preproc_done_at: &Vec<Nanos>,
                     arrivals: &Vec<Nanos>,
                     executed_batches: &mut u64,
                     output_l2: &mut f64|
     -> anyhow::Result<()> {
        while let Some((batch, _)) = batcher.try_form(now_fn()) {
            let formed = now_fn();
            // Pick artifact: smallest lowered batch >= formed size; audio
            // also matches the padded length bucket.
            let want = batch.size();
            let ab = engine
                .pick_batch(cfg.model.name(), want)
                .ok_or_else(|| anyhow::anyhow!("no artifacts for {}", cfg.model.name()))?;
            let len_key = if cfg.model.kind() == ModelKind::Audio {
                buckets.repr_len(buckets.bucket_of(batch.max_len_s))
            } else {
                0.0
            };
            let entry = engine
                .manifest()
                .model(cfg.model.name(), ab, len_key)
                .ok_or_else(|| {
                    anyhow::anyhow!("no artifact {}/b{ab}/len{len_key}", cfg.model.name())
                })?
                .clone();
            // Assemble the padded input batch.
            let per_sample: usize = entry.inputs[0][1..].iter().product();
            let mut flat = vec![0f32; entry.inputs[0].iter().product()];
            for (j, r) in batch.requests.iter().enumerate() {
                let t = tensors[r.id as usize].take().expect("preprocessed tensor");
                anyhow::ensure!(
                    t.len() <= per_sample,
                    "tensor {} > artifact sample {}",
                    t.len(),
                    per_sample
                );
                flat[j * per_sample..j * per_sample + t.len()].copy_from_slice(&t);
            }
            let t_exec0 = now_fn();
            let outs = engine.execute_f32(&entry.key, &[flat])?;
            let t_exec1 = now_fn();
            *executed_batches += 1;
            *output_l2 += outs[0].iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
            for r in &batch.requests {
                let i = r.id as usize;
                let parts = LatencyParts {
                    preprocess: preproc_done_at[i].saturating_sub(arrivals[i]),
                    batching: formed.saturating_sub(r.enqueued),
                    dispatch_wait: t_exec0.saturating_sub(formed),
                    execution: t_exec1.saturating_sub(t_exec0),
                };
                stats.record(parts, t_exec1, batch.size());
            }
        }
        Ok(())
    };

    while received < cfg.requests || batcher.pending() > 0 {
        // Wait for the next request or the next batching deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_sub(clock.now()).max(1_000))
            .unwrap_or(50_000_000);
        let msg = rx.recv_timeout(std::time::Duration::from_nanos(timeout));
        if let Some(raw) = msg {
            let now = clock.now();
            arrivals[raw.id as usize] = raw.arrival;
            // ---- preprocessing (real compute) ----
            let tensor = preprocess_one(cfg, engine, &raw)?;
            tensors[raw.id as usize] = Some(tensor);
            let done = clock.now();
            preproc_done_at[raw.id as usize] = done;
            batcher.enqueue(Request {
                id: raw.id,
                model: cfg.model,
                arrival: raw.arrival,
                enqueued: done,
                len_s: raw.len_s,
            });
            received += 1;
            let _ = now;
        }
        // Timeout-based releases fire inside `drain` via `try_form(now)`;
        // when the frontend is drained the remaining queues empty out as
        // their Time_queue deadlines pass.
        drain(
            &mut batcher,
            engine,
            &|| clock.now(),
            &mut stats,
            &mut tensors,
            &preproc_done_at,
            &arrivals,
            &mut executed_batches,
            &mut output_l2,
        )?;
    }
    // Final drain after last arrival.
    for batch in batcher.flush(clock.now()) {
        exec_flushed(
            cfg, engine, &buckets, batch, &clock, &mut stats, &mut tensors, &preproc_done_at,
            &arrivals, &mut executed_batches, &mut output_l2,
        )?;
    }
    pool.join();

    Ok(RealOutcome { stats, executed_batches, platform: engine.platform(), output_l2 })
}

/// Compile + dry-run all artifacts a serving run may use.
fn warmup(cfg: &RealConfig, engine: &mut Engine) -> anyhow::Result<()> {
    let mut keys: Vec<String> = engine
        .manifest()
        .iter()
        .filter(|e| e.key.starts_with("model/") && e.name == cfg.model.name())
        .filter(|e| cfg.model.kind() == ModelKind::Vision || e.len_s <= cfg.max_audio_s + 1e-9)
        .map(|e| e.key.clone())
        .collect();
    match (cfg.model.kind(), cfg.preproc) {
        (ModelKind::Vision, RealPreproc::DpuPallas) => {
            keys.push("kernel/image_pipeline/b1".to_string());
        }
        (ModelKind::Audio, RealPreproc::DpuPallas) => {
            keys.extend(
                engine
                    .manifest()
                    .iter()
                    .filter(|e| e.name == "audio_pipeline" && e.len_s <= cfg.max_audio_s + 1e-9)
                    .map(|e| e.key.clone()),
            );
        }
        _ => {}
    }
    for key in keys {
        let entry = engine.manifest().get(&key).unwrap().clone();
        let inputs: Vec<Vec<f32>> =
            entry.inputs.iter().map(|s| vec![0f32; s.iter().product()]).collect();
        engine.execute_f32(&key, &inputs)?;
    }
    Ok(())
}

/// Preprocess one raw request on the configured path.
fn preprocess_one(
    cfg: &RealConfig,
    engine: &mut Engine,
    raw: &RawRequest,
) -> anyhow::Result<Vec<f32>> {
    match (cfg.model.kind(), cfg.preproc) {
        (ModelKind::Vision, RealPreproc::HostRust) => {
            // Decode(IDCT) -> resize 72 -> crop 64 -> normalize; must match
            // the Pallas kernel's parameters (python/compile/kernels/).
            Ok(ops::image_pipeline(&raw.data, IMG_SRC, IMG_SRC, 3, 72, 64))
        }
        (ModelKind::Vision, RealPreproc::DpuPallas) => {
            let outs = engine.execute_f32("kernel/image_pipeline/b1", &[raw.data.clone()])?;
            Ok(outs.into_iter().next().unwrap())
        }
        (ModelKind::Audio, RealPreproc::HostRust) => {
            let padded = pad_audio(cfg, &raw.data, raw.len_s);
            let (feat, _, _) = ops::audio_pipeline(&padded, 16_000, 512, 256, 80);
            Ok(feat)
        }
        (ModelKind::Audio, RealPreproc::DpuPallas) => {
            let bucket_len = bucket_len_for(cfg, raw.len_s);
            let padded = pad_audio(cfg, &raw.data, raw.len_s);
            let key = format!("kernel/audio_pipeline/len{}", fmt_len(bucket_len));
            let outs = engine.execute_f32(&key, &[padded])?;
            Ok(outs.into_iter().next().unwrap())
        }
    }
}

/// Pad PCM to its bucket's upper-edge length (what the artifact expects).
fn pad_audio(cfg: &RealConfig, pcm: &[f32], len_s: f64) -> Vec<f32> {
    let bucket_len = bucket_len_for(cfg, len_s);
    let want = (bucket_len * 16_000.0).round() as usize;
    let mut out = pcm.to_vec();
    out.resize(want, 0.0);
    out
}

fn bucket_len_for(cfg: &RealConfig, len_s: f64) -> f64 {
    let b = Bucketizer::new(2.5, cfg.max_audio_s);
    b.repr_len(b.bucket_of(len_s))
}

/// Format a bucket length for artifact keys (2.5 -> "2p5").
pub fn fmt_len(len_s: f64) -> String {
    if (len_s - len_s.round()).abs() < 1e-9 {
        format!("{}", len_s.round() as u64)
    } else {
        format!("{}", len_s).replace('.', "p")
    }
}

/// Clamp a policy's Batch_max values to the largest lowered batch.
fn clamp_policy(policy: BatchPolicy, engine: &Engine, model: ModelId) -> BatchPolicy {
    let max_b = engine.manifest().batches_for(model.name()).last().copied().unwrap_or(1);
    match policy {
        BatchPolicy::Static(mut p) => {
            p.batch_max = p.batch_max.min(max_b);
            BatchPolicy::Static(p)
        }
        BatchPolicy::Dynamic { mut per_bucket } => {
            for p in &mut per_bucket {
                p.batch_max = p.batch_max.min(max_b);
            }
            BatchPolicy::Dynamic { per_bucket }
        }
    }
}

/// Execute a flushed (shutdown-path) batch.
#[allow(clippy::too_many_arguments)]
fn exec_flushed(
    cfg: &RealConfig,
    engine: &mut Engine,
    buckets: &Bucketizer,
    batch: crate::batching::Batch,
    clock: &RealClock,
    stats: &mut RunStats,
    tensors: &mut [Option<Vec<f32>>],
    preproc_done_at: &[Nanos],
    arrivals: &[Nanos],
    executed_batches: &mut u64,
    output_l2: &mut f64,
) -> anyhow::Result<()> {
    let want = batch.size();
    let ab = engine
        .pick_batch(cfg.model.name(), want)
        .ok_or_else(|| anyhow::anyhow!("no artifacts for {}", cfg.model.name()))?;
    let len_key = if cfg.model.kind() == ModelKind::Audio {
        buckets.repr_len(buckets.bucket_of(batch.max_len_s))
    } else {
        0.0
    };
    let entry = engine
        .manifest()
        .model(cfg.model.name(), ab, len_key)
        .ok_or_else(|| anyhow::anyhow!("no artifact {}/b{ab}/len{len_key}", cfg.model.name()))?
        .clone();
    let per_sample: usize = entry.inputs[0][1..].iter().product();
    let mut flat = vec![0f32; entry.inputs[0].iter().product()];
    for (j, r) in batch.requests.iter().enumerate() {
        if let Some(t) = tensors[r.id as usize].take() {
            flat[j * per_sample..j * per_sample + t.len()].copy_from_slice(&t);
        }
    }
    let t0 = clock.now();
    let outs = engine.execute_f32(&entry.key, &[flat])?;
    let t1 = clock.now();
    *executed_batches += 1;
    *output_l2 += outs[0].iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
    for r in &batch.requests {
        let i = r.id as usize;
        let parts = LatencyParts {
            preprocess: preproc_done_at[i].saturating_sub(arrivals[i]),
            batching: t0.saturating_sub(r.enqueued),
            dispatch_wait: 0,
            execution: t1.saturating_sub(t0),
        };
        stats.record(parts, t1, batch.size());
    }
    Ok(())
}
