//! Multi-tenant serving: several models colocated on one MIG GPU, each
//! owning a disjoint subset of vGPUs — the deployment §2.2 motivates
//! ("a single A100 can host seven inference servers") and the setting
//! where the preprocessing bottleneck COUPLES tenants: the host CPU pool
//! is shared, so one preprocessing-heavy tenant (CitriNet) starves the
//! others' preprocessing even though their vGPUs are isolated. PREBA's
//! DPU restores the isolation MIG promised.
//!
//! Two extensions beyond the paper's static deployment:
//! * **Demand-aware placement** ([`place_tenants`]): slice counts sized
//!   from offered rates (the fragmentation-aware packing question of
//!   `mig::placement`, on one GPU), instead of a naive even split.
//! * **Online slice reallocation** ([`MultiConfig::reconfig`]): a
//!   `mig::reconfig` controller watches per-tenant windowed rates and
//!   moves slices between tenants as demand shifts (anti-phase diurnal
//!   peaks, alternating bursts). Transferred slices drain first and pay a
//!   repartition outage before they serve the gaining tenant; untouched
//!   slices keep serving throughout, so a reallocation never stops the
//!   whole GPU.

use crate::batching::{BatchPolicy, Bucketizer, DynamicBatcher, Request};
use crate::clock::{secs, Nanos};
use crate::config::PrebaConfig;
use crate::dpu::Dpu;
use crate::metrics::{LatencyParts, RunStats};
use crate::mig::reconfig::ReconfigEvent;
use crate::mig::{MigConfig, Plan, ReconfigController, ReconfigPolicy, ServiceModel, TenantSpec};
use crate::models::{ModelId, ModelKind};
use crate::preprocess::CpuPool;
use crate::sim::EventQueue;
use crate::util::Rng;
use crate::workload::{QueryGen, RateProfile, TraceGen};

use super::{PolicyKind, PreprocMode};

/// One colocated model.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub model: ModelId,
    /// Number of vGPUs this tenant owns initially (disjoint from other
    /// tenants; the online controller may move slices later).
    pub vgpus: usize,
    /// Offered load, queries/s (the constant rate, or the base of
    /// `profile` when set).
    pub rate_qps: f64,
    /// End-to-end p95 SLA for violation accounting and the reconfig
    /// controller's planning, ms.
    pub sla_ms: f64,
    /// Non-stationary traffic; `None` = constant Poisson at `rate_qps`.
    pub profile: Option<RateProfile>,
}

impl Tenant {
    pub fn new(model: ModelId, vgpus: usize, rate_qps: f64) -> Tenant {
        Tenant { model, vgpus, rate_qps, sla_ms: 50.0, profile: None }
    }
}

/// A tenant's demand, before slices are assigned (input to
/// [`place_tenants`]).
#[derive(Debug, Clone)]
pub struct TenantDemand {
    pub model: ModelId,
    pub rate_qps: f64,
    pub sla_ms: f64,
}

/// Demand-aware placement on one partition: every tenant gets at least
/// one slice, then each remaining slice goes to the tenant with the
/// largest unmet demand (sized at `target_util`). This is
/// `mig::reconfig::alloc_for_rates` applied offline — the same allocator
/// the online controller uses, so a reconfig-enabled run starts from the
/// allocation a demand-aware operator would deploy.
pub fn place_tenants(
    demands: &[TenantDemand],
    mig: MigConfig,
    target_util: f64,
) -> anyhow::Result<Vec<Tenant>> {
    let specs: Vec<TenantSpec> =
        demands.iter().map(|d| TenantSpec::new(d.model, d.sla_ms)).collect();
    let rates: Vec<f64> = demands.iter().map(|d| d.rate_qps).collect();
    let alloc = crate::mig::reconfig::alloc_for_rates(&specs, &rates, mig, target_util)
        .ok_or_else(|| {
            anyhow::anyhow!("{} tenants need more slices than {} offers", demands.len(), mig.name())
        })?;
    Ok(demands
        .iter()
        .zip(alloc)
        .map(|(d, vgpus)| Tenant {
            model: d.model,
            vgpus,
            rate_qps: d.rate_qps,
            sla_ms: d.sla_ms,
            profile: None,
        })
        .collect())
}

/// Naive baseline placement: slices split as evenly as the partition
/// allows (largest remainder, earlier tenants first).
pub fn even_split(demands: &[TenantDemand], mig: MigConfig) -> anyhow::Result<Vec<Tenant>> {
    let n = mig.vgpus();
    let t = demands.len();
    anyhow::ensure!(t >= 1 && t <= n, "{t} tenants on {} slices", n);
    Ok(demands
        .iter()
        .enumerate()
        .map(|(i, d)| Tenant {
            model: d.model,
            vgpus: n / t + usize::from(i < n % t),
            rate_qps: d.rate_qps,
            sla_ms: d.sla_ms,
            profile: None,
        })
        .collect())
}

/// Multi-tenant run parameters.
#[derive(Debug, Clone)]
pub struct MultiConfig {
    pub mig: MigConfig,
    pub tenants: Vec<Tenant>,
    pub preproc: PreprocMode,
    pub policy: PolicyKind,
    /// Requests PER TENANT.
    pub requests: usize,
    pub seed: u64,
    pub warmup_frac: f64,
    /// Online slice reallocation between tenants; `None` = the initial
    /// assignment is fixed for the whole run.
    pub reconfig: Option<ReconfigPolicy>,
}

impl MultiConfig {
    /// Validate that tenant vGPU demands fit the partition.
    pub fn validate(&self) -> anyhow::Result<()> {
        let total: usize = self.tenants.iter().map(|t| t.vgpus).sum();
        anyhow::ensure!(
            total <= self.mig.vgpus(),
            "tenants want {total} vGPUs, partition has {}",
            self.mig.vgpus()
        );
        anyhow::ensure!(!self.tenants.is_empty(), "no tenants");
        anyhow::ensure!(
            self.tenants.iter().all(|t| t.vgpus >= 1),
            "every tenant needs at least one vGPU"
        );
        Ok(())
    }
}

/// Per-tenant outcome + shared-resource stats.
#[derive(Debug)]
pub struct MultiOutcome {
    pub per_tenant: Vec<(ModelId, RunStats)>,
    pub cpu_util: f64,
    pub dpu_util: Option<f64>,
    pub horizon: Nanos,
    /// Committed slice reallocations (0 without a controller).
    pub reconfigs: u64,
    /// Summed transfer outage (drain of moved slices + repartition)
    /// across reallocations.
    pub reconfig_downtime: Nanos,
    /// Reallocation timeline (empty without a controller).
    pub reconfig_events: Vec<ReconfigEvent>,
}

impl MultiOutcome {
    /// Stats for one tenant by index.
    pub fn tenant_stats(&self, i: usize) -> &RunStats {
        &self.per_tenant[i].1
    }

    /// Worst per-tenant p95, ms.
    pub fn worst_p95_ms(&self) -> f64 {
        self.per_tenant.iter().map(|(_, s)| s.p95_ms()).fold(0.0, f64::max)
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival { tenant: usize, idx: usize },
    PreprocDone { tenant: usize, idx: usize },
    BatchTick { tenant: usize },
    ExecDone { tenant: usize, batch_idx: usize },
    /// Close a telemetry window and ask the controller for a reallocation.
    ReconfigCheck,
}

struct TenantState {
    spec: &'static crate::models::ModelSpec,
    sm: ServiceModel,
    buckets: Bucketizer,
    batcher: DynamicBatcher,
    vgpu_free: Vec<Nanos>,
    arrivals: Vec<(Nanos, f64)>,
    preproc_done: Vec<Nanos>,
    in_flight: Vec<Option<crate::batching::Batch>>,
    stats: RunStats,
    completed: usize,
    warmup: usize,
    /// Earliest batching deadline with a BatchTick already scheduled —
    /// suppresses the redundant per-PreprocDone tick (same dedupe as
    /// `sim_driver`'s `armed_tick`).
    armed_tick: Option<Nanos>,
}

impl TenantState {
    /// Rebuild the batching policy for a changed vGPU count (the
    /// Time_queue = Time_knee/n rule depends on it) and carry pending
    /// requests over with their original enqueue times
    /// (`DynamicBatcher::rebuild` — shared with `sim_driver`'s
    /// geometry-reconfig path).
    fn rebuild_policy(&mut self, policy: PolicyKind, sys: &PrebaConfig, now: Nanos) {
        let new_policy = match policy {
            PolicyKind::Dynamic => BatchPolicy::dynamic_from_model(
                self.spec,
                &self.sm,
                &self.buckets,
                self.vgpu_free.len(),
            ),
            PolicyKind::Static => BatchPolicy::Static(crate::batching::QueueParams {
                batch_max: sys.batching.static_batch_max,
                time_queue: sys.batching.static_time_queue,
            }),
        };
        self.batcher.rebuild(new_policy, now);
    }
}

/// Run a multi-tenant simulation over shared preprocessing resources.
pub fn run(cfg: &MultiConfig, sys: &PrebaConfig) -> anyhow::Result<MultiOutcome> {
    cfg.validate()?;
    let mut root = Rng::new(cfg.seed ^ 0xFEED);
    let pool_rng = root.split(1);
    let mut exec_rng = root.split(2);

    let usable = sys.hardware.cpu_cores - sys.hardware.cpu_reserved_cores;
    let mut cpu_pool = CpuPool::new(usable, pool_rng);
    let mut dpu = match cfg.preproc {
        PreprocMode::Dpu => Some(Dpu::new(&sys.dpu, &sys.hardware)),
        _ => None,
    };

    let gpcs = cfg.mig.gpcs_per_vgpu();
    let mut tenants: Vec<TenantState> = Vec::new();
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (ti, t) in cfg.tenants.iter().enumerate() {
        let spec = t.model.spec();
        let sm = ServiceModel::new(spec, gpcs);
        let buckets = match (t.model.kind(), cfg.policy) {
            (ModelKind::Audio, PolicyKind::Dynamic) => {
                Bucketizer::new(sys.batching.bucket_window_s, sys.batching.max_audio_s)
            }
            _ => Bucketizer::fixed(),
        };
        let policy = match cfg.policy {
            PolicyKind::Dynamic => {
                BatchPolicy::dynamic_from_model(spec, &sm, &buckets, t.vgpus)
            }
            PolicyKind::Static => BatchPolicy::Static(crate::batching::QueueParams {
                batch_max: sys.batching.static_batch_max,
                time_queue: sys.batching.static_time_queue,
            }),
        };
        let batcher =
            DynamicBatcher::new(t.model, buckets.clone(), policy, sys.batching.merge_adjacent);
        let gen_rng = root.split(100 + ti as u64);
        let arrivals: Vec<(Nanos, f64)> = match &t.profile {
            None => QueryGen::new(t.model, t.rate_qps, gen_rng)
                .take(cfg.requests)
                .into_iter()
                .map(|a| (a.at, a.len_s))
                .collect(),
            Some(profile) => TraceGen::new(t.model, profile.clone(), gen_rng)
                .take(cfg.requests)
                .into_iter()
                .map(|a| (a.at, a.len_s))
                .collect(),
        };
        for (i, &(at, _)) in arrivals.iter().enumerate() {
            q.schedule(at, Ev::Arrival { tenant: ti, idx: i });
        }
        tenants.push(TenantState {
            spec,
            sm,
            buckets,
            batcher,
            vgpu_free: vec![0; t.vgpus],
            preproc_done: vec![0; arrivals.len()],
            arrivals,
            in_flight: Vec::new(),
            stats: RunStats::new(),
            completed: 0,
            warmup: (cfg.requests as f64 * cfg.warmup_frac) as usize,
            armed_tick: None,
        });
    }

    // Online reallocation controller (None = fixed assignment).
    let mut ctrl = cfg.reconfig.clone().map(|policy| {
        let specs: Vec<TenantSpec> = cfg
            .tenants
            .iter()
            .map(|t| TenantSpec::new(t.model, t.sla_ms))
            .collect();
        let initial =
            Plan { mig: cfg.mig, alloc: cfg.tenants.iter().map(|t| t.vgpus).collect() };
        ReconfigController::new(specs, initial, policy)
    });
    if let Some(c) = &ctrl {
        q.schedule(c.window(), Ev::ReconfigCheck);
    }

    let total_arrivals = cfg.requests * cfg.tenants.len();
    let mut arrivals_seen = 0usize;
    let mut mig_now = cfg.mig;
    let mut downtime: Nanos = 0;
    let mut horizon: Nanos = 0;
    crate::sim::run(&mut q, u64::MAX, |now, ev, q| {
        match ev {
            Ev::Arrival { tenant, idx } => {
                arrivals_seen += 1;
                if let Some(c) = ctrl.as_mut() {
                    c.observe_arrival(tenant);
                }
                let ts = &tenants[tenant];
                let len = ts.arrivals[idx].1;
                let model = ts.batcher.model();
                match cfg.preproc {
                    PreprocMode::Ideal => q.schedule(now, Ev::PreprocDone { tenant, idx }),
                    PreprocMode::Cpu => {
                        let service = tenants[tenant].spec.cpu_preproc_secs(len.max(0.1));
                        let (_, done) = cpu_pool.admit(now, service);
                        q.schedule(done, Ev::PreprocDone { tenant, idx });
                    }
                    PreprocMode::Dpu => {
                        let done = dpu.as_mut().unwrap().admit(now, model, len.max(0.1));
                        q.schedule(done, Ev::PreprocDone { tenant, idx });
                    }
                }
            }
            Ev::PreprocDone { tenant, idx } => {
                let ts = &mut tenants[tenant];
                ts.preproc_done[idx] = now;
                let (at, len) = ts.arrivals[idx];
                ts.batcher.enqueue(Request {
                    id: idx as u64,
                    model: ts.batcher.model(),
                    arrival: at,
                    enqueued: now,
                    len_s: len,
                });
                dispatch_ready(tenant, now, &mut tenants[tenant], q, &mut exec_rng);
                arm_tick(tenant, now, &mut tenants[tenant], q);
            }
            Ev::BatchTick { tenant } => {
                // Stale later ticks drain as no-ops (see sim_driver).
                tenants[tenant].armed_tick = None;
                dispatch_ready(tenant, now, &mut tenants[tenant], q, &mut exec_rng);
                arm_tick(tenant, now, &mut tenants[tenant], q);
            }
            Ev::ExecDone { tenant, batch_idx } => {
                horizon = horizon.max(now);
                let ts = &mut tenants[tenant];
                let batch = ts.in_flight[batch_idx].take().expect("double completion");
                let bsize = batch.size();
                let padded = padded_len(&ts.buckets, &batch);
                let exec_model = crate::clock::secs(ts.sm.exec_secs(bsize, padded));
                for r in &batch.requests {
                    ts.completed += 1;
                    if ts.completed <= ts.warmup {
                        continue;
                    }
                    let i = r.id as usize;
                    let since_formed = now.saturating_sub(batch.formed);
                    let exec_ns = exec_model.min(since_formed);
                    ts.stats.record(
                        LatencyParts {
                            preprocess: ts.preproc_done[i] - ts.arrivals[i].0,
                            batching: batch.formed.saturating_sub(ts.preproc_done[i]),
                            dispatch_wait: since_formed - exec_ns,
                            execution: exec_ns,
                        },
                        now,
                        bsize,
                    );
                }
            }
            Ev::ReconfigCheck => {
                let c = ctrl.as_mut().expect("ReconfigCheck without controller");
                let tail = arrivals_seen >= total_arrivals;
                if tail {
                    c.roll_only(now);
                } else {
                    if let Some(plan) = c.tick(now) {
                        let outage = apply_plan(
                            &mut tenants, &mut mig_now, &plan, cfg, sys, now, q,
                        );
                        downtime += outage;
                    }
                    q.schedule_in(c.window(), Ev::ReconfigCheck);
                }
            }
        }
        true
    });

    let (reconfigs, reconfig_events) = match &ctrl {
        Some(c) => (c.events().len() as u64, c.events().to_vec()),
        None => (0, Vec::new()),
    };

    Ok(MultiOutcome {
        per_tenant: tenants.into_iter().map(|t| (t.batcher.model(), t.stats)).collect(),
        cpu_util: match cfg.preproc {
            PreprocMode::Cpu => cpu_pool.utilization(horizon),
            _ => 0.0,
        },
        dpu_util: dpu.as_ref().map(|d| d.utilization(horizon)),
        horizon,
        reconfigs,
        reconfig_downtime: downtime,
        reconfig_events,
    })
}

/// Apply a committed plan. Same-geometry reallocations move only the
/// affected slices: donors give up their earliest-free slices, which
/// drain, pay the repartition outage, and then serve the gaining tenant —
/// every other slice keeps serving throughout. A geometry change drains
/// the whole GPU. Returns the transfer outage (decision → new slices
/// live).
fn apply_plan(
    tenants: &mut [TenantState],
    mig_now: &mut MigConfig,
    plan: &Plan,
    cfg: &MultiConfig,
    sys: &PrebaConfig,
    now: Nanos,
    q: &mut EventQueue<Ev>,
) -> Nanos {
    let repartition = secs(cfg.reconfig.as_ref().expect("reconfig policy").repartition_s);
    let geometry_change = plan.mig != *mig_now;
    // Allocation before any slices are drained away — the rebuild check
    // below must see the donor's ORIGINAL count (the drain loop already
    // shrinks it).
    let old_alloc: Vec<usize> = tenants.iter().map(|t| t.vgpu_free.len()).collect();
    let avail = if geometry_change {
        // Whole-GPU repartition: every instance drains first.
        let drain_end = tenants
            .iter()
            .flat_map(|t| t.vgpu_free.iter().copied())
            .max()
            .unwrap_or(now)
            .max(now);
        drain_end + repartition
    } else {
        // Only the transferred slices drain: donors give up their
        // earliest-free slices so capacity reaches the gainer soonest.
        let mut drain_end = now;
        for (ts, &target) in tenants.iter_mut().zip(plan.alloc.iter()) {
            if ts.vgpu_free.len() > target {
                ts.vgpu_free.sort_unstable();
                let surplus = ts.vgpu_free.len() - target;
                let donated: Vec<Nanos> = ts.vgpu_free.drain(..surplus).collect();
                for d in donated {
                    drain_end = drain_end.max(d);
                }
            }
        }
        drain_end + repartition
    };

    let gpcs = plan.mig.gpcs_per_vgpu();
    for (i, (ts, &target)) in tenants.iter_mut().zip(plan.alloc.iter()).enumerate() {
        if geometry_change {
            // New instances of the new profile come up together after the
            // global drain. (In-flight batches still complete and keep
            // their latency accounting; only the exec/dispatch split of
            // stragglers uses the new service model.)
            ts.sm = ServiceModel::new(ts.spec, gpcs);
            ts.vgpu_free = vec![avail; target];
        } else if ts.vgpu_free.len() < target {
            ts.vgpu_free.resize(target, avail);
        }
        // Donors AND gainers get a policy rebuild — Time_queue =
        // Time_knee/n must track the live count in both directions.
        if old_alloc[i] != target || geometry_change {
            ts.rebuild_policy(cfg.policy, sys, now);
            // Re-arm the deadline tick under the new policy; anything
            // already releasable goes out on the slices that kept running.
            arm_tick(i, now, ts, q);
        }
    }
    *mig_now = plan.mig;
    avail.saturating_sub(now)
}

fn padded_len(buckets: &Bucketizer, batch: &crate::batching::Batch) -> f64 {
    if batch.max_len_s <= 0.0 {
        return 0.0;
    }
    let edge = buckets.repr_len(buckets.bucket_of(batch.max_len_s));
    if edge > 0.0 {
        edge.max(batch.max_len_s)
    } else {
        batch.max_len_s
    }
}

/// Arm a BatchTick for the tenant's earliest deadline unless an earlier
/// (or equal) tick is already pending.
fn arm_tick(tenant: usize, now: Nanos, ts: &mut TenantState, q: &mut EventQueue<Ev>) {
    if let Some(d) = ts.batcher.next_deadline() {
        if ts.armed_tick.is_none_or(|t| d < t) {
            q.schedule(d, Ev::BatchTick { tenant });
            ts.armed_tick = Some(d.max(now));
        }
    }
}

fn dispatch_ready(
    tenant: usize,
    now: Nanos,
    ts: &mut TenantState,
    q: &mut EventQueue<Ev>,
    exec_rng: &mut Rng,
) {
    while let Some((batch, _)) = ts.batcher.try_form(now) {
        let (vgpu, &free) =
            ts.vgpu_free.iter().enumerate().min_by_key(|(_, &t)| t).expect("vgpus");
        let start = now.max(free);
        let padded = padded_len(&ts.buckets, &batch);
        let exec = crate::clock::secs(ts.sm.exec_secs_jittered(batch.size(), padded, exec_rng));
        let done = start + exec;
        ts.vgpu_free[vgpu] = done;
        let idx = ts.in_flight.len();
        ts.in_flight.push(Some(batch));
        q.schedule(done, Ev::ExecDone { tenant, batch_idx: idx });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenant_cfg(preproc: PreprocMode) -> MultiConfig {
        // MobileNet on 3 vGPUs + CitriNet on 4 vGPUs of a 1g.5gb(7x).
        let mob_rate = 3.0 * ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0) * 0.5;
        let cit_rate =
            4.0 * ServiceModel::new(ModelId::CitriNet.spec(), 1).plateau_qps(10.0) * 0.55;
        MultiConfig {
            mig: MigConfig::Small7,
            tenants: vec![
                Tenant::new(ModelId::MobileNet, 3, mob_rate),
                Tenant::new(ModelId::CitriNet, 4, cit_rate),
            ],
            preproc,
            policy: PolicyKind::Dynamic,
            requests: 3000,
            seed: 99,
            warmup_frac: 0.1,
            reconfig: None,
        }
    }

    /// Two identical vision tenants with anti-phase diurnal demand: total
    /// load is constant and fits the GPU, but each tenant's peak overruns
    /// a fixed fair-share split — the online-reallocation scenario.
    fn antiphase_cfg(online: bool) -> MultiConfig {
        let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0) * 0.9;
        let base = 2.6 * u;
        let mk = |phase_frac: f64| {
            let mut t = Tenant::new(ModelId::SwinTransformer, 0, base);
            t.sla_ms = 25.0;
            t.profile = Some(RateProfile::Diurnal {
                base_qps: base,
                amplitude: 0.577,
                period_s: 6.0,
                phase_frac,
            });
            t
        };
        let mut a = mk(0.0);
        let mut b = mk(0.5);
        // Fair static split for equal mean demand.
        a.vgpus = 4;
        b.vgpus = 3;
        MultiConfig {
            mig: MigConfig::Small7,
            tenants: vec![a, b],
            preproc: PreprocMode::Ideal,
            policy: PolicyKind::Dynamic,
            requests: 6000,
            seed: 7,
            warmup_frac: 0.05,
            reconfig: online.then(ReconfigPolicy::default),
        }
    }

    #[test]
    fn validates_vgpu_budget() {
        let mut cfg = two_tenant_cfg(PreprocMode::Ideal);
        cfg.tenants[0].vgpus = 5; // 5 + 4 > 7
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn all_tenants_complete_all_requests() {
        let cfg = two_tenant_cfg(PreprocMode::Ideal);
        let out = run(&cfg, &PrebaConfig::new()).unwrap();
        for (model, stats) in &out.per_tenant {
            let expect = cfg.requests as u64 - (cfg.requests as f64 * cfg.warmup_frac) as u64;
            assert_eq!(stats.completed, expect, "{model}");
        }
    }

    #[test]
    fn shared_cpu_pool_couples_tenants_dpu_isolates() {
        // The vision tenant's latency under CPU preprocessing suffers from
        // the audio tenant's huge preprocessing demand; the DPU removes
        // the coupling (MIG's isolation restored — the multi-tenant
        // version of the paper's headline).
        let sys = PrebaConfig::new();
        let cpu = run(&two_tenant_cfg(PreprocMode::Cpu), &sys).unwrap();
        let dpu = run(&two_tenant_cfg(PreprocMode::Dpu), &sys).unwrap();
        let p95 = |o: &MultiOutcome, m: ModelId| {
            o.per_tenant.iter().find(|(mm, _)| *mm == m).unwrap().1.p95_ms()
        };
        assert!(
            p95(&cpu, ModelId::MobileNet) > 3.0 * p95(&dpu, ModelId::MobileNet),
            "vision tenant not starved by shared CPU: cpu={} dpu={}",
            p95(&cpu, ModelId::MobileNet),
            p95(&dpu, ModelId::MobileNet)
        );
        assert!(cpu.cpu_util > 0.85, "cpu pool should saturate: {}", cpu.cpu_util);
    }

    #[test]
    fn deterministic() {
        let cfg = two_tenant_cfg(PreprocMode::Dpu);
        let sys = PrebaConfig::new();
        let a = run(&cfg, &sys).unwrap();
        let b = run(&cfg, &sys).unwrap();
        assert_eq!(a.horizon, b.horizon);
        for ((_, s1), (_, s2)) in a.per_tenant.iter().zip(b.per_tenant.iter()) {
            assert_eq!(s1.p95_ms(), s2.p95_ms());
        }
    }

    #[test]
    fn online_reallocation_beats_static_split_on_antiphase_diurnal() {
        // Each tenant's peak needs ~4.1 slices against a fixed 4/3 split;
        // capacity following demand keeps both tails bounded while the
        // static split starves whichever tenant is peaking.
        let sys = PrebaConfig::new();
        let stat = run(&antiphase_cfg(false), &sys).unwrap();
        let online = run(&antiphase_cfg(true), &sys).unwrap();
        assert!(online.reconfigs >= 2, "expected several reallocations: {}", online.reconfigs);
        assert!(
            online.worst_p95_ms() < 0.5 * stat.worst_p95_ms(),
            "online {} vs static {}",
            online.worst_p95_ms(),
            stat.worst_p95_ms()
        );
        let viol = |o: &MultiOutcome| {
            o.per_tenant.iter().map(|(_, s)| s.sla_violation_frac(25.0)).fold(0.0, f64::max)
        };
        assert!(
            viol(&online) < viol(&stat),
            "online {} vs static {}",
            viol(&online),
            viol(&stat)
        );
        // Conservation through reallocations.
        for (model, stats) in &online.per_tenant {
            let cfg = antiphase_cfg(true);
            let expect = cfg.requests as u64 - (cfg.requests as f64 * cfg.warmup_frac) as u64;
            assert_eq!(stats.completed, expect, "{model}");
        }
    }

    #[test]
    fn online_reallocation_stays_put_on_constant_equal_load() {
        let sys = PrebaConfig::new();
        let mut cfg = two_tenant_cfg(PreprocMode::Ideal);
        cfg.reconfig = Some(ReconfigPolicy::default());
        let out = run(&cfg, &sys).unwrap();
        // Both tenants run comfortably inside their shares; hysteresis
        // keeps the allocator from churning (a stray correction at the
        // first window is tolerated, thrash is not).
        assert!(out.reconfigs <= 1, "{:?}", out.reconfig_events);
    }

    #[test]
    fn demand_aware_placement_tracks_rates() {
        let u = ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0);
        let demands = vec![
            TenantDemand { model: ModelId::MobileNet, rate_qps: 3.4 * u, sla_ms: 25.0 },
            TenantDemand { model: ModelId::MobileNet, rate_qps: 1.1 * u, sla_ms: 25.0 },
            TenantDemand { model: ModelId::MobileNet, rate_qps: 0.5 * u, sla_ms: 25.0 },
        ];
        let placed = place_tenants(&demands, MigConfig::Small7, 0.85).unwrap();
        let alloc: Vec<usize> = placed.iter().map(|t| t.vgpus).collect();
        assert_eq!(alloc.iter().sum::<usize>(), 7);
        assert_eq!(alloc, vec![4, 2, 1], "hot tenant gets the slices");
        let even = even_split(&demands, MigConfig::Small7).unwrap();
        let even_alloc: Vec<usize> = even.iter().map(|t| t.vgpus).collect();
        assert_eq!(even_alloc, vec![3, 2, 2]);
    }
}
