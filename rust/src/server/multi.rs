//! Multi-tenant serving: several models colocated on one MIG GPU, each
//! owning a disjoint subset of vGPUs — the deployment §2.2 motivates
//! ("a single A100 can host seven inference servers") and the setting
//! where the preprocessing bottleneck COUPLES tenants: the host CPU pool
//! is shared, so one preprocessing-heavy tenant (CitriNet) starves the
//! others' preprocessing even though their vGPUs are isolated. PREBA's
//! DPU restores the isolation MIG promised.

use crate::batching::{BatchPolicy, Bucketizer, DynamicBatcher, Request};
use crate::clock::Nanos;
use crate::config::PrebaConfig;
use crate::dpu::Dpu;
use crate::metrics::{LatencyParts, RunStats};
use crate::mig::{MigConfig, ServiceModel};
use crate::models::{ModelId, ModelKind};
use crate::preprocess::CpuPool;
use crate::sim::EventQueue;
use crate::util::Rng;
use crate::workload::QueryGen;

use super::{PolicyKind, PreprocMode};

/// One colocated model.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub model: ModelId,
    /// Number of vGPUs this tenant owns (disjoint from other tenants).
    pub vgpus: usize,
    /// Offered Poisson load, queries/s.
    pub rate_qps: f64,
}

/// Multi-tenant run parameters.
#[derive(Debug, Clone)]
pub struct MultiConfig {
    pub mig: MigConfig,
    pub tenants: Vec<Tenant>,
    pub preproc: PreprocMode,
    pub policy: PolicyKind,
    /// Requests PER TENANT.
    pub requests: usize,
    pub seed: u64,
    pub warmup_frac: f64,
}

impl MultiConfig {
    /// Validate that tenant vGPU demands fit the partition.
    pub fn validate(&self) -> anyhow::Result<()> {
        let total: usize = self.tenants.iter().map(|t| t.vgpus).sum();
        anyhow::ensure!(
            total <= self.mig.vgpus(),
            "tenants want {total} vGPUs, partition has {}",
            self.mig.vgpus()
        );
        anyhow::ensure!(!self.tenants.is_empty(), "no tenants");
        Ok(())
    }
}

/// Per-tenant outcome + shared-resource stats.
#[derive(Debug)]
pub struct MultiOutcome {
    pub per_tenant: Vec<(ModelId, RunStats)>,
    pub cpu_util: f64,
    pub dpu_util: Option<f64>,
    pub horizon: Nanos,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival { tenant: usize, idx: usize },
    PreprocDone { tenant: usize, idx: usize },
    BatchTick { tenant: usize },
    ExecDone { tenant: usize, batch_idx: usize },
}

struct TenantState {
    spec: &'static crate::models::ModelSpec,
    sm: ServiceModel,
    buckets: Bucketizer,
    batcher: DynamicBatcher,
    vgpu_free: Vec<Nanos>,
    arrivals: Vec<(Nanos, f64)>,
    preproc_done: Vec<Nanos>,
    in_flight: Vec<Option<crate::batching::Batch>>,
    stats: RunStats,
    completed: usize,
    warmup: usize,
}

/// Run a multi-tenant simulation over shared preprocessing resources.
pub fn run(cfg: &MultiConfig, sys: &PrebaConfig) -> anyhow::Result<MultiOutcome> {
    cfg.validate()?;
    let mut root = Rng::new(cfg.seed ^ 0xFEED);
    let pool_rng = root.split(1);
    let mut exec_rng = root.split(2);

    let usable = sys.hardware.cpu_cores - sys.hardware.cpu_reserved_cores;
    let mut cpu_pool = CpuPool::new(usable, pool_rng);
    let mut dpu = match cfg.preproc {
        PreprocMode::Dpu => Some(Dpu::new(&sys.dpu, &sys.hardware)),
        _ => None,
    };

    let gpcs = cfg.mig.gpcs_per_vgpu();
    let mut tenants: Vec<TenantState> = Vec::new();
    let mut q: EventQueue<Ev> = EventQueue::new();
    for (ti, t) in cfg.tenants.iter().enumerate() {
        let spec = t.model.spec();
        let sm = ServiceModel::new(spec, gpcs);
        let buckets = match (t.model.kind(), cfg.policy) {
            (ModelKind::Audio, PolicyKind::Dynamic) => {
                Bucketizer::new(sys.batching.bucket_window_s, sys.batching.max_audio_s)
            }
            _ => Bucketizer::fixed(),
        };
        let policy = match cfg.policy {
            PolicyKind::Dynamic => {
                BatchPolicy::dynamic_from_model(spec, &sm, &buckets, t.vgpus)
            }
            PolicyKind::Static => BatchPolicy::Static(crate::batching::QueueParams {
                batch_max: sys.batching.static_batch_max,
                time_queue: sys.batching.static_time_queue,
            }),
        };
        let batcher =
            DynamicBatcher::new(t.model, buckets.clone(), policy, sys.batching.merge_adjacent);
        let mut qgen = QueryGen::new(t.model, t.rate_qps, root.split(100 + ti as u64));
        let arrivals: Vec<(Nanos, f64)> =
            qgen.take(cfg.requests).into_iter().map(|a| (a.at, a.len_s)).collect();
        for (i, &(at, _)) in arrivals.iter().enumerate() {
            q.schedule(at, Ev::Arrival { tenant: ti, idx: i });
        }
        tenants.push(TenantState {
            spec,
            sm,
            buckets,
            batcher,
            vgpu_free: vec![0; t.vgpus],
            preproc_done: vec![0; arrivals.len()],
            arrivals,
            in_flight: Vec::new(),
            stats: RunStats::new(),
            completed: 0,
            warmup: (cfg.requests as f64 * cfg.warmup_frac) as usize,
        });
    }

    let mut horizon: Nanos = 0;
    crate::sim::run(&mut q, u64::MAX, |now, ev, q| {
        match ev {
            Ev::Arrival { tenant, idx } => {
                let ts = &tenants[tenant];
                let len = ts.arrivals[idx].1;
                let model = ts.batcher.model();
                match cfg.preproc {
                    PreprocMode::Ideal => q.schedule(now, Ev::PreprocDone { tenant, idx }),
                    PreprocMode::Cpu => {
                        let service = tenants[tenant].spec.cpu_preproc_secs(len.max(0.1));
                        let (_, done) = cpu_pool.admit(now, service);
                        q.schedule(done, Ev::PreprocDone { tenant, idx });
                    }
                    PreprocMode::Dpu => {
                        let done = dpu.as_mut().unwrap().admit(now, model, len.max(0.1));
                        q.schedule(done, Ev::PreprocDone { tenant, idx });
                    }
                }
            }
            Ev::PreprocDone { tenant, idx } => {
                let ts = &mut tenants[tenant];
                ts.preproc_done[idx] = now;
                let (at, len) = ts.arrivals[idx];
                ts.batcher.enqueue(Request {
                    id: idx as u64,
                    model: ts.batcher.model(),
                    arrival: at,
                    enqueued: now,
                    len_s: len,
                });
                dispatch_ready(tenant, now, &mut tenants[tenant], q, &mut exec_rng);
                if let Some(d) = tenants[tenant].batcher.next_deadline() {
                    q.schedule(d, Ev::BatchTick { tenant });
                }
            }
            Ev::BatchTick { tenant } => {
                dispatch_ready(tenant, now, &mut tenants[tenant], q, &mut exec_rng);
                if let Some(d) = tenants[tenant].batcher.next_deadline() {
                    q.schedule(d, Ev::BatchTick { tenant });
                }
            }
            Ev::ExecDone { tenant, batch_idx } => {
                horizon = horizon.max(now);
                let ts = &mut tenants[tenant];
                let batch = ts.in_flight[batch_idx].take().expect("double completion");
                let bsize = batch.size();
                let padded = padded_len(&ts.buckets, &batch);
                let exec_model = crate::clock::secs(ts.sm.exec_secs(bsize, padded));
                for r in &batch.requests {
                    ts.completed += 1;
                    if ts.completed <= ts.warmup {
                        continue;
                    }
                    let i = r.id as usize;
                    let since_formed = now.saturating_sub(batch.formed);
                    let exec_ns = exec_model.min(since_formed);
                    ts.stats.record(
                        LatencyParts {
                            preprocess: ts.preproc_done[i] - ts.arrivals[i].0,
                            batching: batch.formed.saturating_sub(ts.preproc_done[i]),
                            dispatch_wait: since_formed - exec_ns,
                            execution: exec_ns,
                        },
                        now,
                        bsize,
                    );
                }
            }
        }
        true
    });

    Ok(MultiOutcome {
        per_tenant: tenants.into_iter().map(|t| (t.batcher.model(), t.stats)).collect(),
        cpu_util: match cfg.preproc {
            PreprocMode::Cpu => cpu_pool.utilization(horizon),
            _ => 0.0,
        },
        dpu_util: dpu.as_ref().map(|d| d.utilization(horizon)),
        horizon,
    })
}

fn padded_len(buckets: &Bucketizer, batch: &crate::batching::Batch) -> f64 {
    if batch.max_len_s <= 0.0 {
        return 0.0;
    }
    let edge = buckets.repr_len(buckets.bucket_of(batch.max_len_s));
    if edge > 0.0 {
        edge.max(batch.max_len_s)
    } else {
        batch.max_len_s
    }
}

fn dispatch_ready(
    tenant: usize,
    now: Nanos,
    ts: &mut TenantState,
    q: &mut EventQueue<Ev>,
    exec_rng: &mut Rng,
) {
    while let Some((batch, _)) = ts.batcher.try_form(now) {
        let (vgpu, &free) =
            ts.vgpu_free.iter().enumerate().min_by_key(|(_, &t)| t).expect("vgpus");
        let start = now.max(free);
        let padded = padded_len(&ts.buckets, &batch);
        let exec = crate::clock::secs(ts.sm.exec_secs_jittered(batch.size(), padded, exec_rng));
        let done = start + exec;
        ts.vgpu_free[vgpu] = done;
        let idx = ts.in_flight.len();
        ts.in_flight.push(Some(batch));
        q.schedule(done, Ev::ExecDone { tenant, batch_idx: idx });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenant_cfg(preproc: PreprocMode) -> MultiConfig {
        // MobileNet on 3 vGPUs + CitriNet on 4 vGPUs of a 1g.5gb(7x).
        let mob_rate = 3.0 * ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0) * 0.5;
        let cit_rate = 4.0 * ServiceModel::new(ModelId::CitriNet.spec(), 1).plateau_qps(10.0) * 0.55;
        MultiConfig {
            mig: MigConfig::Small7,
            tenants: vec![
                Tenant { model: ModelId::MobileNet, vgpus: 3, rate_qps: mob_rate },
                Tenant { model: ModelId::CitriNet, vgpus: 4, rate_qps: cit_rate },
            ],
            preproc,
            policy: PolicyKind::Dynamic,
            requests: 3000,
            seed: 99,
            warmup_frac: 0.1,
        }
    }

    #[test]
    fn validates_vgpu_budget() {
        let mut cfg = two_tenant_cfg(PreprocMode::Ideal);
        cfg.tenants[0].vgpus = 5; // 5 + 4 > 7
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn all_tenants_complete_all_requests() {
        let cfg = two_tenant_cfg(PreprocMode::Ideal);
        let out = run(&cfg, &PrebaConfig::new()).unwrap();
        for (model, stats) in &out.per_tenant {
            let expect = cfg.requests as u64 - (cfg.requests as f64 * cfg.warmup_frac) as u64;
            assert_eq!(stats.completed, expect, "{model}");
        }
    }

    #[test]
    fn shared_cpu_pool_couples_tenants_dpu_isolates() {
        // The vision tenant's latency under CPU preprocessing suffers from
        // the audio tenant's huge preprocessing demand; the DPU removes
        // the coupling (MIG's isolation restored — the multi-tenant
        // version of the paper's headline).
        let sys = PrebaConfig::new();
        let cpu = run(&two_tenant_cfg(PreprocMode::Cpu), &sys).unwrap();
        let dpu = run(&two_tenant_cfg(PreprocMode::Dpu), &sys).unwrap();
        let p95 = |o: &MultiOutcome, m: ModelId| {
            o.per_tenant.iter().find(|(mm, _)| *mm == m).unwrap().1.p95_ms()
        };
        assert!(
            p95(&cpu, ModelId::MobileNet) > 3.0 * p95(&dpu, ModelId::MobileNet),
            "vision tenant not starved by shared CPU: cpu={} dpu={}",
            p95(&cpu, ModelId::MobileNet),
            p95(&dpu, ModelId::MobileNet)
        );
        assert!(cpu.cpu_util > 0.85, "cpu pool should saturate: {}", cpu.cpu_util);
    }

    #[test]
    fn deterministic() {
        let cfg = two_tenant_cfg(PreprocMode::Dpu);
        let sys = PrebaConfig::new();
        let a = run(&cfg, &sys).unwrap();
        let b = run(&cfg, &sys).unwrap();
        assert_eq!(a.horizon, b.horizon);
        for ((_, s1), (_, s2)) in a.per_tenant.iter().zip(b.per_tenant.iter()) {
            assert_eq!(s1.p95_ms(), s2.p95_ms());
        }
    }
}
