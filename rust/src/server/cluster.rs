//! Cluster-scale serving: one discrete-event simulation spanning a fleet
//! of MIG GPUs.
//!
//! `server::multi` colocates tenants on ONE partitioned GPU; real AIaaS
//! fleets pack tenants over MANY GPUs, and that packing quality — not
//! per-GPU scheduling — is where stranded capacity and tail latency are
//! won or lost (ParvaGPU, arXiv:2409.14447; fragmentation-aware MIG
//! scheduling, arXiv:2512.16099). This module closes the loop between
//! `mig::placement` (which packs a slice-ask inventory analytically) and
//! the DES: tenants are placed onto N A100s by first-fit or
//! best-fit-decreasing, requests are routed to a tenant's per-GPU serving
//! groups (join-shortest-queue or round-robin), each GPU hosts its own
//! preprocessing resources, and one event heap drives everything.
//!
//! Online rebalancing (`ClusterConfig::reconfig`) runs the cross-GPU
//! controller (`mig::reconfig::ClusterReconfigController`): slices move
//! between tenants with a drain → outage → restart cycle per move, where
//! an in-place reassignment (both tenants already serve from that GPU)
//! pays `repartition_s` and a migration (new residency: model weights
//! shipped to a GPU the tenant was not on) pays `migration_s` ≫ that.
//! The plan itself comes from the pluggable solver stack selected by
//! [`ReconfigPolicy::planner`] (greedy fast path, greedy-seeded
//! annealing, or exact branch-and-bound — see `mig::reconfig::planners`);
//! every committed allocation additionally replays through
//! `mig::reconfig::validate_plan` under `debug_assertions`.
//!
//! The inventory may be **heterogeneous** (`ClusterConfig::fleet` mixes
//! [`GpuClass`] entries, e.g. A100 7-GPC + A30-style 4-GPC): packing and
//! rebalancing score every GPU against its own class capacity, and a
//! profile too big for a class is rejected per-GPU, never fleet-wide.
//! **Admission control** (`ClusterConfig::admission`) parks requests of
//! capacity-less tenants in a pending queue and re-offers the packer's
//! rejected asks to the controller each window, so drain/outage events
//! and diurnal troughs convert dropped traffic into deferred-then-served
//! traffic (accounted in [`RunStats`]). Tenants can replay **recorded
//! arrival traces** ([`ClusterTenant::with_trace`]) instead of synthetic
//! Poisson/diurnal profiles.

use crate::batching::{Batch, BatchPolicy, Bucketizer, DynamicBatcher, QueueParams, Request};
use crate::clock::{secs, to_secs, Nanos};
use crate::config::PrebaConfig;
use crate::dpu::Dpu;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::fault::{mttr_s, FaultKind, FaultRecord, FaultSchedule, FaultSpec, RecoveryPolicy};
use crate::metrics::{LatencyParts, RunStats};
use crate::mig::placement::{pack_fleet, Packing, SliceAsk};
use crate::mig::reconfig::{ClusterReconfigEvent, ConsolidationEvent, SliceMove};
use crate::mig::{
    ClusterReconfigController, ConsolidationAction, GpuClass, PackStrategy, ReconfigPolicy,
    ServiceModel, Slice, TenantSpec,
};
use crate::models::{ModelId, ModelKind, ModelSpec};
use crate::obs::{BatchSeg, ObsLog, ObsSpec, Served};
use crate::preprocess::CpuPool;
use crate::sim::EventQueue;
use crate::util::Rng;
use crate::workload::{
    Arrival, ArrivalStream, Bounded, QueryGen, RateProfile, ReplayTrace, StreamSpec, TraceGen,
};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::{PolicyKind, PreprocMode};

/// How arrivals are routed to a tenant's per-GPU serving groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Cycle through the tenant's groups in GPU order.
    RoundRobin,
    /// Join-shortest-queue: the group with the fewest outstanding
    /// requests per slice (ties to the lowest group index).
    ShortestQueue,
}

impl Routing {
    pub fn label(&self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::ShortestQueue => "join-shortest-queue",
        }
    }

    pub fn parse(s: &str) -> Option<Routing> {
        match s {
            "rr" | "round-robin" => Some(Routing::RoundRobin),
            "jsq" | "shortest-queue" => Some(Routing::ShortestQueue),
            _ => None,
        }
    }
}

/// One tenant of the cluster: a model served from `slices` instances of
/// one MIG profile, wherever the packer places them.
#[derive(Debug, Clone)]
pub struct ClusterTenant {
    pub model: ModelId,
    /// Instance profile every replica of this tenant uses.
    pub slice: Slice,
    /// Requested replica count (the packer may admit fewer).
    pub slices: usize,
    /// Offered load, queries/s (mean of `profile` when set).
    pub rate_qps: f64,
    /// End-to-end p95 SLA, ms (violation accounting + the controller).
    pub sla_ms: f64,
    /// Non-stationary traffic; `None` = constant Poisson at `rate_qps`.
    pub profile: Option<RateProfile>,
    /// Recorded-trace replay: when set, this tenant's arrivals are the
    /// trace's timestamps verbatim (`profile` is ignored and `requests`
    /// is the trace length).
    pub trace: Option<ReplayTrace>,
    /// Lazily-pulled arrival source ([`StreamSpec`]): the DES pulls
    /// arrivals through the [`ArrivalStream`] seam without materializing
    /// the trace. Takes precedence over `trace` and `profile`.
    pub stream: Option<StreamSpec>,
    /// Arrivals to generate for this tenant.
    pub requests: usize,
}

impl ClusterTenant {
    pub fn new(model: ModelId, slice: Slice, slices: usize, rate_qps: f64) -> ClusterTenant {
        ClusterTenant {
            model,
            slice,
            slices,
            rate_qps,
            sla_ms: 50.0,
            profile: None,
            trace: None,
            stream: None,
            requests: 4000,
        }
    }

    /// Drive this tenant from a recorded trace: arrivals come from the
    /// trace's timestamps, `requests` becomes the trace length, and
    /// `rate_qps` its mean rate (so sizing heuristics and reports stay
    /// truthful).
    pub fn with_trace(mut self, trace: ReplayTrace) -> ClusterTenant {
        self.requests = trace.len();
        self.rate_qps = trace.mean_qps();
        self.profile = None;
        self.stream = None;
        self.trace = Some(trace);
        self
    }

    /// Drive this tenant from a lazily-pulled arrival stream. The spec
    /// is probed once (a streaming counting pass, nothing materialized)
    /// so `requests` and `rate_qps` reflect the stream exactly; the DES
    /// then pulls arrivals through the [`ArrivalStream`] seam with a
    /// bounded memory footprint however long the trace is. Fails when a
    /// file-backed source cannot be read or fails validation.
    pub fn with_stream(mut self, spec: StreamSpec) -> anyhow::Result<ClusterTenant> {
        let probe = spec.probe()?;
        self.requests = probe.requests;
        if probe.mean_qps > 0.0 {
            self.rate_qps = probe.mean_qps;
        }
        self.profile = None;
        self.trace = None;
        self.stream = Some(spec);
        Ok(self)
    }

    /// Replica count sized by the reconfig controller's own rule
    /// ([`crate::mig::reconfig::slices_for_rate`]), so a sized deployment
    /// starts exactly where the controller would put it (no rebalance at
    /// the first telemetry window).
    pub fn sized_for(
        model: ModelId,
        slice: Slice,
        rate_qps: f64,
        target_util: f64,
    ) -> ClusterTenant {
        let spec = TenantSpec::new(model, 50.0);
        let n = crate::mig::reconfig::slices_for_rate(&spec, slice, rate_qps, target_util);
        ClusterTenant::new(model, slice, n, rate_qps)
    }
}

/// Cluster run parameters.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// The GPU inventory, one class per GPU — homogeneous A100 pools and
    /// mixed A100+A30 fleets alike. Every placement/rebalance decision
    /// scores against `fleet[g]`'s own GPC/memory capacity.
    pub fleet: Vec<GpuClass>,
    /// How tenant slice asks are packed onto the inventory.
    pub strategy: PackStrategy,
    pub routing: Routing,
    pub tenants: Vec<ClusterTenant>,
    /// Preprocessing resources are PER GPU (each GPU lives in its own
    /// host): a request routed to GPU `g` pays `g`'s CPU pool or DPU.
    pub preproc: PreprocMode,
    pub policy: PolicyKind,
    pub seed: u64,
    pub warmup_frac: f64,
    /// Online cross-GPU rebalancing; `None` = the packing is fixed.
    pub reconfig: Option<ReconfigPolicy>,
    /// Admission control: requests for a tenant with no live capacity
    /// wait in a pending queue (dropped-vs-deferred accounting in
    /// [`RunStats`]) and the packer's rejected asks are re-offered to the
    /// reconfig controller every window, instead of that traffic being
    /// dropped forever. Requires `reconfig` — deferral without re-packing
    /// would never flush the queue.
    pub admission: bool,
    /// Energy-aware consolidation
    /// ([`crate::mig::ReconfigPolicy::consolidate`]): under sustained
    /// low load the controller drains the lightest GPU and powers it
    /// down (its idle + uncore energy is elided until demand wakes it).
    /// Requires `reconfig`; setting this forces `consolidate` on in the
    /// policy the run uses.
    pub consolidate: bool,
    /// Fault injection ([`crate::fault`]): what breaks during the run
    /// and whether the fleet fights back. `None` = fair weather.
    /// Recovery requires `reconfig` — failover re-packs displaced
    /// tenants through the controller's admission seam.
    pub faults: Option<FaultSpec>,
    /// Event-heap sharding. `None` (default) = one shard per connected
    /// component of the tenant↔GPU residency graph; `Some(1)` = a single
    /// global heap; `Some(k)` = merge components round-robin into at
    /// most `k` shards. Outcomes are byte-identical across every
    /// setting and every `util::par` worker count. Controller-coupled
    /// runs (reconfig/admission/consolidation/faults) always collapse to
    /// one heap — see [`run`].
    pub shards: Option<usize>,
    /// Observability capture (off by default). Disabled: every hook
    /// early-returns and outcomes are byte-identical to a build without
    /// the field. Enabled: [`ClusterOutcome::obs`] carries the merged
    /// [`ObsLog`], deterministic across `shards` and worker counts
    /// (recording keys are global ids, merged in shard order).
    pub obs: ObsSpec,
}

impl ClusterConfig {
    /// Fluent constructor. Defaults: best-fit-decreasing packing,
    /// join-shortest-queue routing, ideal preprocessing, the dynamic
    /// batching policy, seed `0xC105`, 5% warmup, no controller
    /// features, auto sharding.
    ///
    /// ```
    /// use preba::mig::{GpuClass, PackStrategy, Slice};
    /// use preba::models::ModelId;
    /// use preba::server::cluster::{ClusterConfig, ClusterTenant};
    ///
    /// let t = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 2, 40.0);
    /// let cfg = ClusterConfig::builder()
    ///     .fleet(vec![GpuClass::A100, GpuClass::A30])
    ///     .strategy(PackStrategy::BestFit)
    ///     .tenants(vec![t])
    ///     .build();
    /// assert_eq!(cfg.n_gpus(), 2);
    /// assert!(cfg.validate().is_ok());
    /// ```
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder {
            cfg: ClusterConfig {
                fleet: Vec::new(),
                strategy: PackStrategy::BestFit,
                routing: Routing::ShortestQueue,
                tenants: Vec::new(),
                preproc: PreprocMode::Ideal,
                policy: PolicyKind::Dynamic,
                seed: 0xC105,
                warmup_frac: 0.05,
                reconfig: None,
                admission: false,
                consolidate: false,
                faults: None,
                shards: None,
                obs: ObsSpec::default(),
            },
        }
    }

    /// Homogeneous pool: `n_gpus` A100s.
    #[deprecated(note = "use ClusterConfig::builder().gpus(n).strategy(s).tenants(t).build()")]
    pub fn new(n_gpus: usize, strategy: PackStrategy, tenants: Vec<ClusterTenant>) -> Self {
        ClusterConfig::builder().gpus(n_gpus).strategy(strategy).tenants(tenants).build()
    }

    /// Heterogeneous inventory: one [`GpuClass`] per GPU.
    #[deprecated(note = "use ClusterConfig::builder().fleet(f).strategy(s).tenants(t).build()")]
    pub fn with_fleet(
        fleet: Vec<GpuClass>,
        strategy: PackStrategy,
        tenants: Vec<ClusterTenant>,
    ) -> Self {
        ClusterConfig::builder().fleet(fleet).strategy(strategy).tenants(tenants).build()
    }

    /// GPUs in the inventory.
    pub fn n_gpus(&self) -> usize {
        self.fleet.len()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.fleet.is_empty(), "cluster needs at least one GPU");
        anyhow::ensure!(!self.tenants.is_empty(), "no tenants");
        anyhow::ensure!(
            !self.admission || self.reconfig.is_some(),
            "admission control needs the reconfig controller (deferred \
             requests are only re-admitted when re-packing frees capacity)"
        );
        anyhow::ensure!(
            !self.consolidate || self.reconfig.is_some(),
            "consolidation needs the reconfig controller (power decisions \
             ride the telemetry windows)"
        );
        if let Some(f) = &self.faults {
            f.validate(self.fleet.len())?;
            anyhow::ensure!(
                f.recovery.is_none() || self.reconfig.is_some(),
                "fault recovery needs the reconfig controller (failover \
                 re-packs displaced tenants through its admission seam)"
            );
        }
        for g in &self.fleet {
            anyhow::ensure!(g.gpcs >= 1 && g.mem_gb >= 1, "degenerate GPU class {g}");
        }
        if let Some(k) = self.shards {
            anyhow::ensure!(k >= 1, "shards = 0 is meaningless; use None for auto");
        }
        for t in &self.tenants {
            let name = t.slice.name();
            anyhow::ensure!(t.slice.is_legal(), "{}: illegal profile {name}", t.model);
            anyhow::ensure!(t.slices >= 1, "{}: zero slices requested", t.model);
            anyhow::ensure!(t.requests >= 1, "{}: zero requests", t.model);
            anyhow::ensure!(t.rate_qps > 0.0, "{}: non-positive rate", t.model);
            if let Some(trace) = &t.trace {
                anyhow::ensure!(
                    t.requests == trace.len(),
                    "{}: requests ({}) out of sync with its trace ({}) — use with_trace",
                    t.model,
                    t.requests,
                    trace.len()
                );
            }
        }
        Ok(())
    }

    /// The slice-ask list this cluster presents to the packer, in tenant
    /// order (the "arrival order" first-fit is sensitive to).
    pub fn asks(&self) -> Vec<SliceAsk> {
        let mut out = Vec::new();
        for (i, t) in self.tenants.iter().enumerate() {
            for _ in 0..t.slices {
                out.push(SliceAsk { tenant: i, slice: t.slice });
            }
        }
        out
    }
}

/// Fluent [`ClusterConfig`] constructor ([`ClusterConfig::builder`]).
/// Every knob has a sensible default, so a minimal cluster is
/// `ClusterConfig::builder().gpus(2).tenants(ts).build()`.
#[derive(Debug, Clone)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    /// Homogeneous inventory: `n` A100s (shorthand for [`Self::fleet`]).
    pub fn gpus(mut self, n: usize) -> Self {
        self.cfg.fleet = vec![GpuClass::A100; n];
        self
    }

    /// Heterogeneous inventory: one [`GpuClass`] per GPU.
    pub fn fleet(mut self, fleet: Vec<GpuClass>) -> Self {
        self.cfg.fleet = fleet;
        self
    }

    pub fn strategy(mut self, strategy: PackStrategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    pub fn routing(mut self, routing: Routing) -> Self {
        self.cfg.routing = routing;
        self
    }

    /// Replace the tenant list.
    pub fn tenants(mut self, tenants: Vec<ClusterTenant>) -> Self {
        self.cfg.tenants = tenants;
        self
    }

    /// Append one tenant.
    pub fn tenant(mut self, tenant: ClusterTenant) -> Self {
        self.cfg.tenants.push(tenant);
        self
    }

    pub fn preproc(mut self, preproc: PreprocMode) -> Self {
        self.cfg.preproc = preproc;
        self
    }

    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn warmup_frac(mut self, warmup_frac: f64) -> Self {
        self.cfg.warmup_frac = warmup_frac;
        self
    }

    /// Enable online cross-GPU rebalancing under `policy`.
    pub fn reconfig(mut self, policy: ReconfigPolicy) -> Self {
        self.cfg.reconfig = Some(policy);
        self
    }

    pub fn admission(mut self, admission: bool) -> Self {
        self.cfg.admission = admission;
        self
    }

    pub fn consolidate(mut self, consolidate: bool) -> Self {
        self.cfg.consolidate = consolidate;
        self
    }

    pub fn faults(mut self, faults: FaultSpec) -> Self {
        self.cfg.faults = Some(faults);
        self
    }

    /// Event-heap shard count ([`ClusterConfig::shards`]): `1` forces a
    /// single global heap, `k > 1` caps the shard count. The default
    /// (unset) shards per connected component.
    pub fn shards(mut self, shards: usize) -> Self {
        self.cfg.shards = Some(shards);
        self
    }

    /// Enable observability capture ([`ClusterConfig::obs`]).
    pub fn obs(mut self, obs: ObsSpec) -> Self {
        self.cfg.obs = obs;
        self
    }

    pub fn build(self) -> ClusterConfig {
        self.cfg
    }
}

/// Cluster run results.
#[derive(Debug)]
pub struct ClusterOutcome {
    pub per_tenant: Vec<(ModelId, RunStats)>,
    /// Post-warmup requests that arrived for a tenant with no admitted
    /// capacity anywhere and were never served (counted as SLA
    /// violations). Warmup-window drops are excluded, mirroring how the
    /// latency stats skip warmup completions — the violation fraction
    /// scores one population. Under admission control this counts only
    /// the deferred requests still unserved at the end of the run.
    pub dropped: Vec<u64>,
    /// Post-warmup requests that waited in the admission queue (0 without
    /// `ClusterConfig::admission`).
    pub deferred: Vec<u64>,
    /// Deferred requests eventually served after re-packing freed
    /// capacity — traffic admission control converted from dropped to
    /// merely late.
    pub deferred_served: Vec<u64>,
    /// Rejected asks admitted after t=0 (the pending-queue re-pack).
    pub late_admissions: u64,
    /// The initial placement (stranded-capacity metrics live here).
    pub packing: Packing,
    pub horizon: Nanos,
    /// DES events processed (the `perf_cluster` bench denominator).
    pub events: u64,
    /// Committed rebalances (controller events).
    pub reconfigs: u64,
    /// Cross-GPU migrations among them (new residencies).
    pub migrations: u64,
    /// Summed per-move outage (drain of the moved slice + repartition or
    /// migration) across rebalances.
    pub reconfig_downtime: Nanos,
    pub reconfig_events: Vec<ClusterReconfigEvent>,
    /// `alloc[gpu][tenant]` the run ended on.
    pub final_alloc: Vec<Vec<usize>>,
    /// Fleet-wide integrated component energy over the horizon
    /// ([`crate::energy::EnergyModel`]).
    pub energy: EnergyBreakdown,
    /// Committed consolidation power-downs.
    pub consolidations: u64,
    /// Total GPU-off time across the fleet, seconds (idle-power elision
    /// the consolidation decisions bought).
    pub gpu_off_s: f64,
    /// Consolidation decision timeline (empty without `consolidate`).
    pub consolidation_events: Vec<ConsolidationEvent>,
    /// Post-warmup requests lost to a fault and never served: an
    /// exhausted retry budget, or (no recovery) a backlog stranded on a
    /// unit whose repair never came.
    pub timed_out: Vec<u64>,
    /// Retry attempts the recovery layer issued.
    pub retries: Vec<u64>,
    /// Hedged duplicates issued to a second replica.
    pub hedges: Vec<u64>,
    /// Post-warmup completions executed under a slowdown fault.
    pub served_degraded: Vec<u64>,
    /// Injected-fault lifecycle timeline (empty without faults).
    pub fault_records: Vec<FaultRecord>,
    /// Mean time-to-repair over completed repairs, seconds.
    pub mttr_s: f64,
    /// Rebalances killed mid-drain (an injected abort, or a donor GPU
    /// that crashed between plan and apply) and rolled back.
    pub reconfig_aborts: u64,
    /// Invariant probe: completions recorded on a failed group. The DES
    /// harvests a crashed group's in-flight work, so this must stay 0.
    pub served_by_failed: u64,
    /// Observability capture; `Some` iff [`ClusterConfig::obs`] was
    /// enabled. Shard buffers merged in shard order ([`ObsLog::merge`]),
    /// so the bytes any exporter derives are shard/jobs-invariant.
    pub obs: Option<Box<ObsLog>>,
}

impl ClusterOutcome {
    pub fn tenant_stats(&self, i: usize) -> &RunStats {
        &self.per_tenant[i].1
    }

    /// Run the accounting-conservation audit on every tenant
    /// ([`RunStats::audit`]): served + dropped + timed-out + warmup
    /// exclusions must equal injected arrivals, and the deferred ledger
    /// must nest (`deferred_served ≤ deferred ≤ arrivals`). Errors name
    /// the first offending tenant.
    pub fn audit(&self) -> crate::Result<()> {
        for (ti, (_, s)) in self.per_tenant.iter().enumerate() {
            s.audit().map_err(|e| anyhow::anyhow!("tenant {ti}: {e}"))?;
        }
        Ok(())
    }

    /// Post-warmup completions across all tenants.
    pub fn completed_total(&self) -> u64 {
        self.per_tenant.iter().map(|(_, s)| s.completed).sum()
    }

    /// Fleet energy per completed query, joules.
    pub fn joules_per_query(&self) -> f64 {
        let done = self.completed_total();
        if done == 0 {
            0.0
        } else {
            self.energy.total_j() / done as f64
        }
    }

    /// Fleet energy efficiency, queries per joule (= sustained QPS/W).
    pub fn perf_per_watt(&self) -> f64 {
        let e = self.energy.total_j();
        if e <= 0.0 {
            0.0
        } else {
            self.completed_total() as f64 / e
        }
    }

    /// Worst per-tenant p95, ms.
    pub fn worst_p95_ms(&self) -> f64 {
        self.per_tenant.iter().map(|(_, s)| s.p95_ms()).fold(0.0, f64::max)
    }

    /// Worst per-tenant p99, ms.
    pub fn worst_p99_ms(&self) -> f64 {
        self.per_tenant.iter().map(|(_, s)| s.p99_ms()).fold(0.0, f64::max)
    }

    /// Fraction of post-warmup demand actually served:
    /// `completed / (completed + dropped + timed-out)`. 1.0 when the run
    /// saw no post-warmup demand. This is the A/B metric the `faults`
    /// experiment compares across recovery policies.
    pub fn availability_frac(&self) -> f64 {
        let done = self.completed_total() as f64;
        let lost =
            (self.dropped.iter().sum::<u64>() + self.timed_out.iter().sum::<u64>()) as f64;
        if done + lost == 0.0 {
            1.0
        } else {
            done / (done + lost)
        }
    }

    /// Post-warmup requests lost to faults, all tenants.
    pub fn timed_out_total(&self) -> u64 {
        self.timed_out.iter().sum()
    }

    /// Tenant `i`'s SLA-violation fraction with dropped and timed-out
    /// requests counted as violations (a request a packer turned away, or
    /// one a fault swallowed, still missed its SLA).
    pub fn violation_frac(&self, i: usize, sla_ms: f64) -> f64 {
        let stats = &self.per_tenant[i].1;
        let n = stats.e2e_ms.count() as f64;
        let d = (self.dropped[i] + self.timed_out[i]) as f64;
        if n + d == 0.0 {
            return 0.0;
        }
        (stats.sla_violation_frac(sla_ms) * n + d) / (n + d)
    }

    /// Worst per-tenant violation fraction against each tenant's own SLA.
    pub fn max_violation_frac(&self, tenants: &[ClusterTenant]) -> f64 {
        (0..self.per_tenant.len())
            .map(|i| self.violation_frac(i, tenants[i].sla_ms))
            .fold(0.0, f64::max)
    }
}

/// Runtime events. Arrivals are NOT events: the driver loop injects them
/// lazily from the per-tenant [`ArrivalStream`] sources whenever the next
/// arrival precedes (or ties) the heap's next scheduled event, so the
/// heap never holds a materialized workload.
#[derive(Debug, Clone, Copy)]
enum Ev {
    PreprocDone { tenant: usize, idx: usize },
    BatchTick { group: usize },
    ExecDone { group: usize, batch_idx: usize },
    /// Close a telemetry window and ask the cross-GPU controller for a
    /// rebalance (and, under admission control, re-offer pending asks).
    ReconfigCheck,
    /// Drain the admission queues into (newly live) capacity —
    /// weighted-round-robin across tenants, so one tenant's backlog can
    /// never monopolize a readmission pass.
    Readmit,
    /// An injected fault strikes (index into the run's fault schedule).
    Fault { fault: usize },
    /// The health check notices a crash (recovery runs only): flush the
    /// dead groups, re-route, and failover-re-pack displaced capacity.
    FaultDetect { fault: usize },
    /// The faulted unit's repair completes.
    FaultRepair { fault: usize },
    /// Client-side retry of a request lost to a fault (`attempt` is
    /// 0-based; the backoff doubles per attempt).
    Retry { tenant: usize, idx: usize, attempt: u32 },
    /// Hedge check: re-issue `idx` to a second replica if its routed
    /// group has (possibly still undetected) failed.
    Hedge { tenant: usize, idx: usize },
}

/// Dispatch-time accounting for one in-flight batch: what the energy
/// integral was charged when the batch started, so a crash harvest can
/// refund the unburned tail exactly (the GPU stops drawing active power
/// at the crash, not at the batch's scheduled completion).
#[derive(Debug, Clone, Copy)]
struct BatchMeta {
    /// Scheduled completion instant.
    done: Nanos,
    /// Charged execution span, ns (slowdown, curve and interference
    /// inflation included).
    exec: Nanos,
    /// Power weight applied to this batch's busy time
    /// (`pow_mult × interference penalty`; 1.0 under the flat model).
    pw: f64,
    /// Dispatched under a slowdown fault (served-degraded accounting).
    degraded: bool,
    /// Slice (local to the group) the batch ran on — the obs segment's
    /// track id.
    slot: usize,
    /// Dispatch sequence number within the group (obs segment ordering).
    seq: u64,
}

/// One (tenant, GPU) serving group: the tenant's slices on that GPU share
/// a batcher; dispatch goes to the group's least-loaded slice.
struct Group {
    tenant: usize,
    gpu: usize,
    batcher: DynamicBatcher,
    slice_free: Vec<Nanos>,
    in_flight: Vec<Option<Batch>>,
    /// Per-slot dispatch accounting for `in_flight[i]`.
    in_flight_meta: Vec<BatchMeta>,
    free_slots: Vec<usize>,
    /// Requests routed here and not yet completed (the JSQ signal).
    outstanding: usize,
    armed_tick: Option<Nanos>,
    /// Accumulated per-slice execution time (the energy integral's
    /// active-GPC numerator; × the tenant's GPCs-per-slice at the end).
    busy_ns: u128,
    /// Power-weighted twin of `busy_ns`: each batch's span times its
    /// curve power multiplier and interference penalty. Equal to
    /// `busy_ns` bit-for-bit under the flat model (weight 1.0).
    busy_pw_ns: u128,
    /// Batches dispatched by this group so far (obs segment sequencing;
    /// maintained unconditionally — a plain counter, behavior-neutral).
    dispatched: u64,
    /// Execution-jitter stream, derived from the group's GLOBAL
    /// (GPU, tenant) identity ([`group_exec_rng`]) so jitter draws are a
    /// pure function of the group — identical however the fleet is
    /// sharded across event heaps.
    exec: Rng,
    /// The group's GPU has crashed: dispatch stops, but `slice_free`
    /// survives until detection (or repair) so blind routing keeps
    /// feeding the dead group — the detection-latency window is real.
    failed: bool,
}

/// Per-GPU power timeline: consolidation marks a GPU off once its last
/// mover drains, and any later slice grant wakes it. Off intervals are
/// closed at power-on (or the horizon) into `off_ns`, which the energy
/// integral subtracts from the GPU's powered-on time.
struct GpuPower {
    off_at: Vec<Option<Nanos>>,
    off_ns: Vec<u128>,
}

impl GpuPower {
    fn new(n_gpus: usize) -> GpuPower {
        GpuPower { off_at: vec![None; n_gpus], off_ns: vec![0; n_gpus] }
    }

    /// Mark `g` powered off from `at` (no-op if already off).
    fn power_off(&mut self, g: usize, at: Nanos) {
        if self.off_at[g].is_none() {
            self.off_at[g] = Some(at);
        }
    }

    /// Mark `g` powered on at `now`, closing its off interval. Waking a
    /// GPU whose off mark lies in the future (its drain had not finished
    /// yet) simply cancels the mark.
    fn power_on(&mut self, g: usize, now: Nanos) {
        if let Some(off) = self.off_at[g].take() {
            self.off_ns[g] += now.saturating_sub(off) as u128;
        }
    }

    /// Seconds `g` spent off within `[0, horizon]`.
    fn off_secs(&self, g: usize, horizon: Nanos) -> f64 {
        let open = self.off_at[g].map_or(0, |off| horizon.saturating_sub(off) as u128);
        (self.off_ns[g] + open) as f64 * 1e-9
    }

    /// No off interval is open (pending drains count as off).
    fn is_on(&self, g: usize) -> bool {
        self.off_at[g].is_none()
    }
}

/// Live fault state for one cluster run ([`crate::fault`] wiring).
struct FaultRt {
    /// Per-GPU crash flag (set at the fault, cleared at repair).
    crashed: Vec<bool>,
    /// Per-GPU service-time multiplier (1.0 = healthy).
    slow: Vec<f64>,
    /// Per-GPU preprocessing-outage end: the stage admits no work before
    /// this instant.
    preproc_until: Vec<Nanos>,
    /// The crash itself (not consolidation) powered the GPU off, so the
    /// repair — not a consolidation wake — closes the interval.
    crash_powered_off: Vec<bool>,
    /// One record per scheduled fault, same order as the schedule.
    records: Vec<FaultRecord>,
    /// Armed reconfig-abort faults (schedule indices): the next
    /// committed rebalance rolls back mid-drain.
    abort_arm: Vec<usize>,
    /// Which serving group each SliceFail struck (by schedule index), so
    /// the repair restores the slice to the same group.
    slice_victim: Vec<Option<usize>>,
    aborts: u64,
    served_by_failed: u64,
}

impl FaultRt {
    fn new(n_gpus: usize, schedule: &FaultSchedule) -> FaultRt {
        FaultRt {
            crashed: vec![false; n_gpus],
            slow: vec![1.0; n_gpus],
            preproc_until: vec![0; n_gpus],
            crash_powered_off: vec![false; n_gpus],
            records: schedule
                .events
                .iter()
                .map(|e| FaultRecord {
                    at_s: e.at_s,
                    gpu: e.gpu,
                    kind: e.kind,
                    detected_s: None,
                    repaired_s: None,
                    skipped: false,
                })
                .collect(),
            abort_arm: Vec::new(),
            slice_victim: vec![None; schedule.events.len()],
            aborts: 0,
            served_by_failed: 0,
        }
    }
}

/// A request's terminal bookkeeping. Faults create racing outcomes — a
/// hedge's duplicate completion, a retry chasing a request a flush
/// already re-routed, a timeout racing a late completion — and the first
/// terminal transition wins; everything later is discarded. This is what
/// keeps conservation exact: every arrival ends in exactly one of
/// `Done` / `Dropped` / `TimedOut`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqState {
    Pending,
    Done,
    Dropped,
    TimedOut,
}

struct TenantState {
    spec: &'static ModelSpec,
    sm: ServiceModel,
    /// Resolved performance/energy curve row for this tenant's
    /// (model, slice geometry) — `CurveView::NEUTRAL` when `[curves]` is
    /// disabled, so dispatch holds it unconditionally.
    curve: crate::models::CurveView,
    buckets: Bucketizer,
    arrivals: Vec<(Nanos, f64)>,
    preproc_done: Vec<Nanos>,
    /// Group each request was routed to.
    routed: Vec<usize>,
    /// This tenant's group indices, in GPU order (append order for
    /// migration-created groups).
    route: Vec<usize>,
    rr_cursor: usize,
    stats: RunStats,
    completed: usize,
    warmup: usize,
    dropped: u64,
    /// Admission queue: arrival indices waiting for capacity (FIFO).
    deferred_q: Vec<usize>,
    /// Requests that passed through the admission queue.
    was_deferred: Vec<bool>,
    deferred: u64,
    deferred_served: u64,
    /// Per-request terminal state (the fault-accounting guard).
    state: Vec<ReqState>,
    timed_out: u64,
    retries: u64,
    hedges: u64,
    served_degraded: u64,
    /// Terminals the warmup rules excluded from the counters above:
    /// completions inside the completion-order window plus drops/timeouts
    /// with a warmup arrival index. Closes the conservation law
    /// `completed + dropped + timed_out + warmup_skipped == arrivals`
    /// that [`RunStats::audit`] checks.
    warmup_skipped: u64,
}

impl TenantState {
    /// Count a dropped request, unless it falls in the warmup window
    /// (arrival index as the proxy) — the latency stats skip warmup
    /// completions, so the violation metric must skip warmup drops too.
    /// Idempotent: a request already terminal stays terminal. Returns
    /// `true` iff this call performed the terminal transition.
    fn drop_request(&mut self, idx: usize) -> bool {
        if self.state[idx] != ReqState::Pending {
            return false;
        }
        self.state[idx] = ReqState::Dropped;
        if idx >= self.warmup {
            self.dropped += 1;
        } else {
            self.warmup_skipped += 1;
        }
        true
    }

    /// A request lost to a fault whose retry budget (or horizon) ran
    /// out. Same warmup, idempotence and return-value rules as
    /// [`TenantState::drop_request`].
    fn timeout_request(&mut self, idx: usize) -> bool {
        if self.state[idx] != ReqState::Pending {
            return false;
        }
        self.state[idx] = ReqState::TimedOut;
        if idx >= self.warmup {
            self.timed_out += 1;
        } else {
            self.warmup_skipped += 1;
        }
        true
    }

    /// Park a request in the admission queue instead of dropping it
    /// (same warmup rule as [`TenantState::drop_request`]; a request
    /// deferred more than once is counted once). Returns `true` iff the
    /// request was newly deferred.
    fn defer_request(&mut self, idx: usize) -> bool {
        self.deferred_q.push(idx);
        if !self.was_deferred[idx] {
            self.was_deferred[idx] = true;
            if idx >= self.warmup {
                self.deferred += 1;
            }
            return true;
        }
        false
    }
}

/// Terminal-transition helpers pairing the [`TenantState`] bookkeeping
/// with the obs terminal record (fired only on the transition that wins,
/// so sampled spans reach exactly one terminal). `tg` is the GLOBAL
/// tenant id.
fn obs_drop(ts: &mut TenantState, obs: &mut ObsLog, tg: usize, idx: usize, at: Nanos) {
    let deferred = ts.was_deferred[idx];
    if ts.drop_request(idx) {
        obs.on_dropped(at, tg, idx, ts.arrivals[idx].0, deferred, idx >= ts.warmup);
    }
}

fn obs_timeout(ts: &mut TenantState, obs: &mut ObsLog, tg: usize, idx: usize, at: Nanos) {
    let deferred = ts.was_deferred[idx];
    if ts.timeout_request(idx) {
        obs.on_timed_out(at, tg, idx, ts.arrivals[idx].0, deferred, idx >= ts.warmup);
    }
}

fn obs_defer(ts: &mut TenantState, obs: &mut ObsLog, tg: usize, idx: usize, at: Nanos) {
    let newly = ts.defer_request(idx);
    obs.on_deferred(at, tg, idx, newly && idx >= ts.warmup);
}

fn build_policy(
    policy: PolicyKind,
    sys: &PrebaConfig,
    spec: &'static ModelSpec,
    sm: &ServiceModel,
    buckets: &Bucketizer,
    n_slices: usize,
) -> BatchPolicy {
    match policy {
        PolicyKind::Dynamic => {
            BatchPolicy::dynamic_from_model(spec, sm, buckets, n_slices.max(1))
        }
        PolicyKind::Static => BatchPolicy::Static(QueueParams {
            batch_max: sys.batching.static_batch_max,
            time_queue: sys.batching.static_time_queue,
        }),
    }
}

fn padded_len(buckets: &Bucketizer, batch: &Batch) -> f64 {
    if batch.max_len_s <= 0.0 {
        return 0.0;
    }
    let edge = buckets.repr_len(buckets.bucket_of(batch.max_len_s));
    if edge > 0.0 {
        edge.max(batch.max_len_s)
    } else {
        batch.max_len_s
    }
}

/// Pick the group an arrival is routed to, or `None` when the tenant has
/// no live capacity anywhere (the request is dropped).
fn route(groups: &[Group], ts: &mut TenantState, routing: Routing) -> Option<usize> {
    match routing {
        Routing::RoundRobin => {
            let n_active =
                ts.route.iter().filter(|&&g| !groups[g].slice_free.is_empty()).count();
            if n_active == 0 {
                return None;
            }
            let k = ts.rr_cursor % n_active;
            ts.rr_cursor = ts.rr_cursor.wrapping_add(1);
            ts.route.iter().copied().filter(|&g| !groups[g].slice_free.is_empty()).nth(k)
        }
        Routing::ShortestQueue => {
            let mut best = None;
            let mut best_load = f64::INFINITY;
            for &g in &ts.route {
                if groups[g].slice_free.is_empty() {
                    continue;
                }
                let load = groups[g].outstanding as f64 / groups[g].slice_free.len() as f64;
                if load < best_load {
                    best_load = load;
                    best = Some(g);
                }
            }
            best
        }
    }
}

/// Form and dispatch every releasable batch of `group` onto its
/// least-loaded slice. `slow` is the per-GPU service-time multiplier
/// (slowdown faults); a crashed group dispatches nothing — its queue
/// sits until the health check flushes it (recovery) or the repair
/// revives the GPU (no-recovery baseline).
fn dispatch_ready(
    gi: usize,
    now: Nanos,
    groups: &mut [Group],
    tenants: &[TenantState],
    q: &mut EventQueue<Ev>,
    slow: &[f64],
) {
    if groups[gi].failed || groups[gi].slice_free.is_empty() {
        return;
    }
    let gpu = groups[gi].gpu;
    let slow = slow.get(gpu).copied().unwrap_or(1.0);
    let ts = &tenants[groups[gi].tenant];
    let curve = ts.curve;
    while let Some((batch, _)) = groups[gi].batcher.try_form(now) {
        // Invariant: checked non-empty above, and the loop never
        // removes slices.
        let Some((slot, &free)) =
            groups[gi].slice_free.iter().enumerate().min_by_key(|(_, &t)| t)
        else {
            debug_assert!(false, "dispatch with no slices");
            return;
        };
        let start = now.max(free);
        // Uncore interference (MIGPerf): count the GPU's OTHER slices —
        // any tenant's, this group's siblings included — still executing
        // at the batch's start. Zero contention skips the scan entirely;
        // the penalty is then the exact constant 1.0 and the curve
        // multipliers below are exact no-ops under the flat model.
        let k = if curve.contention > 0.0 {
            busy_neighbors(groups, gi, slot, gpu, start)
        } else {
            0
        };
        let lat_mult = curve.lat_mult(batch.size()) * curve.penalty(k);
        let pw = curve.pow_mult(batch.size()) * curve.penalty(k);
        let grp = &mut groups[gi];
        let padded = padded_len(&ts.buckets, &batch);
        let exec = secs(
            ts.sm.exec_secs_jittered(batch.size(), padded, &mut grp.exec) * slow * lat_mult,
        );
        let done = start + exec;
        grp.slice_free[slot] = done;
        grp.busy_ns += exec as u128;
        grp.busy_pw_ns += weighted_ns(exec, pw);
        let meta =
            BatchMeta { done, exec, pw, degraded: slow > 1.0, slot, seq: grp.dispatched };
        grp.dispatched += 1;
        let idx = match grp.free_slots.pop() {
            Some(slot) => {
                debug_assert!(grp.in_flight[slot].is_none());
                grp.in_flight[slot] = Some(batch);
                grp.in_flight_meta[slot] = meta;
                slot
            }
            None => {
                grp.in_flight.push(Some(batch));
                grp.in_flight_meta.push(meta);
                grp.in_flight.len() - 1
            }
        };
        q.schedule(done, Ev::ExecDone { group: gi, batch_idx: idx });
    }
}

/// Slices on `gpu` — excluding `(gi, slot)` itself — whose current
/// execution extends past `start`: the dispatch-time interference
/// neighbor count `k` in the `1 + contention · k` penalty. A pure read
/// over the shard's groups; a GPU's groups always share a shard (the
/// residency partition unions tenants through their GPUs), so the count
/// is shard-invariant.
fn busy_neighbors(groups: &[Group], gi: usize, slot: usize, gpu: usize, start: Nanos) -> usize {
    let mut k = 0;
    for (j, g) in groups.iter().enumerate() {
        if g.gpu != gpu {
            continue;
        }
        for (s, &free) in g.slice_free.iter().enumerate() {
            if (j, s) != (gi, slot) && free > start {
                k += 1;
            }
        }
    }
    k
}

/// Power-weighted busy nanoseconds for one batch. The neutral weight is
/// special-cased so disabled curves accumulate the exact same u128 sum
/// as the unweighted integral — that identity is what makes flat-model
/// energy bit-identical to pre-curve builds.
fn weighted_ns(exec: Nanos, pw: f64) -> u128 {
    if pw == 1.0 {
        exec as u128
    } else {
        (exec as f64 * pw).round().max(0.0) as u128
    }
}

/// Smooth weighted-round-robin slot order over per-tenant weights (the
/// nginx SWRR discipline): each tenant appears exactly `weights[i]`
/// times, interleaved proportionally, ties to the lowest index. The
/// admission drain walks this order so a tenant with a 100-deep backlog
/// cannot push another tenant's first deferred request behind all 100 of
/// its own (the old FIFO-across-tenants drain) — no tenant starves.
fn wrr_order(weights: &[usize]) -> Vec<usize> {
    let total: usize = weights.iter().sum();
    let mut current: Vec<i64> = vec![0; weights.len()];
    let mut out = Vec::with_capacity(total);
    for _ in 0..total {
        let mut best = usize::MAX;
        let mut best_cur = i64::MIN;
        for (i, c) in current.iter_mut().enumerate() {
            *c += weights[i] as i64;
            if *c > best_cur && weights[i] > 0 {
                best_cur = *c;
                best = i;
            }
        }
        current[best] -= total as i64;
        out.push(best);
    }
    out
}

/// Arm a BatchTick for the group's earliest deadline unless an earlier
/// (or equal) tick is already pending (the `sim_driver` dedupe). A
/// failed group never arms: its queue cannot dispatch, so a stale
/// deadline would re-fire forever — the unfail paths (repair, or the
/// detection flush emptying the queue) re-arm it.
fn arm_tick(gi: usize, now: Nanos, groups: &mut [Group], q: &mut EventQueue<Ev>) {
    let grp = &mut groups[gi];
    if grp.failed {
        return;
    }
    if let Some(d) = grp.batcher.next_deadline() {
        if grp.armed_tick.is_none_or(|t| d < t) {
            q.schedule(d, Ev::BatchTick { group: gi });
            grp.armed_tick = Some(d.max(now));
        }
    }
}

/// Route request `idx` of `tenant` and start its preprocessing on the
/// routed GPU's resources. `false` = the tenant has no live capacity
/// anywhere (the caller drops or defers it). A preprocessing outage
/// (`preproc_until[gpu]` in the future) stalls the stage: work admits
/// once the pool returns — in every mode, including `Ideal`, where the
/// stage is instantaneous but still a stage.
#[allow(clippy::too_many_arguments)]
fn start_request(
    tenant: usize,
    idx: usize,
    now: Nanos,
    cfg: &ClusterConfig,
    groups: &mut [Group],
    tenants: &mut [TenantState],
    cpu_pools: &mut [CpuPool],
    dpus: &mut [Option<Dpu>],
    q: &mut EventQueue<Ev>,
    preproc_until: &[Nanos],
) -> bool {
    let Some(gi) = route(groups, &mut tenants[tenant], cfg.routing) else {
        return false;
    };
    tenants[tenant].routed[idx] = gi;
    groups[gi].outstanding += 1;
    let gpu = groups[gi].gpu;
    let len = tenants[tenant].arrivals[idx].1;
    let at = now.max(preproc_until.get(gpu).copied().unwrap_or(0));
    match cfg.preproc {
        PreprocMode::Ideal => q.schedule(at, Ev::PreprocDone { tenant, idx }),
        PreprocMode::Cpu => {
            let service = tenants[tenant].spec.cpu_preproc_secs(len.max(0.1));
            let (_, done) = cpu_pools[gpu].admit(at, service);
            q.schedule(done, Ev::PreprocDone { tenant, idx });
        }
        PreprocMode::Dpu => {
            let model = cfg.tenants[tenant].model;
            // Invariant: a DPU exists per GPU in Dpu mode (built in
            // `run`); degrade to ideal preprocessing rather than panic.
            let done = match dpus[gpu].as_mut() {
                Some(d) => d.admit(at, model, len.max(0.1)),
                None => {
                    debug_assert!(false, "DPU mode without a DPU on GPU {gpu}");
                    at
                }
            };
            q.schedule(done, Ev::PreprocDone { tenant, idx });
        }
    }
    true
}

/// The (gpu, tenant) serving group, created empty on first residency
/// (shared by rebalance moves and late admissions so group bookkeeping
/// cannot diverge between the two paths).
fn ensure_group(
    ti: usize,
    gpu: usize,
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    groups: &mut Vec<Group>,
    group_of: &mut [Vec<Option<usize>>],
    tenants: &mut [TenantState],
) -> usize {
    if let Some(g) = group_of[gpu][ti] {
        return g;
    }
    let ts = &tenants[ti];
    let policy = build_policy(cfg.policy, sys, ts.spec, &ts.sm, &ts.buckets, 1);
    let batcher = DynamicBatcher::new(
        cfg.tenants[ti].model,
        ts.buckets.clone(),
        policy,
        sys.batching.merge_adjacent,
    );
    group_of[gpu][ti] = Some(groups.len());
    tenants[ti].route.push(groups.len());
    groups.push(Group {
        tenant: ti,
        gpu,
        batcher,
        slice_free: Vec::new(),
        in_flight: Vec::new(),
        in_flight_meta: Vec::new(),
        free_slots: Vec::new(),
        outstanding: 0,
        armed_tick: None,
        busy_ns: 0,
        busy_pw_ns: 0,
        dispatched: 0,
        // Late-admission groups only arise under the coupled policies
        // (reconfig/admission/consolidation), which always run as a
        // single identity shard, so local ids here ARE global ids.
        exec: group_exec_rng(cfg.seed, gpu, ti),
        failed: false,
    });
    groups.len() - 1
}

/// Hand tenant `ti` a freshly created slice on `gpu` (a late admission),
/// available once its spin-up outage ends at `avail`, and rebuild the
/// group's batching policy for the new slice count.
#[allow(clippy::too_many_arguments)]
fn grant_slice(
    ti: usize,
    gpu: usize,
    avail: Nanos,
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    now: Nanos,
    groups: &mut Vec<Group>,
    group_of: &mut [Vec<Option<usize>>],
    tenants: &mut [TenantState],
    q: &mut EventQueue<Ev>,
    slow: &[f64],
) {
    let gi = ensure_group(ti, gpu, cfg, sys, groups, group_of, tenants);
    groups[gi].slice_free.push(avail);
    let n = groups[gi].slice_free.len();
    let ts = &tenants[ti];
    let new_policy = build_policy(cfg.policy, sys, ts.spec, &ts.sm, &ts.buckets, n);
    groups[gi].batcher.rebuild(new_policy, now);
    dispatch_ready(gi, now, groups, tenants, q, slow);
    arm_tick(gi, now, groups, q);
}

/// A shard of the fleet: the subset of global GPU / tenant indices one
/// event heap simulates. Local index `g` in a shard's state maps to
/// global GPU `gpu_ids[g]` (same for tenants), and every derived rng
/// stream is keyed by the GLOBAL id, so shard outputs are a pure
/// function of the global configuration — bitwise identical however the
/// fleet is cut.
struct ShardCtx {
    n_gpus_global: usize,
    gpu_ids: Vec<usize>,
    tenant_ids: Vec<usize>,
}

impl ShardCtx {
    fn identity(n_gpus: usize, n_tenants: usize) -> ShardCtx {
        ShardCtx {
            n_gpus_global: n_gpus,
            gpu_ids: (0..n_gpus).collect(),
            tenant_ids: (0..n_tenants).collect(),
        }
    }

    fn is_identity(&self, cfg: &ClusterConfig) -> bool {
        self.gpu_ids.len() == cfg.n_gpus() && self.tenant_ids.len() == cfg.tenants.len()
    }
}

/// Replay the single-heap setup's root-rng draw order: burn `nth` draws
/// off the root (exec draw #0, then one per CPU pool, then one per
/// tenant), then split with `tag`. Every shard reconstructs exactly the
/// pool / arrival stream the legacy eager setup handed that global
/// index, without owning the root.
fn derived_rng(seed: u64, nth: usize, tag: u64) -> Rng {
    let mut root = Rng::new(seed ^ 0xC1A5);
    for _ in 0..nth {
        root.next_u64();
    }
    root.split(tag)
}

/// Execution-jitter stream for serving group (GPU, tenant), keyed by the
/// GLOBAL ids so the draws a group sees do not depend on which shard —
/// or which event heap — it runs in.
fn group_exec_rng(seed: u64, gpu: usize, tenant: usize) -> Rng {
    let mut r = Rng::new(seed ^ 0xE8EC_C1A5);
    r.split(((gpu as u64) << 32) ^ tenant as u64)
}

/// Union-find root with path halving.
fn uf_find(parent: &mut [usize], mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]];
        x = parent[x];
    }
    x
}

/// Cut the fleet into independently-simulable shards.
///
/// The cluster-wide couplers — rebalancing, admission control,
/// consolidation, fault injection — entangle every GPU through the
/// controller, so any of them (or an explicit `shards = 1`) forces one
/// identity shard. Otherwise GPUs and tenants form a bipartite graph
/// (an edge per admitted slice) whose connected components share no
/// state at all: each becomes a shard, a capacity-less tenant becomes a
/// GPU-less singleton (its requests all drop), and a tenant-less GPU
/// joins no shard (`finalize` charges it idle energy). An explicit
/// `shards = k` bound merges components round-robin into at most `k`
/// shards; merged lists are re-sorted ascending so local index order —
/// and with it every routing tie-break — matches any other shard count.
fn partition(cfg: &ClusterConfig, alloc: &[Vec<usize>]) -> Vec<ShardCtx> {
    let ng = cfg.n_gpus();
    let nt = cfg.tenants.len();
    let coupled = cfg.reconfig.is_some()
        || cfg.admission
        || cfg.consolidate
        || cfg.faults.as_ref().is_some_and(|f| !f.schedule.events.is_empty());
    if coupled || cfg.shards == Some(1) {
        return vec![ShardCtx::identity(ng, nt)];
    }
    // Tenants are nodes [0, nt), GPUs are nodes [nt, nt + ng).
    let mut parent: Vec<usize> = (0..nt + ng).collect();
    for (g, row) in alloc.iter().enumerate() {
        for (ti, &n) in row.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let a = uf_find(&mut parent, ti);
            let b = uf_find(&mut parent, nt + g);
            let (lo, hi) = (a.min(b), a.max(b));
            parent[hi] = lo;
        }
    }
    // Components indexed in smallest-member-tenant order (deterministic:
    // no hash maps anywhere near the partition).
    let mut comp_of_root: Vec<Option<usize>> = vec![None; nt + ng];
    let mut comps: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
    for ti in 0..nt {
        let r = uf_find(&mut parent, ti);
        let c = match comp_of_root[r] {
            Some(c) => c,
            None => {
                comps.push((Vec::new(), Vec::new()));
                comp_of_root[r] = Some(comps.len() - 1);
                comps.len() - 1
            }
        };
        comps[c].0.push(ti);
    }
    for g in 0..ng {
        let r = uf_find(&mut parent, nt + g);
        // A tenant-less GPU has no component: no shard simulates it and
        // `finalize` accounts it as idle for the whole horizon.
        if let Some(c) = comp_of_root[r] {
            comps[c].1.push(g);
        }
    }
    if let Some(k) = cfg.shards {
        if comps.len() > k {
            let mut buckets: Vec<(Vec<usize>, Vec<usize>)> =
                vec![(Vec::new(), Vec::new()); k];
            for (i, (ts, gs)) in comps.into_iter().enumerate() {
                buckets[i % k].0.extend(ts);
                buckets[i % k].1.extend(gs);
            }
            for b in &mut buckets {
                b.0.sort_unstable();
                b.1.sort_unstable();
            }
            comps = buckets;
        }
    }
    comps
        .into_iter()
        .map(|(tenant_ids, gpu_ids)| ShardCtx { n_gpus_global: ng, gpu_ids, tenant_ids })
        .collect()
}

/// Run one cluster simulation.
///
/// The fleet is packed globally, cut into shards ([`partition`]), and
/// each shard runs its own event heap on the worker pool
/// ([`crate::util::par::run_jobs`]); [`finalize`] merges the shard
/// outputs into one [`ClusterOutcome`]. Results are bitwise identical
/// for every worker count and every shard count.
pub fn run(cfg: &ClusterConfig, sys: &PrebaConfig) -> anyhow::Result<ClusterOutcome> {
    cfg.validate()?;

    // Place the slice inventory (each GPU offers its own class capacity).
    let packing = pack_fleet(&cfg.asks(), &cfg.fleet, cfg.strategy);
    let mut alloc: Vec<Vec<usize>> = vec![vec![0; cfg.tenants.len()]; cfg.n_gpus()];
    for (ask, gpu) in &packing.placements {
        alloc[*gpu][ask.tenant] += 1;
    }
    // Admission control: rejected asks wait and are re-offered to the
    // controller every telemetry window (identity shard only — admission
    // is a coupler).
    let pending: Vec<SliceAsk> =
        if cfg.admission { packing.rejected.clone() } else { Vec::new() };

    let parts = partition(cfg, &alloc);
    let results = crate::util::par::run_jobs(parts.len(), |p| {
        let ctx = &parts[p];
        if ctx.is_identity(cfg) {
            run_inner(cfg, sys, ctx, alloc.clone(), pending.clone())
        } else {
            // Restrict the config to the shard's slice of the fleet; the
            // ctx keeps the global ids every rng derivation needs.
            let mut local = cfg.clone();
            local.fleet = ctx.gpu_ids.iter().map(|&g| cfg.fleet[g]).collect();
            local.tenants =
                ctx.tenant_ids.iter().map(|&ti| cfg.tenants[ti].clone()).collect();
            let alloc_local: Vec<Vec<usize>> = ctx
                .gpu_ids
                .iter()
                .map(|&g| ctx.tenant_ids.iter().map(|&ti| alloc[g][ti]).collect())
                .collect();
            run_inner(&local, sys, ctx, alloc_local, Vec::new())
        }
    });
    let outs = results.into_iter().collect::<anyhow::Result<Vec<PartOut>>>()?;
    Ok(finalize(cfg, sys, packing, alloc, &parts, outs))
}

/// Simulate one shard. `cfg` is already restricted to the shard
/// (fleet/tenants local-indexed); `ctx` maps local indices back to
/// global ids so every derived rng replays exactly the stream the
/// single-heap run would hand the same GPU / tenant / group.
fn run_inner(
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    ctx: &ShardCtx,
    alloc: Vec<Vec<usize>>,
    mut pending: Vec<SliceAsk>,
) -> anyhow::Result<PartOut> {
    // Per-GPU preprocessing resources. The split tag lives in its own
    // namespace so pool streams can never collide with the per-tenant
    // arrival streams (`100 + ti`) at any fleet size.
    let usable = sys.hardware.cpu_cores - sys.hardware.cpu_reserved_cores;
    let mut cpu_pools: Vec<CpuPool> = ctx
        .gpu_ids
        .iter()
        .map(|&gg| CpuPool::new(usable, derived_rng(cfg.seed, 1 + gg, 0x9AD5_0000 + gg as u64)))
        .collect();
    let mut dpus: Vec<Option<Dpu>> = (0..cfg.n_gpus())
        .map(|_| match cfg.preproc {
            PreprocMode::Dpu => Some(Dpu::new(&sys.dpu, &sys.hardware)),
            _ => None,
        })
        .collect();

    let mut late_admissions = 0u64;

    // Observability recorder. Disabled (the default): every hook
    // early-returns, draws no RNG, schedules no events — byte-identity
    // with capture-free builds. All keys recorded through `ctx` are
    // GLOBAL ids, so `finalize` merges shard logs by concatenation.
    let mut obs = ObsLog::new(cfg.obs);

    // Tenant state + lazily-pulled workloads: each tenant exposes one
    // bounded [`ArrivalStream`]; the driver loop below injects from it
    // and nothing is materialized up front.
    let mut queue: EventQueue<Ev> = EventQueue::with_capacity(64);
    let mut tenants: Vec<TenantState> = Vec::with_capacity(cfg.tenants.len());
    let mut sources: Vec<Bounded<Box<dyn ArrivalStream>>> =
        Vec::with_capacity(cfg.tenants.len());
    for (ti, t) in cfg.tenants.iter().enumerate() {
        let spec = t.model.spec();
        let sm = ServiceModel::new(spec, t.slice.gpcs);
        let buckets = match (t.model.kind(), cfg.policy) {
            (ModelKind::Audio, PolicyKind::Dynamic) => {
                Bucketizer::new(sys.batching.bucket_window_s, sys.batching.max_audio_s)
            }
            _ => Bucketizer::fixed(),
        };
        let tg = ctx.tenant_ids[ti];
        let gen_rng = derived_rng(cfg.seed, 1 + ctx.n_gpus_global + tg, 100 + tg as u64);
        let src: Box<dyn ArrivalStream> = if let Some(sspec) = &t.stream {
            sspec.open(t.model, gen_rng)?
        } else if let Some(trace) = &t.trace {
            Box::new(trace.cursor(t.model, gen_rng))
        } else if let Some(profile) = &t.profile {
            Box::new(TraceGen::new(t.model, profile.clone(), gen_rng))
        } else {
            Box::new(QueryGen::new(t.model, t.rate_qps, gen_rng))
        };
        sources.push(Bounded::new(src, t.requests));
        tenants.push(TenantState {
            spec,
            sm,
            curve: sys.curves.view(t.model, t.slice.gpcs),
            buckets,
            preproc_done: Vec::new(),
            routed: Vec::new(),
            was_deferred: Vec::new(),
            state: Vec::new(),
            arrivals: Vec::new(),
            route: Vec::new(),
            rr_cursor: 0,
            stats: RunStats::new(),
            completed: 0,
            warmup: (t.requests as f64 * cfg.warmup_frac) as usize,
            dropped: 0,
            deferred_q: Vec::new(),
            deferred: 0,
            deferred_served: 0,
            timed_out: 0,
            retries: 0,
            hedges: 0,
            served_degraded: 0,
            warmup_skipped: 0,
        });
    }

    // Injection frontier: the earliest pending arrival per tenant,
    // ordered (time, tenant) so simultaneous arrivals inject
    // lowest-tenant first — the same order the eager setup's tenant-major
    // `schedule()` seqs produced.
    let mut peeked: Vec<Option<Arrival>> = Vec::with_capacity(sources.len());
    let mut front: BinaryHeap<Reverse<(Nanos, usize)>> = BinaryHeap::new();
    for (ti, s) in sources.iter_mut().enumerate() {
        let a = s.next_arrival();
        if let Some(a) = &a {
            front.push(Reverse((a.at, ti)));
        }
        peeked.push(a);
    }

    // Serving groups, one per (GPU, tenant) with admitted slices, in
    // GPU-major order so every tenant's route list is GPU-ordered.
    let mut groups: Vec<Group> = Vec::new();
    let mut group_of: Vec<Vec<Option<usize>>> =
        vec![vec![None; cfg.tenants.len()]; cfg.n_gpus()];
    for (g, row) in alloc.iter().enumerate() {
        for (ti, &n) in row.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let ts = &tenants[ti];
            let policy = build_policy(cfg.policy, sys, ts.spec, &ts.sm, &ts.buckets, n);
            let batcher = DynamicBatcher::new(
                cfg.tenants[ti].model,
                ts.buckets.clone(),
                policy,
                sys.batching.merge_adjacent,
            );
            group_of[g][ti] = Some(groups.len());
            tenants[ti].route.push(groups.len());
            groups.push(Group {
                tenant: ti,
                gpu: g,
                batcher,
                slice_free: vec![0; n],
                in_flight: Vec::new(),
                in_flight_meta: Vec::new(),
                free_slots: Vec::new(),
                outstanding: 0,
                armed_tick: None,
                busy_ns: 0,
                busy_pw_ns: 0,
                dispatched: 0,
                exec: group_exec_rng(cfg.seed, ctx.gpu_ids[g], ctx.tenant_ids[ti]),
                failed: false,
            });
        }
    }

    // Cross-GPU rebalancing controller (plans against each GPU's class).
    let mut ctrl = cfg.reconfig.clone().map(|mut policy| {
        policy.consolidate |= cfg.consolidate;
        let specs: Vec<TenantSpec> =
            cfg.tenants.iter().map(|t| TenantSpec::new(t.model, t.sla_ms)).collect();
        let slices: Vec<Slice> = cfg.tenants.iter().map(|t| t.slice).collect();
        // Curve-aware planning: each tenant's sizing/prediction scale is
        // its latency multiplier at the knee batch times the contention
        // penalty of a fully co-located host GPU — the conservative
        // planning point for the HeteroMIG setting (neighbors busy).
        // With `[curves]` disabled every view is NEUTRAL and the scales
        // are exactly 1.0 (the controller is bit-identical to before).
        let host_gpcs = cfg.fleet.iter().map(|c| c.gpcs).max().unwrap_or(7);
        let scales: Vec<f64> = cfg
            .tenants
            .iter()
            .map(|t| {
                let len = crate::mig::planner::default_len(t.model);
                let knee = ServiceModel::new(t.model.spec(), t.slice.gpcs).knee(len);
                let neighbors = (host_gpcs / t.slice.gpcs.max(1)).saturating_sub(1);
                sys.curves.view(t.model, t.slice.gpcs).service_scale(knee, neighbors)
            })
            .collect();
        ClusterReconfigController::with_fleet(
            specs,
            slices,
            cfg.fleet.clone(),
            alloc.clone(),
            policy,
        )
        .with_service_scales(scales)
    });
    // Per-GPU power timeline (consolidation's idle-power elision).
    let mut power = GpuPower::new(cfg.n_gpus());
    if let Some(c) = &ctrl {
        queue.schedule(c.window(), Ev::ReconfigCheck);
    }

    // Fault injection: the whole schedule enters the heap up front; the
    // recovery knobs (when present) drive detection, retry, and hedging.
    let fspec = cfg.faults.clone().unwrap_or_default();
    let recovery = fspec.recovery;
    let mut frt = FaultRt::new(cfg.n_gpus(), &fspec.schedule);
    for (k, e) in fspec.schedule.events.iter().enumerate() {
        queue.schedule(secs(e.at_s), Ev::Fault { fault: k });
    }

    let mut downtime: Nanos = 0;
    let mut horizon: Nanos = 0;
    let mut events: u64 = 0;
    // Driver: interleave lazy arrival injection with heap pops. An
    // arrival injects whenever it precedes — or ties — the next
    // scheduled event; ties go to the arrival, matching the eager setup
    // where every arrival's `schedule()` seq was smaller than any
    // runtime event's. Each injection advances virtual time and runs the
    // arrival logic inline, so the heap never holds the workload.
    let q = &mut queue;
    loop {
        while let Some(&Reverse((at, ti))) = front.peek() {
            if q.peek_time().is_some_and(|t| at > t) {
                break;
            }
            front.pop();
            let a = peeked[ti].take().expect("frontier entry without peeked arrival");
            if let Some(next) = sources[ti].next_arrival() {
                front.push(Reverse((next.at, ti)));
                peeked[ti] = Some(next);
            }
            q.advance_to(at);
            events += 1;
            let now = at;
            let ts = &mut tenants[ti];
            let idx = ts.arrivals.len();
            ts.arrivals.push((a.at, a.len_s));
            ts.preproc_done.push(0);
            ts.routed.push(usize::MAX);
            ts.was_deferred.push(false);
            ts.state.push(ReqState::Pending);
            obs.on_arrival(now, ctx.tenant_ids[ti]);
            if let Some(c) = ctrl.as_mut() {
                c.observe_arrival(ti);
            }
            if start_request(
                ti, idx, now, cfg, &mut groups, &mut tenants, &mut cpu_pools, &mut dpus,
                q, &frt.preproc_until,
            ) {
                if let Some(p) = recovery {
                    if p.hedge_s > 0.0 {
                        q.schedule_in(secs(p.hedge_s), Ev::Hedge { tenant: ti, idx });
                    }
                }
            } else if cfg.admission {
                obs_defer(&mut tenants[ti], &mut obs, ctx.tenant_ids[ti], idx, now);
            } else {
                obs_drop(&mut tenants[ti], &mut obs, ctx.tenant_ids[ti], idx, now);
            }
        }
        let Some((now, ev)) = q.pop() else {
            break;
        };
        events += 1;
        match ev {
            Ev::Readmit => {
                // Drain the admission queues into newly-live capacity
                // weighted-round-robin: weights are the backlog depths,
                // so service stays proportional while every waiting
                // tenant gets interleaved slots (FIFO-across-tenants
                // would enqueue one tenant's whole backlog first).
                // Arrival order is preserved within a tenant; anything
                // that still finds no slice goes back to waiting.
                let queues: Vec<Vec<usize>> =
                    tenants.iter_mut().map(|t| std::mem::take(&mut t.deferred_q)).collect();
                let weights: Vec<usize> = queues.iter().map(Vec::len).collect();
                let mut cursor = vec![0usize; queues.len()];
                let mut stalled = vec![false; queues.len()];
                // A tenant stalls permanently within one drain (routing
                // failure is tenant-level), so once every queue is
                // stalled or exhausted the rest of the order is no-ops.
                let mut live = queues.iter().filter(|qd| !qd.is_empty()).count();
                for ti in wrr_order(&weights) {
                    if live == 0 {
                        break;
                    }
                    if stalled[ti] || cursor[ti] >= queues[ti].len() {
                        continue;
                    }
                    let idx = queues[ti][cursor[ti]];
                    if start_request(
                        ti, idx, now, cfg, &mut groups, &mut tenants, &mut cpu_pools,
                        &mut dpus, q, &frt.preproc_until,
                    ) {
                        cursor[ti] += 1;
                        if cursor[ti] >= queues[ti].len() {
                            live -= 1;
                        }
                    } else {
                        stalled[ti] = true;
                        live -= 1;
                    }
                }
                for (ti, qd) in queues.into_iter().enumerate() {
                    tenants[ti].deferred_q.extend(qd.into_iter().skip(cursor[ti]));
                }
            }
            Ev::PreprocDone { tenant, idx } => {
                tenants[tenant].preproc_done[idx] = now;
                let mut gi = tenants[tenant].routed[idx];
                // The routed group may have lost its last slice to a
                // rebalance while this request preprocessed; re-route to
                // the tenant's least-loaded live group.
                if groups[gi].slice_free.is_empty() {
                    groups[gi].outstanding -= 1;
                    match route(&groups, &mut tenants[tenant], Routing::ShortestQueue) {
                        Some(g2) => {
                            gi = g2;
                            tenants[tenant].routed[idx] = gi;
                            groups[gi].outstanding += 1;
                        }
                        None if cfg.admission => {
                            // Park it; it re-enters (and re-preprocesses,
                            // as a resubmission would) once capacity
                            // returns.
                            obs_defer(
                                &mut tenants[tenant], &mut obs, ctx.tenant_ids[tenant],
                                idx, now,
                            );
                            continue;
                        }
                        None => {
                            obs_drop(
                                &mut tenants[tenant], &mut obs, ctx.tenant_ids[tenant],
                                idx, now,
                            );
                            continue;
                        }
                    }
                }
                let (at, len) = tenants[tenant].arrivals[idx];
                groups[gi].batcher.enqueue(Request {
                    id: idx as u64,
                    model: cfg.tenants[tenant].model,
                    arrival: at,
                    enqueued: now,
                    len_s: len,
                });
                dispatch_ready(gi, now, &mut groups, &tenants, q, &frt.slow);
                arm_tick(gi, now, &mut groups, q);
                if obs.enabled() {
                    let grp = &groups[gi];
                    obs.on_queue(
                        now,
                        ctx.gpu_ids[grp.gpu],
                        ctx.tenant_ids[tenant],
                        grp.outstanding,
                        grp.in_flight.len() - grp.free_slots.len(),
                    );
                }
            }
            Ev::BatchTick { group } => {
                groups[group].armed_tick = None;
                dispatch_ready(group, now, &mut groups, &tenants, q, &frt.slow);
                arm_tick(group, now, &mut groups, q);
            }
            Ev::ExecDone { group, batch_idx } => {
                let ti = groups[group].tenant;
                let Some(batch) = groups[group].in_flight[batch_idx].take() else {
                    // The batch was harvested when its GPU crashed; this
                    // is the stale completion still in the heap. Reclaim
                    // the slot (the harvest left it un-recycled for
                    // exactly this moment).
                    groups[group].free_slots.push(batch_idx);
                    continue;
                };
                horizon = horizon.max(now);
                if groups[group].failed {
                    // Invariant probe (must stay 0): a crashed group's
                    // in-flight work was harvested at the fault, so no
                    // completion can land while it is failed.
                    frt.served_by_failed += batch.size() as u64;
                }
                let meta = groups[group].in_flight_meta[batch_idx];
                let degraded = meta.degraded;
                groups[group].free_slots.push(batch_idx);
                let bsize = batch.size();
                groups[group].outstanding = groups[group].outstanding.saturating_sub(bsize);
                let gg = ctx.gpu_ids[groups[group].gpu];
                let tg = ctx.tenant_ids[ti];
                if obs.enabled() {
                    obs.on_batch(BatchSeg {
                        gpu: gg,
                        slice: meta.slot,
                        tenant: tg,
                        seq: meta.seq,
                        start: now.saturating_sub(meta.exec),
                        end: now,
                        size: bsize,
                        gpcs: cfg.tenants[ti].slice.gpcs,
                        pw: meta.pw,
                        harvested: false,
                    });
                    let grp = &groups[group];
                    obs.on_queue(
                        now,
                        gg,
                        tg,
                        grp.outstanding,
                        grp.in_flight.len() - grp.free_slots.len(),
                    );
                }
                let ts = &mut tenants[ti];
                let padded = padded_len(&ts.buckets, &batch);
                let exec_model = secs(ts.sm.exec_secs(bsize, padded));
                let since_formed = now.saturating_sub(batch.formed);
                let exec_ns = exec_model.min(since_formed);
                for r in &batch.requests {
                    let i = r.id as usize;
                    // Terminal-state guard: a hedged duplicate's
                    // completion, or one racing a timeout, is discarded.
                    if ts.state[i] != ReqState::Pending {
                        continue;
                    }
                    ts.state[i] = ReqState::Done;
                    ts.completed += 1;
                    // Deferred-then-served accounting uses the arrival
                    // index for its warmup rule, matching `defer_request`.
                    if ts.was_deferred[i] && i >= ts.warmup {
                        ts.deferred_served += 1;
                    }
                    // Completion-ORDER warmup rule (distinct from the
                    // drop/defer arrival-index rule above).
                    let counted = ts.completed > ts.warmup;
                    if obs.enabled() {
                        obs.on_served(Served {
                            tenant: tg,
                            idx: i,
                            arrival: ts.arrivals[i].0,
                            done: now,
                            parts: LatencyParts {
                                preprocess: ts.preproc_done[i] - ts.arrivals[i].0,
                                batching: batch.formed.saturating_sub(ts.preproc_done[i]),
                                dispatch_wait: since_formed - exec_ns,
                                execution: exec_ns,
                            },
                            gpu: gg,
                            slice: meta.slot,
                            batch: meta.seq,
                            batch_size: bsize,
                            degraded,
                            deferred: ts.was_deferred[i],
                            counted,
                        });
                    }
                    if !counted {
                        ts.warmup_skipped += 1;
                        continue;
                    }
                    if degraded {
                        ts.served_degraded += 1;
                    }
                    ts.stats.record(
                        LatencyParts {
                            preprocess: ts.preproc_done[i] - ts.arrivals[i].0,
                            batching: batch.formed.saturating_sub(ts.preproc_done[i]),
                            dispatch_wait: since_formed - exec_ns,
                            execution: exec_ns,
                        },
                        now,
                        bsize,
                    );
                }
                groups[group].batcher.recycle(batch);
            }
            Ev::ReconfigCheck => {
                // Invariant: ReconfigCheck is only ever scheduled when a
                // controller exists; a stray event is ignored.
                let Some(c) = ctrl.as_mut() else {
                    debug_assert!(false, "ReconfigCheck without controller");
                    continue;
                };
                let tail = front.is_empty();
                if tail {
                    c.roll_only(now);
                } else {
                    if let Some(moves) = c.tick(now) {
                        // Whatever planner produced the plan, the
                        // committed mirror must still replay cleanly
                        // through the shared validity checker (fatal
                        // under test, compiled out in release).
                        debug_assert!(
                            {
                                let sl: Vec<Slice> =
                                    cfg.tenants.iter().map(|t| t.slice).collect();
                                crate::mig::validate_plan(
                                    &sl,
                                    c.fleet(),
                                    c.gpu_failed(),
                                    c.alloc(),
                                    &[],
                                )
                                .is_ok()
                            },
                            "controller committed an invalid allocation"
                        );
                        // A committed rebalance can die mid-drain: an
                        // armed ReconfigAbort fault, or a donor GPU that
                        // crashed inside the detection window (the
                        // controller's mirror is blind until the health
                        // check). Either way the repartition rolls back.
                        let crashed_donor = moves.iter().any(|m| frt.crashed[m.gpu]);
                        if crashed_donor || !frt.abort_arm.is_empty() {
                            if !crashed_donor {
                                let k = frt.abort_arm.remove(0);
                                frt.records[k].repaired_s = Some(to_secs(now));
                            }
                            c.abort_last();
                            frt.aborts += 1;
                            // The aborted drain still disturbed every
                            // surviving donor: its earliest slice pays
                            // the repartition outage and returns.
                            for m in &moves {
                                if frt.crashed[m.gpu] {
                                    continue;
                                }
                                let Some(donor) = group_of[m.gpu][m.from] else {
                                    continue;
                                };
                                let grp = &mut groups[donor];
                                if grp.slice_free.is_empty() {
                                    continue;
                                }
                                grp.slice_free.sort_unstable();
                                let back = grp.slice_free[0].max(now)
                                    + secs(c.policy().repartition_s);
                                grp.slice_free[0] = back;
                                downtime += back - now;
                            }
                        } else {
                            downtime += apply_moves(
                                &moves, c.policy(), cfg, sys, now, &mut groups,
                                &mut group_of, &mut tenants, q, &frt.slow,
                            );
                        }
                    }
                    // Admission re-pack: offer every still-pending ask to
                    // whatever capacity the rebalance freed. An admitted
                    // ask is a new residency — it pays the migration
                    // outage before its slice serves.
                    let mut i = 0;
                    while i < pending.len() {
                        match c.try_admit(pending[i].tenant) {
                            None => i += 1,
                            Some(gpu) => {
                                let ask = pending.remove(i);
                                late_admissions += 1;
                                // Admitting into a parked GPU wakes it.
                                power.power_on(gpu, now);
                                let avail = now + secs(c.policy().migration_s);
                                grant_slice(
                                    ask.tenant, gpu, avail, cfg, sys, now, &mut groups,
                                    &mut group_of, &mut tenants, q, &frt.slow,
                                );
                            }
                        }
                    }
                    // Energy pass: consolidation shares the window
                    // cadence and the cooldown, so a power decision can
                    // never fight the rate-driven moves above.
                    if let Some(action) = c.tick_consolidation(now) {
                        downtime += apply_consolidation(
                            &action, c.policy(), cfg, sys, now, &mut groups, &mut group_of,
                            &mut tenants, q, &mut power, &frt.slow,
                        );
                    }
                    // Wake the admission drain if any waiting tenant now
                    // sees live capacity.
                    if tenants.iter().any(|ts| {
                        !ts.deferred_q.is_empty()
                            && ts.route.iter().any(|&g| !groups[g].slice_free.is_empty())
                    }) {
                        q.schedule(now, Ev::Readmit);
                    }
                    q.schedule_in(c.window(), Ev::ReconfigCheck);
                }
            }
            Ev::Fault { fault } => {
                let e = fspec.schedule.events[fault];
                let g = e.gpu;
                match e.kind {
                    FaultKind::GpuCrash => {
                        if frt.crashed[g] {
                            frt.records[fault].skipped = true;
                            continue;
                        }
                        frt.crashed[g] = true;
                        // Kill every serving group on the GPU: keep the
                        // slice clocks (the router is blind until the
                        // health check), stop dispatch, and harvest the
                        // in-flight batches — their completions will
                        // never arrive. Slots stay un-recycled so the
                        // stale ExecDone events reclaim them gracefully.
                        for gi in 0..groups.len() {
                            if groups[gi].gpu != g {
                                continue;
                            }
                            groups[gi].failed = true;
                            // Harvest the in-flight batches AND refund
                            // each one's unburned tail from the energy
                            // integral: dispatch charged busy time up to
                            // the scheduled completion, but the GPU
                            // stops drawing active power at the crash —
                            // without the refund, busy time can exceed
                            // the powered-on span and conservation
                            // breaks (worst under slowdown-stretched
                            // execution, which inflates the overhang).
                            let mut lost: Vec<Request> = Vec::new();
                            for slot in 0..groups[gi].in_flight.len() {
                                let Some(b) = groups[gi].in_flight[slot].take() else {
                                    continue;
                                };
                                let meta = groups[gi].in_flight_meta[slot];
                                let refund = meta.done.saturating_sub(now).min(meta.exec);
                                groups[gi].busy_ns =
                                    groups[gi].busy_ns.saturating_sub(refund as u128);
                                groups[gi].busy_pw_ns = groups[gi]
                                    .busy_pw_ns
                                    .saturating_sub(weighted_ns(refund, meta.pw));
                                if obs.enabled() {
                                    // Truncated segment: the slice stopped
                                    // burning at the crash, not at the
                                    // batch's scheduled completion.
                                    obs.on_batch(BatchSeg {
                                        gpu: ctx.gpu_ids[g],
                                        slice: meta.slot,
                                        tenant: ctx.tenant_ids[groups[gi].tenant],
                                        seq: meta.seq,
                                        start: meta.done.saturating_sub(meta.exec),
                                        end: now,
                                        size: b.size(),
                                        gpcs: cfg.tenants[groups[gi].tenant].slice.gpcs,
                                        pw: meta.pw,
                                        harvested: true,
                                    });
                                }
                                lost.extend(b.requests);
                            }
                            groups[gi].outstanding =
                                groups[gi].outstanding.saturating_sub(lost.len());
                            let ti = groups[gi].tenant;
                            for r in lost {
                                let idx = r.id as usize;
                                match recovery {
                                    // The client notices at its timeout
                                    // and re-submits with backoff.
                                    Some(p) if p.max_retries > 0 => {
                                        tenants[ti].retries += 1;
                                        obs.mark_retry(ctx.tenant_ids[ti], idx);
                                        q.schedule_in(
                                            secs(p.timeout_s + p.backoff_delay_s(0)),
                                            Ev::Retry { tenant: ti, idx, attempt: 0 },
                                        );
                                    }
                                    _ => obs_timeout(
                                        &mut tenants[ti], &mut obs, ctx.tenant_ids[ti],
                                        idx, now,
                                    ),
                                }
                            }
                        }
                        // A dead GPU draws no power (unless consolidation
                        // already parked it — that interval stands).
                        if power.is_on(g) {
                            power.power_off(g, now);
                            frt.crash_powered_off[g] = true;
                        }
                        if let Some(p) = recovery {
                            q.schedule_in(secs(p.detect_s), Ev::FaultDetect { fault });
                        }
                        // An infinite duration = the unit never comes
                        // back (no repair event enters the heap).
                        if e.duration_s.is_finite() {
                            q.schedule_in(secs(e.duration_s), Ev::FaultRepair { fault });
                        }
                    }
                    FaultKind::SliceFail => {
                        // The fullest group on `g` loses its earliest-free
                        // slice (fail-stop after its current batch).
                        let victim = (0..groups.len())
                            .filter(|&gi| {
                                groups[gi].gpu == g && !groups[gi].slice_free.is_empty()
                            })
                            .max_by_key(|&gi| {
                                (groups[gi].slice_free.len(), std::cmp::Reverse(gi))
                            });
                        let Some(gi) = victim else {
                            frt.records[fault].skipped = true;
                            continue;
                        };
                        frt.slice_victim[fault] = Some(gi);
                        groups[gi].slice_free.sort_unstable();
                        groups[gi].slice_free.remove(0);
                        let ti = groups[gi].tenant;
                        if let Some(c) = ctrl.as_mut() {
                            c.note_slice_lost(g, ti);
                        }
                        // Rebuilds the policy for the shrunken slice
                        // count, or flushes the queue to survivors if
                        // that was the last slice.
                        settle_groups(
                            &[gi], cfg, sys, now, &mut groups, &mut tenants, q, &frt.slow,
                        );
                        if e.duration_s.is_finite() {
                            q.schedule_in(secs(e.duration_s), Ev::FaultRepair { fault });
                        }
                    }
                    FaultKind::PreprocOutage => {
                        let until = now.saturating_add(secs(e.duration_s));
                        frt.preproc_until[g] = frt.preproc_until[g].max(until);
                        if e.duration_s.is_finite() {
                            q.schedule_in(secs(e.duration_s), Ev::FaultRepair { fault });
                        }
                    }
                    FaultKind::Slowdown { factor } => {
                        frt.slow[g] = frt.slow[g].max(factor);
                        if e.duration_s.is_finite() {
                            q.schedule_in(secs(e.duration_s), Ev::FaultRepair { fault });
                        }
                    }
                    FaultKind::ReconfigAbort => {
                        // Arms: the next committed rebalance dies
                        // mid-drain and rolls back (consumed at the
                        // ReconfigCheck that commits it).
                        frt.abort_arm.push(fault);
                    }
                }
            }
            Ev::FaultDetect { fault } => {
                let g = fspec.schedule.events[fault].gpu;
                // Crashes only, and only if the repair has not already
                // raced the health check (a blip shorter than the
                // detection latency needs no failover).
                if !frt.crashed[g] {
                    continue;
                }
                frt.records[fault].detected_s = Some(to_secs(now));
                // The router learns: dead groups lose their slice clocks
                // and their queued requests flush to survivors (or the
                // admission queue) exactly like a rebalance drain.
                let mut touched = Vec::new();
                for gi in 0..groups.len() {
                    if groups[gi].gpu == g && !groups[gi].slice_free.is_empty() {
                        groups[gi].slice_free.clear();
                        touched.push(gi);
                    }
                }
                settle_groups(
                    &touched, cfg, sys, now, &mut groups, &mut tenants, q, &frt.slow,
                );
                // Failover re-pack: the dead GPU's holdings become
                // pending asks and re-admit through the controller's
                // admission seam — immediately if surviving capacity
                // fits them, else at a later window (or repair).
                if let Some(c) = ctrl.as_mut() {
                    for (ti, n) in c.fail_gpu(g) {
                        for _ in 0..n {
                            pending.push(SliceAsk { tenant: ti, slice: cfg.tenants[ti].slice });
                        }
                    }
                    let mut i = 0;
                    while i < pending.len() {
                        match c.try_admit(pending[i].tenant) {
                            None => i += 1,
                            Some(gpu) => {
                                let ask = pending.remove(i);
                                late_admissions += 1;
                                power.power_on(gpu, now);
                                let avail = now + secs(c.policy().migration_s);
                                grant_slice(
                                    ask.tenant, gpu, avail, cfg, sys, now, &mut groups,
                                    &mut group_of, &mut tenants, q, &frt.slow,
                                );
                            }
                        }
                    }
                }
            }
            Ev::FaultRepair { fault } => {
                let e = fspec.schedule.events[fault];
                let g = e.gpu;
                match e.kind {
                    FaultKind::GpuCrash => {
                        frt.records[fault].repaired_s = Some(to_secs(now));
                        frt.crashed[g] = false;
                        if frt.crash_powered_off[g] {
                            frt.crash_powered_off[g] = false;
                            power.power_on(g, now);
                        }
                        if ctrl.is_some() && recovery.is_some() {
                            // The repaired GPU rejoins empty: capacity
                            // was re-packed at failover, and the
                            // controller may grant into it again (its
                            // old groups revive on the next grant).
                            for grp in groups.iter_mut() {
                                if grp.gpu == g {
                                    grp.failed = false;
                                }
                            }
                            if let Some(c) = ctrl.as_mut() {
                                c.restore_gpu(g);
                            }
                        } else {
                            // No recovery: capacity returns exactly as it
                            // was and the stranded backlog finally drains.
                            let mut touched = Vec::new();
                            for gi in 0..groups.len() {
                                if groups[gi].gpu == g && groups[gi].failed {
                                    groups[gi].failed = false;
                                    touched.push(gi);
                                }
                            }
                            for gi in touched {
                                dispatch_ready(gi, now, &mut groups, &tenants, q, &frt.slow);
                                arm_tick(gi, now, &mut groups, q);
                            }
                        }
                    }
                    FaultKind::SliceFail => {
                        frt.records[fault].repaired_s = Some(to_secs(now));
                        let Some(gi) = frt.slice_victim[fault].take() else {
                            continue;
                        };
                        // If the whole GPU crashed meanwhile, the
                        // GPU-level repair/restore path owns the state.
                        if frt.crashed[g] {
                            continue;
                        }
                        groups[gi].slice_free.push(now);
                        let ti = groups[gi].tenant;
                        if let Some(c) = ctrl.as_mut() {
                            c.note_slice_restored(g, ti);
                        }
                        settle_groups(
                            &[gi], cfg, sys, now, &mut groups, &mut tenants, q, &frt.slow,
                        );
                    }
                    FaultKind::PreprocOutage => {
                        frt.records[fault].repaired_s = Some(to_secs(now));
                    }
                    FaultKind::Slowdown { .. } => {
                        frt.records[fault].repaired_s = Some(to_secs(now));
                        // Overlapping slowdowns: keep the strongest of
                        // whatever is still active on this GPU.
                        frt.slow[g] = fspec
                            .schedule
                            .events
                            .iter()
                            .enumerate()
                            .filter(|&(k, e2)| k != fault && e2.gpu == g)
                            .filter_map(|(_, e2)| match e2.kind {
                                FaultKind::Slowdown { factor }
                                    if secs(e2.at_s) <= now
                                        && now < secs(e2.at_s + e2.duration_s) =>
                                {
                                    Some(factor)
                                }
                                _ => None,
                            })
                            .fold(1.0, f64::max);
                    }
                    FaultKind::ReconfigAbort => {}
                }
            }
            Ev::Retry { tenant, idx, attempt } => {
                // The retry is moot once the request reached a terminal
                // state (a racing completion, or an earlier give-up).
                if tenants[tenant].state[idx] != ReqState::Pending {
                    continue;
                }
                if start_request(
                    tenant, idx, now, cfg, &mut groups, &mut tenants, &mut cpu_pools,
                    &mut dpus, q, &frt.preproc_until,
                ) {
                    // Re-issued: a fresh preprocess + enqueue, exactly
                    // like a client re-submission.
                } else if cfg.admission {
                    obs_defer(&mut tenants[tenant], &mut obs, ctx.tenant_ids[tenant], idx, now);
                } else if let Some(p) = recovery {
                    if attempt + 1 < p.max_retries {
                        tenants[tenant].retries += 1;
                        obs.mark_retry(ctx.tenant_ids[tenant], idx);
                        q.schedule_in(
                            secs(p.timeout_s + p.backoff_delay_s(attempt + 1)),
                            Ev::Retry { tenant, idx, attempt: attempt + 1 },
                        );
                    } else {
                        obs_timeout(
                            &mut tenants[tenant], &mut obs, ctx.tenant_ids[tenant], idx, now,
                        );
                    }
                } else {
                    obs_timeout(&mut tenants[tenant], &mut obs, ctx.tenant_ids[tenant], idx, now);
                }
            }
            Ev::Hedge { tenant, idx } => {
                // Hedge only when the request is still unanswered AND its
                // routed group has failed (possibly still undetected) —
                // the duplicate goes to the tenant's best healthy group.
                let gi = tenants[tenant].routed[idx];
                if tenants[tenant].state[idx] != ReqState::Pending
                    || gi == usize::MAX
                    || !groups[gi].failed
                {
                    continue;
                }
                let mut best = None;
                let mut best_load = f64::INFINITY;
                for &g2 in &tenants[tenant].route {
                    if g2 == gi || groups[g2].failed || groups[g2].slice_free.is_empty() {
                        continue;
                    }
                    let load =
                        groups[g2].outstanding as f64 / groups[g2].slice_free.len() as f64;
                    if load < best_load {
                        best_load = load;
                        best = Some(g2);
                    }
                }
                let Some(g2) = best else {
                    continue;
                };
                tenants[tenant].hedges += 1;
                obs.mark_hedge(ctx.tenant_ids[tenant], idx);
                // The duplicate re-routes and re-preprocesses; whichever
                // copy completes first wins (the loser is discarded by
                // the terminal-state guard at ExecDone).
                tenants[tenant].routed[idx] = g2;
                groups[g2].outstanding += 1;
                let gpu = groups[g2].gpu;
                let len = tenants[tenant].arrivals[idx].1;
                let at = now.max(frt.preproc_until[gpu]);
                match cfg.preproc {
                    PreprocMode::Ideal => q.schedule(at, Ev::PreprocDone { tenant, idx }),
                    PreprocMode::Cpu => {
                        let service =
                            tenants[tenant].spec.cpu_preproc_secs(len.max(0.1));
                        let (_, done) = cpu_pools[gpu].admit(at, service);
                        q.schedule(done, Ev::PreprocDone { tenant, idx });
                    }
                    PreprocMode::Dpu => {
                        let model = cfg.tenants[tenant].model;
                        let done = match dpus[gpu].as_mut() {
                            Some(d) => d.admit(at, model, len.max(0.1)),
                            None => at,
                        };
                        q.schedule(done, Ev::PreprocDone { tenant, idx });
                    }
                }
            }
        }
    }

    // A file-backed arrival source whose trace mutated on disk between the
    // probe and the end of replay has silently diverged from the workload
    // the run was sized for — fail loudly rather than report stats for a
    // hybrid workload nobody asked for.
    for (ti, s) in sources.iter().enumerate() {
        s.verify_source().map_err(|e| {
            e.context(format!(
                "tenant {} (global {}): arrival trace changed during replay",
                ti, ctx.tenant_ids[ti]
            ))
        })?;
    }

    let (reconfigs, migrations, reconfig_events) = match &ctrl {
        Some(c) => (c.events().len() as u64, c.migrations(), c.events().to_vec()),
        None => (0, 0, Vec::new()),
    };
    let (consolidations, consolidation_events) = match &ctrl {
        Some(c) => (c.consolidations(), c.consolidation_events().to_vec()),
        None => (0, Vec::new()),
    };
    let final_alloc = match &ctrl {
        Some(c) => c.alloc().to_vec(),
        None => alloc,
    };

    // Busy GPC-time per local GPU, accumulated in group-creation order
    // (the same order the single-heap run sums it).
    let mut busy_gpc_s = vec![0.0f64; cfg.n_gpus()];
    let mut busy_pw_gpc_s = vec![0.0f64; cfg.n_gpus()];
    for grp in &groups {
        busy_gpc_s[grp.gpu] +=
            grp.busy_ns as f64 * 1e-9 * cfg.tenants[grp.tenant].slice.gpcs as f64;
        busy_pw_gpc_s[grp.gpu] +=
            grp.busy_pw_ns as f64 * 1e-9 * cfg.tenants[grp.tenant].slice.gpcs as f64;
    }

    // Requests still parked in an admission queue never got capacity:
    // they end the run as drops (same post-warmup rule), and the
    // dropped-vs-deferred split lands in each tenant's RunStats. A fault
    // can also strand requests forever (a dead group's backlog when the
    // repair never comes): anything still pending after that is a
    // timed-out request, so conservation stays exact — every arrival is
    // served, dropped, or timed out, exactly once.
    for (ti, ts) in tenants.iter_mut().enumerate() {
        let tg = ctx.tenant_ids[ti];
        let waiting = std::mem::take(&mut ts.deferred_q);
        for idx in waiting {
            obs_drop(ts, &mut obs, tg, idx, horizon);
        }
        for idx in 0..ts.state.len() {
            if ts.state[idx] == ReqState::Pending {
                obs_timeout(ts, &mut obs, tg, idx, horizon);
            }
        }
        ts.stats.dropped = ts.dropped;
        ts.stats.deferred = ts.deferred;
        ts.stats.deferred_served = ts.deferred_served;
        ts.stats.timed_out = ts.timed_out;
        ts.stats.retries = ts.retries;
        ts.stats.hedges = ts.hedges;
        ts.stats.served_degraded = ts.served_degraded;
        // Terminal conservation: every injected arrival is served, dropped
        // or timed out exactly once; the warmup rules' exclusions land in
        // `warmup_skipped`, making the audit identity exact.
        ts.stats.arrivals = ts.state.len() as u64;
        ts.stats.warmup_skipped = ts.warmup_skipped;
        debug_assert!(
            ts.stats.audit().is_ok(),
            "tenant {tg} accounting audit failed: {:?}",
            ts.stats.audit()
        );
    }

    Ok(PartOut {
        tenants,
        late_admissions,
        events,
        horizon,
        downtime,
        reconfigs,
        migrations,
        reconfig_events,
        final_alloc,
        consolidations,
        consolidation_events,
        busy_gpc_s,
        busy_pw_gpc_s,
        cpu_pools,
        dpus,
        power,
        fault_records: frt.records,
        reconfig_aborts: frt.aborts,
        served_by_failed: frt.served_by_failed,
        obs,
    })
}

/// One shard's raw output, local-indexed; [`finalize`] scatters it back
/// onto the global fleet/tenant axes.
struct PartOut {
    tenants: Vec<TenantState>,
    late_admissions: u64,
    events: u64,
    horizon: Nanos,
    downtime: Nanos,
    reconfigs: u64,
    migrations: u64,
    reconfig_events: Vec<ClusterReconfigEvent>,
    final_alloc: Vec<Vec<usize>>,
    consolidations: u64,
    consolidation_events: Vec<ConsolidationEvent>,
    busy_gpc_s: Vec<f64>,
    busy_pw_gpc_s: Vec<f64>,
    cpu_pools: Vec<CpuPool>,
    dpus: Vec<Option<Dpu>>,
    power: GpuPower,
    fault_records: Vec<FaultRecord>,
    reconfig_aborts: u64,
    served_by_failed: u64,
    obs: ObsLog,
}

/// Merge shard outputs into one global [`ClusterOutcome`].
///
/// Scalars sum, timelines concatenate, and every per-GPU / per-tenant
/// series scatters through its shard's id maps. Energy integrates over
/// the GLOBAL horizon: a shard that drained early — or a GPU no shard
/// simulated at all — still pays idle, CPU-reserved and base power to
/// the end of the run, exactly as the single-heap accounting charges an
/// untouched GPU (whose utilizations are all zero).
fn finalize(
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    packing: Packing,
    alloc: Vec<Vec<usize>>,
    parts: &[ShardCtx],
    outs: Vec<PartOut>,
) -> ClusterOutcome {
    let horizon = outs.iter().map(|o| o.horizon).max().unwrap_or(0);
    let usable = sys.hardware.cpu_cores - sys.hardware.cpu_reserved_cores;

    // Scatter the per-GPU utilization inputs to global indices (absent
    // GPUs keep zeros), then run the fleet energy integral: each GPU's
    // class parameters over busy GPC-time and powered-on time, plus its
    // host's CPU cores, DPU and base draw. Power-downs show up as
    // shortened `on_s` — the idle-power elision consolidation buys.
    let mut busy_gpc_s = vec![0.0f64; cfg.n_gpus()];
    let mut busy_pw_gpc_s = vec![0.0f64; cfg.n_gpus()];
    let mut pool_util = vec![0.0f64; cfg.n_gpus()];
    let mut dpu_util = vec![0.0f64; cfg.n_gpus()];
    let mut off_s_gpu = vec![0.0f64; cfg.n_gpus()];
    for (ctx, o) in parts.iter().zip(&outs) {
        for (g, &gg) in ctx.gpu_ids.iter().enumerate() {
            busy_gpc_s[gg] = o.busy_gpc_s[g];
            busy_pw_gpc_s[gg] = o.busy_pw_gpc_s[g];
            pool_util[gg] = o.cpu_pools[g].utilization(horizon);
            if let Some(d) = &o.dpus[g] {
                dpu_util[gg] = d.utilization(horizon);
            }
            off_s_gpu[gg] = o.power.off_secs(g, horizon);
        }
    }
    let em = EnergyModel::new(&sys.energy);
    let horizon_s = to_secs(horizon);
    let mut energy = EnergyBreakdown::default();
    let mut gpu_off_s = 0.0;
    for g in 0..cfg.n_gpus() {
        gpu_off_s += off_s_gpu[g];
        let on_s = (horizon_s - off_s_gpu[g]).max(0.0);
        let (active_j, idle_j) =
            em.gpu_energy_weighted(&cfg.fleet[g], busy_gpc_s[g], busy_pw_gpc_s[g], on_s);
        energy.gpu_active_j += active_j;
        energy.gpu_idle_j += idle_j;
        let pool_busy_s = pool_util[g] * usable as f64 * horizon_s;
        let reserved_s = sys.hardware.cpu_reserved_cores as f64 * horizon_s;
        energy.cpu_j += em.cpu_energy(
            reserved_s + pool_busy_s,
            sys.hardware.cpu_cores as f64 * horizon_s,
        );
        if matches!(cfg.preproc, PreprocMode::Dpu) {
            energy.dpu_j += em.dpu_energy(dpu_util[g], horizon_s);
        }
        energy.base_j += em.base_energy(horizon_s);
    }

    let mut events = 0u64;
    let mut downtime: Nanos = 0;
    let mut late_admissions = 0u64;
    let mut reconfigs = 0u64;
    let mut migrations = 0u64;
    let mut consolidations = 0u64;
    let mut reconfig_aborts = 0u64;
    let mut served_by_failed = 0u64;
    let mut reconfig_events = Vec::new();
    let mut consolidation_events = Vec::new();
    let mut fault_records = Vec::new();
    let mut final_alloc = alloc;
    let nt = cfg.tenants.len();
    let mut dropped = vec![0u64; nt];
    let mut deferred = vec![0u64; nt];
    let mut deferred_served = vec![0u64; nt];
    let mut timed_out = vec![0u64; nt];
    let mut retries = vec![0u64; nt];
    let mut hedges = vec![0u64; nt];
    let mut served_degraded = vec![0u64; nt];
    let mut per_tenant: Vec<Option<(ModelId, RunStats)>> = (0..nt).map(|_| None).collect();
    let mut obs_parts = Vec::new();
    for (ctx, o) in parts.iter().zip(outs.into_iter()) {
        obs_parts.push(o.obs);
        events += o.events;
        downtime += o.downtime;
        late_admissions += o.late_admissions;
        reconfigs += o.reconfigs;
        migrations += o.migrations;
        consolidations += o.consolidations;
        reconfig_aborts += o.reconfig_aborts;
        served_by_failed += o.served_by_failed;
        reconfig_events.extend(o.reconfig_events);
        consolidation_events.extend(o.consolidation_events);
        fault_records.extend(o.fault_records);
        for (g, &gg) in ctx.gpu_ids.iter().enumerate() {
            for (ti, &tg) in ctx.tenant_ids.iter().enumerate() {
                final_alloc[gg][tg] = o.final_alloc[g][ti];
            }
        }
        for (ti, mut ts) in o.tenants.into_iter().enumerate() {
            let tg = ctx.tenant_ids[ti];
            // Degenerate-window throughput guard: a tenant whose
            // completions all land on one timestamp (or that completes a
            // single request) still reports honest QPS over the run.
            ts.stats.note_horizon(horizon);
            dropped[tg] = ts.dropped;
            deferred[tg] = ts.deferred;
            deferred_served[tg] = ts.deferred_served;
            timed_out[tg] = ts.timed_out;
            retries[tg] = ts.retries;
            hedges[tg] = ts.hedges;
            served_degraded[tg] = ts.served_degraded;
            per_tenant[tg] = Some((cfg.tenants[tg].model, ts.stats));
        }
    }

    ClusterOutcome {
        dropped,
        deferred,
        deferred_served,
        timed_out,
        retries,
        hedges,
        served_degraded,
        late_admissions,
        per_tenant: per_tenant
            .into_iter()
            .map(|t| t.expect("every tenant belongs to exactly one shard"))
            .collect(),
        packing,
        horizon,
        events,
        reconfigs,
        migrations,
        reconfig_downtime: downtime,
        reconfig_events,
        final_alloc,
        energy,
        consolidations,
        gpu_off_s,
        consolidation_events,
        mttr_s: mttr_s(&fault_records),
        fault_records,
        reconfig_aborts,
        served_by_failed,
        obs: if cfg.obs.enabled {
            Some(Box::new(ObsLog::merge(cfg.obs, obs_parts)))
        } else {
            None
        },
    }
}

/// Apply a committed move list. Each move drains the donor group's
/// earliest-free slice, pays its outage (repartition in place, migration
/// for a new residency), and hands the slice to the gaining tenant's
/// group on that GPU (created on first residency). Donor groups that lose
/// their last slice re-route their queued requests to the tenant's
/// least-loaded surviving group. Returns the summed per-move outage.
#[allow(clippy::too_many_arguments)]
fn apply_moves(
    moves: &[SliceMove],
    policy: &ReconfigPolicy,
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    now: Nanos,
    groups: &mut Vec<Group>,
    group_of: &mut [Vec<Option<usize>>],
    tenants: &mut [TenantState],
    q: &mut EventQueue<Ev>,
    slow: &[f64],
) -> Nanos {
    let mut downtime: Nanos = 0;
    let mut touched: Vec<usize> = Vec::new();
    for m in moves {
        // Invariant: the controller only plans moves from GPUs the donor
        // holds slices on (its alloc mirror). A divergence — e.g. a
        // fault the controller has not seen yet — skips the move rather
        // than corrupting group state; the mirror re-syncs at detection.
        let donor = match group_of[m.gpu][m.from] {
            Some(g) if !groups[g].slice_free.is_empty() => g,
            _ => {
                debug_assert!(false, "move from a GPU the donor is not on: {m:?}");
                continue;
            }
        };
        // Earliest-free slice drains soonest; it is the one transferred.
        groups[donor].slice_free.sort_unstable();
        let drained = groups[donor].slice_free.remove(0).max(now);
        let avail = drained + secs(m.outage_s(policy));
        downtime += avail - now;

        let gainer = ensure_group(m.to, m.gpu, cfg, sys, groups, group_of, tenants);
        groups[gainer].slice_free.push(avail);
        for g in [donor, gainer] {
            if !touched.contains(&g) {
                touched.push(g);
            }
        }
    }

    settle_groups(&touched, cfg, sys, now, groups, tenants, q, slow);
    downtime
}

/// Post-move settlement shared by rebalances and consolidation: rebuild
/// batching policies for every touched group (Time_queue = Time_knee/n
/// tracks the live slice count in both directions), then re-route the
/// queues of groups that lost their last slice.
#[allow(clippy::too_many_arguments)]
fn settle_groups(
    touched: &[usize],
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    now: Nanos,
    groups: &mut [Group],
    tenants: &mut [TenantState],
    q: &mut EventQueue<Ev>,
    slow: &[f64],
) {
    for &gi in touched {
        let ti = groups[gi].tenant;
        let n = groups[gi].slice_free.len();
        if n > 0 {
            let ts = &tenants[ti];
            let new_policy = build_policy(cfg.policy, sys, ts.spec, &ts.sm, &ts.buckets, n);
            groups[gi].batcher.rebuild(new_policy, now);
            dispatch_ready(gi, now, groups, tenants, q, slow);
            arm_tick(gi, now, groups, q);
        }
    }
    for &gi in touched {
        if !groups[gi].slice_free.is_empty() || groups[gi].batcher.pending() == 0 {
            continue;
        }
        let ti = groups[gi].tenant;
        let target = route(groups, &mut tenants[ti], Routing::ShortestQueue);
        let pending: Vec<Request> = groups[gi]
            .batcher
            .flush(now)
            .into_iter()
            .flat_map(|b| b.requests)
            .collect();
        groups[gi].outstanding = groups[gi].outstanding.saturating_sub(pending.len());
        match target {
            Some(tg) => {
                groups[tg].outstanding += pending.len();
                for r in pending {
                    tenants[ti].routed[r.id as usize] = tg;
                    groups[tg].batcher.enqueue(r);
                }
                dispatch_ready(tg, now, groups, tenants, q, slow);
                arm_tick(tg, now, groups, q);
            }
            // Same no-capacity contract as the Arrival/PreprocDone
            // paths: under admission control the flushed requests wait
            // for re-packed capacity (re-entering as resubmissions),
            // otherwise they are dropped.
            None if cfg.admission => {
                for r in pending {
                    tenants[ti].defer_request(r.id as usize);
                }
            }
            None => {
                for r in pending {
                    tenants[ti].drop_request(r.id as usize);
                }
            }
        }
    }
}

/// Apply a committed consolidation decision.
///
/// * Power-down: every retired replica drains its group's earliest-free
///   slice and is destroyed (scale-in, no spin-up anywhere); every
///   relocation drains the same way and re-appears on its target GPU a
///   `migration_s` outage later (a new residency — weights ship). The
///   victim GPU powers off once its last mover drains; emptied groups
///   re-route exactly like rebalance moves.
/// * Power-up: the GPU powers on at the decision instant and each
///   granted instance becomes serveable after the migration (spin-up)
///   outage.
///
/// Returns the summed relocation/grant outage (retirements remove
/// capacity and charge none).
#[allow(clippy::too_many_arguments)]
fn apply_consolidation(
    action: &ConsolidationAction,
    policy: &ReconfigPolicy,
    cfg: &ClusterConfig,
    sys: &PrebaConfig,
    now: Nanos,
    groups: &mut Vec<Group>,
    group_of: &mut [Vec<Option<usize>>],
    tenants: &mut [TenantState],
    q: &mut EventQueue<Ev>,
    power: &mut GpuPower,
    slow: &[f64],
) -> Nanos {
    let mut downtime: Nanos = 0;
    match action {
        ConsolidationAction::PowerDown { gpu, retire, relocate } => {
            let mut touched: Vec<usize> = Vec::new();
            let touch = |g: usize, touched: &mut Vec<usize>| {
                if !touched.contains(&g) {
                    touched.push(g);
                }
            };
            // The GPU can only power off once its last in-flight work
            // has drained off it. A retire/relocate source the groups no
            // longer hold (controller-mirror divergence — should not
            // happen) is skipped rather than corrupting group state.
            let mut off_at = now;
            for &(g, ti) in retire {
                let gi = match group_of[g][ti] {
                    Some(gi) if !groups[gi].slice_free.is_empty() => gi,
                    _ => {
                        debug_assert!(false, "retire from a GPU tenant {ti} is not on");
                        continue;
                    }
                };
                groups[gi].slice_free.sort_unstable();
                let drained = groups[gi].slice_free.remove(0).max(now);
                if g == *gpu {
                    off_at = off_at.max(drained);
                }
                touch(gi, &mut touched);
            }
            for r in relocate {
                let donor = match group_of[r.from_gpu][r.tenant] {
                    Some(gi) if !groups[gi].slice_free.is_empty() => gi,
                    _ => {
                        debug_assert!(false, "relocate from an absent group: {r:?}");
                        continue;
                    }
                };
                groups[donor].slice_free.sort_unstable();
                let drained = groups[donor].slice_free.remove(0).max(now);
                off_at = off_at.max(drained);
                let avail = drained + secs(policy.migration_s);
                downtime += avail - now;
                let gainer =
                    ensure_group(r.tenant, r.to_gpu, cfg, sys, groups, group_of, tenants);
                groups[gainer].slice_free.push(avail);
                touch(donor, &mut touched);
                touch(gainer, &mut touched);
            }
            settle_groups(&touched, cfg, sys, now, groups, tenants, q, slow);
            power.power_off(*gpu, off_at);
        }
        ConsolidationAction::PowerUp { gpu, grants } => {
            power.power_on(*gpu, now);
            let avail = now + secs(policy.migration_s);
            for &(ti, n) in grants {
                for _ in 0..n {
                    downtime += avail - now;
                    grant_slice(
                        ti, *gpu, avail, cfg, sys, now, groups, group_of, tenants, q, slow,
                    );
                }
            }
        }
    }
    downtime
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_g() -> Slice {
        Slice::new(1, 5)
    }

    fn swin_unit() -> f64 {
        ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0)
    }

    /// Two 4-slice tenants on 2 GPUs; BFD packs 4+3 / 1, so one tenant
    /// spans both GPUs and exercises cross-GPU routing.
    fn two_tenant_cfg() -> ClusterConfig {
        let u = swin_unit();
        let mk = || {
            let mut t =
                ClusterTenant::new(ModelId::SwinTransformer, one_g(), 4, 2.0 * u);
            t.requests = 2000;
            t.sla_ms = 25.0;
            t
        };
        ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::BestFit)
            .tenants(vec![mk(), mk()])
            .build()
    }

    /// Two full-GPU tenants on 2 GPUs: the tenant/GPU graph splits into
    /// two independent components, so auto-sharding actually shards.
    fn disjoint_pair_cfg() -> ClusterConfig {
        let u = swin_unit();
        let mk = || {
            let mut t = ClusterTenant::new(ModelId::SwinTransformer, one_g(), 7, 3.0 * u);
            t.requests = 1500;
            t.sla_ms = 25.0;
            t
        };
        ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::FirstFit)
            .tenants(vec![mk(), mk()])
            .build()
    }

    #[test]
    fn sized_for_matches_the_planner_rule() {
        let u = swin_unit();
        let t = ClusterTenant::sized_for(ModelId::SwinTransformer, one_g(), 3.0 * u, 0.85);
        assert_eq!(t.slices, (3.0f64 / 0.85).ceil() as usize, "rule drifted from the planner");
    }

    #[test]
    fn all_requests_complete_and_nothing_drops() {
        let cfg = two_tenant_cfg();
        let out = run(&cfg, &PrebaConfig::new()).unwrap();
        assert!(out.packing.rejected.is_empty(), "{:?}", out.packing.rejected);
        for (i, (model, stats)) in out.per_tenant.iter().enumerate() {
            let expect = cfg.tenants[i].requests as u64
                - (cfg.tenants[i].requests as f64 * cfg.warmup_frac) as u64;
            assert_eq!(stats.completed, expect, "{model}");
            assert_eq!(out.dropped[i], 0, "{model}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = two_tenant_cfg();
        let sys = PrebaConfig::new();
        let a = run(&cfg, &sys).unwrap();
        let b = run(&cfg, &sys).unwrap();
        assert_eq!(a.horizon, b.horizon);
        assert_eq!(a.events, b.events);
        for ((_, s1), (_, s2)) in a.per_tenant.iter().zip(b.per_tenant.iter()) {
            assert_eq!(s1.p95_ms(), s2.p95_ms());
        }
    }

    /// Obs capture must be a pure observer: enabling it cannot move a
    /// single event or completion, and the windowed series it records
    /// must reconcile exactly with the headline counters.
    #[test]
    fn obs_capture_reconciles_and_does_not_perturb() {
        let sys = PrebaConfig::new();
        let base_cfg = two_tenant_cfg();
        let base = run(&base_cfg, &sys).unwrap();
        assert!(base.obs.is_none(), "obs off by default");
        let mut on_cfg = two_tenant_cfg();
        on_cfg.obs = ObsSpec::on(0.5, 4);
        let on = run(&on_cfg, &sys).unwrap();
        assert_outcomes_identical(&base, &on, "obs on vs off");
        let log = on.obs.as_ref().expect("obs enabled");
        assert_eq!(log.windowed_served_total(), on.completed_total(), "windowed vs headline");
        let (arrivals, ..) = log.windowed_totals();
        let injected: u64 = on_cfg.tenants.iter().map(|t| t.requests as u64).sum();
        assert_eq!(arrivals, injected, "every arrival windowed");
        assert!(!log.spans.is_empty() && !log.segs.is_empty(), "sampled spans + segments");
        on.audit().unwrap();
    }

    /// Obs content is shard- and jobs-invariant: the merged log from a
    /// sharded parallel run matches the single-heap run byte-for-byte
    /// (compared structurally here; the export layer is pure over this).
    #[test]
    fn obs_capture_is_shard_invariant() {
        let sys = PrebaConfig::new();
        let mk = |shards| {
            let mut cfg = disjoint_pair_cfg();
            cfg.obs = ObsSpec::on(0.5, 4);
            cfg.shards = shards;
            cfg
        };
        let serial = run(&mk(1), &sys).unwrap();
        let sharded =
            crate::util::par::with_jobs(4, || run(&mk(0), &sys)).unwrap();
        assert_outcomes_identical(&serial, &sharded, "obs shards 1 vs auto");
        let (a, b) = (serial.obs.as_ref().unwrap(), sharded.obs.as_ref().unwrap());
        assert_eq!(a.tenant_cells, b.tenant_cells, "tenant cells");
        assert_eq!(a.group_cells, b.group_cells, "group cells");
        assert_eq!(a.spans, b.spans, "spans");
        assert_eq!(a.segs, b.segs, "segs");
    }

    #[test]
    fn tenant_without_capacity_drops_all_requests() {
        let u = swin_unit();
        // Second tenant asks a full GPU the 1-GPU inventory cannot host.
        let mut a = ClusterTenant::new(ModelId::SwinTransformer, one_g(), 7, 2.0 * u);
        a.requests = 800;
        let mut b = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(7, 40), 1, u);
        b.requests = 500;
        let cfg = ClusterConfig::builder()
            .gpus(1)
            .strategy(PackStrategy::FirstFit)
            .tenants(vec![a, b])
            .build();
        let out = run(&cfg, &PrebaConfig::new()).unwrap();
        assert_eq!(out.packing.rejected.len(), 1);
        // Post-warmup drops only: 500 requests minus the 5% warmup window.
        let warmup = (500.0 * cfg.warmup_frac) as u64;
        assert_eq!(out.dropped[1], 500 - warmup);
        assert_eq!(out.per_tenant[1].1.completed, 0);
        assert!(out.violation_frac(1, 25.0) == 1.0);
    }

    #[test]
    fn jsq_beats_rr_on_an_asymmetric_split() {
        // FF places the light tenant's 5 slices on GPU0, splitting the hot
        // tenant 2/5 across GPUs. Round-robin halves the hot tenant's load
        // onto the 2-slice group (overload); JSQ balances by backlog. The
        // scenario is the `cluster` experiment's shared constructor so the
        // test and `preba experiment cluster` validate the same fleet.
        let mut cfg = ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::FirstFit)
            .tenants(crate::experiments::cluster::asym_routing_tenants(3.5))
            .build();
        let sys = PrebaConfig::new();
        cfg.routing = Routing::ShortestQueue;
        let jsq = run(&cfg, &sys).unwrap();
        cfg.routing = Routing::RoundRobin;
        let rr = run(&cfg, &sys).unwrap();
        // Hot tenant spans 2 + 5 slices.
        assert_eq!(jsq.final_alloc[0][1], 2, "{:?}", jsq.final_alloc);
        assert_eq!(jsq.final_alloc[1][1], 5);
        assert!(
            jsq.worst_p95_ms() < 0.7 * rr.worst_p95_ms(),
            "jsq {} vs rr {}",
            jsq.worst_p95_ms(),
            rr.worst_p95_ms()
        );
    }

    #[test]
    fn hetero_fleet_rejects_per_gpu_not_fleet_wide() {
        let u = swin_unit();
        // 4g fits the A30 exactly; 7g fits only the A100. With BFD both
        // are admitted; the 7g is *not* rejected just because one class
        // cannot host it.
        let mut a = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(7, 40), 1, 3.0 * u);
        a.requests = 600;
        let mut b = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(4, 20), 1, 2.0 * u);
        b.requests = 600;
        let cfg = ClusterConfig::builder()
            .fleet(vec![GpuClass::A100, GpuClass::A30])
            .strategy(PackStrategy::BestFit)
            .tenants(vec![a, b])
            .build();
        let out = run(&cfg, &PrebaConfig::new()).unwrap();
        assert!(out.packing.rejected.is_empty(), "{:?}", out.packing.rejected);
        assert_eq!(out.final_alloc[0], vec![1, 0], "7g must sit on the A100");
        assert_eq!(out.final_alloc[1], vec![0, 1], "4g must sit on the A30");
        assert_eq!(out.dropped, vec![0, 0]);
        for (model, stats) in &out.per_tenant {
            assert!(stats.completed > 0, "{model}");
        }
    }

    /// The admission-control scenario: tenant A fills a 2-GPU pool with
    /// 14×1g; tenant B's 2×1g ask is rejected at pack time. Without
    /// admission, B's pre-rescue traffic is dropped even though the
    /// controller later migrates slices to B; with admission it waits in
    /// the pending queue and is served late (deferred_served > 0,
    /// strictly fewer drops).
    fn admission_cfg(admission: bool) -> ClusterConfig {
        let u = swin_unit();
        let sys = PrebaConfig::new();
        let horizon = 6.0;
        let mut a =
            ClusterTenant::new(ModelId::SwinTransformer, one_g(), 14, 9.0 * u);
        a.sla_ms = 25.0;
        a.profile = Some(RateProfile::Diurnal {
            base_qps: a.rate_qps,
            amplitude: 0.5,
            period_s: horizon / 2.0,
            phase_frac: 0.0,
        });
        a.requests = (a.rate_qps * horizon).ceil() as usize;
        let mut b = ClusterTenant::new(ModelId::SwinTransformer, one_g(), 2, 2.0 * u);
        b.sla_ms = 25.0;
        b.requests = (b.rate_qps * horizon).ceil() as usize;
        ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::BestFit)
            .tenants(vec![a, b])
            .reconfig(crate::experiments::cluster::policy(&sys))
            .admission(admission)
            .warmup_frac(0.01)
            .build()
    }

    #[test]
    fn admission_converts_drops_into_deferred_served() {
        let sys = PrebaConfig::new();
        let base = run(&admission_cfg(false), &sys).unwrap();
        let adm = run(&admission_cfg(true), &sys).unwrap();
        // The packer rejected B in both runs.
        assert_eq!(base.packing.rejected.len(), 2, "{:?}", base.packing.rejected);
        assert!(base.dropped[1] > 0, "baseline never dropped — scenario broken");
        assert_eq!(base.deferred, vec![0, 0]);
        assert!(adm.deferred[1] > 0, "nothing was deferred");
        assert!(
            adm.deferred_served[1] > 0,
            "admission never served deferred traffic: {:?}",
            adm.deferred
        );
        assert!(
            adm.dropped[1] < base.dropped[1],
            "admission {} vs baseline {} drops",
            adm.dropped[1],
            base.dropped[1]
        );
        assert!(adm.per_tenant[1].1.deferred_served == adm.deferred_served[1]);
        // Conservation: every post-warmup request of B is served or
        // dropped exactly once.
        let cfg = admission_cfg(true);
        let warmup = (cfg.tenants[1].requests as f64 * cfg.warmup_frac) as u64;
        assert_eq!(
            adm.per_tenant[1].1.completed + adm.dropped[1],
            cfg.tenants[1].requests as u64 - warmup,
            "B's accounting leaked requests"
        );
    }

    #[test]
    fn wrr_order_interleaves_proportionally_without_starvation() {
        // Exact slot counts: every tenant appears weight[i] times.
        let order = wrr_order(&[3, 1]);
        assert_eq!(order, vec![0, 0, 1, 0], "smooth-WRR order drifted");
        for (weights, n) in [(vec![5usize, 1, 1], 7usize), (vec![2, 2, 2], 6)] {
            let order = wrr_order(&weights);
            assert_eq!(order.len(), n);
            for (i, &w) in weights.iter().enumerate() {
                assert_eq!(order.iter().filter(|&&t| t == i).count(), w, "tenant {i}");
            }
        }
        // No starvation: with a 100-deep backlog against a 2-deep one,
        // the small tenant's first slot lands near its proportional
        // position, not behind all 100 (FIFO-across-tenants would put it
        // at index 100).
        let order = wrr_order(&[100, 2]);
        let first_b = order.iter().position(|&t| t == 1).unwrap();
        assert!(first_b < 52, "tenant 1 starved until slot {first_b}");
        // Zero-weight tenants never appear; empty input is empty.
        assert!(wrr_order(&[0, 4, 0]).iter().all(|&t| t == 1));
        assert!(wrr_order(&[]).is_empty());
    }

    #[test]
    fn admission_drain_serves_every_deferred_tenant() {
        // The admission scenario with the rejected ask split across TWO
        // tenants: both are parked at pack time, both defer traffic, and
        // the WRR drain + rescue must serve both — neither may starve
        // behind the other's backlog.
        let u = swin_unit();
        let sys = PrebaConfig::new();
        let horizon = 6.0;
        let mut a = ClusterTenant::new(ModelId::SwinTransformer, one_g(), 14, 9.0 * u);
        a.sla_ms = 25.0;
        a.profile = Some(RateProfile::Diurnal {
            base_qps: a.rate_qps,
            amplitude: 0.5,
            period_s: horizon / 2.0,
            phase_frac: 0.0,
        });
        a.requests = (a.rate_qps * horizon).ceil() as usize;
        let mk_small = || {
            let mut t = ClusterTenant::new(ModelId::SwinTransformer, one_g(), 1, 2.0 * u);
            t.sla_ms = 25.0;
            t.requests = (t.rate_qps * horizon).ceil() as usize;
            t
        };
        let cfg = ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::BestFit)
            .tenants(vec![a, mk_small(), mk_small()])
            .reconfig(crate::experiments::cluster::policy(&sys))
            .admission(true)
            .warmup_frac(0.01)
            .build();
        let out = run(&cfg, &sys).unwrap();
        assert_eq!(out.packing.rejected.len(), 2, "{:?}", out.packing.rejected);
        for ti in [1, 2] {
            assert!(out.deferred[ti] > 0, "tenant {ti} never deferred");
            assert!(
                out.deferred_served[ti] > 0,
                "tenant {ti} starved: deferred {} served 0 (other: {:?})",
                out.deferred[ti],
                out.deferred_served
            );
        }
    }

    /// Anti-phase diurnal tenants each owning one full GPU: capacity can
    /// only follow demand by crossing GPUs, so the first rebalance move is
    /// a migration (new residency), and later moves on that GPU are
    /// in-place. Scenario and tuning come from the `cluster` experiment's
    /// shared constructors so this test cannot drift from what
    /// `preba experiment cluster` / `preba cluster` actually run.
    fn antiphase_cfg(online: bool) -> ClusterConfig {
        let sys = PrebaConfig::new();
        let mut cfg = ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::BestFit)
            .tenants(crate::experiments::cluster::antiphase_pair(12.0))
            .build();
        cfg.reconfig = online.then(|| crate::experiments::cluster::policy(&sys));
        cfg
    }

    #[test]
    fn cross_gpu_reconfig_migrates_and_beats_the_static_packing() {
        let sys = PrebaConfig::new();
        let stat = run(&antiphase_cfg(false), &sys).unwrap();
        let online = run(&antiphase_cfg(true), &sys).unwrap();
        assert!(online.reconfigs >= 2, "{:?}", online.reconfig_events);
        assert!(online.migrations >= 1, "never crossed a GPU: {:?}", online.reconfig_events);
        assert!(online.reconfig_downtime > 0);
        assert!(
            online.worst_p95_ms() < stat.worst_p95_ms(),
            "online {} vs static {}",
            online.worst_p95_ms(),
            stat.worst_p95_ms()
        );
        let cfg = antiphase_cfg(true);
        assert!(
            online.max_violation_frac(&cfg.tenants) < stat.max_violation_frac(&cfg.tenants),
            "online {} vs static {}",
            online.max_violation_frac(&cfg.tenants),
            stat.max_violation_frac(&cfg.tenants)
        );
        // Conservation through rebalances: every request completes once.
        for (i, (model, stats)) in online.per_tenant.iter().enumerate() {
            let expect = cfg.tenants[i].requests as u64
                - (cfg.tenants[i].requests as f64 * cfg.warmup_frac) as u64;
            assert_eq!(stats.completed, expect, "{model}");
            assert_eq!(online.dropped[i], 0, "{model}");
        }
    }

    /// Bit-compare the outcome fields that matter across shard layouts.
    fn assert_outcomes_identical(a: &ClusterOutcome, b: &ClusterOutcome, label: &str) {
        assert_eq!(a.events, b.events, "{label}: events");
        assert_eq!(a.horizon, b.horizon, "{label}: horizon");
        assert_eq!(a.dropped, b.dropped, "{label}: dropped");
        assert_eq!(a.final_alloc, b.final_alloc, "{label}: final_alloc");
        assert_eq!(
            a.energy.total_j().to_bits(),
            b.energy.total_j().to_bits(),
            "{label}: energy {} vs {}",
            a.energy.total_j(),
            b.energy.total_j()
        );
        for (ti, ((ma, sa), (mb, sb))) in a.per_tenant.iter().zip(&b.per_tenant).enumerate() {
            assert_eq!(ma, mb, "{label}: tenant {ti} model");
            assert_eq!(sa.completed, sb.completed, "{label}: tenant {ti} completed");
            assert_eq!(
                sa.p95_ms().to_bits(),
                sb.p95_ms().to_bits(),
                "{label}: tenant {ti} p95 {} vs {}",
                sa.p95_ms(),
                sb.p95_ms()
            );
            assert_eq!(
                sa.mean_ms().to_bits(),
                sb.mean_ms().to_bits(),
                "{label}: tenant {ti} mean {} vs {}",
                sa.mean_ms(),
                sb.mean_ms()
            );
        }
    }

    /// The tentpole acceptance invariant: sharding is an execution
    /// strategy, not a model change. `shards = Some(1)` forces the
    /// single-heap identity path; `None` auto-partitions; explicit
    /// counts re-bucket the components. All must agree bit-for-bit.
    #[test]
    fn sharded_runs_match_single_heap_exactly() {
        let sys = PrebaConfig::new();
        for base in [two_tenant_cfg(), disjoint_pair_cfg()] {
            let mut single = base.clone();
            single.shards = Some(1);
            let reference = run(&single, &sys).unwrap();
            for shards in [None, Some(2), Some(4)] {
                let mut cfg = base.clone();
                cfg.shards = shards;
                let out = run(&cfg, &sys).unwrap();
                assert_outcomes_identical(&out, &reference, &format!("shards={shards:?}"));
            }
        }
    }

    /// Auto-sharding must also be invariant to the worker count the
    /// partitions are executed on (`run_jobs` merges in job order).
    #[test]
    fn sharded_run_is_jobs_invariant() {
        let sys = PrebaConfig::new();
        let cfg = disjoint_pair_cfg();
        let serial = crate::util::par::with_jobs(1, || run(&cfg, &sys)).unwrap();
        let parallel = crate::util::par::with_jobs(4, || run(&cfg, &sys)).unwrap();
        assert_outcomes_identical(&serial, &parallel, "jobs 1 vs 4");
    }

    /// The deprecated positional constructors are thin shims over the
    /// builder; both must produce the same config.
    #[test]
    #[allow(deprecated)]
    fn deprecated_ctors_delegate_to_builder() {
        let u = swin_unit();
        let t = ClusterTenant::new(ModelId::SwinTransformer, one_g(), 2, u);
        let a = ClusterConfig::new(2, PackStrategy::BestFit, vec![t.clone()]);
        let b = ClusterConfig::builder()
            .gpus(2)
            .strategy(PackStrategy::BestFit)
            .tenants(vec![t.clone()])
            .build();
        assert_eq!(a.fleet, b.fleet);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.warmup_frac, b.warmup_frac);
        assert_eq!(a.shards, b.shards);
        let c = ClusterConfig::with_fleet(
            vec![GpuClass::A100, GpuClass::A30],
            PackStrategy::FirstFit,
            vec![t],
        );
        assert_eq!(c.fleet, vec![GpuClass::A100, GpuClass::A30]);
        assert!(matches!(c.strategy, PackStrategy::FirstFit));
    }
}
