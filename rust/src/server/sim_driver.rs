//! Discrete-event simulation driver for the PREBA server.
//!
//! Wires workload generator → preprocessing stage (Ideal / CPU pool /
//! DPU) → `DynamicBatcher` → vGPU execution workers over the DES event
//! queue. All the coordinator decisions (bucketing, Batch_max, Time_queue,
//! merging, least-loaded vGPU dispatch) are the same code the real driver
//! uses; only the stage *timings* come from the calibrated models.

use crate::batching::{Batch, BatchPolicy, Bucketizer, DynamicBatcher, QueueParams, Request};
use crate::clock::Nanos;
use crate::config::PrebaConfig;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::metrics::{LatencyParts, RunStats};
use crate::mig::{GpuClass, MigConfig, ServiceModel};
use crate::models::{ModelId, ModelKind};
use crate::obs::{BatchSeg, ObsLog, ObsSpec, Served};
use crate::preprocess::CpuPool;
use crate::dpu::Dpu;
use crate::sim::EventQueue;
use crate::util::Rng;
use crate::workload::{ArrivalStream, Bounded, QueryGen, TraceGen};

use super::PolicyKind;

/// Preprocessing-stage design point (paper §6 nomenclature).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreprocMode {
    /// Oracular upper bound: preprocessing is free ("Ideal").
    Ideal,
    /// Baseline: host CPU pool ("Preprocessing (CPU)").
    Cpu,
    /// PREBA's DPU ("Preprocessing (DPU)").
    Dpu,
}

impl PreprocMode {
    pub fn label(&self) -> &'static str {
        match self {
            PreprocMode::Ideal => "Ideal",
            PreprocMode::Cpu => "Preprocessing (CPU)",
            PreprocMode::Dpu => "Preprocessing (DPU)",
        }
    }
}

/// One simulation run's parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: ModelId,
    pub mig: MigConfig,
    /// How many of the partition's vGPUs host an active inference server
    /// (Fig 9 / Fig 17 sweep this 1..=7).
    pub active_servers: usize,
    pub preproc: PreprocMode,
    pub policy: PolicyKind,
    /// Offered Poisson load, queries/s. Use `saturating_rate` to measure
    /// peak throughput.
    pub rate_qps: f64,
    pub requests: usize,
    pub seed: u64,
    /// Fraction of leading completions excluded from stats.
    pub warmup_frac: f64,
    /// Fix every audio input to this length instead of sampling the
    /// LibriSpeech distribution (the paper's §3 characterization fixes
    /// 2.5 s: "the input audio length is fixed at 2.5 sec").
    pub fixed_len_s: Option<f64>,
    /// Non-stationary traffic profile; `None` = constant Poisson at
    /// `rate_qps` (the MLPerf-server default).
    pub profile: Option<crate::workload::RateProfile>,
    /// Online MIG reconfiguration (`mig::reconfig`): when set, a
    /// controller watches windowed arrival rates and repartitions the GPU
    /// (drain → repartition outage → restart) when the predicted gain
    /// amortizes the cost. `active_servers` is ignored in this mode — the
    /// controller owns the whole GPU.
    pub reconfig: Option<crate::mig::ReconfigPolicy>,
    /// End-to-end SLA the reconfig controller plans against (and the
    /// violation-rate metric uses), ms.
    pub sla_ms: f64,
    /// Observability capture (off by default). When disabled every hook
    /// early-returns and the run is byte-identical to a build without
    /// this field; when enabled the outcome carries an [`ObsLog`].
    pub obs: ObsSpec,
}

impl SimConfig {
    pub fn new(model: ModelId, mig: MigConfig, preproc: PreprocMode) -> SimConfig {
        SimConfig {
            model,
            mig,
            active_servers: mig.vgpus(),
            preproc,
            policy: PolicyKind::Dynamic,
            rate_qps: 0.0, // caller sets or uses saturating_rate
            requests: 20_000,
            seed: 0xBEEF,
            warmup_frac: 0.1,
            fixed_len_s: None,
            profile: None,
            reconfig: None,
            sla_ms: 50.0,
            obs: ObsSpec::default(),
        }
    }

    /// Offered rate that saturates the configured design (~1.25× the
    /// model-execution capacity of the active vGPUs).
    pub fn saturating_rate(&self) -> f64 {
        let sm = ServiceModel::new(self.model.spec(), self.mig.gpcs_per_vgpu());
        let len = match self.model.kind() {
            ModelKind::Vision => 0.0,
            // Mean LibriSpeech-ish length unless pinned.
            ModelKind::Audio => self.fixed_len_s.unwrap_or(10.0),
        };
        1.25 * self.active_servers as f64 * sm.plateau_qps(len)
    }
}

/// Results of a run.
#[derive(Debug)]
pub struct SimOutcome {
    pub stats: RunStats,
    /// Total DES events processed (throughput denominator for the §Perf
    /// events/s metric).
    pub events: u64,
    /// Preprocessing-pool CPU utilization (0 when not in CPU mode).
    pub cpu_util: f64,
    /// Mean busy fraction of the active vGPUs.
    pub gpu_util: f64,
    /// DPU CU utilization (None when no DPU).
    pub dpu_util: Option<f64>,
    /// PCIe bandwidth the DPU used, GB/s.
    pub pcie_gbps: f64,
    /// Virtual time of the last completion.
    pub horizon: Nanos,
    /// Offered load, for reference.
    pub offered_qps: f64,
    /// Committed online reconfigurations (0 without a controller).
    pub reconfigs: u64,
    /// Total decision→restart wall time across reconfigurations (drain +
    /// repartition outage).
    pub reconfig_downtime: Nanos,
    /// Reconfiguration timeline (empty without a controller).
    pub reconfig_events: Vec<crate::mig::reconfig::ReconfigEvent>,
    /// Partition the run ended on (== the configured one without a
    /// controller).
    pub final_mig: MigConfig,
    /// Observability capture; `Some` iff [`SimConfig::obs`] was enabled.
    /// Boxed so the disabled path stays one pointer wide.
    pub obs: Option<Box<ObsLog>>,
}

impl SimOutcome {
    /// Measured throughput (completions over the measurement window).
    pub fn qps(&self) -> f64 {
        self.stats.throughput_qps()
    }

    pub fn p95_ms(&self) -> f64 {
        self.stats.p95_ms()
    }
}

/// Execution length a batch is padded to: the longest member's bucket
/// upper edge under PREBA's bucketed queues, or the longest member itself
/// under the naive single-queue baseline (which pads batch-by-batch).
fn padded_len_of(buckets: &Bucketizer, batch: &Batch) -> f64 {
    if batch.max_len_s <= 0.0 {
        return 0.0; // vision
    }
    let edge = buckets.repr_len(buckets.bucket_of(batch.max_len_s));
    if edge > 0.0 {
        edge.max(batch.max_len_s)
    } else {
        batch.max_len_s
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    PreprocDone(usize),
    /// Re-check batching deadlines.
    BatchTick,
    ExecDone {
        /// Worker that ran the batch (the span's slice id).
        vgpu: usize,
        batch_idx: usize,
    },
    /// Close a telemetry window and ask the reconfig controller for a
    /// decision (scheduled every `ReconfigPolicy::window_s`).
    ReconfigCheck,
    /// The drain + repartition outage finished: bring the new partition
    /// up and resume dispatch.
    ReconfigApply { to: MigConfig },
}

struct ReqState {
    arrival: Nanos,
    len_s: f64,
    preproc_done: Nanos,
}

/// Batching policy for the current partition — shared by the initial
/// build and reconfig-time rebuilds (the Time_queue = Time_knee/n rule
/// depends on the live vGPU count).
fn build_policy(
    policy: PolicyKind,
    sys: &PrebaConfig,
    spec: &'static crate::models::ModelSpec,
    sm: &ServiceModel,
    buckets: &Bucketizer,
    n_vgpus: usize,
) -> BatchPolicy {
    match policy {
        PolicyKind::Static => BatchPolicy::Static(QueueParams {
            batch_max: sys.batching.static_batch_max,
            time_queue: sys.batching.static_time_queue,
        }),
        PolicyKind::Dynamic => {
            let mut p = BatchPolicy::dynamic_from_model(spec, sm, buckets, n_vgpus);
            // Time_queue-rule ablation: rescale every bucket's wait from
            // the paper's /n_vGPUs rule to the configured divisor.
            if let (Some(div), BatchPolicy::Dynamic { per_bucket }) =
                (sys.batching.time_queue_divisor, &mut p)
            {
                for q in per_bucket {
                    q.time_queue =
                        (q.time_queue as f64 * n_vgpus as f64 / div.max(1e-6)) as u64;
                }
            }
            p
        }
    }
}

/// Run one simulation.
pub fn run(cfg: &SimConfig, sys: &PrebaConfig) -> SimOutcome {
    let spec = cfg.model.spec();
    // Under online reconfiguration the controller owns the whole GPU;
    // otherwise the configured partition + active-server count are fixed
    // for the run.
    let mut mig_now = cfg.mig;
    let mut n_vgpus = match cfg.reconfig {
        Some(_) => cfg.mig.vgpus(),
        None => cfg.active_servers.min(cfg.mig.vgpus()).max(1),
    };
    let mut sm = ServiceModel::new(spec, mig_now.gpcs_per_vgpu());
    // Per-(model, profile, batch) performance/energy curve for the live
    // geometry (the exact NEUTRAL constant when `[curves]` is disabled);
    // re-resolved whenever a reconfiguration changes the slice size.
    let mut curve = sys.curves.view(cfg.model, mig_now.gpcs_per_vgpu());

    let mut root_rng = Rng::new(cfg.seed ^ 0x5EED);
    let gen_rng = root_rng.split(1);
    let pool_rng = root_rng.split(2);
    let mut exec_rng = root_rng.split(3);

    // Bucketizer + policy. The naive static baseline batches all lengths
    // in ONE queue (what a stock Triton-style server does); PREBA's
    // dynamic policy gets the per-length bucket queues (paper §4.3).
    let buckets = match (cfg.model.kind(), cfg.policy) {
        (ModelKind::Audio, PolicyKind::Dynamic) => {
            Bucketizer::new(sys.batching.bucket_window_s, sys.batching.max_audio_s)
        }
        _ => Bucketizer::fixed(),
    };
    let policy = build_policy(cfg.policy, sys, spec, &sm, &buckets, n_vgpus);
    let mut batcher =
        DynamicBatcher::new(cfg.model, buckets.clone(), policy, sys.batching.merge_adjacent);

    // Online reconfiguration controller (None = static partition).
    let mut ctrl = cfg.reconfig.clone().map(|policy| {
        let len_s = match cfg.model.kind() {
            ModelKind::Vision => 0.0,
            ModelKind::Audio => cfg.fixed_len_s.unwrap_or(10.0),
        };
        crate::mig::ReconfigController::new(
            vec![crate::mig::TenantSpec { model: cfg.model, sla_ms: cfg.sla_ms, len_s }],
            crate::mig::Plan::single(cfg.mig),
            policy,
        )
    });

    // Preprocessing stage.
    let usable_cores = sys.hardware.cpu_cores - sys.hardware.cpu_reserved_cores;
    let mut cpu_pool = CpuPool::new(usable_cores, pool_rng);
    let mut dpu = match cfg.preproc {
        PreprocMode::Dpu => Some(Dpu::new(&sys.dpu, &sys.hardware)),
        _ => None,
    };

    // vGPU workers: busy-until + accumulated busy ns (plus the
    // power-weighted twin feeding the active-energy integral).
    let mut vgpu_free: Vec<Nanos> = vec![0; n_vgpus];
    let mut vgpu_busy: Vec<u128> = vec![0; n_vgpus];
    let mut vgpu_busy_pw: Vec<u128> = vec![0; n_vgpus];

    // Workload: a bounded pull-based stream. Arrivals are injected into
    // the event heap lazily — at most one is pending outside the heap at
    // a time — so the heap stays O(in-flight events) instead of holding
    // every future arrival up front.
    let gen: Box<dyn ArrivalStream> = match &cfg.profile {
        None => Box::new(QueryGen::new(cfg.model, cfg.rate_qps, gen_rng)),
        Some(profile) => Box::new(TraceGen::new(cfg.model, profile.clone(), gen_rng)),
    };
    let mut source = Bounded::new(gen, cfg.requests);
    let mut peeked = source.next_arrival();

    let mut reqs: Vec<ReqState> = Vec::with_capacity(cfg.requests);

    let mut queue: EventQueue<Ev> = EventQueue::with_capacity(64);
    if let Some(c) = &ctrl {
        queue.schedule(c.window(), Ev::ReconfigCheck);
    }

    let warmup = (cfg.requests as f64 * cfg.warmup_frac) as usize;
    let mut stats = RunStats::new();
    // Reconfiguration state: while a drain is in progress no new batches
    // are dispatched (in-flight ones finish); `busy_folded` accumulates
    // the busy time of torn-down vGPU sets and `cap_ns` integrates
    // capacity (vGPUs × time) across geometry changes so utilization
    // stays meaningful.
    let mut reconfiguring = false;
    let mut downtime: Nanos = 0;
    let mut arrivals_seen: usize = 0;
    let mut busy_folded: u128 = 0;
    // Busy time weighted by the epoch's GPCs-per-vGPU (the energy
    // integral's active-GPC numerator) — folded at geometry changes like
    // `busy_folded`, because a vGPU-nanosecond costs more GPC-power on a
    // coarser partition.
    let mut busy_gpc_folded: u128 = 0;
    let mut busy_pw_gpc_folded: u128 = 0;
    let mut cap_last_change: Nanos = 0;
    let mut cap_ns: u128 = 0;
    // In-flight batch slab: completed slots go on a free list and are
    // reused, so memory stays O(outstanding batches) instead of growing
    // O(total batches) over the run.
    let mut in_flight_batches: Vec<Option<Batch>> = Vec::new();
    let mut free_slots: Vec<usize> = Vec::new();
    // Earliest batching deadline with a BatchTick already scheduled; lets
    // us suppress the redundant tick the old code scheduled on *every*
    // PreprocDone (ticks at or after this deadline would be no-ops).
    let mut armed_tick: Option<Nanos> = None;
    let mut horizon: Nanos = 0;
    let mut completed = 0usize;
    // Observability capture: every hook early-returns when disabled, so
    // the disabled path touches no RNG and schedules no events. `slot_seq`
    // remembers each in-flight slab slot's batch sequence number so the
    // ExecDone span can name the batch that served it.
    let mut obs = ObsLog::new(cfg.obs);
    let mut batch_seq: u64 = 0;
    let mut slot_seq: Vec<u64> = Vec::new();

    // Dispatch a batch to the least-loaded vGPU. Curve-aware: execution
    // stretches by the batch-bucket latency multiplier times the uncore
    // interference penalty (k = sibling vGPUs still executing at start),
    // and the power-weighted busy integral accumulates the matching
    // power multiplier. With curves disabled both multipliers are the
    // exact constant 1.0 and the arithmetic is bit-identical to the flat
    // model.
    let dispatch = |batch: Batch,
                    now: Nanos,
                    vgpu_free: &mut [Nanos],
                    vgpu_busy: &mut [u128],
                    vgpu_busy_pw: &mut [u128],
                    in_flight: &mut Vec<Option<Batch>>,
                    free_slots: &mut Vec<usize>,
                    q: &mut EventQueue<Ev>,
                    exec_rng: &mut Rng,
                    sm: &ServiceModel,
                    buckets: &Bucketizer,
                    curve: &crate::models::CurveView,
                    obs: &mut ObsLog,
                    batch_seq: &mut u64,
                    slot_seq: &mut Vec<u64>,
                    gpcs: usize| {
        let (vgpu, &free) =
            vgpu_free.iter().enumerate().min_by_key(|(_, &t)| t).expect("vgpus");
        let start = now.max(free);
        let k = if curve.contention > 0.0 {
            vgpu_free.iter().enumerate().filter(|&(j, &f)| j != vgpu && f > start).count()
        } else {
            0
        };
        let lat_mult = curve.lat_mult(batch.size()) * curve.penalty(k);
        let pw = curve.pow_mult(batch.size()) * curve.penalty(k);
        let padded_len = padded_len_of(buckets, &batch);
        let exec = crate::clock::secs(
            sm.exec_secs_jittered(batch.size(), padded_len, exec_rng) * lat_mult,
        );
        let done = start + exec;
        vgpu_free[vgpu] = done;
        vgpu_busy[vgpu] += exec as u128;
        vgpu_busy_pw[vgpu] += if pw == 1.0 {
            exec as u128
        } else {
            (exec as f64 * pw).round().max(0.0) as u128
        };
        let seq = *batch_seq;
        *batch_seq += 1;
        obs.on_batch(BatchSeg {
            gpu: 0,
            slice: vgpu,
            tenant: 0,
            seq,
            start,
            end: done,
            size: batch.size(),
            gpcs,
            pw,
            harvested: false,
        });
        let idx = match free_slots.pop() {
            Some(slot) => {
                debug_assert!(in_flight[slot].is_none());
                in_flight[slot] = Some(batch);
                slot
            }
            None => {
                in_flight.push(Some(batch));
                in_flight.len() - 1
            }
        };
        if slot_seq.len() <= idx {
            slot_seq.resize(idx + 1, 0);
        }
        slot_seq[idx] = seq;
        q.schedule(done, Ev::ExecDone { vgpu, batch_idx: idx });
    };

    let mut events: u64 = 0;
    let q = &mut queue;
    loop {
        // Inject every arrival due at or before the next scheduled event;
        // ties go to the arrival, matching the FIFO priority the old
        // pre-scheduled Arrival events had (setup-time sequence numbers).
        while let Some(a) = peeked {
            if q.peek_time().is_some_and(|t| a.at > t) {
                break;
            }
            peeked = source.next_arrival();
            q.advance_to(a.at);
            events += 1;
            let now = a.at;
            let i = reqs.len();
            reqs.push(ReqState {
                arrival: a.at,
                len_s: match (cfg.model.kind(), cfg.fixed_len_s) {
                    (ModelKind::Audio, Some(l)) => l,
                    _ => a.len_s,
                },
                preproc_done: 0,
            });
            arrivals_seen += 1;
            obs.on_arrival(now, 0);
            if let Some(c) = ctrl.as_mut() {
                c.observe_arrival(0);
            }
            let len = reqs[i].len_s;
            match cfg.preproc {
                PreprocMode::Ideal => q.schedule(now, Ev::PreprocDone(i)),
                PreprocMode::Cpu => {
                    let service = spec.cpu_preproc_secs(len.max(0.1));
                    let (_, done) = cpu_pool.admit(now, service);
                    q.schedule(done, Ev::PreprocDone(i));
                }
                PreprocMode::Dpu => {
                    let done = dpu.as_mut().unwrap().admit(now, cfg.model, len.max(0.1));
                    q.schedule(done, Ev::PreprocDone(i));
                }
            }
        }
        let Some((now, ev)) = q.pop() else {
            break;
        };
        events += 1;
        match ev {
            Ev::PreprocDone(i) => {
                reqs[i].preproc_done = now;
                batcher.enqueue(Request {
                    id: i as u64,
                    model: cfg.model,
                    arrival: reqs[i].arrival,
                    enqueued: now,
                    len_s: reqs[i].len_s,
                });
                // During a reconfiguration drain requests queue up in the
                // batcher; ReconfigApply resumes dispatch.
                if !reconfiguring {
                    while let Some((batch, _)) = batcher.try_form(now) {
                        dispatch(
                            batch, now, &mut vgpu_free, &mut vgpu_busy, &mut vgpu_busy_pw,
                            &mut in_flight_batches, &mut free_slots, q, &mut exec_rng, &sm,
                            &buckets, &curve, &mut obs, &mut batch_seq, &mut slot_seq,
                            mig_now.gpcs_per_vgpu(),
                        );
                    }
                    // Arm a tick only when this enqueue moved the earliest
                    // deadline forward; an already-armed earlier (or equal)
                    // tick covers this deadline.
                    if let Some(deadline) = batcher.next_deadline() {
                        if armed_tick.is_none_or(|t| deadline < t) {
                            q.schedule(deadline, Ev::BatchTick);
                            armed_tick = Some(deadline.max(now));
                        }
                    }
                }
            }
            Ev::BatchTick => {
                // The earliest armed tick is the one firing now. Later
                // stale ticks may still sit in the queue; they drain as
                // no-ops. Resetting to None can only over-schedule, never
                // miss a deadline.
                armed_tick = None;
                if !reconfiguring {
                    while let Some((batch, _)) = batcher.try_form(now) {
                        dispatch(
                            batch, now, &mut vgpu_free, &mut vgpu_busy, &mut vgpu_busy_pw,
                            &mut in_flight_batches, &mut free_slots, q, &mut exec_rng, &sm,
                            &buckets, &curve, &mut obs, &mut batch_seq, &mut slot_seq,
                            mig_now.gpcs_per_vgpu(),
                        );
                    }
                    if let Some(deadline) = batcher.next_deadline() {
                        q.schedule(deadline, Ev::BatchTick);
                        armed_tick = Some(deadline.max(now));
                    }
                }
            }
            Ev::ExecDone { vgpu, batch_idx } => {
                let batch = in_flight_batches[batch_idx].take().expect("batch completed twice");
                free_slots.push(batch_idx);
                horizon = horizon.max(now);
                let bsize = batch.size();
                // Split (formed -> done) into dispatch wait + exec:
                // attribute the jitterless model time to execution and the
                // remainder to waiting for a free vGPU. All of this is
                // per-batch, not per-request — hoisted out of the loop.
                let padded_len = padded_len_of(&buckets, &batch);
                let exec_model = crate::clock::secs(sm.exec_secs(bsize, padded_len));
                let since_formed = now.saturating_sub(batch.formed);
                let exec_ns = exec_model.min(since_formed);
                for r in &batch.requests {
                    completed += 1;
                    // Completion-ORDER warmup rule: the first `warmup`
                    // completions are excluded from stats (but still
                    // observable as WARMUP-flagged spans).
                    let counted = completed > warmup;
                    let rs = &reqs[r.id as usize];
                    let parts = LatencyParts {
                        preprocess: rs.preproc_done - rs.arrival,
                        batching: batch.formed.saturating_sub(rs.preproc_done),
                        dispatch_wait: since_formed - exec_ns,
                        execution: exec_ns,
                    };
                    obs.on_served(Served {
                        tenant: 0,
                        idx: r.id as usize,
                        arrival: rs.arrival,
                        done: now,
                        parts,
                        gpu: 0,
                        slice: vgpu,
                        batch: slot_seq[batch_idx],
                        batch_size: bsize,
                        degraded: false,
                        deferred: false,
                        counted,
                    });
                    if counted {
                        stats.record(parts, now, bsize);
                    }
                }
                // Return the request vector to the batcher's pool so the
                // next formation reuses the allocation.
                batcher.recycle(batch);
            }
            Ev::ReconfigCheck => {
                let c = ctrl.as_mut().expect("ReconfigCheck without controller");
                let tail = arrivals_seen >= cfg.requests;
                if reconfiguring || tail {
                    // Keep telemetry rolling, but don't stack a second
                    // reconfiguration on a live drain or on the workload
                    // tail (an empty window would read as rate ~ 0).
                    c.roll_only(now);
                } else if let Some(plan) = c.tick(now) {
                    // Commit: stop dispatching, let in-flight batches
                    // drain, then pay the repartition outage.
                    reconfiguring = true;
                    let drain_end =
                        vgpu_free.iter().copied().max().unwrap_or(now).max(now);
                    let resume =
                        drain_end + crate::clock::secs(c.policy().repartition_s);
                    downtime += resume - now;
                    q.schedule(resume, Ev::ReconfigApply { to: plan.mig });
                }
                if !tail {
                    let w = c.window();
                    q.schedule_in(w, Ev::ReconfigCheck);
                }
            }
            Ev::ReconfigApply { to } => {
                // Fold the old vGPU set's accounting.
                let epoch_busy: u128 = vgpu_busy.iter().sum();
                busy_folded += epoch_busy;
                busy_gpc_folded += epoch_busy * mig_now.gpcs_per_vgpu() as u128;
                busy_pw_gpc_folded +=
                    vgpu_busy_pw.iter().sum::<u128>() * mig_now.gpcs_per_vgpu() as u128;
                cap_ns +=
                    n_vgpus as u128 * (now.saturating_sub(cap_last_change)) as u128;
                cap_last_change = now;
                // Bring up the new partition.
                mig_now = to;
                n_vgpus = to.vgpus();
                sm = ServiceModel::new(spec, to.gpcs_per_vgpu());
                curve = sys.curves.view(cfg.model, to.gpcs_per_vgpu());
                vgpu_free = vec![now; n_vgpus];
                vgpu_busy = vec![0; n_vgpus];
                vgpu_busy_pw = vec![0; n_vgpus];
                // Rebuild the batching policy for the new slice count and
                // carry queued requests over (original enqueue times keep
                // their deadlines honest).
                batcher.rebuild(build_policy(cfg.policy, sys, spec, &sm, &buckets, n_vgpus), now);
                reconfiguring = false;
                // Dispatch whatever became releasable during the outage
                // and re-arm the deadline tick.
                while let Some((batch, _)) = batcher.try_form(now) {
                    dispatch(
                        batch, now, &mut vgpu_free, &mut vgpu_busy, &mut vgpu_busy_pw,
                        &mut in_flight_batches, &mut free_slots, q, &mut exec_rng, &sm,
                        &buckets, &curve, &mut obs, &mut batch_seq, &mut slot_seq,
                        mig_now.gpcs_per_vgpu(),
                    );
                }
                if let Some(deadline) = batcher.next_deadline() {
                    if armed_tick.is_none_or(|t| deadline < t) {
                        q.schedule(deadline, Ev::BatchTick);
                        armed_tick = Some(deadline.max(now));
                    }
                }
            }
        }
    }

    // Close the capacity integral at the horizon (vGPUs × time survives
    // geometry changes); without reconfiguration this reduces to the old
    // `n_vgpus * horizon` denominator.
    cap_ns += n_vgpus as u128 * (horizon.saturating_sub(cap_last_change)) as u128;
    let busy_total = busy_folded + vgpu_busy.iter().sum::<u128>();
    let gpu_util = if cap_ns > 0 {
        (busy_total as f64 / cap_ns as f64).min(1.0)
    } else {
        0.0
    };

    let (reconfigs, reconfig_events) = match &ctrl {
        Some(c) => (c.events().len() as u64, c.events().to_vec()),
        None => (0, Vec::new()),
    };

    // Integrate component energy over the horizon: active GPCs from the
    // folded busy×geometry integral, idle GPCs + uncore for the rest of
    // the (always powered) GPU, the host's preprocessing + reserve cores
    // and base draw, and the DPU when installed.
    let em = EnergyModel::new(&sys.energy);
    let horizon_s = crate::clock::to_secs(horizon);
    let gpu_class =
        GpuClass { name: "a100", gpcs: sys.hardware.gpcs, mem_gb: GpuClass::A100.mem_gb };
    let busy_gpc_total =
        busy_gpc_folded + vgpu_busy.iter().sum::<u128>() * mig_now.gpcs_per_vgpu() as u128;
    let busy_pw_gpc_total = busy_pw_gpc_folded
        + vgpu_busy_pw.iter().sum::<u128>() * mig_now.gpcs_per_vgpu() as u128;
    let (gpu_active_j, gpu_idle_j) = em.gpu_energy_weighted(
        &gpu_class,
        busy_gpc_total as f64 * 1e-9,
        busy_pw_gpc_total as f64 * 1e-9,
        horizon_s,
    );
    let usable_s = usable_cores as f64 * horizon_s;
    let pool_busy_s = match cfg.preproc {
        PreprocMode::Cpu => cpu_pool.utilization(horizon) * usable_s,
        _ => 0.0,
    };
    let reserved_s = sys.hardware.cpu_reserved_cores as f64 * horizon_s;
    stats.note_horizon(horizon);
    stats.energy = EnergyBreakdown {
        gpu_active_j,
        gpu_idle_j,
        cpu_j: em
            .cpu_energy(reserved_s + pool_busy_s, sys.hardware.cpu_cores as f64 * horizon_s),
        dpu_j: dpu
            .as_ref()
            .map_or(0.0, |d| em.dpu_energy(d.utilization(horizon), horizon_s)),
        base_j: em.base_energy(horizon_s),
    };

    // Terminal conservation (satellite invariant): the single-GPU driver
    // has no drops or timeouts, so post-warmup completions plus the
    // warmup-skipped ones must equal the injected arrivals exactly.
    stats.arrivals = reqs.len() as u64;
    stats.warmup_skipped = completed.min(warmup) as u64;
    debug_assert!(stats.audit().is_ok(), "{:?}", stats.audit());

    let obs = if cfg.obs.enabled {
        obs.seal();
        Some(Box::new(obs))
    } else {
        None
    };

    SimOutcome {
        events,
        cpu_util: match cfg.preproc {
            PreprocMode::Cpu => cpu_pool.utilization(horizon),
            _ => 0.0,
        },
        gpu_util,
        dpu_util: dpu.as_ref().map(|d| d.utilization(horizon)),
        pcie_gbps: dpu.as_ref().map(|d| d.pcie_gbps_used(horizon)).unwrap_or(0.0),
        horizon,
        offered_qps: cfg.rate_qps,
        reconfigs,
        reconfig_downtime: downtime,
        reconfig_events,
        final_mig: mig_now,
        stats,
        obs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(model: ModelId, preproc: PreprocMode) -> (SimConfig, PrebaConfig) {
        let mut c = SimConfig::new(model, MigConfig::Small7, preproc);
        c.requests = 4000;
        c.rate_qps = c.saturating_rate();
        (c, PrebaConfig::new())
    }

    #[test]
    fn all_requests_complete() {
        let (cfg, sys) = base_cfg(ModelId::MobileNet, PreprocMode::Ideal);
        let out = run(&cfg, &sys);
        let warmup = (cfg.requests as f64 * cfg.warmup_frac) as u64;
        assert_eq!(out.stats.completed, cfg.requests as u64 - warmup);
    }

    #[test]
    fn cpu_preprocessing_caps_throughput() {
        // Fig 8: with preprocessing on the host CPU, throughput collapses
        // vs Ideal for preprocessing-heavy models.
        let (ci, sys) = base_cfg(ModelId::CitriNet, PreprocMode::Ideal);
        let (cc, _) = base_cfg(ModelId::CitriNet, PreprocMode::Cpu);
        let ideal = run(&ci, &sys).qps();
        let cpu = run(&cc, &sys).qps();
        assert!(cpu < ideal * 0.45, "cpu={cpu} ideal={ideal}");
    }

    #[test]
    fn dpu_restores_near_ideal_throughput() {
        let (ci, sys) = base_cfg(ModelId::CitriNet, PreprocMode::Ideal);
        let (cd, _) = base_cfg(ModelId::CitriNet, PreprocMode::Dpu);
        let ideal = run(&ci, &sys).qps();
        let dpu = run(&cd, &sys).qps();
        assert!(dpu > ideal * 0.85, "dpu={dpu} ideal={ideal}");
    }

    #[test]
    fn cpu_pool_saturates_near_90pct() {
        let (cfg, sys) = base_cfg(ModelId::ConformerSmall, PreprocMode::Cpu);
        let out = run(&cfg, &sys);
        assert!(out.cpu_util > 0.85, "cpu_util={}", out.cpu_util);
    }

    #[test]
    fn vision_vs_audio_modes_run() {
        for m in [ModelId::SqueezeNet, ModelId::ConformerDefault] {
            for p in [PreprocMode::Ideal, PreprocMode::Cpu, PreprocMode::Dpu] {
                let (mut cfg, sys) = base_cfg(m, p);
                cfg.requests = 1200;
                let out = run(&cfg, &sys);
                assert!(out.qps() > 0.0, "{m} {p:?}");
                assert!(out.p95_ms() > 0.0);
            }
        }
    }

    #[test]
    fn tick_suppression_bounds_event_count() {
        // Every request contributes one Arrival, one PreprocDone and a
        // share of an ExecDone (~3N total); with redundant BatchTicks
        // suppressed the tick population must stay well under one per
        // request rather than the old one-per-PreprocDone.
        let (cfg, sys) = base_cfg(ModelId::CitriNet, PreprocMode::Dpu);
        let out = run(&cfg, &sys);
        assert!(out.events > 2 * cfg.requests as u64, "events={}", out.events);
        assert!(
            out.events < 4 * cfg.requests as u64 + 64,
            "events={} for {} requests — BatchTick dedupe regressed?",
            out.events,
            cfg.requests
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, sys) = base_cfg(ModelId::MobileNet, PreprocMode::Dpu);
        let a = run(&cfg, &sys);
        let b = run(&cfg, &sys);
        assert_eq!(a.qps(), b.qps());
        assert_eq!(a.p95_ms(), b.p95_ms());
        assert_eq!(a.horizon, b.horizon);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn obs_capture_reconciles_and_does_not_perturb() {
        let (cfg, sys) = base_cfg(ModelId::MobileNet, PreprocMode::Dpu);
        let base = run(&cfg, &sys);
        assert!(base.obs.is_none(), "obs is off by default");
        let mut on = cfg.clone();
        on.obs = ObsSpec::on(0.5, 4);
        let traced = run(&on, &sys);
        // Enabling capture must not perturb the simulation.
        assert_eq!(traced.stats.completed, base.stats.completed);
        assert_eq!(traced.p95_ms(), base.p95_ms());
        assert_eq!(traced.horizon, base.horizon);
        assert_eq!(traced.events, base.events);
        let log = traced.obs.expect("enabled run carries a log");
        // Windowed cells reconcile with the run's own counters.
        assert_eq!(log.windowed_served_total(), traced.stats.completed);
        let (arrivals, _, dropped, timed_out, _) = log.windowed_totals();
        assert_eq!(arrivals, cfg.requests as u64);
        assert_eq!(dropped + timed_out, 0);
        assert!(!log.spans.is_empty(), "1-in-4 sampling captured spans");
        assert!(!log.segs.is_empty(), "dispatch recorded batch segments");
    }

    #[test]
    fn dynamic_policy_beats_static_on_tail_latency() {
        // Fig 22's software ablation, in miniature: at moderate load the
        // dynamic policy should cut tail latency vs a naive static batcher.
        let mut cfg =
            SimConfig::new(ModelId::ConformerDefault, MigConfig::Small7, PreprocMode::Dpu);
        cfg.requests = 4000;
        cfg.rate_qps = 0.7 * cfg.saturating_rate() / 1.25;
        let sys = PrebaConfig::new();
        let dyn_out = run(&cfg, &sys);
        cfg.policy = PolicyKind::Static;
        let static_out = run(&cfg, &sys);
        assert!(
            dyn_out.p95_ms() < static_out.p95_ms(),
            "dynamic {} vs static {}",
            dyn_out.p95_ms(),
            static_out.p95_ms()
        );
    }

    #[test]
    fn online_reconfig_rescues_a_bad_static_partition() {
        // A full-GPU deployment offered ~95% of its plateau runs past its
        // batch-limited sustained capacity and diverges; the online
        // controller should repartition to 1g.5gb(7x) (higher aggregate
        // capacity, paper Fig 5) within a few windows and keep the tail
        // bounded. The static run is identical except reconfig is off.
        let sys = PrebaConfig::new();
        let mut cfg =
            SimConfig::new(ModelId::SwinTransformer, MigConfig::Full1, PreprocMode::Ideal);
        cfg.requests = 4000;
        cfg.rate_qps =
            0.95 * crate::mig::ServiceModel::new(cfg.model.spec(), 7).plateau_qps(0.0);
        cfg.sla_ms = 50.0;
        let static_out = run(&cfg, &sys);
        cfg.reconfig = Some(crate::mig::ReconfigPolicy::default());
        let online = run(&cfg, &sys);
        assert!(online.reconfigs >= 1, "controller never repartitioned");
        assert_eq!(online.final_mig, MigConfig::Small7, "{:?}", online.reconfig_events);
        assert!(online.reconfig_downtime > 0);
        // Conservation: every request still completes exactly once.
        let warmup = (cfg.requests as f64 * cfg.warmup_frac) as u64;
        assert_eq!(online.stats.completed, cfg.requests as u64 - warmup);
        assert!(
            online.p95_ms() < static_out.p95_ms(),
            "online {} vs static {}",
            online.p95_ms(),
            static_out.p95_ms()
        );
        assert!(
            online.stats.sla_violation_frac(cfg.sla_ms)
                <= static_out.stats.sla_violation_frac(cfg.sla_ms),
            "online {} vs static {}",
            online.stats.sla_violation_frac(cfg.sla_ms),
            static_out.stats.sla_violation_frac(cfg.sla_ms)
        );
    }

    #[test]
    fn reconfig_stays_put_on_well_partitioned_constant_load() {
        // 1g.5gb(7x) at a comfortable constant load is already the best
        // partition; hysteresis must keep the controller from thrashing.
        let sys = PrebaConfig::new();
        let mut cfg =
            SimConfig::new(ModelId::SwinTransformer, MigConfig::Small7, PreprocMode::Ideal);
        cfg.requests = 4000;
        cfg.rate_qps = 0.6 * cfg.saturating_rate() / 1.25;
        cfg.reconfig = Some(crate::mig::ReconfigPolicy::default());
        let out = run(&cfg, &sys);
        assert_eq!(out.reconfigs, 0, "{:?}", out.reconfig_events);
        assert_eq!(out.final_mig, MigConfig::Small7);
        assert_eq!(out.reconfig_downtime, 0);
    }

    #[test]
    fn reconfig_runs_deterministic_given_seed() {
        let sys = PrebaConfig::new();
        let mut cfg =
            SimConfig::new(ModelId::SwinTransformer, MigConfig::Full1, PreprocMode::Ideal);
        cfg.requests = 2000;
        cfg.rate_qps =
            0.95 * crate::mig::ServiceModel::new(cfg.model.spec(), 7).plateau_qps(0.0);
        cfg.reconfig = Some(crate::mig::ReconfigPolicy::default());
        let a = run(&cfg, &sys);
        let b = run(&cfg, &sys);
        assert_eq!(a.p95_ms(), b.p95_ms());
        assert_eq!(a.horizon, b.horizon);
        assert_eq!(a.reconfigs, b.reconfigs);
        assert_eq!(a.reconfig_downtime, b.reconfig_downtime);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn energy_integrates_per_mode() {
        let (ci, sys) = base_cfg(ModelId::CitriNet, PreprocMode::Ideal);
        let (cc, _) = base_cfg(ModelId::CitriNet, PreprocMode::Cpu);
        let (cd, _) = base_cfg(ModelId::CitriNet, PreprocMode::Dpu);
        let ideal = run(&ci, &sys);
        let cpu = run(&cc, &sys);
        let dpu = run(&cd, &sys);
        for out in [&ideal, &cpu, &dpu] {
            assert!(out.stats.energy_j() > 0.0);
            assert!(out.stats.joules_per_query() > 0.0);
            assert!(out.stats.perf_per_watt() > 0.0);
        }
        // The DPU draws power only when installed.
        assert_eq!(ideal.stats.energy.dpu_j, 0.0);
        assert_eq!(cpu.stats.energy.dpu_j, 0.0);
        assert!(dpu.stats.energy.dpu_j > 0.0);
        // Host preprocessing burns cores: the CPU design's mean host
        // power must exceed Ideal's idle-floor draw.
        let mean_cpu_w =
            |o: &SimOutcome| o.stats.energy.cpu_j / crate::clock::to_secs(o.horizon);
        assert!(
            mean_cpu_w(&cpu) > 1.5 * mean_cpu_w(&ideal),
            "cpu {} vs ideal {}",
            mean_cpu_w(&cpu),
            mean_cpu_w(&ideal)
        );
        // The paper's §6.2 direction: offloading preprocessing makes the
        // system far more energy-efficient at saturation.
        assert!(
            dpu.stats.perf_per_watt() > 2.0 * cpu.stats.perf_per_watt(),
            "dpu {} vs cpu {}",
            dpu.stats.perf_per_watt(),
            cpu.stats.perf_per_watt()
        );
    }

    #[test]
    fn full_gpu_needs_bigger_batches_than_slices() {
        let mut small = SimConfig::new(ModelId::MobileNet, MigConfig::Small7, PreprocMode::Ideal);
        small.requests = 4000;
        small.rate_qps = small.saturating_rate();
        let mut full = SimConfig::new(ModelId::MobileNet, MigConfig::Full1, PreprocMode::Ideal);
        full.requests = 4000;
        full.rate_qps = full.saturating_rate();
        let sys = PrebaConfig::new();
        let s = run(&small, &sys);
        let f = run(&full, &sys);
        assert!(
            f.stats.batch_sizes.mean() > 3.0 * s.stats.batch_sizes.mean(),
            "full {} vs small {}",
            f.stats.batch_sizes.mean(),
            s.stats.batch_sizes.mean()
        );
    }
}
