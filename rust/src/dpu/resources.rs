//! FPGA resource budget — the Table 1 analogue.
//!
//! The paper reports post-implementation Vitis HLS resource utilization of
//! each functional unit on the Alveo U55C. We reproduce the table verbatim
//! as the DPU's resource model and use it to check that the configured CU
//! counts fit the card — plus, for the TPU adaptation, each row carries
//! the Pallas-kernel VMEM footprint and MXU-utilization estimate derived
//! from the kernel BlockSpecs (DESIGN.md §Hardware-Adaptation, §Perf).

use crate::config::DpuConfig;
use crate::preprocess::pipeline::StageKind;

/// One functional unit's resource usage, in % of the U55C.
#[derive(Debug, Clone, Copy)]
pub struct ResourceRow {
    pub app: &'static str,
    pub unit: &'static str,
    pub stage: StageKind,
    pub lut_pct: f64,
    pub reg_pct: f64,
    pub bram_pct: f64,
    pub uram_pct: f64,
    pub dsp_pct: f64,
    /// Pallas-kernel VMEM working set for this unit's tile, KiB
    /// (estimated from the kernel BlockSpec; see python/compile/kernels/).
    pub vmem_kib: f64,
    /// Estimated MXU utilization of the unit's Pallas matmul core
    /// (fraction of peak; element-wise units are VPU-bound, ~0).
    pub mxu_util: f64,
}

/// Paper Table 1 (single-CU utilization of the U55C), extended with the
/// TPU-adaptation columns.
pub fn resource_table() -> Vec<ResourceRow> {
    use StageKind::*;
    vec![
        ResourceRow {
            app: "Image",
            unit: "Decode",
            stage: Decode,
            lut_pct: 19.7,
            reg_pct: 8.6,
            bram_pct: 0.7,
            uram_pct: 22.5,
            dsp_pct: 6.2,
            vmem_kib: 288.0,
            mxu_util: 0.31,
        },
        ResourceRow {
            app: "Image",
            unit: "Resize",
            stage: Resize,
            lut_pct: 7.1,
            reg_pct: 2.3,
            bram_pct: 0.0,
            uram_pct: 0.0,
            dsp_pct: 8.6,
            vmem_kib: 412.0,
            mxu_util: 0.24,
        },
        ResourceRow {
            app: "Image",
            unit: "Crop",
            stage: Crop,
            lut_pct: 0.6,
            reg_pct: 0.4,
            bram_pct: 0.0,
            uram_pct: 0.0,
            dsp_pct: 0.0,
            vmem_kib: 48.0,
            mxu_util: 0.0,
        },
        ResourceRow {
            app: "Image",
            unit: "Normalize",
            stage: NormalizeImage,
            lut_pct: 13.0,
            reg_pct: 3.3,
            bram_pct: 11.2,
            uram_pct: 0.0,
            dsp_pct: 3.0,
            vmem_kib: 48.0,
            mxu_util: 0.0,
        },
        ResourceRow {
            app: "Audio",
            unit: "Resample",
            stage: Resample,
            lut_pct: 0.2,
            reg_pct: 0.1,
            bram_pct: 1.0,
            uram_pct: 0.0,
            dsp_pct: 0.0,
            vmem_kib: 96.0,
            mxu_util: 0.08,
        },
        ResourceRow {
            app: "Audio",
            unit: "Mel spectrogram",
            stage: MelSpectrogram,
            lut_pct: 41.5,
            reg_pct: 24.6,
            bram_pct: 18.2,
            uram_pct: 37.5,
            dsp_pct: 34.2,
            vmem_kib: 1620.0,
            mxu_util: 0.47,
        },
        ResourceRow {
            app: "Audio",
            unit: "Normalize",
            stage: NormalizeAudio,
            lut_pct: 3.1,
            reg_pct: 1.7,
            bram_pct: 1.7,
            uram_pct: 7.5,
            dsp_pct: 1.3,
            vmem_kib: 84.0,
            mxu_util: 0.0,
        },
    ]
}

/// Sum a resource column over an app's units (the Table 1 "Total" rows).
pub fn totals(app: &str) -> (f64, f64, f64, f64, f64) {
    resource_table().iter().filter(|r| r.app == app).fold(
        (0.0, 0.0, 0.0, 0.0, 0.0),
        |(l, r2, b, u, d), row| {
            (l + row.lut_pct, r2 + row.reg_pct, b + row.bram_pct, u + row.uram_pct, d + row.dsp_pct)
        },
    )
}

/// Do the configured CU counts fit the FPGA? Each additional CU replicates
/// its units' resources. The image CU carries all four image units; the
/// audio split CUs carry their respective subsets.
pub fn fits_fpga(cfg: &DpuConfig) -> bool {
    let t = resource_table();
    let find = |app: &str, unit: &str| t.iter().find(|r| r.app == app && r.unit == unit).unwrap();

    // LUTs are the binding resource on the U55C for this design (Table 1).
    let image_cu_lut = totals("Image").0;
    let mel_cu_lut = find("Audio", "Resample").lut_pct + find("Audio", "Mel spectrogram").lut_pct;
    let norm_cu_lut = find("Audio", "Normalize").lut_pct;

    // The paper deploys the image and audio DPUs as separate bitstreams
    // (Table 1 reports them separately), so each modality gets the full
    // card budget.
    let lut_image = cfg.image_cus as f64 * image_cu_lut;
    let lut_audio =
        cfg.audio_mel_cus as f64 * mel_cu_lut + cfg.audio_norm_cus as f64 * norm_cu_lut;
    lut_image <= 100.0 && lut_audio <= 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_totals_match_paper() {
        // Paper Table 1 totals: Image 44.5/16.5(REG sums 14.6 in the
        // per-row arithmetic; the paper's 16.5 includes interconnect —
        // we check LUT exactly and others loosely).
        let (lut, _reg, _bram, uram, _dsp) = totals("Image");
        assert!((lut - 40.4).abs() < 0.01, "image LUT sum {lut}");
        assert!((uram - 22.5).abs() < 0.01);
        let (lut_a, _, _, uram_a, dsp_a) = totals("Audio");
        assert!((lut_a - 44.8).abs() < 0.01, "audio LUT sum {lut_a}");
        assert!((uram_a - 45.0).abs() < 0.01);
        assert!((dsp_a - 35.5).abs() < 0.01);
    }

    #[test]
    fn default_config_fits() {
        assert!(fits_fpga(&DpuConfig::default()));
    }

    #[test]
    fn absurd_config_rejected() {
        let mut cfg = DpuConfig::default();
        cfg.image_cus = 5; // 5 x 40.4% LUT > 100%
        assert!(!fits_fpga(&cfg));
    }

    #[test]
    fn mel_unit_dominates_audio_resources() {
        // The paper's Mel spectrogram unit is by far the largest — the
        // motivation for replicating the mel CU, not the norm CU.
        let t = resource_table();
        let mel = t.iter().find(|r| r.unit == "Mel spectrogram").unwrap();
        let norm =
            t.iter().find(|r| r.app == "Audio" && r.unit == "Normalize").unwrap();
        assert!(mel.lut_pct > 10.0 * norm.lut_pct);
    }

    #[test]
    fn every_stage_has_a_row() {
        use StageKind::*;
        let t = resource_table();
        for k in [Decode, Resize, Crop, NormalizeImage, Resample, MelSpectrogram, NormalizeAudio] {
            assert!(t.iter().any(|r| r.stage == k), "{k:?}");
        }
    }
}
