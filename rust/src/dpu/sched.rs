//! DPU CU scheduling and timing (paper Fig 11/12).

use crate::clock::{secs, Nanos};
use crate::config::{DpuConfig, HardwareConfig};
use crate::models::{ModelId, ModelKind};
use crate::preprocess::pipeline::{self, StageKind};

/// The CU types the DPU instantiates (Fig 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CuKind {
    /// All four image units in one CU (sequential dataflow pipelines
    /// cleanly — Fig 12a).
    Image,
    /// Monolithic audio CU: Resample + Mel + Normalize in one CU; the
    /// Normalize full-input dependency stalls the pipeline (Fig 12b).
    AudioMonolithic,
    /// Split design, first CU type: Resample + Mel spectrogram.
    AudioMel,
    /// Split design, second CU type: Normalize.
    AudioNorm,
}

/// Which audio design the DPU is built with (ablation: Fig 12 b vs c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DpuDesign {
    Monolithic,
    Split,
}

/// Per-CU timing for one single-input request of `len_s` seconds.
///
/// * `latency` — time the request occupies the CU output path (sum of the
///   stages the CU runs).
/// * `ii` — initiation interval: how long until the CU can accept the
///   next request. A pipelined CU's II is its slowest stage; a stalled
///   (monolithic audio) CU's II is its full latency.
#[derive(Debug, Clone, Copy)]
pub struct CuTiming {
    pub latency_s: f64,
    pub ii_s: f64,
}

/// Timing of a CU kind for a request of `len_s` (audio) / fixed image.
pub fn cu_timing(kind: CuKind, len_s: f64) -> CuTiming {
    let model = match kind {
        CuKind::Image => ModelId::MobileNet, // any vision id: same pipeline
        _ => ModelId::CitriNet,              // any audio id: same pipeline
    };
    let stage = |k: StageKind| {
        pipeline::stages_for(model)
            .iter()
            .find(|s| s.kind == k)
            .map(|s| pipeline::stage_secs(model, s, len_s))
            .expect("stage present")
    };
    match kind {
        CuKind::Image => {
            let total: f64 = pipeline::stages_for(model)
                .iter()
                .map(|s| pipeline::stage_secs(model, s, len_s))
                .sum();
            let slowest = pipeline::stages_for(model)
                .iter()
                .map(|s| pipeline::stage_secs(model, s, len_s))
                .fold(0.0, f64::max);
            CuTiming { latency_s: total, ii_s: slowest }
        }
        CuKind::AudioMonolithic => {
            // Fig 12b: Normalize cannot start until Resample+Mel finished
            // the WHOLE input, and the next request cannot enter while any
            // unit is mid-request => II == full latency.
            let total = stage(StageKind::Resample)
                + stage(StageKind::MelSpectrogram)
                + stage(StageKind::NormalizeAudio);
            CuTiming { latency_s: total, ii_s: total }
        }
        CuKind::AudioMel => {
            let lat = stage(StageKind::Resample) + stage(StageKind::MelSpectrogram);
            // Resample/Mel stream sample groups (Fig 12c: S_i pipelined),
            // so the CU initiates the next request after its slowest unit.
            let ii = stage(StageKind::Resample).max(stage(StageKind::MelSpectrogram));
            CuTiming { latency_s: lat, ii_s: ii }
        }
        CuKind::AudioNorm => {
            let t = stage(StageKind::NormalizeAudio);
            CuTiming { latency_s: t, ii_s: t }
        }
    }
}

/// One CU instance's occupancy state.
#[derive(Debug, Clone)]
struct Cu {
    kind: CuKind,
    /// Earliest time the CU can initiate the next request.
    next_free: Nanos,
    busy_ns: u128,
}

/// The DPU: a set of CU instances + PCIe transfer model.
#[derive(Debug)]
pub struct Dpu {
    cus: Vec<Cu>,
    design: DpuDesign,
    dispatch_overhead: Nanos,
    pcie_latency: Nanos,
    pcie_gbps: f64,
    /// Total bytes moved over PCIe (for the bandwidth report, §4.2).
    pub pcie_bytes: u128,
    pub served: u64,
}

impl Dpu {
    pub fn new(cfg: &DpuConfig, hw: &HardwareConfig) -> Dpu {
        let design = if cfg.split_audio_cu { DpuDesign::Split } else { DpuDesign::Monolithic };
        let mut cus = Vec::new();
        for _ in 0..cfg.image_cus {
            cus.push(Cu { kind: CuKind::Image, next_free: 0, busy_ns: 0 });
        }
        match design {
            DpuDesign::Split => {
                for _ in 0..cfg.audio_mel_cus {
                    cus.push(Cu { kind: CuKind::AudioMel, next_free: 0, busy_ns: 0 });
                }
                for _ in 0..cfg.audio_norm_cus {
                    cus.push(Cu { kind: CuKind::AudioNorm, next_free: 0, busy_ns: 0 });
                }
            }
            DpuDesign::Monolithic => {
                // Same silicon budget: monolithic CUs replace the mel CUs.
                for _ in 0..cfg.audio_mel_cus {
                    cus.push(Cu { kind: CuKind::AudioMonolithic, next_free: 0, busy_ns: 0 });
                }
            }
        }
        Dpu {
            cus,
            design,
            dispatch_overhead: cfg.cu_dispatch_overhead,
            pcie_latency: hw.pcie_latency,
            pcie_gbps: hw.pcie_gbps,
            pcie_bytes: 0,
            served: 0,
        }
    }

    pub fn design(&self) -> DpuDesign {
        self.design
    }

    /// PCIe time to move `bytes` one way.
    fn xfer(&self, bytes: u64) -> Nanos {
        self.pcie_latency + secs(bytes as f64 / (self.pcie_gbps * 1e9))
    }

    /// Earliest-free CU of a kind; returns its index.
    fn pick(&self, kind: CuKind) -> Option<usize> {
        self.cus
            .iter()
            .enumerate()
            .filter(|(_, c)| c.kind == kind)
            .min_by_key(|(_, c)| c.next_free)
            .map(|(i, _)| i)
    }

    /// Run one stage-set on a CU kind: occupy the earliest-free CU,
    /// starting no earlier than `ready`, return (start, done).
    fn run_on(&mut self, kind: CuKind, ready: Nanos, len_s: f64) -> (Nanos, Nanos) {
        let t = cu_timing(kind, len_s);
        let idx = self.pick(kind).unwrap_or_else(|| panic!("no CU of kind {kind:?}"));
        let cu = &mut self.cus[idx];
        let start = ready.max(cu.next_free);
        let done = start + secs(t.latency_s);
        cu.next_free = start + secs(t.ii_s);
        cu.busy_ns += secs(t.ii_s) as u128;
        (start, done)
    }

    /// Preprocess one single-input request on the DPU. Returns the time
    /// the preprocessed tensor is back in host memory.
    ///
    /// Timeline: host→DPU PCIe in → CU pipeline (one or two CU types) →
    /// DPU→host PCIe out (paper: DPU→CPU→GPU; the extra hop is tens of µs
    /// and modeled in `xfer`).
    pub fn admit(&mut self, now: Nanos, model: ModelId, len_s: f64) -> Nanos {
        let spec = model.spec();
        let in_ready = now + self.dispatch_overhead + self.xfer(spec.raw_input_bytes);
        let done = match model.kind() {
            ModelKind::Vision => self.run_on(CuKind::Image, in_ready, len_s).1,
            ModelKind::Audio => match self.design {
                DpuDesign::Monolithic => self.run_on(CuKind::AudioMonolithic, in_ready, len_s).1,
                DpuDesign::Split => {
                    // Fig 12c: fine-grained scheduling across the two CU
                    // types — Normalize starts as soon as Mel finishes.
                    let (_, mel_done) = self.run_on(CuKind::AudioMel, in_ready, len_s);
                    self.run_on(CuKind::AudioNorm, mel_done, len_s).1
                }
            },
        };
        self.pcie_bytes += (spec.raw_input_bytes + spec.tensor_bytes) as u128;
        self.served += 1;
        done + self.xfer(spec.tensor_bytes)
    }

    /// Aggregate preprocessing throughput bound for a modality, req/s
    /// (sum over that modality's bottleneck CU type of 1/II).
    pub fn capacity_qps(&self, kind: ModelKind, len_s: f64) -> f64 {
        let per_kind = |k: CuKind| -> f64 {
            let n = self.cus.iter().filter(|c| c.kind == k).count() as f64;
            n / cu_timing(k, len_s).ii_s
        };
        match kind {
            ModelKind::Vision => per_kind(CuKind::Image),
            ModelKind::Audio => match self.design {
                DpuDesign::Monolithic => per_kind(CuKind::AudioMonolithic),
                DpuDesign::Split => per_kind(CuKind::AudioMel).min(per_kind(CuKind::AudioNorm)),
            },
        }
    }

    /// Mean CU utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: Nanos) -> f64 {
        if horizon == 0 || self.cus.is_empty() {
            return 0.0;
        }
        let busy: u128 = self.cus.iter().map(|c| c.busy_ns).sum();
        (busy as f64 / (horizon as f64 * self.cus.len() as f64)).min(1.0)
    }

    /// Average PCIe bandwidth used over `[0, horizon]`, GB/s (paper §4.2
    /// reports 6.13 / 0.9 GB/s for MobileNet / CitriNet).
    pub fn pcie_gbps_used(&self, horizon: Nanos) -> f64 {
        if horizon == 0 {
            return 0.0;
        }
        self.pcie_bytes as f64 / (horizon as f64 * 1e-9) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::to_millis;
    use crate::config::{DpuConfig, HardwareConfig};

    fn mk(split: bool) -> Dpu {
        let mut cfg = DpuConfig::default();
        cfg.split_audio_cu = split;
        Dpu::new(&cfg, &HardwareConfig::default())
    }

    #[test]
    fn single_input_latency_sub_ms() {
        let mut dpu = mk(true);
        let done = dpu.admit(0, ModelId::MobileNet, 0.0);
        assert!(to_millis(done) < 1.0, "image: {} ms", to_millis(done));
        let done = dpu.admit(0, ModelId::CitriNet, 2.5);
        assert!(to_millis(done) < 2.0, "audio: {} ms", to_millis(done));
    }

    #[test]
    fn image_cu_pipelines_back_to_back() {
        // Fig 12a: request X+1 starts while X is in later stages — the
        // inter-completion gap equals the II (slowest stage), not the
        // full latency.
        let mut dpu = mk(true);
        let d1 = dpu.admit(0, ModelId::MobileNet, 0.0);
        let d2 = dpu.admit(0, ModelId::MobileNet, 0.0);
        let d3 = dpu.admit(0, ModelId::MobileNet, 0.0);
        // CUs are picked earliest-free: with 2 image CUs, reqs 1-2 go to
        // different CUs; req 3 shares CU with req 1 offset by II.
        let ii = cu_timing(CuKind::Image, 0.0).ii_s;
        let lat = cu_timing(CuKind::Image, 0.0).latency_s;
        assert!(ii < lat);
        assert!((d3 - d1) as f64 * 1e-9 - ii < 1e-6, "pipelined II");
        assert_eq!(d1, d2); // parallel CUs
    }

    #[test]
    fn monolithic_audio_serializes_split_pipelines() {
        // Fig 12 b vs c: with the same number of front CUs, inter-
        // completion time is the full pipeline latency for monolithic but
        // only the mel II for split.
        let mut mono = mk(false);
        let m1 = mono.admit(0, ModelId::CitriNet, 2.5);
        let m2 = mono.admit(0, ModelId::CitriNet, 2.5);
        let m3 = mono.admit(0, ModelId::CitriNet, 2.5);
        let mono_gap = (m3 - m1) as f64 * 1e-9; // same-CU gap

        let mut split = mk(true);
        let s1 = split.admit(0, ModelId::CitriNet, 2.5);
        let s2 = split.admit(0, ModelId::CitriNet, 2.5);
        let s3 = split.admit(0, ModelId::CitriNet, 2.5);
        let split_gap = (s3 - s1) as f64 * 1e-9;

        assert!(
            split_gap < mono_gap * 0.98,
            "split should pipeline: mono_gap={mono_gap} split_gap={split_gap}"
        );
        let _ = (m2, s2);
    }

    #[test]
    fn split_audio_capacity_exceeds_monolithic() {
        let split = mk(true);
        let mono = mk(false);
        let cs = split.capacity_qps(ModelKind::Audio, 2.5);
        let cm = mono.capacity_qps(ModelKind::Audio, 2.5);
        assert!(cs > cm * 1.1, "split {cs} vs mono {cm}");
    }

    #[test]
    fn dpu_capacity_covers_ideal_demand() {
        // The DPU must not be the new bottleneck (paper: PREBA reaches
        // >91.6% of Ideal for 5/6 models).
        let dpu = mk(true);
        // Highest-demand vision model: MobileNet on 1g.5gb(7x).
        let need_img = 7.0 * ModelId::MobileNet.spec().plateau_qps_per_gpc;
        assert!(
            dpu.capacity_qps(ModelKind::Vision, 0.0) >= need_img * 0.9,
            "image capacity {} vs need {need_img}",
            dpu.capacity_qps(ModelKind::Vision, 0.0)
        );
        // Highest-demand audio model: CitriNet.
        let need_aud = 7.0 * ModelId::CitriNet.spec().plateau_qps_per_gpc;
        assert!(
            dpu.capacity_qps(ModelKind::Audio, 2.5) >= need_aud,
            "audio capacity {} vs need {need_aud}",
            dpu.capacity_qps(ModelKind::Audio, 2.5)
        );
    }

    #[test]
    fn pcie_bandwidth_below_gen4_limit() {
        // Paper §4.2: worst case 6.13 GB/s << 32 GB/s.
        let mut dpu = mk(true);
        let qps = 17_500.0;
        let dt = secs(1.0 / qps);
        for i in 0..10_000u64 {
            dpu.admit(i * dt, ModelId::MobileNet, 0.0);
        }
        let gbps = dpu.pcie_gbps_used(10_000 * dt);
        assert!(gbps < 32.0, "PCIe saturated: {gbps}");
        assert!(gbps > 1.0, "suspiciously low: {gbps}");
    }

    #[test]
    fn utilization_bounded() {
        let mut dpu = mk(true);
        for i in 0..100u64 {
            dpu.admit(i * 1000, ModelId::MobileNet, 0.0);
        }
        let u = dpu.utilization(secs(1.0));
        assert!((0.0..=1.0).contains(&u));
    }
}
