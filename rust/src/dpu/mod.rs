//! PREBA's FPGA Data Processing Unit (paper §4.2) — scheduling + cost
//! model, plus the Table-1 resource budget.
//!
//! The DPU is latency-optimized for *single-input* batches (so the
//! downstream batcher keeps full freedom over batch sizes) and gains
//! throughput via multiple CUs (request-level parallelism). For audio, a
//! monolithic CU serializes on the Normalize unit's global mean/variance
//! dependency (Fig 12b); PREBA's split design (Resample+Mel CU, Normalize
//! CU — Fig 11b/12c) restores pipelining.
//!
//! Real compute: the Pallas kernels in `python/compile/kernels/` implement
//! these exact pipelines and are executed on PJRT by the real driver; this
//! module provides the timing/occupancy model used by the DES and the
//! host-side CU scheduler shared by both drivers.

pub mod resources;
pub mod sched;

pub use resources::{resource_table, ResourceRow};
pub use sched::{CuKind, Dpu, DpuDesign};
