//! Offline profiling (paper §4.3 "Profiling-based estimation of
//! Batch_max").
//!
//! PREBA profiles the throughput vs tail-latency curve as a function of
//! batch size (and input length for audio) on the target MIG slice, finds
//! `Batch_knee` (the smallest batch reaching `knee_frac` of plateau
//! throughput), reads off `Time_knee`, and derives the dynamic policy
//! (`Batch_max = Batch_knee`, `Time_queue = Time_knee / n_vGPUs`).
//!
//! In this reproduction the "measurement" runs the calibrated service
//! model with jitter — exactly what the DES executes — so the profiled
//! policy is an *empirical* estimate that must agree with the analytic
//! one (`BatchPolicy::dynamic_from_model`); `tests::profiled_matches_analytic`
//! pins that agreement.

use crate::batching::{BatchPolicy, Bucketizer, QueueParams};
use crate::clock::secs;
use crate::mig::ServiceModel;
use crate::models::ModelSpec;
use crate::util::{Rng, Summary};

/// One profiled point of the batch-size sweep.
#[derive(Debug, Clone, Copy)]
pub struct ProfilePoint {
    pub batch: usize,
    /// Sustained throughput running back-to-back batches, queries/s.
    pub qps: f64,
    /// 95%-ile batch execution latency, ms.
    pub p95_ms: f64,
    /// Mean execution latency, ms.
    pub mean_ms: f64,
    /// Slice utilization proxy (fraction of plateau achieved).
    pub util: f64,
}

/// Batch sizes to sweep (the paper sweeps powers of two, Fig 6's log x-axis).
pub fn sweep_batches(max: usize) -> Vec<usize> {
    let mut v = vec![1usize];
    while *v.last().unwrap() < max {
        v.push(v.last().unwrap() * 2);
    }
    v
}

/// Denser ~1.4x-spaced sweep (1, 2, 3, 4, 6, 8, 12, ...) used when the
/// knee must be located precisely — a pure power-of-two grid can overshoot
/// the knee by up to 2x, inflating the measured Time_knee (the batching
/// policy pays that directly as added tail latency).
pub fn sweep_batches_dense(max: usize) -> Vec<usize> {
    let mut v = vec![1usize, 2];
    let mut p = 2usize;
    while p < max {
        if p + p / 2 <= max {
            v.push(p + p / 2);
        }
        p *= 2;
        v.push(p.min(max));
    }
    v.sort_unstable();
    v.dedup();
    v
}

/// Profile one (model, slice, length): run `reps` jittered executions per
/// batch size and record throughput + tail latency.
pub fn profile_curve(
    spec: &ModelSpec,
    gpcs: usize,
    len_s: f64,
    batches: &[usize],
    reps: usize,
    rng: &mut Rng,
) -> Vec<ProfilePoint> {
    let sm = ServiceModel::new(spec, gpcs);
    batches
        .iter()
        .map(|&b| {
            let mut lat = Summary::new();
            let mut total_s = 0.0;
            for _ in 0..reps {
                let t = sm.exec_secs_jittered(b, len_s, rng);
                lat.add(t * 1e3);
                total_s += t;
            }
            let qps = (reps * b) as f64 / total_s;
            ProfilePoint {
                batch: b,
                qps,
                p95_ms: lat.p95(),
                mean_ms: lat.mean(),
                util: qps / sm.plateau_qps(len_s),
            }
        })
        .collect()
}

/// Measurement-noise guard on the knee threshold: the analytic knee sits
/// *exactly* at `knee_frac` of plateau, and the plateau estimate (max of
/// noisy sweep points) is biased high by ~1%, so without a small guard
/// the profiled knee would randomly land one grid step past the true one.
const KNEE_NOISE_GUARD: f64 = 0.025;

/// Find `Batch_knee`: smallest profiled batch whose throughput reaches
/// `knee_frac` of the observed plateau (max over the sweep).
pub fn find_knee(curve: &[ProfilePoint], knee_frac: f64) -> ProfilePoint {
    assert!(!curve.is_empty());
    let plateau = curve.iter().map(|p| p.qps).fold(0.0, f64::max);
    *curve
        .iter()
        .find(|p| p.qps >= knee_frac * plateau * (1.0 - KNEE_NOISE_GUARD))
        .unwrap_or(curve.last().unwrap())
}

/// Build PREBA's dynamic batching policy from measured curves: one
/// profiled knee per audio bucket (vision: the single fixed bucket).
pub fn knee_table(
    spec: &ModelSpec,
    gpcs: usize,
    buckets: &Bucketizer,
    n_vgpus: usize,
    knee_frac: f64,
    rng: &mut Rng,
) -> BatchPolicy {
    let batches = sweep_batches_dense(256);
    let per_bucket = (0..buckets.n_buckets())
        .map(|bk| {
            let len = buckets.repr_len(bk);
            let curve = profile_curve(spec, gpcs, len, &batches, 60, rng);
            let knee = find_knee(&curve, knee_frac);
            QueueParams {
                batch_max: knee.batch,
                time_queue: secs(knee.mean_ms * 1e-3 / n_vgpus as f64),
            }
        })
        .collect();
    BatchPolicy::Dynamic { per_bucket }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    #[test]
    fn sweep_is_pow2() {
        assert_eq!(sweep_batches(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(sweep_batches(1), vec![1]);
    }

    #[test]
    fn curve_throughput_monotonic_until_plateau() {
        let mut rng = Rng::new(11);
        let curve =
            profile_curve(ModelId::MobileNet.spec(), 1, 0.0, &sweep_batches(256), 40, &mut rng);
        // QPS non-decreasing (within jitter tolerance).
        for w in curve.windows(2) {
            assert!(
                w[1].qps > w[0].qps * 0.97,
                "b={} {} -> b={} {}",
                w[0].batch,
                w[0].qps,
                w[1].batch,
                w[1].qps
            );
        }
        // Latency strictly grows with batch.
        for w in curve.windows(2) {
            assert!(w[1].p95_ms > w[0].p95_ms);
        }
    }

    #[test]
    fn profiled_knee_matches_paper_for_vision() {
        let mut rng = Rng::new(3);
        for (m, k1, k7) in [
            (ModelId::MobileNet, 16, 128),
            (ModelId::SqueezeNet, 4, 32),
            (ModelId::SwinTransformer, 2, 16),
        ] {
            for (g, expect) in [(1usize, k1), (7usize, k7)] {
                let curve =
                    profile_curve(m.spec(), g, 0.0, &sweep_batches(256), 80, &mut rng);
                let knee = find_knee(&curve, 0.90);
                assert_eq!(knee.batch, expect, "{m} {g}g");
            }
        }
    }

    #[test]
    fn profiled_matches_analytic() {
        // The measured knee table must agree with the closed-form policy.
        let mut rng = Rng::new(17);
        let spec = ModelId::ConformerDefault.spec();
        let buckets = Bucketizer::new(2.5, 25.0);
        let sm = crate::mig::ServiceModel::new(spec, 1);
        let analytic = BatchPolicy::dynamic_from_model(spec, &sm, &buckets, 7);
        let measured = knee_table(spec, 1, &buckets, 7, 0.90, &mut rng);
        for bk in 0..buckets.n_buckets() {
            let a = analytic.params(bk);
            let m = measured.params(bk);
            // Knee on the pow2 grid vs analytic integer knee: within 2x.
            let ratio = a.batch_max as f64 / m.batch_max as f64;
            assert!((0.5..=2.0).contains(&ratio), "bucket {bk}: analytic {a:?} measured {m:?}");
        }
    }

    #[test]
    fn audio_time_knee_constant_across_lengths() {
        // Fig 15's key observation, recovered from measurement. Lengths
        // whose knee hits the batch=1 floor are excluded: there the
        // single-input time exceeds Time_knee by construction (paper
        // Fig 14a's yellow batch-1 cells).
        let mut rng = Rng::new(23);
        let spec = ModelId::CitriNet.spec();
        let mut knee_lat = Vec::new();
        for len in [2.5, 5.0, 7.5] {
            let curve = profile_curve(spec, 1, len, &sweep_batches_dense(256), 80, &mut rng);
            let knee = find_knee(&curve, 0.90);
            if knee.batch >= 2 {
                knee_lat.push(knee.mean_ms);
            }
        }
        assert!(knee_lat.len() >= 2, "not enough non-degenerate knees");
        for t in &knee_lat {
            assert!((t - 35.0).abs() < 12.0, "Time_knee drifted: {knee_lat:?}");
        }
        let spread = knee_lat.iter().cloned().fold(0.0, f64::max)
            - knee_lat.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 15.0, "spread={spread} {knee_lat:?}");
    }
}
