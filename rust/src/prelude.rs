//! One-line import surface for the crate's most-used types.
//!
//! Scripts, examples and experiments keep reaching for the same ~15
//! names (config, fleet/tenant description, the workload sources and the
//! outcome types); `use preba::prelude::*` pulls them in without a wall
//! of `use` lines. Functions stay on their module paths
//! (`server::cluster::run`, `server::sim_driver::run`) — the prelude
//! re-exports *types and traits* only, so glob-importing it cannot
//! shadow local fn names.
//!
//! ```
//! use preba::prelude::*;
//!
//! let tenant = ClusterTenant::new(ModelId::MobileNet, Slice::new(1, 5), 1, 50.0);
//! let cfg = ClusterConfig::builder().gpus(1).tenants(vec![tenant]).build();
//! assert_eq!(cfg.fleet, vec![GpuClass::A100]);
//! assert!(matches!(cfg.routing, Routing::ShortestQueue));
//! ```

pub use crate::config::PrebaConfig;
pub use crate::mig::{GpuClass, MigConfig, PackStrategy, ReconfigPolicy, Slice};
pub use crate::models::ModelId;
pub use crate::server::cluster::{
    ClusterConfig, ClusterConfigBuilder, ClusterOutcome, ClusterTenant, Routing,
};
pub use crate::server::{PolicyKind, PreprocMode, SimConfig, SimOutcome};
pub use crate::util::Rng;
pub use crate::workload::{
    Arrival, ArrivalStream, Bounded, QueryGen, RateProfile, ReplayTrace, Rescale, StreamSpec,
    TraceGen,
};
