//! Figure 13: histogram of LibriSpeech audio input lengths — the
//! distribution the workload generator draws from.

use crate::config::PrebaConfig;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::stats::Histogram;
use crate::util::Rng;
use crate::workload::sample_librispeech_len;

pub fn run(_sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 13: LibriSpeech-shaped audio length histogram");
    let mut rng = Rng::new(13);
    let mut h = Histogram::new(0.0, 25.0, 10); // 2.5 s buckets, like Fig 16
    let n = 100_000;
    for _ in 0..n {
        h.add(sample_librispeech_len(&mut rng));
    }
    rep.section("2.5 s buckets");
    let mut rows = Vec::new();
    let max = h.bins().iter().copied().max().unwrap() as f64;
    for (center, count) in h.rows() {
        let bar = "#".repeat(((count as f64 / max) * 50.0) as usize);
        rep.row(&format!(
            "[{:>4.1}-{:>4.1} s) {:>7} {}",
            center - 1.25,
            center + 1.25,
            count,
            bar
        ));
        rows.push(Json::obj(vec![
            ("center_s", Json::num(center)),
            ("count", Json::num(count as f64)),
        ]));
    }
    rep.data("bins", Json::Arr(rows));
    rep.finish("fig13")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_is_unimodal_in_the_body_with_short_mode() {
        let doc = run(&PrebaConfig::new());
        let bins = doc.get("data").unwrap().get("bins").unwrap().as_arr().unwrap();
        let counts: Vec<f64> =
            bins.iter().map(|b| b.get("count").unwrap().as_f64().unwrap()).collect();
        assert_eq!(counts.len(), 10);
        // Peak in the 10-17.5 s region (bins 4-6), tail small.
        let peak =
            counts.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!((3..=6).contains(&peak), "peak bin {peak}");
        assert!(counts[9] < counts[peak] * 0.5);
    }
}
