//! Table 1: DPU resource utilization per functional unit — the paper's
//! FPGA numbers plus this reproduction's TPU-adaptation columns (Pallas
//! VMEM footprint + MXU utilization estimates, DESIGN.md §Hardware-
//! Adaptation).

use crate::config::PrebaConfig;
use crate::dpu::{resource_table, resources};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Table 1: DPU resource utilization (FPGA + TPU adaptation)");
    let mut t = Table::new(&[
        "App", "Unit", "LUT %", "REG %", "BRAM %", "URAM %", "DSP %", "VMEM KiB", "MXU util",
    ]);
    let mut rows = Vec::new();
    for r in resource_table() {
        t.row(&[
            r.app.to_string(),
            r.unit.to_string(),
            num(r.lut_pct),
            num(r.reg_pct),
            num(r.bram_pct),
            num(r.uram_pct),
            num(r.dsp_pct),
            num(r.vmem_kib),
            num(r.mxu_util),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::str(r.app)),
            ("unit", Json::str(r.unit)),
            ("lut_pct", Json::num(r.lut_pct)),
            ("dsp_pct", Json::num(r.dsp_pct)),
            ("vmem_kib", Json::num(r.vmem_kib)),
            ("mxu_util", Json::num(r.mxu_util)),
        ]));
    }
    for app in ["Image", "Audio"] {
        let (l, r2, b, u, d) = resources::totals(app);
        t.row(&[
            app.to_string(),
            "Total".to_string(),
            num(l),
            num(r2),
            num(b),
            num(u),
            num(d),
            String::new(),
            String::new(),
        ]);
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.row(&format!(
        "\nconfigured CU counts fit the U55C: {}",
        resources::fits_fpga(&sys.dpu)
    ));
    rep.data("rows", Json::Arr(rows));
    rep.finish("table1")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_all_units() {
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 7);
    }
}
