//! Figure 6: throughput (bars) + p95 latency (line) vs batch size with the
//! Batch_knee marker, per MIG config × model, preprocessing disabled.
//!
//! Paper shape: throughput plateaus, then tail latency spikes with small
//! batch increases; knees at 16/4/2 (1g) and 128/32/16 (7g) for
//! MobileNet/SqueezeNet/Swin.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::profiler;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};
use crate::util::Rng;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 6: throughput + tail latency vs batch; Batch_knee markers");
    let batches = profiler::sweep_batches(256);

    // One profiling job per model × MIG config cell, fanned out over the
    // job pool with per-cell seeds (results identical at any worker count).
    let grid = super::support::cross2(&ModelId::ALL, &MigConfig::ALL);
    let curves = super::sweep(&grid, |&(model, cfg)| {
        let mut rng = Rng::new(0x0600 ^ ((model as u64) << 8) ^ cfg.gpcs_per_vgpu() as u64);
        // 80 reps (not the seed's 60): the per-cell RNG streams are new,
        // and the knee assertions are exact — keep the qps SE well inside
        // the profiler's 2.5% knee noise guard.
        profiler::profile_curve(model.spec(), cfg.gpcs_per_vgpu(), 2.5, &batches, 80, &mut rng)
    });

    let mut cells = grid.iter().zip(curves.iter());
    let mut knees = Vec::new();
    for model in ModelId::ALL {
        rep.section(model.display());
        let mut t = Table::new(&["config", "batch", "agg QPS", "p95 ms", "knee?"]);
        for _ in MigConfig::ALL {
            let (&(_, cfg), curve) = cells.next().expect("grid exhausted");
            let knee = profiler::find_knee(curve, sys.batching.knee_frac);
            knees.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("config", Json::str(cfg.name())),
                ("knee_batch", Json::num(knee.batch as f64)),
                ("knee_p95_ms", Json::num(knee.p95_ms)),
            ]));
            for p in curve {
                t.row(&[
                    cfg.name().to_string(),
                    p.batch.to_string(),
                    num(p.qps * cfg.vgpus() as f64),
                    num(p.p95_ms),
                    if p.batch == knee.batch { "<-- knee".to_string() } else { String::new() },
                ]);
            }
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("knees", Json::Arr(knees));
    rep.finish("fig06")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knees_match_paper_vision_values() {
        let doc = run(&PrebaConfig::new());
        let knees = doc.get("data").unwrap().get("knees").unwrap().as_arr().unwrap();
        let find = |m: &str, c: &str| -> usize {
            knees
                .iter()
                .find(|k| {
                    k.get("model").unwrap().as_str() == Some(m)
                        && k.get("config").unwrap().as_str() == Some(c)
                })
                .unwrap()
                .get("knee_batch")
                .unwrap()
                .as_usize()
                .unwrap()
        };
        assert_eq!(find("mobilenet", "1g.5gb(7x)"), 16);
        assert_eq!(find("squeezenet", "1g.5gb(7x)"), 4);
        assert_eq!(find("swin", "1g.5gb(7x)"), 2);
        assert_eq!(find("mobilenet", "7g.40gb(1x)"), 128);
        assert_eq!(find("squeezenet", "7g.40gb(1x)"), 32);
        assert_eq!(find("swin", "7g.40gb(1x)"), 16);
    }
}
