//! Figure 17: end-to-end inference throughput of 1g.5gb(7x) as the number
//! of activated inference servers grows 1→7, for Ideal / PREBA (DPU) /
//! baseline (CPU preprocessing).
//!
//! Paper headline: baseline loses 77.2% vs Ideal; PREBA reaches ≥91.6% of
//! Ideal for 5 of 6 models → average 3.7× over baseline.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 17: e2e throughput vs active servers (Ideal / DPU / CPU)");
    let requests = super::default_requests();
    let mut rows = Vec::new();
    let mut speedups = Vec::new();

    // The full sweep grid — model × servers × design, one simulation per
    // cell — fans out as 126 independent jobs.
    let servers: Vec<usize> = (1..=7).collect();
    let grid = support::cross3(
        &ModelId::ALL,
        &servers,
        &[PreprocMode::Ideal, PreprocMode::Dpu, PreprocMode::Cpu],
    );
    let cell_qps = super::sweep(&grid, |&(model, servers, preproc)| {
        support::saturated_qps(
            model, MigConfig::Small7, preproc, PolicyKind::Dynamic, servers, requests, sys,
        )
        .qps()
    });

    let mut cells = grid.iter().zip(cell_qps.iter());
    for model in ModelId::ALL {
        rep.section(model.display());
        let mut t = Table::new(&["servers", "Ideal", "PREBA (DPU)", "CPU baseline"]);
        let mut at7 = (0.0, 0.0, 0.0);
        for servers in 1..=7usize {
            let mut qps = [0.0; 3];
            for (i, preproc) in
                [PreprocMode::Ideal, PreprocMode::Dpu, PreprocMode::Cpu].iter().enumerate()
            {
                let (_, &q) = cells.next().expect("grid exhausted");
                qps[i] = q;
                rows.push(Json::obj(vec![
                    ("model", Json::str(model.name())),
                    ("servers", Json::num(servers as f64)),
                    ("design", Json::str(preproc.label())),
                    ("qps", Json::num(qps[i])),
                ]));
            }
            if servers == 7 {
                at7 = (qps[0], qps[1], qps[2]);
            }
            t.row(&[servers.to_string(), num(qps[0]), num(qps[1]), num(qps[2])]);
        }
        for line in t.render() {
            rep.row(&line);
        }
        let (ideal, dpu, cpu) = at7;
        speedups.push(dpu / cpu);
        rep.row(&format!(
            "at 7 servers: PREBA = {:.1}% of Ideal, {:.2}x over CPU baseline",
            100.0 * dpu / ideal,
            dpu / cpu
        ));
    }
    let avg = support::geomean(&speedups);
    rep.row(&format!("\naverage end-to-end speedup: {avg:.2}x (paper: 3.7x)"));
    rep.data("rows", Json::Arr(rows));
    rep.data("avg_speedup", Json::num(avg));
    rep.finish("fig17")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preba_speedup_in_paper_band() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let avg = doc.get("data").unwrap().get("avg_speedup").unwrap().as_f64().unwrap();
        // Paper: 3.7x average. Accept the 2.5-6x band for the simulated
        // substrate (who-wins + rough factor, DESIGN.md §7).
        assert!((2.5..6.0).contains(&avg), "avg speedup {avg}");
    }
}
