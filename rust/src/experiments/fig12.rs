//! Figure 12: DPU execution timelines — (a) the image CU pipelines
//! consecutive requests; (b) a monolithic audio CU serializes on the
//! Normalize unit's full-input dependency; (c) PREBA's split CU design
//! restores pipelining.

use crate::clock::to_millis;
use crate::config::{DpuConfig, PrebaConfig};
use crate::dpu::{sched::cu_timing, CuKind, Dpu};
use crate::models::ModelId;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 12: DPU CU pipelining — image; audio monolithic vs split");

    // (a) image: inter-completion gap == slowest-stage II, not latency.
    rep.section("(a) image CU, 4 back-to-back requests (1 CU)");
    let mut cfg1 = DpuConfig::default();
    cfg1.image_cus = 1;
    let mut dpu = Dpu::new(&cfg1, &sys.hardware);
    let mut t = Table::new(&["req", "done ms"]);
    let mut img_done = Vec::new();
    for i in 0..4 {
        let d = dpu.admit(0, ModelId::MobileNet, 0.0);
        t.row(&[i.to_string(), num(to_millis(d))]);
        img_done.push(d);
    }
    for line in t.render() {
        rep.row(&line);
    }
    let img_gap = to_millis(img_done[3] - img_done[2]);
    let img_lat = cu_timing(CuKind::Image, 0.0).latency_s * 1e3;
    rep.row(&format!(
        "steady-state gap {img_gap:.3} ms << single-request pipeline {img_lat:.3} ms (pipelined)"
    ));

    // (b)/(c) audio.
    let run_audio = |split: bool| -> Vec<u64> {
        let mut cfg = DpuConfig::default();
        cfg.split_audio_cu = split;
        cfg.audio_mel_cus = 1;
        cfg.audio_norm_cus = 1;
        let mut dpu = Dpu::new(&cfg, &sys.hardware);
        (0..4).map(|_| dpu.admit(0, ModelId::CitriNet, 2.5)).collect()
    };
    let mono = run_audio(false);
    let split = run_audio(true);

    rep.section("(b) monolithic audio CU vs (c) split CUs, 4 requests @2.5 s");
    let mut t = Table::new(&["req", "mono done ms", "split done ms"]);
    for i in 0..4 {
        t.row(&[i.to_string(), num(to_millis(mono[i])), num(to_millis(split[i]))]);
    }
    for line in t.render() {
        rep.row(&line);
    }
    let mono_gap = to_millis(mono[3] - mono[2]);
    let split_gap = to_millis(split[3] - split[2]);
    rep.row(&format!(
        "steady-state gap: monolithic {mono_gap:.3} ms vs split {split_gap:.3} ms ({}x better utilization)",
        crate::util::round_to(mono_gap / split_gap, 2)
    ));

    rep.data(
        "gaps_ms",
        Json::obj(vec![
            ("image", Json::num(img_gap)),
            ("audio_monolithic", Json::num(mono_gap)),
            ("audio_split", Json::num(split_gap)),
        ]),
    );
    rep.finish("fig12")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_gap_beats_monolithic() {
        let doc = run(&PrebaConfig::new());
        let gaps = doc.get("data").unwrap().get("gaps_ms").unwrap();
        let mono = gaps.get("audio_monolithic").unwrap().as_f64().unwrap();
        let split = gaps.get("audio_split").unwrap().as_f64().unwrap();
        assert!(split < mono, "split {split} !< mono {mono}");
        let img = gaps.get("image").unwrap().as_f64().unwrap();
        assert!(img < 0.2, "image gap should be ~II: {img}");
    }
}
