//! Figure 18: throughput vs tail-latency curves for the three designs on
//! 1g.5gb(7x).
//!
//! Paper shape: the CPU baseline's tail latency explodes at a much lower
//! throughput; PREBA tracks Ideal closely (5 of 6 models).

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode, SimConfig};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

/// Load fractions of the ideal capacity to sweep.
const FRACS: [f64; 7] = [0.1, 0.25, 0.4, 0.55, 0.7, 0.85, 1.0];

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 18: throughput vs p95 latency (Ideal / DPU / CPU)");
    let requests = super::default_requests();
    let mut rows = Vec::new();

    // Sweep grid: model × design × load fraction (126 independent sims).
    // The capacity anchor is analytic (cheap), computed once per model
    // while building the job list.
    let caps: Vec<(ModelId, f64)> = ModelId::ALL
        .iter()
        .map(|&model| {
            let cap = SimConfig::new(model, MigConfig::Small7, PreprocMode::Ideal)
                .saturating_rate()
                / 1.25;
            (model, cap)
        })
        .collect();
    let grid: Vec<(ModelId, PreprocMode, f64)> = support::cross3(
        &caps,
        &[PreprocMode::Ideal, PreprocMode::Dpu, PreprocMode::Cpu],
        &FRACS,
    )
    .into_iter()
    .map(|((model, cap), preproc, frac)| (model, preproc, cap * frac))
    .collect();
    let outs = super::sweep(&grid, |&(model, preproc, rate)| {
        support::run(
            model, MigConfig::Small7, preproc, PolicyKind::Dynamic, 7, rate, requests, sys,
        )
    });

    let mut cells = grid.iter().zip(outs.iter());
    for model in ModelId::ALL {
        rep.section(model.display());
        let mut t = Table::new(&["design", "offered QPS", "achieved QPS", "p95 ms"]);
        for preproc in [PreprocMode::Ideal, PreprocMode::Dpu, PreprocMode::Cpu] {
            for _ in FRACS {
                let (&(_, _, rate), out) = cells.next().expect("grid exhausted");
                t.row(&[
                    preproc.label().to_string(),
                    num(rate),
                    num(out.qps()),
                    num(out.p95_ms()),
                ]);
                rows.push(Json::obj(vec![
                    ("model", Json::str(model.name())),
                    ("design", Json::str(preproc.label())),
                    ("offered", Json::num(rate)),
                    ("qps", Json::num(out.qps())),
                    ("p95_ms", Json::num(out.p95_ms())),
                ]));
            }
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("rows", Json::Arr(rows));
    rep.finish("fig18")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_tail_explodes_before_preba() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        // At 70% of capacity for Conformer(default): CPU's p95 must be far
        // above the DPU's.
        let get = |design: &str| -> f64 {
            rows.iter()
                .filter(|r| {
                    r.get("model").unwrap().as_str() == Some("conformer_default")
                        && r.get("design").unwrap().as_str() == Some(design)
                })
                .map(|r| {
                    (
                        r.get("offered").unwrap().as_f64().unwrap(),
                        r.get("p95_ms").unwrap().as_f64().unwrap(),
                    )
                })
                .filter(|(o, _)| *o > 0.0)
                .collect::<Vec<_>>()[4] // 0.7 fraction
                .1
        };
        let cpu = get("Preprocessing (CPU)");
        let dpu = get("Preprocessing (DPU)");
        assert!(cpu > 3.0 * dpu, "cpu p95 {cpu} vs dpu p95 {dpu}");
    }
}
