//! Fault injection & failure recovery at fleet scale.
//!
//! The cluster DES grew packing, routing, cross-GPU reconfiguration and
//! power-aware consolidation in fair weather; this experiment breaks the
//! machines underneath it (`crate::fault`) and measures whether the
//! recovery stack — detection, retry, hedging, failover re-packing
//! through the controller's `try_admit` seam — actually buys
//! availability. Three sections:
//!
//! 1. **Failover A/B**: tenant A fills GPU0 (7×1g), tenant B spans GPU1
//!    (7×1g) + GPU2 (2×1g); GPU1 crashes a quarter into the run and
//!    never comes back. The no-recovery baseline keeps blind-routing
//!    into the dead group and strands its backlog; with recovery the
//!    health check flushes the queue, in-flight losses are retried, the
//!    blind window is hedged to GPU2, and the displaced slices re-pack
//!    onto GPU2's free GPCs. Recovery must win strictly on availability
//!    AND served count at identical load and schedule.
//! 2. **Crash during consolidation** (the PR-5 interplay): sustained low
//!    load lets the consolidation controller drain and power down the
//!    lighter GPU; then the GPU carrying everything crashes. Failover
//!    wakes the parked GPU through the same `try_admit`/power-on seam
//!    consolidation used to park it — proving the power-down path and
//!    the failover path never fight.
//! 3. **Stochastic MTBF sweep**: seeded alternating-renewal fault
//!    streams at a few MTBF points, recovery on — the availability
//!    erosion curve as faults densify.

use crate::fault::{FaultEvent, FaultKind, FaultSchedule, FaultSpec, RecoveryPolicy};
use crate::mig::ServiceModel;
use crate::prelude::*;
use crate::server::cluster;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

fn swin_plateau(gpcs: usize) -> f64 {
    ServiceModel::new(ModelId::SwinTransformer.spec(), gpcs).plateau_qps(0.0)
}

/// The recovery stack under test: the `[fault]` config knobs with
/// hedging switched on (30 ms — well inside the 200 ms blind window, so
/// requests routed to the silently-dead group get a second copy).
pub fn recovery_policy(sys: &PrebaConfig) -> RecoveryPolicy {
    RecoveryPolicy { hedge_s: 0.03, ..sys.fault.recovery() }
}

/// §1 fleet: tenant A 7×1g fills GPU0; tenant B 9×1g spans GPU1 (7
/// slices) + GPU2 (2 slices), leaving 5 GPCs free on GPU2 as failover
/// headroom. Both offered ~45% of asked capacity.
pub fn failover_tenants(horizon_s: f64) -> Vec<ClusterTenant> {
    let u = swin_plateau(1);
    let mk = |slices: usize| {
        let rate = 0.45 * slices as f64 * u;
        let mut t = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), slices, rate);
        t.sla_ms = 40.0;
        t.requests = (rate * horizon_s).ceil() as usize;
        t
    };
    vec![mk(7), mk(9)]
}

/// §1's fault: GPU1 — tenant B's 7-slice group — dies a quarter into the
/// run and stays dead past the horizon (repair never lands).
pub fn crash_schedule(horizon_s: f64) -> FaultSchedule {
    FaultSchedule::scripted(vec![FaultEvent {
        at_s: 0.25 * horizon_s,
        gpu: 1,
        kind: FaultKind::GpuCrash,
        duration_s: f64::INFINITY,
    }])
}

/// One §1 cell: identical fleet, load, seed and crash; `recover` toggles
/// the recovery stack (false = the blind baseline). `pub` so the
/// property tests and the CLI rerun the exact reported scenario.
pub fn failover_cfg(recover: bool, horizon_s: f64, sys: &PrebaConfig) -> ClusterConfig {
    let sched = crash_schedule(horizon_s);
    // Deferral/telemetry from the first window; the crash comparison
    // must score the whole run, not a warmup-trimmed tail.
    ClusterConfig::builder()
        .gpus(3)
        .strategy(PackStrategy::BestFit)
        .tenants(failover_tenants(horizon_s))
        .seed(0xFA01)
        .reconfig(super::cluster::policy(sys))
        .warmup_frac(0.01)
        .faults(if recover {
            FaultSpec::recovering(sched, recovery_policy(sys))
        } else {
            FaultSpec::baseline(sched)
        })
        .build()
}

/// §2: sustained ~20% load on two 5×1g tenants packed 7+3 across two
/// A100s — the consolidation regime. The controller drains and powers
/// down the lighter GPU; then GPU0, now carrying everything, crashes at
/// 55% of the horizon and stays down. Recovery must wake the parked GPU.
pub fn consolidation_crash_cfg(horizon_s: f64, sys: &PrebaConfig) -> ClusterConfig {
    let u = swin_plateau(1);
    let mk = || {
        let rate = 0.2 * 5.0 * u;
        let mut t = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 5, rate);
        t.sla_ms = 60.0;
        t.requests = (rate * horizon_s).ceil() as usize;
        t
    };
    // Admission queues give the detect-time queue flush somewhere to put
    // requests while the parked GPU is still waking (graceful
    // degradation instead of drops).
    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(vec![mk(), mk()])
        .seed(0xFA02)
        .reconfig(super::cluster::policy(sys))
        .consolidate(true)
        .admission(true)
        .warmup_frac(0.01)
        .faults(FaultSpec::recovering(
            FaultSchedule::scripted(vec![FaultEvent {
                at_s: 0.55 * horizon_s,
                gpu: 0,
                kind: FaultKind::GpuCrash,
                duration_s: f64::INFINITY,
            }]),
            recovery_policy(sys),
        ))
        .build()
}

fn run_cell(cfg: &ClusterConfig, sys: &PrebaConfig) -> ClusterOutcome {
    cluster::run(cfg, sys).expect("valid cluster config")
}

fn fault_row(label: &str, out: &ClusterOutcome) -> Json {
    Json::obj(vec![
        ("mode", Json::str(label)),
        ("availability_frac", Json::num(out.availability_frac())),
        ("completed", Json::num(out.completed_total() as f64)),
        ("timed_out", Json::num(out.timed_out_total() as f64)),
        ("dropped", Json::num(out.dropped.iter().sum::<u64>() as f64)),
        ("retries", Json::num(out.retries.iter().sum::<u64>() as f64)),
        ("hedges", Json::num(out.hedges.iter().sum::<u64>() as f64)),
        ("reconfig_aborts", Json::num(out.reconfig_aborts as f64)),
        ("served_by_failed", Json::num(out.served_by_failed as f64)),
        ("mttr_s", Json::num(out.mttr_s)),
        ("worst_p95_ms", Json::num(out.worst_p95_ms())),
    ])
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Faults: injection, recovery and failover re-packing");
    let horizon_s = if super::fast() { 8.0 } else { 16.0 };

    // ---- Section 1: failover A/B at identical load + schedule. ----
    rep.section("GPU crash, never repaired: no-recovery baseline vs full recovery stack");
    let modes = [false, true];
    let cfgs: Vec<ClusterConfig> =
        modes.iter().map(|&rec| failover_cfg(rec, horizon_s, sys)).collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "mode", "avail %", "served", "timed out", "retries", "hedges", "aborts", "MTTR s",
    ]);
    let mut rows = Vec::new();
    for (&rec, out) in modes.iter().zip(outs.iter()) {
        let mode = if rec { "recovery" } else { "baseline" };
        t.row(&[
            mode.to_string(),
            num(out.availability_frac() * 100.0),
            out.completed_total().to_string(),
            out.timed_out_total().to_string(),
            out.retries.iter().sum::<u64>().to_string(),
            out.hedges.iter().sum::<u64>().to_string(),
            out.reconfig_aborts.to_string(),
            num(out.mttr_s),
        ]);
        rows.push(fault_row(mode, out));
    }
    for line in t.render() {
        rep.row(&line);
    }
    if let Some(recov) = outs.get(1) {
        for r in &recov.fault_records {
            rep.row(&format!(
                "  t={:.2}s {} on gpu{} -> detected {} repaired {}",
                r.at_s,
                r.kind.label(),
                r.gpu,
                r.detected_s.map_or("never".into(), |d| format!("{d:.2}s")),
                r.repaired_s.map_or("never".into(), |d| format!("{d:.2}s")),
            ));
        }
    }
    rep.data("failover", Json::Arr(rows));

    // ---- Section 2: crash during consolidation. ----
    rep.section("low load parks a GPU; the loaded one crashes — failover wakes the parked GPU");
    let cfg = consolidation_crash_cfg(horizon_s, sys);
    let out = run_cell(&cfg, sys);
    let mut t = Table::new(&[
        "consolidations", "gpu off s", "avail %", "served", "timed out", "served-by-failed",
    ]);
    t.row(&[
        out.consolidations.to_string(),
        num(out.gpu_off_s),
        num(out.availability_frac() * 100.0),
        out.completed_total().to_string(),
        out.timed_out_total().to_string(),
        out.served_by_failed.to_string(),
    ]);
    for line in t.render() {
        rep.row(&line);
    }
    let mut row = fault_row("consolidation-crash", &out);
    if let Json::Obj(pairs) = &mut row {
        pairs.insert("consolidations".to_string(), Json::num(out.consolidations as f64));
        pairs.insert("gpu_off_s".to_string(), Json::num(out.gpu_off_s));
    }
    rep.data("consolidation_crash", row);

    // ---- Section 3: stochastic MTBF sweep, recovery on. ----
    rep.section("seeded stochastic faults (alternating renewal): availability vs MTBF");
    let mtbfs = [10.0f64, 30.0];
    let cfgs: Vec<ClusterConfig> = mtbfs
        .iter()
        .map(|&mtbf| {
            let mut cfg = failover_cfg(true, horizon_s, sys);
            cfg.seed = 0xFA03;
            let sched =
                FaultSchedule::parse(&format!("mtbf:{mtbf},mttr:1"), 3, horizon_s, cfg.seed)
                    .expect("valid stochastic spec");
            cfg.faults = Some(FaultSpec::recovering(sched, recovery_policy(sys)));
            cfg
        })
        .collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&["MTBF s", "faults", "avail %", "timed out", "MTTR s"]);
    let mut rows = Vec::new();
    for ((&mtbf, cfg), out) in mtbfs.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let n_faults =
            cfg.faults.as_ref().map_or(0, |f| f.schedule.events.len());
        t.row(&[
            num(mtbf),
            n_faults.to_string(),
            num(out.availability_frac() * 100.0),
            out.timed_out_total().to_string(),
            num(out.mttr_s),
        ]);
        rows.push(Json::obj(vec![
            ("mtbf_s", Json::num(mtbf)),
            ("faults", Json::num(n_faults as f64)),
            ("availability_frac", Json::num(out.availability_frac())),
            ("timed_out", Json::num(out.timed_out_total() as f64)),
            ("mttr_s", Json::num(out.mttr_s)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("stochastic", Json::Arr(rows));

    rep.finish("faults")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(r: &Json, key: &str) -> f64 {
        r.get(key).unwrap().as_f64().unwrap()
    }

    /// One test, one `run()` — every assertion (failover A/B,
    /// consolidation interplay, stochastic sweep) shares one execution.
    #[test]
    fn recovery_beats_baseline_and_coexists_with_consolidation() {
        crate::experiments::set_fast(true);
        let sys = PrebaConfig::new();
        let doc = run(&sys);
        let data = doc.get("data").unwrap();

        // §1: recovery strictly wins on availability and served count at
        // identical load and fault schedule.
        let rows = data.get("failover").unwrap().as_arr().unwrap();
        let row = |mode: &str| {
            rows.iter().find(|r| r.get("mode").unwrap().as_str() == Some(mode)).unwrap()
        };
        let (base, rec) = (row("baseline"), row("recovery"));
        assert!(
            f(rec, "availability_frac") > f(base, "availability_frac"),
            "recovery {} vs baseline {} availability",
            f(rec, "availability_frac"),
            f(base, "availability_frac")
        );
        assert!(
            f(rec, "completed") > f(base, "completed"),
            "recovery {} vs baseline {} served",
            f(rec, "completed"),
            f(base, "completed")
        );
        assert!(f(rec, "timed_out") < f(base, "timed_out"), "recovery must strand less");
        assert!(f(base, "timed_out") > 0.0, "the crash must actually hurt the baseline");
        assert!(f(rec, "retries") > 0.0, "in-flight losses were never retried");
        assert!(f(rec, "hedges") > 0.0, "the blind window was never hedged");
        assert_eq!(f(base, "retries"), 0.0, "baseline has no recovery stack");
        assert_eq!(f(base, "hedges"), 0.0);
        // Nothing is ever served by a failed group, with or without
        // recovery (the dispatch gate, not the recovery stack, owns this).
        assert_eq!(f(base, "served_by_failed"), 0.0);
        assert_eq!(f(rec, "served_by_failed"), 0.0);

        // §1 conservation: every post-warmup request ends in exactly one
        // terminal bucket on both sides of the A/B. (8.0 s matches the
        // fast-mode horizon `run` used above.)
        let cfg = failover_cfg(true, 8.0, &sys);
        let demand: f64 = cfg
            .tenants
            .iter()
            .map(|t| (t.requests - (t.requests as f64 * cfg.warmup_frac) as usize) as f64)
            .sum();
        for r in [base, rec] {
            assert_eq!(
                f(r, "completed") + f(r, "timed_out") + f(r, "dropped"),
                demand,
                "conservation broke for {:?}",
                r.get("mode")
            );
        }

        // §2: consolidation parked a GPU, the crash did not un-prove it,
        // and failover re-served the load on the woken GPU.
        let cc = data.get("consolidation_crash").unwrap();
        assert!(f(cc, "consolidations") >= 1.0, "never powered a GPU down");
        assert!(f(cc, "gpu_off_s") > 0.0);
        assert_eq!(f(cc, "served_by_failed"), 0.0);
        assert!(
            f(cc, "availability_frac") > 0.9,
            "failover through the consolidation seam failed: {}",
            f(cc, "availability_frac")
        );

        // §3: the dense-fault cell actually injected faults.
        let rows = data.get("stochastic").unwrap().as_arr().unwrap();
        let dense = rows.iter().find(|r| f(r, "mtbf_s") == 10.0).unwrap();
        assert!(f(dense, "faults") >= 1.0, "stochastic schedule was empty");
    }
}
