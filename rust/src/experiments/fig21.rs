//! Figure 21: cost-efficiency (TCO) — queries per dollar over the 3-year
//! horizon, baseline vs PREBA (paper: 3.0× average improvement despite the
//! FPGA CAPEX).

use crate::config::PrebaConfig;
use crate::energy::TcoModel;
use crate::models::ModelId;
use crate::server::PreprocMode;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::{fig20, support};

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 21: cost-efficiency (TCO)");
    let requests = super::default_requests();
    let tco = TcoModel::new(&sys.tco);
    let mut rows = Vec::new();
    let mut ratios = Vec::new();

    let mut t = Table::new(&[
        "model", "design", "CAPEX $", "OPEX $", "Mqueries/$", "gain",
    ]);
    // One saturated measurement per model × design, fanned out in parallel.
    let grid = super::support::cross2(&ModelId::ALL, &[PreprocMode::Cpu, PreprocMode::Dpu]);
    let measured =
        super::sweep(&grid, |&(model, preproc)| fig20::measure(model, preproc, requests, sys));
    for (mi, model) in ModelId::ALL.iter().enumerate() {
        let model = *model;
        let (q_base, p_base) = &measured[2 * mi];
        let (q_preba, p_preba) = &measured[2 * mi + 1];
        let r_base = tco.evaluate(*q_base, p_base, false);
        let r_preba = tco.evaluate(*q_preba, p_preba, true);
        let gain = r_preba.queries_per_usd / r_base.queries_per_usd;
        ratios.push(gain);
        for (label, r, g) in [("baseline", r_base, 1.0), ("PREBA", r_preba, gain)] {
            t.row(&[
                model.display().to_string(),
                label.to_string(),
                num(r.capex_usd),
                num(r.opex_usd),
                num(r.queries_per_usd / 1e6),
                num(g),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("design", Json::str(label)),
                ("capex", Json::num(r.capex_usd)),
                ("opex", Json::num(r.opex_usd)),
                ("queries_per_usd", Json::num(r.queries_per_usd)),
            ]));
        }
    }
    for line in t.render() {
        rep.row(&line);
    }
    let avg = support::geomean(&ratios);
    rep.row(&format!("\navg cost-efficiency gain: {avg:.2}x (paper: 3.0x)"));
    rep.data("rows", Json::Arr(rows));
    rep.data("avg_gain", Json::num(avg));
    rep.finish("fig21")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tco_gain_in_paper_band() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let avg = doc.get("data").unwrap().get("avg_gain").unwrap().as_f64().unwrap();
        assert!((2.0..6.0).contains(&avg), "TCO gain {avg}");
    }
}
