//! One module per paper figure/table (DESIGN.md §6 per-experiment index).
//!
//! Each experiment exposes `run(&PrebaConfig) -> Reporter`-style functions
//! returning the same rows/series the paper reports; the `benches/` bench
//! targets and the `preba experiment` CLI both call into here.

pub mod ablation;
pub mod cluster;
pub mod energy;
pub mod faults;
pub mod interference;
pub mod optimality;
pub mod packing;
pub mod reconfig;
pub mod support;

pub mod fig05;
pub mod fig06;
pub mod fig07;
pub mod fig08;
pub mod fig09;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig17;
pub mod fig18;
pub mod fig19;
pub mod fig20;
pub mod fig21;
pub mod fig22;
pub mod table1;

use crate::config::PrebaConfig;
use crate::util::json::Json;

/// Registry of all experiments for `preba experiment <id>` / `all`.
pub const ALL: [(&str, fn(&PrebaConfig) -> Json); 27] = [
    ("fig5", fig05::run),
    ("fig6", fig06::run),
    ("fig7", fig07::run),
    ("fig8", fig08::run),
    ("fig9", fig09::run),
    ("fig12", fig12::run),
    ("fig13", fig13::run),
    ("fig14", fig14::run),
    ("fig15", fig15::run),
    ("fig17", fig17::run),
    ("fig18", fig18::run),
    ("fig19", fig19::run),
    ("fig20", fig20::run),
    ("fig21", fig21::run),
    ("fig22", fig22::run),
    ("table1", table1::run),
    // Design-choice ablations beyond the paper's figures (DESIGN.md §8).
    ("abl_merge", ablation::run_merge),
    ("abl_policy", ablation::run_policy),
    ("abl_traffic", ablation::run_traffic),
    ("abl_dpu", ablation::run_dpu_granularity),
    // Online MIG reconfiguration + multi-tenant packing (beyond the
    // paper: reconfigurable machine scheduling / fragmentation).
    ("reconfig", reconfig::run),
    ("packing", packing::run),
    ("cluster", cluster::run),
    // Energy & cost accounting: DES-integrated power, TCO, and the
    // power-aware consolidation study (paper §6.2/§6.3 at fleet scale).
    ("energy", energy::run),
    // Fault injection & failure recovery: crashes, stragglers, outages
    // and the detect/retry/hedge/failover stack (fault::*).
    ("faults", faults::run),
    // Interference-aware performance/energy curves: flat vs curve-aware
    // provisioning beside saturating neighbor slices (MIGPerf scenario).
    ("interference", interference::run),
    // Reconfiguration-planner optimality gap: greedy vs anneal vs exact
    // on identical instances (RMSP, MIG-Serving arXiv:2109.11067).
    ("optimality", optimality::run),
];

/// Look up an experiment by id.
pub fn by_id(id: &str) -> Option<fn(&PrebaConfig) -> Json> {
    ALL.iter().find(|(k, _)| *k == id).map(|(_, f)| *f)
}

/// Request-budget mode, resolved once. Programmatic callers (the CLI's
/// `--fast`, lib tests, benches) inject it through [`set_fast`]; absent
/// that, the first `default_requests` call samples the `PREBA_FAST`
/// environment variable. Injection exists because the old idiom — tests
/// calling `std::env::set_var` — is UB on glibc once the test harness
/// runs threads in parallel (setenv racing getenv).
static FAST: once_cell::sync::OnceCell<bool> = once_cell::sync::OnceCell::new();

/// Choose the request-budget mode programmatically. First caller wins
/// (and an earlier `default_requests` call wins over both); safe to call
/// from any thread, idempotent across parallel tests.
pub fn set_fast(fast: bool) {
    let _ = FAST.set(fast);
}

/// True when running with CI-sized request budgets.
pub fn fast() -> bool {
    *FAST.get_or_init(|| std::env::var("PREBA_FAST").is_ok())
}

/// Shared default: fewer requests in fast mode (CI).
pub fn default_requests() -> usize {
    if fast() {
        2_000
    } else {
        8_000
    }
}

/// Fan a list of independent sweep cells out over the job pool
/// ([`crate::util::par`]), returning results in cell order so rendered
/// tables and JSON are identical to a serial sweep. Each cell must be a
/// pure function of its parameters (every simulation is seed-determined).
pub(crate) fn sweep<P: Sync, T: Send>(
    params: &[P],
    f: impl Fn(&P) -> T + Sync,
) -> Vec<T> {
    crate::util::par::run_jobs(params.len(), |i| f(&params[i]))
}
