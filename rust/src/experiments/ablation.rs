//! Design-choice ablations beyond the paper's Fig 22 (DESIGN.md §7/§8):
//!
//! * `merge`  — adjacent-bucket merging on/off (paper §4.3 last paragraph).
//! * `policy` — the `Time_queue = Time_knee / n_vGPUs` rule vs
//!   alternatives (full `Time_knee`, near-zero wait) and `knee_frac`
//!   sensitivity.
//! * `traffic` — PREBA vs the static baseline under non-stationary
//!   traffic (diurnal / bursty), where batching hyperparameters matter
//!   most (§3.2: "input traffic patterns are constantly changing").
//! * `dpu_granularity` — the paper's §4.2 motivation for SINGLE-INPUT
//!   DPU batches: a k-batched preprocessing accelerator adds group-fill
//!   wait and quantizes the downstream batcher's choices.

use crate::config::PrebaConfig;
use crate::mig::{MigConfig, ServiceModel};
use crate::models::ModelId;
use crate::server::{sim_driver, PolicyKind, PreprocMode, SimConfig};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};
use crate::workload::RateProfile;

use super::support;

/// Adjacent-bucket merging on/off.
pub fn run_merge(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Ablation: adjacent-bucket merging (paper §4.3)");
    let requests = super::default_requests();
    let mut rows = Vec::new();
    let mut t = Table::new(&["model", "load", "merge", "QPS", "p95 ms", "mean batch"]);
    // Sweep grid: model × load × merge flag, one simulation per cell.
    // Low load is where merging matters: buckets rarely fill alone.
    let grid = support::cross3(&ModelId::AUDIO, &[0.15, 0.5], &[false, true]);
    let outs = super::sweep(&grid, |&(model, load_frac, merge)| {
        let cap =
            SimConfig::new(model, MigConfig::Small7, PreprocMode::Dpu).saturating_rate() / 1.25;
        let mut sys2 = sys.clone();
        sys2.batching.merge_adjacent = merge;
        support::run(
            model,
            MigConfig::Small7,
            PreprocMode::Dpu,
            PolicyKind::Dynamic,
            7,
            cap * load_frac,
            requests,
            &sys2,
        )
    });
    let mut cells = grid.iter().zip(outs.iter());
    for model in ModelId::AUDIO {
        for load_frac in [0.15, 0.5] {
            for merge in [false, true] {
                let (_, out) = cells.next().expect("grid exhausted");
                t.row(&[
                    model.display().to_string(),
                    format!("{:.0}%", load_frac * 100.0),
                    merge.to_string(),
                    num(out.qps()),
                    num(out.p95_ms()),
                    num(out.stats.batch_sizes.mean()),
                ]);
                rows.push(Json::obj(vec![
                    ("model", Json::str(model.name())),
                    ("load", Json::num(load_frac)),
                    ("merge", Json::Bool(merge)),
                    ("qps", Json::num(out.qps())),
                    ("p95_ms", Json::num(out.p95_ms())),
                    ("mean_batch", Json::num(out.stats.batch_sizes.mean())),
                ]));
            }
        }
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("rows", Json::Arr(rows));
    rep.finish("abl_merge")
}

/// Time_queue rule + knee_frac sensitivity.
pub fn run_policy(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Ablation: Time_queue rule and knee_frac sensitivity");
    let requests = super::default_requests();
    let model = ModelId::ConformerDefault;
    let cap = SimConfig::new(model, MigConfig::Small7, PreprocMode::Dpu).saturating_rate() / 1.25;

    rep.section("Time_queue rule at 60% load (paper rule: Time_knee / n_vGPUs)");
    let mut t = Table::new(&["rule", "QPS", "p95 ms", "mean batch", "gpu util %"]);
    let mut rows = Vec::new();
    // One simulation per Time_queue rule, in parallel.
    let rules: [(&str, f64); 3] =
        [("Time_knee/n (PREBA)", 1.0 / 7.0), ("Time_knee", 1.0), ("~zero wait", 0.01 / 7.0)];
    let rule_outs = super::sweep(&rules, |&(_, scale)| {
        run_with_time_queue_scale(model, cap * 0.6, scale * 7.0, requests, sys)
    });
    for (&(label, _), out) in rules.iter().zip(rule_outs.iter()) {
        t.row(&[
            label.to_string(),
            num(out.qps()),
            num(out.p95_ms()),
            num(out.stats.batch_sizes.mean()),
            num(out.gpu_util * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("rule", Json::str(label)),
            ("qps", Json::num(out.qps())),
            ("p95_ms", Json::num(out.p95_ms())),
            ("mean_batch", Json::num(out.stats.batch_sizes.mean())),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("time_queue_rules", Json::Arr(rows));

    rep.section("knee_frac sensitivity (Batch_max selection)");
    let mut t = Table::new(&["knee_frac", "MobileNet knee(1g)", "Swin knee(1g)", "Citri knee@5s"]);
    let mut rows = Vec::new();
    // One profiling job per knee_frac; each re-seeds its own RNG (the
    // serial code did the same per iteration) so fan-out preserves output.
    let fracs = [0.80, 0.90, 0.95];
    let knees = super::sweep(&fracs, |&frac| {
        let mut rng = crate::util::Rng::new(77);
        let grid = crate::profiler::sweep_batches_dense(256);
        let mut knee = |m: ModelId, len: f64| {
            let curve = crate::profiler::profile_curve(m.spec(), 1, len, &grid, 60, &mut rng);
            crate::profiler::find_knee(&curve, frac).batch
        };
        (
            knee(ModelId::MobileNet, 0.0),
            knee(ModelId::SwinTransformer, 0.0),
            knee(ModelId::CitriNet, 5.0),
        )
    });
    for (&frac, &(a, b, c)) in fracs.iter().zip(knees.iter()) {
        t.row(&[format!("{frac}"), a.to_string(), b.to_string(), c.to_string()]);
        rows.push(Json::obj(vec![
            ("frac", Json::num(frac)),
            ("mobilenet", Json::num(a as f64)),
            ("swin", Json::num(b as f64)),
            ("citrinet_5s", Json::num(c as f64)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("knee_frac", Json::Arr(rows));
    rep.finish("abl_policy")
}

/// Helper: run with every bucket's Time_queue scaled (rule ablation).
fn run_with_time_queue_scale(
    model: ModelId,
    rate: f64,
    n_divisor_override: f64,
    requests: usize,
    sys: &PrebaConfig,
) -> sim_driver::SimOutcome {
    // The paper rule divides Time_knee by n_vgpus; we emulate other rules
    // by pretending a different divisor via active_servers in the policy
    // build. Simplest faithful route: run the standard dynamic policy but
    // scale static_time_queue via a custom config is not applicable; so
    // we rebuild via PolicyKind::Dynamic with a modified vGPU count in the
    // Time_queue derivation only. We approximate by scaling
    // `bucket_window_s`-independent knob: run with the standard policy
    // when divisor==7 and with a custom config otherwise.
    let mut cfg = SimConfig::new(model, MigConfig::Small7, PreprocMode::Dpu);
    cfg.policy = PolicyKind::Dynamic;
    cfg.requests = requests;
    cfg.rate_qps = rate;
    // Encode the rule by overriding the divisor through the seed-free
    // path: we exploit that Time_queue scales 1/n — setting
    // `time_queue_divisor` on the config.
    let mut sys2 = sys.clone();
    sys2.batching.time_queue_divisor = Some(n_divisor_override);
    sim_driver::run(&cfg, &sys2)
}

/// PREBA vs static baseline under non-stationary traffic.
pub fn run_traffic(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Ablation: traffic shape (constant / diurnal / bursty)");
    let requests = super::default_requests();
    let model = ModelId::CitriNet;
    let cap = SimConfig::new(model, MigConfig::Small7, PreprocMode::Dpu).saturating_rate() / 1.25;
    let mean = cap * 0.5;
    let profiles: [(&str, RateProfile); 3] = [
        ("constant", RateProfile::Constant { qps: mean }),
        (
            "diurnal",
            RateProfile::Diurnal {
                base_qps: mean,
                amplitude: 0.7,
                period_s: 30.0,
                phase_frac: 0.0,
            },
        ),
        (
            "bursty",
            RateProfile::Bursty {
                quiet_qps: mean * 0.25,
                burst_qps: mean * 2.5,
                mean_quiet_s: 4.0,
                mean_burst_s: 1.5,
            },
        ),
    ];
    let mut t = Table::new(&["traffic", "policy", "QPS", "p95 ms", "p99 ms"]);
    let mut rows = Vec::new();
    // Sweep grid: traffic shape × policy, one simulation per cell.
    let grid: Vec<(&str, RateProfile, PolicyKind)> =
        support::cross2(&profiles, &[PolicyKind::Static, PolicyKind::Dynamic])
            .into_iter()
            .map(|((name, profile), policy)| (name, profile, policy))
            .collect();
    let outs = super::sweep(&grid, |(_, profile, policy)| {
        let mut cfg = SimConfig::new(model, MigConfig::Small7, PreprocMode::Dpu);
        cfg.policy = *policy;
        cfg.requests = requests;
        cfg.rate_qps = mean;
        cfg.profile = Some(profile.clone());
        sim_driver::run(&cfg, sys)
    });
    let mut cells = grid.iter().zip(outs.iter());
    for &(name, _) in &profiles {
        for policy in [PolicyKind::Static, PolicyKind::Dynamic] {
            let (_, out) = cells.next().expect("grid exhausted");
            t.row(&[
                name.to_string(),
                format!("{policy:?}"),
                num(out.qps()),
                num(out.p95_ms()),
                num(out.stats.e2e_ms.p99()),
            ]);
            rows.push(Json::obj(vec![
                ("traffic", Json::str(name)),
                (
                    "policy",
                    Json::str(if policy == PolicyKind::Static { "static" } else { "dynamic" }),
                ),
                ("qps", Json::num(out.qps())),
                ("p95_ms", Json::num(out.p95_ms())),
            ]));
        }
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("rows", Json::Arr(rows));
    rep.finish("abl_traffic")
}

/// Single-input vs k-batched DPU preprocessing (paper §4.2 motivation).
pub fn run_dpu_granularity(_sys: &PrebaConfig) -> Json {
    let mut rep =
        Reporter::new("Ablation: DPU preprocessing granularity (single-input vs k-batched)");
    rep.section("added preprocessing-stage latency at a 1g.5gb(7x) moderate load");
    let mut t =
        Table::new(&["model", "k", "group-fill p95 ms", "flexibility (batch sizes reachable)"]);
    let mut rows = Vec::new();
    for model in [ModelId::MobileNet, ModelId::CitriNet] {
        let sm = ServiceModel::new(model.spec(), 1);
        let len = if model.kind() == crate::models::ModelKind::Audio { 2.5 } else { 0.0 };
        let lambda = 0.6 * 7.0 * sm.plateau_qps(len); // offered load
        let knee = sm.knee(len);
        for k in [1usize, 4, 16] {
            // A k-batched DPU releases preprocessed inputs in groups of k:
            // the first request of a group waits for k-1 more arrivals.
            // P95 of Erlang(k-1, lambda) ≈ quantile of the gamma.
            let p95_fill_ms = if k == 1 {
                0.0
            } else {
                // crude gamma quantile: mean + 1.65 * std
                let mean = (k - 1) as f64 / lambda;
                let std = ((k - 1) as f64).sqrt() / lambda;
                (mean + 1.65 * std) * 1e3
            };
            // Downstream batcher can only form batches in multiples of k.
            let reachable = (1..=knee).filter(|b| b % k == 0 || k == 1).count();
            t.row(&[
                model.display().to_string(),
                k.to_string(),
                num(p95_fill_ms),
                format!("{reachable}/{knee}"),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("k", Json::num(k as f64)),
                ("fill_p95_ms", Json::num(p95_fill_ms)),
                ("reachable", Json::num(reachable as f64)),
                ("knee", Json::num(knee as f64)),
            ]));
        }
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.row(
        "single-input (k=1) adds zero fill latency and reaches every batch size — the paper's design point.",
    );
    rep.data("rows", Json::Arr(rows));
    rep.finish("abl_dpu")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merging_helps_tail_latency_at_low_load() {
        crate::experiments::set_fast(true);
        let doc = run_merge(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        // At 15% load, for each audio model: merge=true p95 <= merge=false.
        let mut wins = 0;
        let mut total = 0;
        for model in ModelId::AUDIO {
            let get = |merge: bool| -> f64 {
                rows.iter()
                    .find(|r| {
                        r.get("model").unwrap().as_str() == Some(model.name())
                            && r.get("load").unwrap().as_f64() == Some(0.15)
                            && r.get("merge").unwrap().as_bool() == Some(merge)
                    })
                    .unwrap()
                    .get("p95_ms")
                    .unwrap()
                    .as_f64()
                    .unwrap()
            };
            total += 1;
            if get(true) <= get(false) * 1.05 {
                wins += 1;
            }
        }
        assert!(wins >= total - 1, "merging regressed tails: {wins}/{total}");
    }

    #[test]
    fn bursty_traffic_widens_dynamic_advantage() {
        crate::experiments::set_fast(true);
        let doc = run_traffic(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        let p95 = |traffic: &str, policy: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("traffic").unwrap().as_str() == Some(traffic)
                        && r.get("policy").unwrap().as_str() == Some(policy)
                })
                .unwrap()
                .get("p95_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // Dynamic beats static under every traffic shape.
        for t in ["constant", "diurnal", "bursty"] {
            assert!(p95(t, "dynamic") < p95(t, "static"), "{t}");
        }
    }

    #[test]
    fn dpu_k1_is_strictly_most_flexible() {
        let doc = run_dpu_granularity(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        for r in rows {
            let k = r.get("k").unwrap().as_usize().unwrap();
            let fill = r.get("fill_p95_ms").unwrap().as_f64().unwrap();
            if k == 1 {
                assert_eq!(fill, 0.0);
                assert_eq!(
                    r.get("reachable").unwrap().as_usize(),
                    r.get("knee").unwrap().as_usize()
                );
            } else {
                assert!(fill > 0.0);
            }
        }
    }
}
