//! Figure 7: average-latency breakdown when 1g.5gb(7x) and 7g.40gb(1x)
//! are each configured with the Batch_max that sustains the SAME
//! end-to-end throughput (preprocessing disabled).
//!
//! Paper shape: the small-slice configuration spends far less time in the
//! "Batching" stage because its Batch_max is much smaller.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 7: latency breakdown at iso-throughput, 1g(7x) vs 7g(1x)");
    let requests = super::default_requests();
    let mut data = Vec::new();

    // Phase 1 (parallel): saturated QPS per model × config to locate the
    // iso-throughput point.
    let mut sat_grid = Vec::new();
    for model in ModelId::ALL {
        for cfg in [MigConfig::Small7, MigConfig::Full1] {
            sat_grid.push((model, cfg));
        }
    }
    let sats = super::sweep(&sat_grid, |&(model, cfg)| {
        support::saturated_qps(
            model, cfg, PreprocMode::Ideal, PolicyKind::Dynamic, cfg.vgpus(), requests, sys,
        )
        .qps()
    });
    // Phase 2 (parallel): the measured runs at 80% of the weaker config.
    let mut run_grid = Vec::new();
    for (mi, model) in ModelId::ALL.iter().enumerate() {
        let rate = 0.8 * sats[2 * mi].min(sats[2 * mi + 1]);
        for cfg in [MigConfig::Small7, MigConfig::Full1] {
            run_grid.push((*model, cfg, rate));
        }
    }
    let outs = super::sweep(&run_grid, |&(model, cfg, rate)| {
        support::run(
            model, cfg, PreprocMode::Ideal, PolicyKind::Dynamic, cfg.vgpus(), rate, requests, sys,
        )
    });

    let mut cells = run_grid.iter().zip(outs.iter());
    for model in ModelId::ALL {
        rep.section(model.display());
        let mut t =
            Table::new(&["config", "QPS", "batching ms", "dispatch ms", "exec ms", "total ms"]);
        for _ in 0..2 {
            let (&(_, cfg, _), out) = cells.next().expect("grid exhausted");
            let (_pre, bat, disp, exec) = out.stats.breakdown_ms();
            t.row(&[
                cfg.name().to_string(),
                num(out.qps()),
                num(bat),
                num(disp),
                num(exec),
                num(out.stats.mean_ms()),
            ]);
            data.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("config", Json::str(cfg.name())),
                ("qps", Json::num(out.qps())),
                ("batching_ms", Json::num(bat)),
                ("exec_ms", Json::num(exec)),
                ("total_ms", Json::num(out.stats.mean_ms())),
            ]));
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("rows", Json::Arr(data));
    rep.finish("fig07")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_slices_spend_less_time_batching() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        // For MobileNet, batching time on 1g(7x) must be below 7g(1x).
        let get = |cfg: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("model").unwrap().as_str() == Some("mobilenet")
                        && r.get("config").unwrap().as_str() == Some(cfg)
                })
                .unwrap()
                .get("batching_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(
            get("1g.5gb(7x)") < get("7g.40gb(1x)"),
            "batching {} vs {}",
            get("1g.5gb(7x)"),
            get("7g.40gb(1x)")
        );
    }
}
