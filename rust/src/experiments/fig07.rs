//! Figure 7: average-latency breakdown when 1g.5gb(7x) and 7g.40gb(1x)
//! are each configured with the Batch_max that sustains the SAME
//! end-to-end throughput (preprocessing disabled).
//!
//! Paper shape: the small-slice configuration spends far less time in the
//! "Batching" stage because its Batch_max is much smaller.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 7: latency breakdown at iso-throughput, 1g(7x) vs 7g(1x)");
    let requests = super::default_requests();
    let mut data = Vec::new();

    for model in ModelId::ALL {
        rep.section(model.display());
        // Iso-throughput point: 80% of the weaker config's saturated QPS.
        let sat_small = support::saturated_qps(
            model, MigConfig::Small7, PreprocMode::Ideal, PolicyKind::Dynamic, 7, requests, sys,
        )
        .qps();
        let sat_full = support::saturated_qps(
            model, MigConfig::Full1, PreprocMode::Ideal, PolicyKind::Dynamic, 1, requests, sys,
        )
        .qps();
        let rate = 0.8 * sat_small.min(sat_full);

        let mut t = Table::new(&["config", "QPS", "batching ms", "dispatch ms", "exec ms", "total ms"]);
        for cfg in [MigConfig::Small7, MigConfig::Full1] {
            let out = support::run(
                model, cfg, PreprocMode::Ideal, PolicyKind::Dynamic, cfg.vgpus(), rate, requests, sys,
            );
            let (_pre, bat, disp, exec) = out.stats.breakdown_ms();
            t.row(&[
                cfg.name().to_string(),
                num(out.qps()),
                num(bat),
                num(disp),
                num(exec),
                num(out.stats.mean_ms()),
            ]);
            data.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("config", Json::str(cfg.name())),
                ("qps", Json::num(out.qps())),
                ("batching_ms", Json::num(bat)),
                ("exec_ms", Json::num(exec)),
                ("total_ms", Json::num(out.stats.mean_ms())),
            ]));
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("rows", Json::Arr(data));
    rep.finish("fig07")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_slices_spend_less_time_batching() {
        std::env::set_var("PREBA_FAST", "1");
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        // For MobileNet, batching time on 1g(7x) must be below 7g(1x).
        let get = |cfg: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("model").unwrap().as_str() == Some("mobilenet")
                        && r.get("config").unwrap().as_str() == Some(cfg)
                })
                .unwrap()
                .get("batching_ms")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(
            get("1g.5gb(7x)") < get("7g.40gb(1x)"),
            "batching {} vs {}",
            get("1g.5gb(7x)"),
            get("7g.40gb(1x)")
        );
    }
}
