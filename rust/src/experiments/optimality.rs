//! Optimality gap of the reconfiguration planner stack (RMSP,
//! MIG-Serving arXiv:2109.11067).
//!
//! The cluster controller's greedy fast path decides in microseconds,
//! but how much plan quality does that speed cost? This experiment
//! builds *identical* rebalance instances — the diurnal fleet with a
//! hot/cold rate split, and a replay-flavored lognormal rate draw — and
//! hands each one to all three [`Planner`]s:
//!
//! * `greedy` — the deterministic worst-deficit heuristic the controller
//!   ships with,
//! * `anneal` — greedy-seeded simulated annealing (never worse, by
//!   construction),
//! * `exact` — branch-and-bound ground truth, run on fleets ≤ 16 GPUs.
//!
//! Reported per fleet size: each planner's [`plan_cost`] (latency mass
//! over one cooldown + amortized outage, queue-seconds), its optimality
//! gap against the best plan found, and its planning latency. The
//! latency columns are wall-clock measurements — report-only, never
//! asserted — while the cost ordering IS asserted: anneal ≤ greedy and
//! exact ≤ anneal on every instance (the 8-GPU rows are the acceptance
//! gate).

use crate::mig::reconfig::planners::{
    plan_cost, AnnealPlanner, ExactPlanner, GreedyPlanner, OwnedInstance, Planner,
};
use crate::mig::placement::{pack_fleet, SliceAsk};
use crate::mig::TenantSpec;
use crate::prelude::*;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

/// Rate multipliers of the hot/cold split (diurnal flavor): strong
/// enough that hot tenants size past their packed instance count and
/// cold tenants hold surplus — the planner must cross tenants (and
/// often GPUs) to close the deficit.
const HOT: f64 = 1.8;
const COLD: f64 = 0.4;

/// Largest fleet the exact solver is asked to certify.
const EXACT_MAX_GPUS: usize = 16;

/// One rebalance instance over `n_gpus` A100s: the `cluster`
/// experiment's diurnal tenant mix (per 2 GPUs: 3×1g.5gb, 1×3g.20gb,
/// 2×4g.20gb), packed best-fit at its base rates, then re-rated by
/// `flavor` so the packed allocation no longer matches demand.
///
/// * `"diurnal"` — deterministic hot/cold split: odd tenants run at
///   [`HOT`]× base, even at [`COLD`]× (the anti-phase diurnal extreme).
/// * `"replay"` — seeded lognormal rate draw per tenant (σ=0.6), the
///   shape of replayed production traces.
pub fn instance(sys: &PrebaConfig, n_gpus: usize, flavor: &str) -> OwnedInstance {
    let base = super::cluster::diurnal_fleet(n_gpus, 1.0);
    let fleet = vec![GpuClass::A100; n_gpus];
    let asks: Vec<SliceAsk> = base
        .iter()
        .enumerate()
        .flat_map(|(ti, t)| {
            std::iter::repeat(SliceAsk { tenant: ti, slice: t.slice }).take(t.slices)
        })
        .collect();
    let packing = pack_fleet(&asks, &fleet, PackStrategy::BestFit);
    let mut alloc = vec![vec![0usize; base.len()]; n_gpus];
    for (ask, gpu) in &packing.placements {
        alloc[*gpu][ask.tenant] += 1;
    }
    let mut rng = Rng::new(0x09CA_1117 ^ n_gpus as u64);
    let rates: Vec<f64> = base
        .iter()
        .enumerate()
        .map(|(ti, t)| match flavor {
            "replay" => t.rate_qps * rng.lognormal(0.0, 0.6),
            _ => t.rate_qps * if ti % 2 == 1 { HOT } else { COLD },
        })
        .collect();
    let tenants: Vec<TenantSpec> =
        base.iter().map(|t| TenantSpec::new(t.model, t.sla_ms)).collect();
    let slices: Vec<Slice> = base.iter().map(|t| t.slice).collect();
    let mut policy = super::cluster::policy(sys);
    policy.anneal_iters = if super::fast() { 400 } else { sys.reconfig.anneal_iters };
    OwnedInstance {
        tenants,
        slices,
        rates,
        alloc,
        fleet,
        policy,
        scales: vec![1.0; base.len()],
    }
}

/// The 64-GPU diurnal instance the `perf_cluster` bench probes
/// (`planner_gap` / `planner_greedy_p99_us` BENCH keys).
pub fn bench_instance(sys: &PrebaConfig, n_gpus: usize) -> OwnedInstance {
    instance(sys, n_gpus, "diurnal")
}

struct Cell {
    flavor: &'static str,
    n_gpus: usize,
    greedy_cost: f64,
    anneal_cost: f64,
    exact_cost: Option<f64>,
    greedy_ms: f64,
    anneal_ms: f64,
    exact_ms: Option<f64>,
    moves: usize,
}

fn solve(sys: &PrebaConfig, flavor: &'static str, n_gpus: usize) -> Cell {
    let own = instance(sys, n_gpus, flavor);
    let inst = own.as_instance();
    let mut plans: Vec<Vec<crate::mig::SliceMove>> = Vec::new();
    let mut timed = |p: &dyn Planner| -> (f64, f64) {
        let t0 = std::time::Instant::now();
        let plan = p.plan(&inst);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let cost = plan_cost(&inst, &plan);
        plans.push(plan);
        (cost, ms)
    };
    let (greedy_cost, greedy_ms) = timed(&GreedyPlanner);
    let (anneal_cost, anneal_ms) = timed(&AnnealPlanner::budgeted(own.policy.anneal_iters));
    let (exact_cost, exact_ms) = if n_gpus <= EXACT_MAX_GPUS {
        let exact = ExactPlanner {
            max_gpus: EXACT_MAX_GPUS,
            node_budget: if super::fast() { 20_000 } else { 200_000 },
        };
        let (c, ms) = timed(&exact);
        (Some(c), Some(ms))
    } else {
        (None, None)
    };
    // Every plan must replay cleanly — the shared validity contract.
    let failed = vec![false; own.fleet.len()];
    for plan in &plans {
        crate::mig::validate_plan(&own.slices, &own.fleet, &failed, &own.alloc, plan)
            .expect("planner emitted an invalid plan");
    }
    Cell {
        flavor,
        n_gpus,
        greedy_cost,
        anneal_cost,
        exact_cost,
        greedy_ms,
        anneal_ms,
        exact_ms,
        moves: plans[0].len(),
    }
}

fn gap_pct(cost: f64, best: f64) -> f64 {
    if best <= 0.0 {
        0.0
    } else {
        (cost / best - 1.0) * 100.0
    }
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new(
        "Optimality gap: greedy vs anneal vs exact reconfiguration planning (RMSP)",
    );
    let sizes: Vec<usize> =
        if super::fast() { vec![8, 16] } else { vec![8, 16, 64, 256] };
    let cells: Vec<(&'static str, usize)> = ["diurnal", "replay"]
        .into_iter()
        .flat_map(|f| sizes.iter().map(move |&n| (f, n)))
        .collect();
    let solved = super::sweep(&cells, |&(flavor, n)| solve(sys, flavor, n));

    let mut rows = Vec::new();
    for flavor in ["diurnal", "replay"] {
        rep.section(&format!(
            "{flavor} workload: plan cost (queue-seconds, lower is better) vs fleet size"
        ));
        let mut t = Table::new(&[
            "GPUs", "moves", "greedy cost", "anneal cost", "exact cost", "greedy gap %",
            "anneal gap %", "greedy ms", "anneal ms", "exact ms",
        ]);
        for c in solved.iter().filter(|c| c.flavor == flavor) {
            // Ground truth where the exact solver ran; otherwise the best
            // plan any planner found (anneal, by the never-worse chain).
            let best = c.exact_cost.unwrap_or(c.anneal_cost.min(c.greedy_cost));
            t.row(&[
                c.n_gpus.to_string(),
                c.moves.to_string(),
                num(c.greedy_cost),
                num(c.anneal_cost),
                c.exact_cost.map_or("-".into(), num),
                num(gap_pct(c.greedy_cost, best)),
                num(gap_pct(c.anneal_cost, best)),
                num(c.greedy_ms),
                num(c.anneal_ms),
                c.exact_ms.map_or("-".into(), num),
            ]);
            rows.push(Json::obj(vec![
                ("flavor", Json::str(flavor)),
                ("gpus", Json::num(c.n_gpus as f64)),
                ("greedy_cost", Json::num(c.greedy_cost)),
                ("anneal_cost", Json::num(c.anneal_cost)),
                ("exact_cost", c.exact_cost.map_or(Json::Null, Json::num)),
                ("greedy_gap_pct", Json::num(gap_pct(c.greedy_cost, best))),
                ("anneal_gap_pct", Json::num(gap_pct(c.anneal_cost, best))),
                ("greedy_ms", Json::num(c.greedy_ms)),
                ("anneal_ms", Json::num(c.anneal_ms)),
                ("exact_ms", c.exact_ms.map_or(Json::Null, Json::num)),
            ]));
        }
        for line in t.render() {
            rep.row(&line);
        }
    }

    // Acceptance gate: on the 8-GPU instances the solver chain must be
    // monotone — anneal never above greedy, exact never above anneal.
    // (True at every size by construction; asserted where exact runs.)
    for c in solved.iter().filter(|c| c.n_gpus <= EXACT_MAX_GPUS) {
        assert!(
            c.anneal_cost <= c.greedy_cost + 1e-9,
            "{} @ {} GPUs: anneal {} worse than greedy {}",
            c.flavor,
            c.n_gpus,
            c.anneal_cost,
            c.greedy_cost
        );
        let exact = c.exact_cost.expect("exact runs at small sizes");
        assert!(
            exact <= c.anneal_cost + 1e-9,
            "{} @ {} GPUs: exact {} worse than anneal {}",
            c.flavor,
            c.n_gpus,
            exact,
            c.anneal_cost
        );
    }
    rep.row("solver chain verified: exact <= anneal <= greedy on every small-fleet instance");
    rep.data("gap", Json::Arr(rows));
    rep.finish("optimality")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instances_are_deterministic_and_planners_ordered_at_8_gpus() {
        crate::experiments::set_fast(true);
        let sys = PrebaConfig::new();
        let a = instance(&sys, 8, "diurnal");
        let b = instance(&sys, 8, "diurnal");
        assert_eq!(a.alloc, b.alloc);
        assert_eq!(a.rates, b.rates);
        // The hot/cold split must leave real work: some tenant under-
        // provisioned against its sizing rule, so planners emit moves.
        let cell = super::solve(&sys, "diurnal", 8);
        assert!(cell.moves > 0, "instance demands no rebalance — perturb harder");
        assert!(cell.anneal_cost <= cell.greedy_cost + 1e-9);
        assert!(cell.exact_cost.unwrap() <= cell.anneal_cost + 1e-9);
    }
}
