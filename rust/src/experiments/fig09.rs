//! Figure 9: throughput (left) and CPU utilization (right) as a function
//! of the number of inference servers activated within 1g.5gb(7x).
//!
//! Paper shape: CPU utilization saturates ~90% with only a few servers;
//! throughput stops scaling beyond that point while the idle vGPUs starve.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 9: scaling active servers under CPU preprocessing");
    let requests = super::default_requests();
    let mut all = Vec::new();

    // Sweep grid: model × active-server count, one simulation per cell.
    let servers: Vec<usize> = (1..=7).collect();
    let grid = support::cross2(&ModelId::ALL, &servers);
    let outs = super::sweep(&grid, |&(model, servers)| {
        // S3 protocol: audio inputs fixed at 2.5 s.
        support::saturated_qps_fixed_len(
            model,
            MigConfig::Small7,
            PreprocMode::Cpu,
            PolicyKind::Dynamic,
            servers,
            2.5,
            requests,
            sys,
        )
    });

    let mut cells = grid.iter().zip(outs.iter());
    for model in ModelId::ALL {
        rep.section(model.display());
        let mut t = Table::new(&["servers", "QPS", "CPU util %"]);
        for servers in 1..=7usize {
            let (_, out) = cells.next().expect("grid exhausted");
            t.row(&[servers.to_string(), num(out.qps()), num(out.cpu_util * 100.0)]);
            all.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("servers", Json::num(servers as f64)),
                ("qps", Json::num(out.qps())),
                ("cpu_util", Json::num(out.cpu_util)),
            ]));
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("rows", Json::Arr(all));
    rep.finish("fig09")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_saturates_and_throughput_flattens() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        let get = |m: &str, s: usize, k: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("model").unwrap().as_str() == Some(m)
                        && r.get("servers").unwrap().as_usize() == Some(s)
                })
                .unwrap()
                .get(k)
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // CitriNet: CPU saturated already with 1-2 servers.
        assert!(get("citrinet", 2, "cpu_util") > 0.85);
        // Throughput gain from 4 -> 7 servers is marginal once saturated.
        let q4 = get("citrinet", 4, "qps");
        let q7 = get("citrinet", 7, "qps");
        assert!(q7 < q4 * 1.25, "q4={q4} q7={q7}");
        // MobileNet: also preprocessing-bound well below 7 servers.
        assert!(get("mobilenet", 7, "cpu_util") > 0.85);
    }
}
