//! Figure 14: tail-latency heat map over (batch size × audio length) for
//! Conformer(default) on 1g.5gb(7x) and 7g.40gb(1x). The Batch_knee ridge
//! is where the color transitions (paper: green -> yellow at ~35 ms).

use crate::config::PrebaConfig;
use crate::mig::{MigConfig, ServiceModel};
use crate::models::ModelId;
use crate::util::bench::Reporter;
use crate::util::json::Json;

pub fn run(_sys: &PrebaConfig) -> Json {
    let mut rep =
        Reporter::new("Fig 14: p95 latency heatmap, batch x audio length, Conformer(default)");
    let model = ModelId::ConformerDefault;
    let batches: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 64];
    let lens: Vec<f64> = (1..=10).map(|i| i as f64 * 2.5).collect();
    // One analytic grid job per MIG config; rows are pre-rendered in the
    // job and replayed in order so fan-out preserves the report.
    let cfgs = [MigConfig::Small7, MigConfig::Full1];
    let mut grids = super::sweep(&cfgs, |&cfg| {
        let sm = ServiceModel::new(model.spec(), cfg.gpcs_per_vgpu());
        let mut lines = Vec::new();
        let mut cells = Vec::new();
        for &len in &lens {
            let mut line = format!("{len:>6.1} ");
            for &b in &batches {
                let ms = sm.exec_secs(b, len) * 1e3;
                // Color-class the cell like the heatmap: <35 "ok",
                // 35-70 "knee", >70 "hot".
                let mark = if ms < 35.0 {
                    '.'
                } else if ms < 70.0 {
                    'o'
                } else {
                    'X'
                };
                line.push_str(&format!("{:>6.0}{mark}", ms));
                cells.push(Json::obj(vec![
                    ("config", Json::str(cfg.name())),
                    ("len_s", Json::num(len)),
                    ("batch", Json::num(b as f64)),
                    ("ms", Json::num(ms)),
                ]));
            }
            lines.push(line);
        }
        let knees: Vec<String> =
            lens.iter().map(|&l| format!("{}@{l}s", sm.knee(l))).collect();
        lines.push(format!("Batch_knee ridge: {}", knees.join(", ")));
        (lines, Json::Arr(cells))
    });

    let header = batches.iter().map(|b| format!("{b:>7}")).collect::<Vec<_>>().join("");
    for (cfg, (lines, _)) in cfgs.iter().zip(grids.iter()) {
        rep.section(&format!("{} (rows: length s, cols: batch; cell: mean exec ms)", cfg.name()));
        rep.row(&format!("  len\\b {header}"));
        for line in lines {
            rep.row(line);
        }
    }
    let (_, grid_full1) = grids.remove(1);
    let (_, grid_small7) = grids.remove(0);
    rep.data("grid_small7", grid_small7);
    rep.data("grid_full1", grid_full1);
    rep.finish("fig14")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knee_ridge_shifts_down_with_length_and_up_with_gpcs() {
        let _ = run(&PrebaConfig::new());
        let m = ModelId::ConformerDefault.spec();
        let sm1 = ServiceModel::new(m, 1);
        let sm7 = ServiceModel::new(m, 7);
        assert!(sm1.knee(25.0) < sm1.knee(2.5));
        assert!(sm7.knee(5.0) > sm1.knee(5.0));
        // Latency at the ridge is ~35 ms wherever the knee is a real
        // batch (>= 2); at the batch=1 floor the single-input time rules
        // (the yellow batch-1 cells at the top of paper Fig 14a).
        for sm in [&sm1, &sm7] {
            for len in [5.0, 12.5, 25.0] {
                let knee = sm.knee(len);
                let ms = sm.exec_secs(knee, len) * 1e3;
                if knee >= 2 {
                    assert!((ms - 35.0).abs() < 10.0, "ridge at {ms} ms");
                } else {
                    assert!(ms > 25.0, "batch-1 floor below Time_knee: {ms}");
                }
            }
        }
    }
}
