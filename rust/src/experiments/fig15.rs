//! Figure 15: tail latency vs batch size for CitriNet and the two
//! Conformers on 1g.5gb(7x) at 5 / 15 / 25 s input lengths.
//!
//! Key observation to reproduce: the tail latency AT the knee
//! (`Time_knee`) is ~constant (~35 ms) regardless of input length, even
//! though the knee batch itself shifts.

use crate::config::PrebaConfig;
use crate::models::ModelId;
use crate::profiler;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};
use crate::util::Rng;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 15: tail latency vs batch at 5/15/25 s; Time_knee ~ const");
    // Dense grid: locating the knee precisely is the whole point here.
    let batches = profiler::sweep_batches_dense(128);
    let mut knees = Vec::new();

    // One profiling job per model × input length, seeded per cell.
    let grid = super::support::cross2(&ModelId::AUDIO, &[5.0, 15.0, 25.0]);
    let curves = super::sweep(&grid, |&(model, len)| {
        let mut rng = Rng::new(0x1500 ^ ((model as u64) << 8) ^ len as u64);
        profiler::profile_curve(model.spec(), 1, len, &batches, 60, &mut rng)
    });

    let mut cells = grid.iter().zip(curves.iter());
    for model in ModelId::AUDIO {
        rep.section(model.display());
        let mut t = Table::new(&["len s", "batch", "p95 ms", "knee?"]);
        for len in [5.0, 15.0, 25.0] {
            let (_, curve) = cells.next().expect("grid exhausted");
            let knee = profiler::find_knee(curve, sys.batching.knee_frac);
            for p in curve {
                if p.batch > knee.batch * 4 {
                    break; // the paper's plots stop shortly past the knee
                }
                t.row(&[
                    num(len),
                    p.batch.to_string(),
                    num(p.p95_ms),
                    if p.batch == knee.batch { "<-- knee".into() } else { String::new() },
                ]);
            }
            knees.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("len_s", Json::num(len)),
                ("knee_batch", Json::num(knee.batch as f64)),
                ("time_knee_ms", Json::num(knee.p95_ms)),
            ]));
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("knees", Json::Arr(knees));
    rep.finish("fig15")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_knee_constant_at_35ms_across_lengths() {
        let doc = run(&PrebaConfig::new());
        let knees = doc.get("data").unwrap().get("knees").unwrap().as_arr().unwrap();
        assert_eq!(knees.len(), 9); // 3 models x 3 lengths
        for k in knees {
            let knee_b = k.get("knee_batch").unwrap().as_usize().unwrap();
            if knee_b < 2 {
                // batch=1 floor: single-input time legitimately exceeds
                // Time_knee for long inputs on a 1g slice (Fig 14a).
                continue;
            }
            let t = k.get("time_knee_ms").unwrap().as_f64().unwrap();
            assert!(
                (t - 35.0).abs() < 14.0,
                "{}: Time_knee {t} ms drifted from 35 ms",
                k.get("model").unwrap().as_str().unwrap()
            );
        }
        // Knee batch shrinks as length grows (per model).
        for m in ModelId::AUDIO {
            let get = |len: f64| -> usize {
                knees
                    .iter()
                    .find(|k| {
                        k.get("model").unwrap().as_str() == Some(m.name())
                            && k.get("len_s").unwrap().as_f64() == Some(len)
                    })
                    .unwrap()
                    .get("knee_batch")
                    .unwrap()
                    .as_usize()
                    .unwrap()
            };
            assert!(get(5.0) >= get(25.0), "{m}");
        }
    }
}
