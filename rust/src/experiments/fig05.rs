//! Figure 5: model-execution throughput (bars) and GPU utilization (line)
//! vs per-vGPU batch size, preprocessing disabled, for the three MIG
//! configurations × six models.
//!
//! Paper shape to reproduce: utilization rises monotonically with batch
//! everywhere, but ramps much faster on 1g.5gb(7x); the fine-grained
//! partition's *aggregate* plateau exceeds 7g.40gb(1x).

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::profiler;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};
use crate::util::Rng;

pub fn run(_sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 5: exec throughput + GPU utilization vs batch (preproc off)");
    let batches = profiler::sweep_batches(256);

    // Sweep grid: model × MIG config, one profiling job per cell. Each
    // cell gets its own seeded RNG so results are independent of worker
    // count and scheduling.
    let grid = super::support::cross2(&ModelId::ALL, &MigConfig::ALL);
    let curves = super::sweep(&grid, |&(model, cfg)| {
        let mut rng = Rng::new(0x0500 ^ ((model as u64) << 8) ^ cfg.gpcs_per_vgpu() as u64);
        profiler::profile_curve(model.spec(), cfg.gpcs_per_vgpu(), 2.5, &batches, 40, &mut rng)
    });

    let mut cells = grid.iter().zip(curves.iter());
    for model in ModelId::ALL {
        rep.section(model.display());
        let mut t = Table::new(&["config", "batch", "agg QPS", "util %"]);
        let mut series = Vec::new();
        for _ in MigConfig::ALL {
            let (&(_, cfg), curve) = cells.next().expect("grid exhausted");
            for p in curve {
                let agg = p.qps * cfg.vgpus() as f64;
                t.row(&[
                    cfg.name().to_string(),
                    p.batch.to_string(),
                    num(agg),
                    num(p.util * 100.0),
                ]);
                series.push(Json::obj(vec![
                    ("config", Json::str(cfg.name())),
                    ("batch", Json::num(p.batch as f64)),
                    ("agg_qps", Json::num(agg)),
                    ("util", Json::num(p.util)),
                ]));
            }
        }
        for line in t.render() {
            rep.row(&line);
        }
        rep.data(model.name(), Json::Arr(series));
    }

    // Headline check rows: small-slice aggregate vs full GPU at plateau.
    rep.section("aggregate plateau: 1g.5gb(7x) vs 7g.40gb(1x)");
    let mut t = Table::new(&["model", "7x1g QPS", "1x7g QPS", "ratio"]);
    for model in ModelId::ALL {
        let small = crate::mig::ServiceModel::new(model.spec(), 1).plateau_qps(2.5) * 7.0;
        let full = crate::mig::ServiceModel::new(model.spec(), 7).plateau_qps(2.5);
        t.row(&[model.display().to_string(), num(small), num(full), num(small / full)]);
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.finish("fig05")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports_all_models() {
        let doc = run(&PrebaConfig::new());
        let data = doc.get("data").unwrap();
        for m in ModelId::ALL {
            assert!(data.get(m.name()).is_some(), "{m}");
        }
    }
}
