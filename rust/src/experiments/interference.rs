//! Interference-aware curves: flat-model vs curve-aware provisioning
//! under neighbor-slice contention (the HeteroMIG/MIGPerf scenario).
//!
//! MIG partitions isolate SMs and memory slices, but the uncore — L2
//! ways, HBM controllers — is shared, so a 1g slice surrounded by six
//! busy neighbors runs measurably slower than the same slice on an
//! otherwise-idle GPU (MIGPerf, arXiv 2301.00407). The `[curves]` layer
//! models exactly that: per-(model, profile, batch-bucket) latency/power
//! multipliers plus a per-profile contention coefficient that inflates
//! execution time by `1 + c·k` for `k` busy sibling slices at dispatch.
//!
//! This experiment stages the failure mode the curves exist to prevent:
//! one latency-SLA "main" tenant shares two A100s with saturating
//! background tenants, so its slices always see ~6 busy neighbors. A
//! planner that sizes the main tenant off the flat (isolated-slice)
//! plateau under-provisions — the contention-deflated capacity sits at
//! or below the offered rate and the tail diverges. The curve-aware
//! sizing rule ([`slices_for_rate_scaled`] with the tenant's
//! `service_scale`) buys one more slice and restores the SLA. Both cells
//! replay the same ground truth (curves ON); only the sizing differs.
//!
//! §2 shows the planner surface itself: predicted p95 as the neighbor
//! count climbs, flat vs curve-aware — the same scaled predictor the
//! cluster reconfiguration controller plans with when curves are on.

use crate::mig::reconfig::{predicted_p95_ms_gpcs_scaled, slices_for_rate_scaled, TenantSpec};
use crate::mig::ServiceModel;
use crate::prelude::*;
use crate::server::cluster;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

/// Main tenant's end-to-end p95 SLA, ms.
pub const MAIN_SLA_MS: f64 = 40.0;

/// Sizing rule's utilization target (the fraction of effective plateau
/// the planner is willing to load a slice to).
const TARGET_UTIL: f64 = 0.8;

fn swin_plateau_1g() -> f64 {
    ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0)
}

/// `sys` with the MIGPerf-calibrated `[curves]` layer switched on — the
/// ground truth both A/B cells replay under. `pub` so the CLI's
/// `--interference` flag and the perf bench stage the same world.
pub fn curved(sys: &PrebaConfig) -> PrebaConfig {
    let mut c = sys.clone();
    c.curves.enabled = true;
    c.curves.source = "migperf".to_string();
    c
}

/// The main tenant's curve-derived service-time scale on a fully
/// contended A100: batch-knee latency multiplier × the `1 + c·6`
/// neighbor penalty (six busy sibling 1g slices).
pub fn main_service_scale(csys: &PrebaConfig) -> f64 {
    let view = csys.curves.view(ModelId::SwinTransformer, 1);
    let knee = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).knee(0.0);
    view.service_scale(knee, 6)
}

/// One A/B cell: two A100s (14×1g), a latency-SLA main tenant plus
/// background tenants saturating every remaining slice. `curve_aware`
/// picks the sizing rule for the main tenant — flat plateau vs
/// contention-deflated plateau; everything else (load, seed, ground
/// truth) is identical. `csys` must be the [`curved`] system config.
pub fn scenario_cfg(curve_aware: bool, horizon_s: f64, csys: &PrebaConfig) -> ClusterConfig {
    let u = swin_plateau_1g();
    let rate = 2.3 * u;
    let spec = TenantSpec::new(ModelId::SwinTransformer, MAIN_SLA_MS);
    let scale = if curve_aware { main_service_scale(csys) } else { 1.0 };
    let main_slices =
        slices_for_rate_scaled(&spec, Slice::new(1, 5), rate, TARGET_UTIL, scale);
    let mut main =
        ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), main_slices, rate);
    main.sla_ms = MAIN_SLA_MS;
    main.requests = (rate * horizon_s).ceil() as usize;

    // Background: every slice the main tenant did not take, offered 90%
    // of the FLAT plateau per slice — above the contention-deflated
    // capacity, so the neighbors never drain and the main tenant's
    // dispatches always see a busy GPU. No latency SLA of their own.
    let bg_slices = 14 - main_slices;
    let bg_rate = 0.9 * bg_slices as f64 * u;
    let mut bg =
        ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), bg_slices, bg_rate);
    bg.sla_ms = 10_000.0;
    bg.requests = (bg_rate * horizon_s).ceil() as usize;

    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(vec![main, bg])
        .seed(0x1F01)
        .warmup_frac(0.05)
        .build()
}

/// Main tenant's SLA-violation fraction (tenant 0 in [`scenario_cfg`]).
pub fn main_violation_frac(out: &ClusterOutcome) -> f64 {
    out.violation_frac(0, MAIN_SLA_MS)
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Interference: curve-aware vs flat provisioning under contention");
    let horizon_s = if super::fast() { 8.0 } else { 16.0 };
    let csys = curved(sys);
    let scale = main_service_scale(&csys);

    // ---- Section 1: sizing A/B on identical contended ground truth. ----
    rep.section("latency-SLA tenant beside saturating neighbors: flat vs curve-aware sizing");
    rep.row(&format!(
        "main tenant service scale under full contention: {:.3} (knee batch x 1 + c*6)",
        scale
    ));
    let modes = [false, true];
    let cfgs: Vec<ClusterConfig> =
        modes.iter().map(|&aware| scenario_cfg(aware, horizon_s, &csys)).collect();
    let outs = super::sweep(&cfgs, |cfg| {
        cluster::run(cfg, &csys).expect("valid interference config")
    });
    let mut t = Table::new(&[
        "sizing", "main slices", "viol %", "main p95 ms", "served", "dropped",
    ]);
    let mut rows = Vec::new();
    for ((&aware, cfg), out) in modes.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let mode = if aware { "curve-aware" } else { "flat" };
        let viol = main_violation_frac(out);
        t.row(&[
            mode.to_string(),
            cfg.tenants[0].slices.to_string(),
            num(viol * 100.0),
            num(out.tenant_stats(0).p95_ms()),
            out.completed_total().to_string(),
            out.dropped.iter().sum::<u64>().to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("sizing", Json::str(mode)),
            ("main_slices", Json::num(cfg.tenants[0].slices as f64)),
            ("main_violation_frac", Json::num(viol)),
            ("main_p95_ms", Json::num(out.tenant_stats(0).p95_ms())),
            ("completed", Json::num(out.completed_total() as f64)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("sizing", Json::Arr(rows));

    // ---- Section 2: the planner surface the controller consumes. ----
    rep.section("predicted main-tenant p95 vs busy neighbors (the controller's scaled predictor)");
    let spec = TenantSpec::new(ModelId::SwinTransformer, MAIN_SLA_MS);
    let view = csys.curves.view(ModelId::SwinTransformer, 1);
    let knee = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).knee(0.0);
    let rate = 2.3 * swin_plateau_1g();
    let mut t = Table::new(&["busy neighbors", "scale", "p95 ms (3 slices)", "p95 ms (4 slices)"]);
    let mut rows = Vec::new();
    for k in 0..=6usize {
        let s = view.service_scale(knee, k);
        let p3 = predicted_p95_ms_gpcs_scaled(&spec, 1, 3, rate, s);
        let p4 = predicted_p95_ms_gpcs_scaled(&spec, 1, 4, rate, s);
        t.row(&[k.to_string(), num(s), num(p3), num(p4)]);
        rows.push(Json::obj(vec![
            ("busy_neighbors", Json::num(k as f64)),
            ("service_scale", Json::num(s)),
            ("p95_ms_3_slices", Json::num(p3)),
            ("p95_ms_4_slices", Json::num(p4)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("predictor", Json::Arr(rows));

    rep.finish("interference")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(r: &Json, key: &str) -> f64 {
        r.get(key).unwrap().as_f64().unwrap()
    }

    #[test]
    fn curve_aware_sizing_beats_flat_on_main_tenant_sla() {
        crate::experiments::set_fast(true);
        let sys = PrebaConfig::new();
        let doc = run(&sys);
        let data = doc.get("data").unwrap();

        let rows = data.get("sizing").unwrap().as_arr().unwrap();
        let row = |mode: &str| {
            rows.iter().find(|r| r.get("sizing").unwrap().as_str() == Some(mode)).unwrap()
        };
        let (flat, aware) = (row("flat"), row("curve-aware"));
        // The curve-aware rule must actually buy capacity...
        assert!(
            f(aware, "main_slices") > f(flat, "main_slices"),
            "aware {} vs flat {} slices",
            f(aware, "main_slices"),
            f(flat, "main_slices")
        );
        // ...and convert it into a strictly better main-tenant SLA.
        assert!(
            f(aware, "main_violation_frac") < f(flat, "main_violation_frac"),
            "aware {} vs flat {} violation",
            f(aware, "main_violation_frac"),
            f(flat, "main_violation_frac")
        );
        assert!(
            f(flat, "main_violation_frac") > 0.02,
            "contention never hurt the flat sizing: {}",
            f(flat, "main_violation_frac")
        );

        // §2: the scaled predictor is monotone in the neighbor count.
        let rows = data.get("predictor").unwrap().as_arr().unwrap();
        for w in rows.windows(2) {
            assert!(f(&w[1], "service_scale") > f(&w[0], "service_scale"));
            assert!(f(&w[1], "p95_ms_3_slices") >= f(&w[0], "p95_ms_3_slices"));
            assert!(f(&w[1], "p95_ms_4_slices") >= f(&w[0], "p95_ms_4_slices"));
        }
        // More slices never predict worse at the same contention.
        for r in rows {
            assert!(f(r, "p95_ms_4_slices") <= f(r, "p95_ms_3_slices"));
        }
    }

    #[test]
    fn scenario_is_deterministic_and_curved() {
        let sys = PrebaConfig::new();
        let csys = curved(&sys);
        assert!(csys.curves.enabled && csys.curves.source == "migperf");
        assert!(main_service_scale(&csys) > 1.2, "contention scale too weak to matter");
        let cfg = scenario_cfg(false, 4.0, &csys);
        let a = cluster::run(&cfg, &csys).unwrap();
        let b = cluster::run(&cfg, &csys).unwrap();
        assert_eq!(a.horizon, b.horizon);
        assert_eq!(a.events, b.events);
        assert_eq!(
            main_violation_frac(&a).to_bits(),
            main_violation_frac(&b).to_bits()
        );
    }
}
