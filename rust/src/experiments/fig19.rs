//! Figure 19: end-to-end latency breakdown (preprocess / batch / queue /
//! execute) for SqueezeNet and Conformer(default) under the Fig 18 sweep.
//!
//! Paper numbers to reproduce in shape: preprocessing is 53% (SqueezeNet)
//! and 72% (Conformer) of baseline inference time; PREBA removes it.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode, SimConfig};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 19: latency breakdown (SqueezeNet / Conformer(default))");
    let requests = super::default_requests();
    let mut rows = Vec::new();

    // Sweep grid: model × design at the moderate-load anchor (55% of the
    // ideal capacity, which is analytic).
    let caps: Vec<(ModelId, f64)> = [ModelId::SqueezeNet, ModelId::ConformerDefault]
        .iter()
        .map(|&model| {
            let cap = SimConfig::new(model, MigConfig::Small7, PreprocMode::Ideal)
                .saturating_rate()
                / 1.25;
            (model, cap)
        })
        .collect();
    let grid: Vec<(ModelId, PreprocMode, f64)> =
        support::cross2(&caps, &[PreprocMode::Ideal, PreprocMode::Dpu, PreprocMode::Cpu])
            .into_iter()
            .map(|((model, cap), preproc)| (model, preproc, 0.55 * cap))
            .collect();
    let outs = super::sweep(&grid, |&(model, preproc, rate)| {
        support::run(
            model, MigConfig::Small7, preproc, PolicyKind::Dynamic, 7, rate, requests, sys,
        )
    });

    let mut cells = grid.iter().zip(outs.iter());
    for model in [ModelId::SqueezeNet, ModelId::ConformerDefault] {
        rep.section(model.display());
        let mut t =
            Table::new(&["design", "preproc ms", "batch ms", "queue ms", "exec ms", "pre %"]);
        for _ in 0..3 {
            let (&(_, preproc, _), out) = cells.next().expect("grid exhausted");
            let (pre, bat, disp, exec) = out.stats.breakdown_ms();
            let total = pre + bat + disp + exec;
            t.row(&[
                preproc.label().to_string(),
                num(pre),
                num(bat),
                num(disp),
                num(exec),
                num(100.0 * pre / total),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("design", Json::str(preproc.label())),
                ("preproc_ms", Json::num(pre)),
                ("batching_ms", Json::num(bat)),
                ("queue_ms", Json::num(disp)),
                ("exec_ms", Json::num(exec)),
                ("preproc_frac", Json::num(pre / total)),
            ]));
        }
        for line in t.render() {
            rep.row(&line);
        }
    }
    rep.data("rows", Json::Arr(rows));
    rep.finish("fig19")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_dominated_by_preprocessing_preba_not() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        let frac = |m: &str, d: &str| -> f64 {
            rows.iter()
                .find(|r| {
                    r.get("model").unwrap().as_str() == Some(m)
                        && r.get("design").unwrap().as_str() == Some(d)
                })
                .unwrap()
                .get("preproc_frac")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // Paper: 53% / 72% of baseline time is preprocessing.
        assert!(frac("squeezenet", "Preprocessing (CPU)") > 0.35);
        assert!(frac("conformer_default", "Preprocessing (CPU)") > 0.5);
        // PREBA: preprocessing nearly vanishes from the breakdown.
        assert!(frac("squeezenet", "Preprocessing (DPU)") < 0.15);
        assert!(frac("conformer_default", "Preprocessing (DPU)") < 0.15);
    }
}
