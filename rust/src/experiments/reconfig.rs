//! Static vs online MIG partitioning under non-stationary traffic.
//!
//! Scenario: two Swin-Transformer tenants colocated on 1g.5gb(7x), each
//! holding a fair static share (4/3 slices) sized for its *mean* demand.
//! Under constant load that split is fine. Under anti-phase diurnal load
//! (one tenant's day is the other's night) or alternating MMPP bursts,
//! each tenant's peak overruns its fixed share while the other tenant's
//! slices idle — the reconfigurable-machine-scheduling gap (Tan et al.,
//! arXiv:2109.11067). The online controller (`mig::reconfig`) moves
//! slices to follow demand, paying a drain + repartition outage per move.
//!
//! Expected qualitative outcome: online ≈ static on constant load (no
//! reconfigurations — hysteresis holds), online beats static on tail
//! latency and SLA-violation rate under diurnal and bursty traces.
//!
//! A second section shows the single-tenant geometry case through
//! `server::sim_driver`: a full-GPU deployment pushed past its sustained
//! capacity is rescued by repartitioning to 1g.5gb(7x) mid-run.

use crate::mig::ServiceModel;
use crate::prelude::*;
use crate::server::multi::{self, MultiConfig, MultiOutcome, Tenant};
use crate::server::sim_driver;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

/// Per-tenant SLA for violation accounting, ms.
const SLA_MS: f64 = 25.0;

/// Controller tuned for the scenarios' seconds-scale periods (production
/// would scale window/cooldown with its traffic periods).
fn policy() -> ReconfigPolicy {
    ReconfigPolicy {
        window_s: 0.5,
        ewma_alpha: 0.7,
        cooldown_s: 1.0,
        min_gain: 0.10,
        repartition_s: 0.1,
        migration_s: 0.3,
        target_util: 0.85,
        ..ReconfigPolicy::default()
    }
}

/// Sustained per-slice throughput unit for Swin on a 1g slice (knee-batch
/// operating point), queries/s.
fn slice_unit() -> f64 {
    ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0) * 0.9
}

struct Scenario {
    name: &'static str,
    /// (profile, mean rate) per tenant; `None` profile = constant Poisson.
    tenants: [(Option<RateProfile>, f64); 2],
    /// Request-budget multiplier (bursty needs a longer horizon to sample
    /// several burst cycles).
    requests_x: usize,
}

fn scenarios() -> Vec<Scenario> {
    let u = slice_unit();
    let diurnal = |phase_frac: f64| RateProfile::Diurnal {
        base_qps: 2.6 * u,
        amplitude: 0.577, // swings 1.1–4.1 slices' worth of demand
        period_s: 6.0,
        phase_frac,
    };
    let bursty = RateProfile::Bursty {
        quiet_qps: 0.4 * u,
        burst_qps: 4.2 * u, // a solo burst wants ~5 of the 7 slices
        mean_quiet_s: 6.0,
        mean_burst_s: 4.0,
    };
    vec![
        Scenario {
            name: "constant",
            tenants: [(None, 2.6 * u), (None, 2.6 * u)],
            requests_x: 1,
        },
        Scenario {
            name: "diurnal",
            tenants: [
                (Some(diurnal(0.0)), 2.6 * u),
                (Some(diurnal(0.5)), 2.6 * u),
            ],
            requests_x: 1,
        },
        Scenario {
            name: "bursty",
            tenants: [(Some(bursty.clone()), 1.92 * u), (Some(bursty), 1.92 * u)],
            requests_x: 2,
        },
    ]
}

fn run_cell(scenario: &Scenario, online: bool, requests: usize, sys: &PrebaConfig) -> MultiOutcome {
    let mk = |(profile, rate): &(Option<RateProfile>, f64), vgpus: usize| {
        let mut t = Tenant::new(ModelId::SwinTransformer, vgpus, *rate);
        t.sla_ms = SLA_MS;
        t.profile = profile.clone();
        t
    };
    let cfg = MultiConfig {
        mig: MigConfig::Small7,
        // Fair static split for equal mean demand; the online run starts
        // from the same split so any advantage comes from reallocation.
        tenants: vec![mk(&scenario.tenants[0], 4), mk(&scenario.tenants[1], 3)],
        preproc: PreprocMode::Ideal,
        policy: PolicyKind::Dynamic,
        requests: requests * scenario.requests_x,
        seed: 0x7EC0,
        warmup_frac: 0.05,
        reconfig: online.then(policy),
    };
    multi::run(&cfg, sys).expect("valid multi-tenant config")
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep =
        Reporter::new("Reconfig: static vs online MIG partitioning under non-stationary traffic");
    let requests = 3 * super::default_requests();
    let scens = scenarios();

    // Sweep grid: scenario × {static, online}, one multi-tenant DES run
    // per cell.
    let idx: Vec<usize> = (0..scens.len()).collect();
    let grid = support::cross2(&idx, &[false, true]);
    let outs = super::sweep(&grid, |&(si, online)| run_cell(&scens[si], online, requests, sys));

    rep.section("two anti-phase tenants on 1g.5gb(7x), fair 4/3 static split");
    let mut t = Table::new(&[
        "traffic", "mode", "worst p95 ms", "max viol %", "reconfigs", "outage ms",
    ]);
    let mut rows = Vec::new();
    for (&(si, online), out) in grid.iter().zip(outs.iter()) {
        let viol = out
            .per_tenant
            .iter()
            .map(|(_, s)| s.sla_violation_frac(SLA_MS))
            .fold(0.0, f64::max);
        let mode = if online { "online" } else { "static" };
        t.row(&[
            scens[si].name.to_string(),
            mode.to_string(),
            num(out.worst_p95_ms()),
            num(viol * 100.0),
            out.reconfigs.to_string(),
            num(out.reconfig_downtime as f64 * 1e-6),
        ]);
        rows.push(Json::obj(vec![
            ("traffic", Json::str(scens[si].name)),
            ("mode", Json::str(mode)),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("max_violation_frac", Json::num(viol)),
            ("reconfigs", Json::num(out.reconfigs as f64)),
            ("outage_ms", Json::num(out.reconfig_downtime as f64 * 1e-6)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("scenarios", Json::Arr(rows));

    // Single-tenant geometry rescue through the sim driver.
    rep.section("single-tenant geometry: 7g.40gb(1x) at 95% plateau, online repartition");
    let mut cfg =
        SimConfig::new(ModelId::SwinTransformer, MigConfig::Full1, PreprocMode::Ideal);
    cfg.requests = requests;
    cfg.rate_qps = 0.95 * ServiceModel::new(cfg.model.spec(), 7).plateau_qps(0.0);
    cfg.sla_ms = 2.0 * SLA_MS;
    let static_out = sim_driver::run(&cfg, sys);
    cfg.reconfig = Some(ReconfigPolicy::default());
    let online_out = sim_driver::run(&cfg, sys);
    let mut t = Table::new(&["mode", "p95 ms", "viol %", "final partition", "reconfigs"]);
    let mut rows = Vec::new();
    for (mode, out) in [("static", &static_out), ("online", &online_out)] {
        t.row(&[
            mode.to_string(),
            num(out.p95_ms()),
            num(out.stats.sla_violation_frac(cfg.sla_ms) * 100.0),
            out.final_mig.name().to_string(),
            out.reconfigs.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("p95_ms", Json::num(out.p95_ms())),
            ("violation_frac", Json::num(out.stats.sla_violation_frac(cfg.sla_ms))),
            ("final_mig", Json::str(out.final_mig.name())),
            ("reconfigs", Json::num(out.reconfigs as f64)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    for ev in &online_out.reconfig_events {
        rep.row(&format!(
            "  t={:.2}s -> {} (predicted gain {:.1} ms)",
            crate::clock::to_secs(ev.at),
            ev.plan,
            ev.predicted_gain_ms
        ));
    }
    rep.data("geometry", Json::Arr(rows));
    rep.finish("reconfig")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row<'a>(rows: &'a [Json], traffic: &str, mode: &str) -> &'a Json {
        rows.iter()
            .find(|r| {
                r.get("traffic").unwrap().as_str() == Some(traffic)
                    && r.get("mode").unwrap().as_str() == Some(mode)
            })
            .unwrap()
    }

    fn f(r: &Json, key: &str) -> f64 {
        r.get(key).unwrap().as_f64().unwrap()
    }

    /// One test, one `run()` — the full sweep is the heaviest in the
    /// suite, so all assertions (scenarios + geometry section) share a
    /// single execution.
    #[test]
    fn online_beats_static_where_it_should_and_matches_elsewhere() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("scenarios").unwrap().as_arr().unwrap();

        // Constant load: no reconfigurations (at most one early correction)
        // and statistically equal tails.
        let c_static = row(rows, "constant", "static");
        let c_online = row(rows, "constant", "online");
        assert!(f(c_online, "reconfigs") <= 1.0, "thrash on constant load");
        let ratio = f(c_online, "worst_p95_ms") / f(c_static, "worst_p95_ms").max(1e-9);
        assert!((0.8..1.25).contains(&ratio), "constant-load tails diverged: {ratio}");

        // Diurnal anti-phase: capacity follows demand — the headline win.
        let d_static = row(rows, "diurnal", "static");
        let d_online = row(rows, "diurnal", "online");
        assert!(f(d_online, "reconfigs") >= 2.0, "controller never followed the cycle");
        assert!(
            f(d_online, "worst_p95_ms") < 0.5 * f(d_static, "worst_p95_ms"),
            "online {} vs static {}",
            f(d_online, "worst_p95_ms"),
            f(d_static, "worst_p95_ms")
        );
        assert!(f(d_online, "max_violation_frac") < f(d_static, "max_violation_frac"));

        // Bursty MMPP: solo bursts get rescued (overlapping bursts exceed
        // the GPU either way), so online must not lose and normally wins.
        let b_static = row(rows, "bursty", "static");
        let b_online = row(rows, "bursty", "online");
        assert!(
            f(b_online, "max_violation_frac") <= f(b_static, "max_violation_frac") * 1.02 + 0.01,
            "online {} vs static {}",
            f(b_online, "max_violation_frac"),
            f(b_static, "max_violation_frac")
        );

        // Geometry section: the overloaded full-GPU deployment gets
        // repartitioned to 1g.5gb(7x).
        let geo = doc.get("data").unwrap().get("geometry").unwrap().as_arr().unwrap();
        let online = geo
            .iter()
            .find(|r| r.get("mode").unwrap().as_str() == Some("online"))
            .unwrap();
        assert!(f(online, "reconfigs") >= 1.0);
        assert_eq!(online.get("final_mig").unwrap().as_str(), Some("1g.5gb(7x)"));
    }
}
