//! Figure 22: ablation — Base / Base+DPU / Base+DPU+DynamicBatching on the
//! audio models (the dynamic batcher targets variable-length audio).
//!
//! Paper: +DPU gives +101% over Base; +DynamicBatching a further +54%.
//! Metric: saturated end-to-end throughput. The DPU step removes the CPU
//! preprocessing cap; the dynamic-batching step removes the *padding
//! waste* of the naive single-queue batcher (every mixed-length batch
//! executes padded to its longest member) plus its oversized Batch_max.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 22: ablation Base / +DPU / +DPU+DynamicBatching (audio)");
    let requests = super::default_requests();
    let mut rows = Vec::new();
    let mut dpu_gains = Vec::new();
    let mut dyn_gains = Vec::new();

    let mut t = Table::new(&["model", "Base", "Base+DPU", "Base+DPU+Dyn", "DPU gain", "Dyn gain"]);
    // Ablation grid: model × design step (Base / +DPU / +DPU+Dynamic),
    // one saturated simulation per cell, in parallel.
    let steps = [
        (PreprocMode::Cpu, PolicyKind::Static),
        (PreprocMode::Dpu, PolicyKind::Static),
        (PreprocMode::Dpu, PolicyKind::Dynamic),
    ];
    let grid: Vec<(ModelId, PreprocMode, PolicyKind)> =
        support::cross2(&ModelId::AUDIO, &steps)
            .into_iter()
            .map(|(model, (preproc, policy))| (model, preproc, policy))
            .collect();
    let qps = super::sweep(&grid, |&(model, preproc, policy)| {
        support::saturated_qps(model, MigConfig::Small7, preproc, policy, 7, requests, sys).qps()
    });
    for (mi, model) in ModelId::AUDIO.iter().enumerate() {
        let model = *model;
        let (base, dpu, full) = (qps[3 * mi], qps[3 * mi + 1], qps[3 * mi + 2]);
        let g_dpu = dpu / base.max(1e-9);
        let g_dyn = full / dpu.max(1e-9);
        dpu_gains.push(g_dpu);
        dyn_gains.push(g_dyn);
        t.row(&[
            model.display().to_string(),
            num(base),
            num(dpu),
            num(full),
            format!("{:.2}x", g_dpu),
            format!("{:.2}x", g_dyn),
        ]);
        rows.push(Json::obj(vec![
            ("model", Json::str(model.name())),
            ("base_qps", Json::num(base)),
            ("dpu_qps", Json::num(dpu)),
            ("full_qps", Json::num(full)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    let avg_dpu = support::geomean(&dpu_gains);
    let avg_dyn = support::geomean(&dyn_gains);
    rep.row(&format!(
        "\navg: +DPU {:.0}% (paper: +101%), +DynamicBatching {:.0}% (paper: +54%)",
        100.0 * (avg_dpu - 1.0),
        100.0 * (avg_dyn - 1.0)
    ));
    rep.data("rows", Json::Arr(rows));
    rep.data("avg_dpu_gain", Json::num(avg_dpu));
    rep.data("avg_dyn_gain", Json::num(avg_dyn));
    rep.finish("fig22")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_ablation_steps_help() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let d = doc.get("data").unwrap();
        let dpu = d.get("avg_dpu_gain").unwrap().as_f64().unwrap();
        let dynb = d.get("avg_dyn_gain").unwrap().as_f64().unwrap();
        assert!(dpu > 1.3, "DPU ablation gain {dpu}");
        assert!(dynb > 1.1, "dynamic batching ablation gain {dynb}");
    }
}
