//! Shared helpers for the figure experiments.

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{sim_driver, PolicyKind, PreprocMode, SimConfig, SimOutcome};

/// Run a simulation with the standard request budget.
pub fn run(
    model: ModelId,
    mig: MigConfig,
    preproc: PreprocMode,
    policy: PolicyKind,
    servers: usize,
    rate_qps: f64,
    requests: usize,
    sys: &PrebaConfig,
) -> SimOutcome {
    let mut cfg = SimConfig::new(model, mig, preproc);
    cfg.policy = policy;
    cfg.active_servers = servers;
    cfg.requests = requests;
    cfg.rate_qps = rate_qps;
    sim_driver::run(&cfg, sys)
}

/// Peak sustained throughput: drive at a saturating offered load and
/// measure the completion rate.
pub fn saturated_qps(
    model: ModelId,
    mig: MigConfig,
    preproc: PreprocMode,
    policy: PolicyKind,
    servers: usize,
    requests: usize,
    sys: &PrebaConfig,
) -> SimOutcome {
    let mut cfg = SimConfig::new(model, mig, preproc);
    cfg.policy = policy;
    cfg.active_servers = servers;
    cfg.requests = requests;
    cfg.rate_qps = cfg.saturating_rate() * servers as f64 / mig.vgpus() as f64;
    sim_driver::run(&cfg, sys)
}

/// `saturated_qps` with every audio input pinned to `len_s` — the paper's
/// §3 characterization protocol ("input audio length is fixed at 2.5 sec").
pub fn saturated_qps_fixed_len(
    model: ModelId,
    mig: MigConfig,
    preproc: PreprocMode,
    policy: PolicyKind,
    servers: usize,
    len_s: f64,
    requests: usize,
    sys: &PrebaConfig,
) -> SimOutcome {
    let mut cfg = SimConfig::new(model, mig, preproc);
    cfg.policy = policy;
    cfg.active_servers = servers;
    cfg.requests = requests;
    cfg.fixed_len_s = Some(len_s);
    cfg.rate_qps = cfg.saturating_rate() * servers as f64 / mig.vgpus() as f64;
    sim_driver::run(&cfg, sys)
}

/// Largest offered load whose p95 stays under `sla_ms` (bisection over
/// the offered rate). Returns (qps_achieved, p95_ms at that load).
pub fn max_qps_under_sla(
    model: ModelId,
    mig: MigConfig,
    preproc: PreprocMode,
    policy: PolicyKind,
    sla_ms: f64,
    requests: usize,
    sys: &PrebaConfig,
) -> (f64, f64) {
    let cfg0 = SimConfig::new(model, mig, preproc);
    let hi_rate = cfg0.saturating_rate() * 1.2;
    let mut lo = hi_rate * 0.01;
    let mut hi = hi_rate;
    let mut best = (0.0, 0.0);
    for _ in 0..9 {
        let mid = 0.5 * (lo + hi);
        let out = run(model, mig, preproc, policy, mig.vgpus(), mid, requests, sys);
        if out.p95_ms() <= sla_ms && out.qps() >= mid * 0.85 {
            best = (out.qps(), out.p95_ms());
            lo = mid;
        } else {
            hi = mid;
        }
    }
    best
}

/// Cross product of two parameter axes, row-major (`a` outer, `b` inner)
/// — the sweep-grid/job-list shape every figure experiment fans out
/// through [`super::sweep`]. Replaces the hand-rolled nested-push
/// boilerplate each `fig*.rs` used to repeat.
pub fn cross2<A: Clone, B: Clone>(a: &[A], b: &[B]) -> Vec<(A, B)> {
    let mut out = Vec::with_capacity(a.len() * b.len());
    for x in a {
        for y in b {
            out.push((x.clone(), y.clone()));
        }
    }
    out
}

/// Three-axis cross product, row-major (`a` outermost).
pub fn cross3<A: Clone, B: Clone, C: Clone>(a: &[A], b: &[B], c: &[C]) -> Vec<(A, B, C)> {
    let mut out = Vec::with_capacity(a.len() * b.len() * c.len());
    for x in a {
        for y in b {
            for z in c {
                out.push((x.clone(), y.clone(), z.clone()));
            }
        }
    }
    out
}

/// Geometric mean of ratios (the paper's "average X× improvement").
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn cross_products_are_row_major() {
        assert_eq!(cross2(&[1, 2], &["a", "b"]), vec![(1, "a"), (1, "b"), (2, "a"), (2, "b")]);
        assert_eq!(
            cross3(&[1, 2], &["a"], &[true, false]),
            vec![(1, "a", true), (1, "a", false), (2, "a", true), (2, "a", false)]
        );
        assert!(cross2::<u8, u8>(&[], &[1]).is_empty());
    }

    #[test]
    fn sla_search_finds_feasible_point() {
        let sys = PrebaConfig::new();
        let (qps, p95) = max_qps_under_sla(
            ModelId::SqueezeNet,
            MigConfig::Small7,
            PreprocMode::Ideal,
            PolicyKind::Dynamic,
            25.0,
            1500,
            &sys,
        );
        assert!(qps > 0.0);
        assert!(p95 <= 25.0, "p95={p95}");
    }
}
