//! Cluster-scale serving: packing quality, routing, and cross-GPU
//! reconfiguration, measured in simulated latency.
//!
//! `mig::placement` shows analytically that best-fit-decreasing strands
//! fewer GPCs than first-fit; this experiment closes the loop by driving
//! the packed inventory with the cluster DES (`server::cluster`) so the
//! stranded capacity shows up where it hurts — the fleet's p99 and
//! SLA-violation fraction (ParvaGPU, arXiv:2409.14447). Five sections:
//!
//! 1. **FF vs BFD at 2/4/8 GPUs** under diurnal multi-tenant load. The
//!    ask list arrives small-profile-first (the adversarial order for
//!    first-fit): FF strands GPCs and rejects one hot tenant's second
//!    4g.20gb replica, overloading it; BFD admits everything.
//! 2. **Routing**: join-shortest-queue vs round-robin for a tenant whose
//!    slices are split asymmetrically (2/5) across GPUs.
//! 3. **Cross-GPU reconfiguration**: two anti-phase diurnal tenants each
//!    packed onto their own GPU. Capacity can only follow demand by
//!    crossing GPUs — the controller's first move is a migration (paying
//!    `migration_s`), follow-ups on the same GPU are in-place.
//! 4. **Heterogeneous fleet** (2×A100 + 2×A30-style 4-GPC): per-GPU
//!    class capacity decides placement quality — FF burns the big GPUs
//!    on small slices and rejects a hot 4g replica, BFD packs the tight
//!    A30 bins with the 4g replicas first.
//! 5. **Trace replay + admission control**: both tenants replay
//!    Azure-style recorded traces; one tenant's ask is rejected at pack
//!    time. Without admission its pre-rescue traffic is dropped; with
//!    admission it waits in the pending queue and is served once the
//!    controller re-packs capacity freed by the other tenant's diurnal
//!    trough (deferred_served > 0, strictly fewer drops).

use crate::mig::ServiceModel;
use crate::prelude::*;
use crate::server::cluster;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

/// Per-tenant p95 SLA for violation accounting, ms. Sized so a
/// well-packed tenant (BFD) sits inside it with headroom while a tenant
/// running past its admitted capacity (FF's rejected replica) blows
/// through it.
const SLA_MS: f64 = 40.0;

fn swin_plateau(gpcs: usize) -> f64 {
    ServiceModel::new(ModelId::SwinTransformer.spec(), gpcs).plateau_qps(0.0)
}

/// Controller tuned for the sections' seconds-scale diurnal periods —
/// the ONE cluster-controller tuning, shared by the `preba cluster` CLI
/// and the `perf_cluster` bench so they measure the configuration this
/// experiment ships.
pub fn policy(sys: &PrebaConfig) -> ReconfigPolicy {
    ReconfigPolicy {
        window_s: 0.5,
        ewma_alpha: 0.7,
        cooldown_s: 1.0,
        min_gain: 0.10,
        repartition_s: sys.cluster.repartition_s,
        migration_s: sys.cluster.migration_s,
        target_util: 0.85,
        planner: sys.reconfig.planner_kind().unwrap_or_default(),
        anneal_iters: sys.reconfig.anneal_iters,
        ..ReconfigPolicy::default()
    }
}

/// The diurnal multi-tenant fleet: per 2 GPUs, three Swin tenants asking
/// 3×1g.5gb, 1×3g.20gb and 2×4g.20gb (14 GPCs — exactly two A100s), each
/// offered 55% of its asked capacity with a ±35% staggered diurnal swing.
/// Ask order is small-profile-first per block — the order that tricks
/// first-fit into stranding GPCs while best-fit-decreasing packs the
/// inventory perfectly.
pub fn diurnal_fleet(n_gpus: usize, horizon_s: f64) -> Vec<ClusterTenant> {
    let k = (n_gpus / 2).max(1);
    let mut out = Vec::new();
    for b in 0..k {
        let mut mk = |slice: Slice, count: usize, role: usize| {
            let rate = 0.55 * count as f64 * swin_plateau(slice.gpcs);
            let mut t = ClusterTenant::new(ModelId::SwinTransformer, slice, count, rate);
            t.sla_ms = SLA_MS;
            t.profile = Some(RateProfile::Diurnal {
                base_qps: rate,
                amplitude: 0.35,
                period_s: 4.0,
                phase_frac: (b * 3 + role) as f64 / (3 * k) as f64,
            });
            t.requests = (rate * horizon_s).ceil() as usize;
            out.push(t);
        };
        mk(Slice::new(1, 5), 3, 0);
        mk(Slice::new(3, 20), 1, 1);
        mk(Slice::new(4, 20), 2, 2);
    }
    out
}

/// Routing study tenants: a light tenant occupies 5 GPCs of GPU0 so the
/// hot tenant's 7 slices split 2/5 across the two GPUs.
pub fn asym_routing_tenants(horizon_s: f64) -> Vec<ClusterTenant> {
    let u = swin_plateau(1);
    let mut light = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 5, 1.5 * u);
    light.sla_ms = SLA_MS;
    light.requests = (light.rate_qps * horizon_s).ceil() as usize;
    let mut hot = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 7, 5.25 * u);
    hot.sla_ms = SLA_MS;
    hot.requests = (hot.rate_qps * horizon_s).ceil() as usize;
    vec![light, hot]
}

/// Cross-GPU reconfiguration tenants: two 7×1g.5gb tenants, each filling
/// one GPU, with anti-phase diurnal demand whose peaks overrun a single
/// GPU's capacity.
pub fn antiphase_pair(horizon_s: f64) -> Vec<ClusterTenant> {
    let base = 5.6 * 0.9 * swin_plateau(1);
    let mk = |phase_frac: f64| {
        let mut t = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 7, base);
        t.sla_ms = 25.0;
        t.profile = Some(RateProfile::Diurnal {
            base_qps: base,
            amplitude: 0.577,
            period_s: 5.0,
            phase_frac,
        });
        t.requests = (base * horizon_s).ceil() as usize;
        t
    };
    vec![mk(0.0), mk(0.5)]
}

/// The heterogeneous inventory of section 4: two A100s + two A30-style
/// 4-GPC GPUs (22 GPCs total).
pub fn hetero_fleet() -> Vec<GpuClass> {
    vec![GpuClass::A100, GpuClass::A100, GpuClass::A30, GpuClass::A30]
}

/// Heterogeneous-fleet tenants: 6×1g (light), 2×3g (medium), 3×4g (hot).
/// In ask order (small-profile-first) first-fit burns the A100s on small
/// slices, parks two 4g replicas on the A30s and must reject the third —
/// the hot tenant then runs ~40% past its admitted capacity and its tail
/// diverges. Best-fit-decreasing gives the 4g replicas the tight A30 bins
/// first, packs 22/24 GPCs and keeps every tenant under ρ≈0.7.
pub fn hetero_tenants(horizon_s: f64) -> Vec<ClusterTenant> {
    let mk = |slice: Slice, count: usize, util: f64| {
        let rate = util * count as f64 * swin_plateau(slice.gpcs);
        let mut t = ClusterTenant::new(ModelId::SwinTransformer, slice, count, rate);
        t.sla_ms = SLA_MS;
        t.requests = (rate * horizon_s).ceil() as usize;
        t
    };
    vec![
        mk(Slice::new(1, 5), 6, 0.45),
        mk(Slice::new(3, 20), 2, 0.5),
        mk(Slice::new(4, 20), 3, 0.7),
    ]
}

/// Trace-replay + admission tenants (section 5): tenant A replays an
/// Azure-style recorded trace sized to fill both GPUs at its diurnal
/// peak (asking all 14 slices); tenant B replays a light trace but its
/// 2×1g ask is REJECTED at pack time — the fleet is full. The cross-GPU
/// controller rescues B out of A's diurnal trough; admission control
/// decides whether B's pre-rescue traffic waits (deferred-then-served)
/// or is dropped.
pub fn replay_tenants(horizon_s: f64) -> Vec<ClusterTenant> {
    let u = swin_plateau(1);
    let mut a = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 14, 9.0 * u)
        .with_trace(ReplayTrace::synth_azure(0xA2A1, horizon_s, 9.0 * u));
    a.sla_ms = SLA_MS;
    let mut b = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 2, 2.0 * u)
        .with_trace(ReplayTrace::synth_azure(0xA2B2, horizon_s, 2.0 * u));
    b.sla_ms = SLA_MS;
    vec![a, b]
}

/// One replay-run config for section 5: BFD packing, online controller,
/// admission on/off. `pub` so tests and examples can rerun the exact
/// scenario the experiment reports.
pub fn replay_cfg(admission: bool, horizon_s: f64, sys: &PrebaConfig) -> ClusterConfig {
    // Deferral starts at the first telemetry window; a 5% warmup would
    // swallow the pre-rescue drops the comparison scores.
    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(replay_tenants(horizon_s))
        .seed(0xC1A3)
        .reconfig(policy(sys))
        .admission(admission)
        .warmup_frac(0.01)
        .build()
}

fn run_cell(cfg: &ClusterConfig, sys: &PrebaConfig) -> ClusterOutcome {
    cluster::run(cfg, sys).expect("valid cluster config")
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Cluster: multi-GPU packing, routing and cross-GPU reconfig");
    // Fast mode shortens the simulated horizon, not the fleet.
    let horizon_s = if super::fast() { 10.0 } else { 20.0 };

    // ---- Section 1: FF vs BFD packing under diurnal load. ----
    rep.section("first-fit vs best-fit-decreasing, diurnal fleet, 2/4/8 GPUs");
    let grid = support::cross2(&[2usize, 4, 8], &[PackStrategy::FirstFit, PackStrategy::BestFit]);
    // One config per cell, shared by the sweep and the reporting loop so
    // outcomes are always scored against the tenants that produced them.
    let cfgs: Vec<ClusterConfig> = grid
        .iter()
        .map(|&(n_gpus, strategy)| {
            ClusterConfig::builder()
                .gpus(n_gpus)
                .strategy(strategy)
                .tenants(diurnal_fleet(n_gpus, horizon_s))
                .seed(0xC1A0)
                .build()
        })
        .collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "GPUs", "packing", "admitted", "asked", "stranded %", "worst p95 ms", "worst p99 ms",
        "viol %", "dropped",
    ]);
    let mut rows = Vec::new();
    for ((&(n_gpus, strategy), cfg), out) in grid.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let viol = out.max_violation_frac(&cfg.tenants);
        let dropped: u64 = out.dropped.iter().sum();
        t.row(&[
            n_gpus.to_string(),
            strategy.label().to_string(),
            out.packing.admitted_gpcs().to_string(),
            out.packing.asked_gpcs().to_string(),
            num(out.packing.fragmentation() * 100.0),
            num(out.worst_p95_ms()),
            num(out.worst_p99_ms()),
            num(viol * 100.0),
            dropped.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("gpus", Json::num(n_gpus as f64)),
            ("strategy", Json::str(strategy.label())),
            ("admitted_gpcs", Json::num(out.packing.admitted_gpcs() as f64)),
            ("asked_gpcs", Json::num(out.packing.asked_gpcs() as f64)),
            ("stranded_gpcs", Json::num(out.packing.stranded_gpcs() as f64)),
            ("stranded_frac", Json::num(out.packing.fragmentation())),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("worst_p99_ms", Json::num(out.worst_p99_ms())),
            ("max_violation_frac", Json::num(viol)),
            ("dropped", Json::num(dropped as f64)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("packing", Json::Arr(rows));

    // ---- Section 2: routing policy. ----
    rep.section("join-shortest-queue vs round-robin, hot tenant split 2/5 across GPUs");
    let routings = [Routing::ShortestQueue, Routing::RoundRobin];
    let cfgs: Vec<ClusterConfig> = routings
        .iter()
        .map(|&routing| {
            ClusterConfig::builder()
                .gpus(2)
                .strategy(PackStrategy::FirstFit)
                .tenants(asym_routing_tenants(horizon_s * 0.5))
                .routing(routing)
                .seed(0xC1A1)
                .build()
        })
        .collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&["routing", "worst p95 ms", "worst p99 ms", "viol %"]);
    let mut rows = Vec::new();
    for ((routing, cfg), out) in routings.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let viol = out.max_violation_frac(&cfg.tenants);
        t.row(&[
            routing.label().to_string(),
            num(out.worst_p95_ms()),
            num(out.worst_p99_ms()),
            num(viol * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("routing", Json::str(routing.label())),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("worst_p99_ms", Json::num(out.worst_p99_ms())),
            ("max_violation_frac", Json::num(viol)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("routing", Json::Arr(rows));

    // ---- Section 3: cross-GPU reconfiguration. ----
    rep.section("anti-phase tenants on separate GPUs: static packing vs online rebalancing");
    let modes = [false, true];
    let cfgs: Vec<ClusterConfig> = modes
        .iter()
        .map(|&online| {
            let mut cfg = ClusterConfig::builder()
                .gpus(2)
                .strategy(PackStrategy::BestFit)
                .tenants(antiphase_pair(horizon_s * 1.2))
                .seed(0xC1A2)
                .build();
            cfg.reconfig = online.then(|| policy(sys));
            cfg
        })
        .collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "mode", "worst p95 ms", "viol %", "rebalances", "migrations", "outage ms",
    ]);
    let mut rows = Vec::new();
    for ((&online, cfg), out) in modes.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let viol = out.max_violation_frac(&cfg.tenants);
        let mode = if online { "online" } else { "static" };
        t.row(&[
            mode.to_string(),
            num(out.worst_p95_ms()),
            num(viol * 100.0),
            out.reconfigs.to_string(),
            out.migrations.to_string(),
            num(out.reconfig_downtime as f64 * 1e-6),
        ]);
        rows.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("max_violation_frac", Json::num(viol)),
            ("reconfigs", Json::num(out.reconfigs as f64)),
            ("migrations", Json::num(out.migrations as f64)),
            ("outage_ms", Json::num(out.reconfig_downtime as f64 * 1e-6)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    if let Some(online) = outs.get(1) {
        for ev in &online.reconfig_events {
            rep.row(&format!(
                "  t={:.2}s -> {} moves ({} migration) (predicted gain {:.1} ms)",
                crate::clock::to_secs(ev.at),
                ev.moves.len(),
                ev.migrations(),
                ev.predicted_gain_ms
            ));
        }
    }
    rep.data("reconfig", Json::Arr(rows));

    // ---- Section 4: heterogeneous fleet (A100 + A30) FF vs BFD. ----
    rep.section("heterogeneous fleet (2×A100 + 2×A30): first-fit vs best-fit-decreasing");
    let strategies = [PackStrategy::FirstFit, PackStrategy::BestFit];
    let cfgs: Vec<ClusterConfig> = strategies
        .iter()
        .map(|&strategy| {
            ClusterConfig::builder()
                .fleet(hetero_fleet())
                .strategy(strategy)
                .tenants(hetero_tenants(horizon_s * 0.5))
                .seed(0xC1A4)
                .build()
        })
        .collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "packing", "admitted", "asked", "stranded %", "worst p95 ms", "worst p99 ms", "viol %",
    ]);
    let mut rows = Vec::new();
    for ((strategy, cfg), out) in strategies.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let viol = out.max_violation_frac(&cfg.tenants);
        t.row(&[
            strategy.label().to_string(),
            out.packing.admitted_gpcs().to_string(),
            out.packing.asked_gpcs().to_string(),
            num(out.packing.fragmentation() * 100.0),
            num(out.worst_p95_ms()),
            num(out.worst_p99_ms()),
            num(viol * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("strategy", Json::str(strategy.label())),
            ("admitted_gpcs", Json::num(out.packing.admitted_gpcs() as f64)),
            ("asked_gpcs", Json::num(out.packing.asked_gpcs() as f64)),
            ("stranded_gpcs", Json::num(out.packing.stranded_gpcs() as f64)),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("worst_p99_ms", Json::num(out.worst_p99_ms())),
            ("max_violation_frac", Json::num(viol)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("hetero", Json::Arr(rows));

    // ---- Section 5: trace replay + admission control. ----
    rep.section("Azure-style trace replay: rejected tenant, drop vs admission-defer");
    let modes = [false, true];
    let cfgs: Vec<ClusterConfig> =
        modes.iter().map(|&adm| replay_cfg(adm, horizon_s * 0.6, sys)).collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "mode", "dropped", "deferred", "deferred served", "rebalances", "migrations",
        "worst p95 ms",
    ]);
    let mut rows = Vec::new();
    for ((&adm, cfg), out) in modes.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let mode = if adm { "admission" } else { "drop" };
        let dropped: u64 = out.dropped.iter().sum();
        let deferred: u64 = out.deferred.iter().sum();
        let deferred_served: u64 = out.deferred_served.iter().sum();
        t.row(&[
            mode.to_string(),
            dropped.to_string(),
            deferred.to_string(),
            deferred_served.to_string(),
            out.reconfigs.to_string(),
            out.migrations.to_string(),
            num(out.worst_p95_ms()),
        ]);
        rows.push(Json::obj(vec![
            ("mode", Json::str(mode)),
            ("dropped", Json::num(dropped as f64)),
            ("deferred", Json::num(deferred as f64)),
            ("deferred_served", Json::num(deferred_served as f64)),
            ("rejected_asks", Json::num(out.packing.rejected.len() as f64)),
            ("reconfigs", Json::num(out.reconfigs as f64)),
            ("migrations", Json::num(out.migrations as f64)),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("max_violation_frac", Json::num(out.max_violation_frac(&cfg.tenants))),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("replay", Json::Arr(rows));

    rep.finish("cluster")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(r: &Json, key: &str) -> f64 {
        r.get(key).unwrap().as_f64().unwrap()
    }

    fn packing_row<'a>(rows: &'a [Json], gpus: f64, strategy: &str) -> &'a Json {
        rows.iter()
            .find(|r| {
                f(r, "gpus") == gpus
                    && r.get("strategy").unwrap().as_str().unwrap().starts_with(strategy)
            })
            .unwrap()
    }

    /// One test, one `run()` — the sweep is heavy, so every assertion
    /// (packing, routing, reconfig sections) shares a single execution.
    #[test]
    fn bfd_beats_ff_at_fleet_scale_and_rebalancing_crosses_gpus() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let data = doc.get("data").unwrap();

        // Packing: at 4 and 8 GPUs, BFD admits more capacity, strands
        // fewer GPCs, and that shows up in the fleet tail.
        let rows = data.get("packing").unwrap().as_arr().unwrap();
        for gpus in [4.0, 8.0] {
            let ff = packing_row(rows, gpus, "first-fit");
            let bf = packing_row(rows, gpus, "best-fit");
            assert!(
                f(bf, "stranded_gpcs") < f(ff, "stranded_gpcs"),
                "gpus={gpus}: bfd stranded {} vs ff {}",
                f(bf, "stranded_gpcs"),
                f(ff, "stranded_gpcs")
            );
            assert!(f(bf, "admitted_gpcs") > f(ff, "admitted_gpcs"), "gpus={gpus}");
            assert!(
                f(bf, "worst_p99_ms") < f(ff, "worst_p99_ms"),
                "gpus={gpus}: bfd p99 {} vs ff {}",
                f(bf, "worst_p99_ms"),
                f(ff, "worst_p99_ms")
            );
            assert!(
                f(bf, "max_violation_frac") < f(ff, "max_violation_frac"),
                "gpus={gpus}"
            );
        }

        // Routing: JSQ keeps the asymmetric split balanced; RR overloads
        // the small group.
        let rows = data.get("routing").unwrap().as_arr().unwrap();
        let get = |label: &str, key: &str| -> f64 {
            f(
                rows.iter()
                    .find(|r| r.get("routing").unwrap().as_str().unwrap().starts_with(label))
                    .unwrap(),
                key,
            )
        };
        assert!(
            get("join", "worst_p95_ms") < 0.7 * get("round", "worst_p95_ms"),
            "jsq {} vs rr {}",
            get("join", "worst_p95_ms"),
            get("round", "worst_p95_ms")
        );

        // Cross-GPU reconfig: the online controller migrates at least
        // once (capacity crosses GPUs) and beats the static packing.
        let rows = data.get("reconfig").unwrap().as_arr().unwrap();
        let row = |mode: &str| {
            rows.iter().find(|r| r.get("mode").unwrap().as_str() == Some(mode)).unwrap()
        };
        assert!(f(row("online"), "reconfigs") >= 2.0);
        assert!(f(row("online"), "migrations") >= 1.0, "never crossed a GPU");
        assert!(
            f(row("online"), "worst_p95_ms") < f(row("static"), "worst_p95_ms"),
            "online {} vs static {}",
            f(row("online"), "worst_p95_ms"),
            f(row("static"), "worst_p95_ms")
        );
        assert!(
            f(row("online"), "max_violation_frac") < f(row("static"), "max_violation_frac")
        );

        // Heterogeneous fleet: BFD admits more capacity (the A30 bins go
        // to the 4g replicas), strands less, and the hot tenant's tail
        // shows the difference.
        let rows = data.get("hetero").unwrap().as_arr().unwrap();
        let row = |s: &str| {
            rows.iter()
                .find(|r| r.get("strategy").unwrap().as_str().unwrap().starts_with(s))
                .unwrap()
        };
        let (ff, bf) = (row("first-fit"), row("best-fit"));
        assert!(f(bf, "admitted_gpcs") > f(ff, "admitted_gpcs"), "hetero admitted");
        assert!(f(bf, "stranded_gpcs") < f(ff, "stranded_gpcs"), "hetero stranded");
        assert!(
            f(bf, "worst_p99_ms") < f(ff, "worst_p99_ms"),
            "hetero p99: bfd {} vs ff {}",
            f(bf, "worst_p99_ms"),
            f(ff, "worst_p99_ms")
        );
        assert!(f(bf, "max_violation_frac") < f(ff, "max_violation_frac"), "hetero viol");

        // Trace replay + admission: the rejected tenant's traffic is
        // deferred-then-served instead of dropped.
        let rows = data.get("replay").unwrap().as_arr().unwrap();
        let row = |mode: &str| {
            rows.iter().find(|r| r.get("mode").unwrap().as_str() == Some(mode)).unwrap()
        };
        let (drop, adm) = (row("drop"), row("admission"));
        assert!(f(drop, "rejected_asks") >= 1.0, "nothing was rejected at pack time");
        assert!(f(drop, "dropped") > 0.0, "baseline never dropped");
        assert_eq!(f(drop, "deferred"), 0.0);
        assert!(f(adm, "deferred_served") > 0.0, "admission served no deferred traffic");
        assert!(
            f(adm, "dropped") < f(drop, "dropped"),
            "admission {} vs drop {} drops",
            f(adm, "dropped"),
            f(drop, "dropped")
        );
        assert!(f(adm, "migrations") >= 1.0, "the rescue must cross GPUs");
    }
}
