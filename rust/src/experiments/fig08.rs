//! Figure 8: end-to-end throughput with vs without CPU data preprocessing
//! on 1g.5gb(7x) (left axis), and the minimum CPU cores required for
//! preprocessing alone to sustain the full model-execution throughput
//! (right axis — CitriNet: 393 cores).

use crate::config::PrebaConfig;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 8: preprocessing bottleneck on 1g.5gb(7x)");
    let requests = super::default_requests();

    let mut t = Table::new(&[
        "model", "ideal QPS", "w/ CPU preproc QPS", "drop %", "cores required",
    ]);
    let mut rows = Vec::new();
    let mut drops = Vec::new();
    // The paper's characterization fixes audio inputs at 2.5 s (S3).
    const LEN: f64 = 2.5;
    // One saturated run per model × preprocessing design, in parallel.
    let grid = support::cross2(&ModelId::ALL, &[PreprocMode::Ideal, PreprocMode::Cpu]);
    let qps = super::sweep(&grid, |&(model, preproc)| {
        support::saturated_qps_fixed_len(
            model, MigConfig::Small7, preproc, PolicyKind::Dynamic, 7, LEN, requests, sys,
        )
        .qps()
    });
    for (mi, model) in ModelId::ALL.iter().enumerate() {
        let model = *model;
        let ideal = qps[2 * mi];
        let cpu = qps[2 * mi + 1];
        // Cores needed for preprocessing alone to sustain the model-
        // execution stage's MAXIMUM throughput (the gray bars = the
        // plateau of all seven slices; paper right axis).
        let per_req = model.spec().cpu_preproc_secs(match model.kind() {
            crate::models::ModelKind::Vision => 0.0,
            crate::models::ModelKind::Audio => LEN,
        });
        let plateau =
            7.0 * crate::mig::ServiceModel::new(model.spec(), 1).plateau_qps(LEN);
        let cores = plateau * per_req;
        let drop = 100.0 * (1.0 - cpu / ideal);
        drops.push(drop);
        t.row(&[
            model.display().to_string(),
            num(ideal),
            num(cpu),
            num(drop),
            num(cores),
        ]);
        rows.push(Json::obj(vec![
            ("model", Json::str(model.name())),
            ("ideal_qps", Json::num(ideal)),
            ("cpu_qps", Json::num(cpu)),
            ("drop_pct", Json::num(drop)),
            ("cores_required", Json::num(cores)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    let avg_drop = drops.iter().sum::<f64>() / drops.len() as f64;
    rep.row(&format!("average throughput drop: {:.1}% (paper: 75.6%)", avg_drop));
    rep.data("rows", Json::Arr(rows));
    rep.data("avg_drop_pct", Json::num(avg_drop));
    rep.finish("fig08")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn citrinet_needs_hundreds_of_cores_and_throughput_collapses() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let rows = doc.get("data").unwrap().get("rows").unwrap().as_arr().unwrap();
        let citrinet = rows
            .iter()
            .find(|r| r.get("model").unwrap().as_str() == Some("citrinet"))
            .unwrap();
        let cores = citrinet.get("cores_required").unwrap().as_f64().unwrap();
        // Paper: "a staggering 393 preprocessing CPU cores".
        assert!((cores - 393.0).abs() < 25.0, "cores={cores}");
        let drop = citrinet.get("drop_pct").unwrap().as_f64().unwrap();
        assert!(drop > 60.0, "drop={drop}");
        let avg = doc.get("data").unwrap().get("avg_drop_pct").unwrap().as_f64().unwrap();
        assert!((50.0..95.0).contains(&avg), "avg drop {avg} out of paper band");
    }
}
