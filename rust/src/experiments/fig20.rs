//! Figure 20: system power breakdown (left) and energy-efficiency
//! (Perf/Watt, right) for baseline vs PREBA.
//!
//! Paper observations to reproduce: PREBA cuts CPU power (~35%), raises
//! GPU power (utilization up, ~2.8× for audio), adds FPGA power, and still
//! improves system energy-efficiency ~3.5× on average.

use crate::config::PrebaConfig;
use crate::energy::PowerModel;
use crate::mig::MigConfig;
use crate::models::ModelId;
use crate::server::{PolicyKind, PreprocMode};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

/// Component utilizations + throughput for one design point.
pub fn measure(
    model: ModelId,
    preproc: PreprocMode,
    requests: usize,
    sys: &PrebaConfig,
) -> (f64, crate::energy::PowerBreakdown) {
    let out = support::saturated_qps(
        model, MigConfig::Small7, preproc, PolicyKind::Dynamic, 7, requests, sys,
    );
    // Host CPU: preprocessing pool + the serving reserve.
    let reserve = sys.hardware.cpu_reserved_cores as f64 / sys.hardware.cpu_cores as f64;
    let pool_frac = 1.0 - reserve;
    let cpu_util = reserve + pool_frac * out.cpu_util;
    let pm = PowerModel::new(&sys.power);
    let fpga = match preproc {
        PreprocMode::Dpu => out.dpu_util,
        _ => None,
    };
    (out.qps(), pm.power(cpu_util, out.gpu_util, fpga))
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Fig 20: power breakdown + energy efficiency");
    let requests = super::default_requests();
    let pm = PowerModel::new(&sys.power);
    let mut rows = Vec::new();
    let mut eff_ratios = Vec::new();
    let mut cpu_cuts = Vec::new();

    let mut t = Table::new(&[
        "model", "design", "CPU W", "GPU W", "FPGA W", "total W", "QPS", "QPS/W",
    ]);
    // One saturated measurement per model × design, fanned out in parallel.
    let grid = super::support::cross2(&ModelId::ALL, &[PreprocMode::Cpu, PreprocMode::Dpu]);
    let measured = super::sweep(&grid, |&(model, preproc)| measure(model, preproc, requests, sys));
    for (mi, model) in ModelId::ALL.iter().enumerate() {
        let model = *model;
        let (q_base, p_base) = &measured[2 * mi];
        let (q_preba, p_preba) = &measured[2 * mi + 1];
        let (q_base, q_preba) = (*q_base, *q_preba);
        for (label, q, p) in
            [("baseline", q_base, p_base), ("PREBA", q_preba, p_preba)]
        {
            t.row(&[
                model.display().to_string(),
                label.to_string(),
                num(p.cpu_w),
                num(p.gpu_w),
                num(p.fpga_w),
                num(p.total()),
                num(q),
                num(pm.qpj(q, p)),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("design", Json::str(label)),
                ("cpu_w", Json::num(p.cpu_w)),
                ("gpu_w", Json::num(p.gpu_w)),
                ("fpga_w", Json::num(p.fpga_w)),
                ("total_w", Json::num(p.total())),
                ("qps", Json::num(q)),
                ("qps_per_w", Json::num(pm.qpj(q, p))),
            ]));
        }
        eff_ratios.push(pm.qpj(q_preba, p_preba) / pm.qpj(q_base, p_base));
        cpu_cuts.push(1.0 - p_preba.cpu_w / p_base.cpu_w);
    }
    for line in t.render() {
        rep.row(&line);
    }
    let avg_eff = support::geomean(&eff_ratios);
    let avg_cut = cpu_cuts.iter().sum::<f64>() / cpu_cuts.len() as f64;
    rep.row(&format!(
        "\navg energy-efficiency gain {avg_eff:.2}x (paper: 3.5x); avg CPU power cut {:.1}% (paper: 35.4%)",
        100.0 * avg_cut
    ));
    rep.data("rows", Json::Arr(rows));
    rep.data("avg_eff_gain", Json::num(avg_eff));
    rep.data("avg_cpu_cut", Json::num(avg_cut));
    rep.finish("fig20")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_gain_in_paper_band() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let eff = doc.get("data").unwrap().get("avg_eff_gain").unwrap().as_f64().unwrap();
        assert!((2.0..6.0).contains(&eff), "eff gain {eff}");
        let cut = doc.get("data").unwrap().get("avg_cpu_cut").unwrap().as_f64().unwrap();
        assert!(cut > 0.15, "cpu power cut {cut}");
    }
}
