//! Energy & cost accounting (`preba experiment energy`): the paper's two
//! economic headline claims measured as *integrated* energy through the
//! DES, plus the power-aware fleet consolidation study.
//!
//! Three sections:
//!
//! 1. **Single-server energy & cost** — every paper model at saturation,
//!    baseline (CPU preprocessing) vs PREBA (DPU), with
//!    `energy::EnergyModel` integrating per-GPC/CPU-core/DPU power over
//!    the simulated horizon. Reports J/query, Perf/Watt and the TCO fold
//!    (queries/$ via `energy::tco` from the measured mean power). The
//!    paper's claims: ~3.5× energy-efficiency, ~3.0× cost-efficiency;
//!    CitriNet — the preprocessing-heaviest headline workload (the
//!    "393 cores" model) — must clear 3× outright.
//! 2. **Cluster fleet, baseline vs PREBA-DPU** — a diurnal CitriNet
//!    fleet on 2 GPUs. Host preprocessing saturates each GPU's CPU pool,
//!    stretching the horizon and burning energy per served query; the
//!    DPU restores near-ideal serving. Fleet Perf/Watt must again clear
//!    3×.
//! 3. **Consolidation** — the same fleet shape overnight (low diurnal
//!    base): the energy-aware controller
//!    (`ReconfigPolicy::consolidate`) shrinks over-provisioned tenants,
//!    drains the lighter GPU and powers it down. Consolidation must cut
//!    fleet energy at equal served count with no increase in the
//!    SLA-violation fraction.

use crate::energy::TcoModel;
use crate::mig::ServiceModel;
use crate::prelude::*;
use crate::server::cluster;
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

use super::support;

/// One saturated single-server design point on the paper's `1g.5gb(7x)`
/// partition, with integrated energy in `stats.energy` (shared by the
/// experiment and the `preba energy` CLI).
pub fn measure(
    model: ModelId,
    preproc: PreprocMode,
    requests: usize,
    sys: &PrebaConfig,
) -> SimOutcome {
    support::saturated_qps(
        model, MigConfig::Small7, preproc, PolicyKind::Dynamic, 7, requests, sys,
    )
}

/// Mean measured system power of a run, W (integrated energy over the
/// horizon) — the figure the TCO fold extrapolates.
pub fn mean_w(o: &SimOutcome) -> f64 {
    o.stats.energy_j() / crate::clock::to_secs(o.horizon).max(1e-9)
}

/// Section 1's measurement sweep, shared with the `preba energy` CLI:
/// per model, the saturated (baseline CPU, PREBA DPU) outcome pair,
/// fanned out over the job pool.
pub fn measure_all(
    requests: usize,
    sys: &PrebaConfig,
) -> Vec<(ModelId, SimOutcome, SimOutcome)> {
    let grid = support::cross2(&ModelId::ALL, &[PreprocMode::Cpu, PreprocMode::Dpu]);
    let measured = super::sweep(&grid, |&(m, p)| measure(m, p, requests, sys));
    let mut it = measured.into_iter();
    ModelId::ALL
        .iter()
        .map(|&m| {
            let base = it.next().expect("grid arity");
            let preba = it.next().expect("grid arity");
            (m, base, preba)
        })
        .collect()
}

fn citrinet_unit() -> f64 {
    let len = crate::mig::planner::default_len(ModelId::CitriNet);
    ServiceModel::new(ModelId::CitriNet.spec(), 1).plateau_qps(len)
}

/// Section 2's busy diurnal fleet: two CitriNet tenants, each owning a
/// full A100 (7×1g.5gb) at 55% mean utilization with a ±35% staggered
/// swing. With `PreprocMode::Cpu` each GPU's 30-core pool is offered
/// several times its preprocessing capacity — the Fig 8 bottleneck at
/// fleet scale.
pub fn busy_fleet_cfg(preproc: PreprocMode, horizon_s: f64) -> ClusterConfig {
    let u = citrinet_unit();
    let mk = |phase_frac: f64| {
        let rate = 0.55 * 7.0 * u;
        let mut t = ClusterTenant::new(ModelId::CitriNet, Slice::new(1, 5), 7, rate);
        t.sla_ms = 120.0;
        t.profile = Some(RateProfile::Diurnal {
            base_qps: rate,
            amplitude: 0.35,
            period_s: horizon_s / 2.0,
            phase_frac,
        });
        t.requests = (rate * horizon_s).ceil() as usize;
        t
    };
    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(vec![mk(0.0), mk(0.5)])
        .preproc(preproc)
        .seed(0xE6E1)
        .build()
}

/// Section 3's overnight fleet: two Swin tenants asking 5×1g.5gb each
/// (packed 7 + 3 across two A100s) at a ~20% diurnal base — sustained
/// low load with ample headroom, the regime where consolidation should
/// drain and power down the lighter GPU. Shared with
/// `tests/prop_energy.rs` so the never-increases-energy property tests
/// the exact shipped scenario.
pub fn idle_fleet_cfg(consolidate: bool, horizon_s: f64, sys: &PrebaConfig) -> ClusterConfig {
    let u = ServiceModel::new(ModelId::SwinTransformer.spec(), 1).plateau_qps(0.0);
    let mk = |phase_frac: f64| {
        let rate = 0.2 * 5.0 * u;
        let mut t = ClusterTenant::new(ModelId::SwinTransformer, Slice::new(1, 5), 5, rate);
        t.sla_ms = 60.0;
        t.profile = Some(RateProfile::Diurnal {
            base_qps: rate,
            amplitude: 0.25,
            period_s: horizon_s / 2.0,
            phase_frac,
        });
        t.requests = (rate * horizon_s).ceil() as usize;
        t
    };
    ClusterConfig::builder()
        .gpus(2)
        .strategy(PackStrategy::BestFit)
        .tenants(vec![mk(0.0), mk(0.5)])
        .preproc(PreprocMode::Dpu)
        .seed(0xE6E2)
        .reconfig(crate::experiments::cluster::policy(sys))
        .consolidate(consolidate)
        .build()
}

fn run_cell(cfg: &ClusterConfig, sys: &PrebaConfig) -> ClusterOutcome {
    cluster::run(cfg, sys).expect("valid cluster config")
}

fn fleet_row(label: &str, out: &ClusterOutcome) -> Json {
    Json::obj(vec![
        ("mode", Json::str(label)),
        ("completed", Json::num(out.completed_total() as f64)),
        ("energy_j", Json::num(out.energy.total_j())),
        ("joules_per_query", Json::num(out.joules_per_query())),
        ("perf_per_watt", Json::num(out.perf_per_watt())),
        ("gpu_off_s", Json::num(out.gpu_off_s)),
        ("consolidations", Json::num(out.consolidations as f64)),
        ("worst_p95_ms", Json::num(out.worst_p95_ms())),
    ])
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Energy: integrated power, TCO, and fleet consolidation");
    let requests = super::default_requests();
    let tco = TcoModel::new(&sys.tco);

    // ---- Section 1: single-server integrated energy per model. ----
    rep.section("single-server: baseline (CPU preproc) vs PREBA (DPU), integrated energy");
    let measured = measure_all(requests, sys);
    let mut t = Table::new(&[
        "model", "design", "QPS", "mean W", "J/query", "QPS/W", "Mqueries/$",
    ]);
    let mut rows = Vec::new();
    let mut eff_gains = Vec::new();
    let mut cost_gains = Vec::new();
    let mut citrinet_gain = 0.0;
    for (model, base, preba) in &measured {
        let model = *model;
        let report = |o: &SimOutcome, with_fpga: bool| {
            tco.evaluate_watts(o.qps(), mean_w(o), with_fpga)
        };
        for (label, o, fpga) in [("baseline", base, false), ("PREBA", preba, true)] {
            t.row(&[
                model.display().to_string(),
                label.to_string(),
                num(o.qps()),
                num(mean_w(o)),
                num(o.stats.joules_per_query()),
                num(o.stats.perf_per_watt()),
                num(report(o, fpga).queries_per_usd / 1e6),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name())),
                ("design", Json::str(label)),
                ("qps", Json::num(o.qps())),
                ("mean_w", Json::num(mean_w(o))),
                ("joules_per_query", Json::num(o.stats.joules_per_query())),
                ("perf_per_watt", Json::num(o.stats.perf_per_watt())),
                ("queries_per_usd", Json::num(report(o, fpga).queries_per_usd)),
            ]));
        }
        let eff = preba.stats.perf_per_watt() / base.stats.perf_per_watt().max(1e-12);
        let cost = report(preba, true).queries_per_usd
            / report(base, false).queries_per_usd.max(1e-12);
        eff_gains.push(eff);
        cost_gains.push(cost);
        if model == ModelId::CitriNet {
            citrinet_gain = eff;
        }
    }
    for line in t.render() {
        rep.row(&line);
    }
    let avg_eff = support::geomean(&eff_gains);
    let avg_cost = support::geomean(&cost_gains);
    rep.row(&format!(
        "\navg energy-efficiency gain {avg_eff:.2}x (paper: 3.5x); avg cost-efficiency \
         gain {avg_cost:.2}x (paper: 3.0x); CitriNet perf/W gain {citrinet_gain:.2}x"
    ));
    rep.data("models", Json::Arr(rows));
    rep.data("avg_perf_w_gain", Json::num(avg_eff));
    rep.data("avg_cost_gain", Json::num(avg_cost));
    rep.data("citrinet_perf_w_gain", Json::num(citrinet_gain));

    // ---- Section 2: cluster fleet, baseline vs PREBA-DPU. ----
    rep.section("diurnal CitriNet fleet (2 GPUs): host preprocessing vs DPU, fleet energy");
    let horizon_s = if super::fast() { 8.0 } else { 16.0 };
    let modes = [("baseline", PreprocMode::Cpu), ("PREBA-DPU", PreprocMode::Dpu)];
    let cfgs: Vec<ClusterConfig> =
        modes.iter().map(|&(_, p)| busy_fleet_cfg(p, horizon_s)).collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "mode", "completed", "fleet kJ", "J/query", "perf/W", "worst p95 ms",
    ]);
    let mut rows = Vec::new();
    for ((label, _), out) in modes.iter().zip(outs.iter()) {
        t.row(&[
            label.to_string(),
            out.completed_total().to_string(),
            num(out.energy.total_j() / 1e3),
            num(out.joules_per_query()),
            num(out.perf_per_watt()),
            num(out.worst_p95_ms()),
        ]);
        rows.push(fleet_row(label, out));
    }
    for line in t.render() {
        rep.row(&line);
    }
    let fleet_gain = outs[1].perf_per_watt() / outs[0].perf_per_watt().max(1e-12);
    rep.row(&format!("\nfleet perf/W gain (DPU over host preproc): {fleet_gain:.2}x"));
    rep.data("fleet", Json::Arr(rows));
    rep.data("fleet_perf_w_gain", Json::num(fleet_gain));

    // ---- Section 3: power-aware consolidation at low load. ----
    rep.section("overnight fleet: PREBA-DPU with vs without consolidation");
    let modes = [false, true];
    let cfgs: Vec<ClusterConfig> =
        modes.iter().map(|&c| idle_fleet_cfg(c, horizon_s, sys)).collect();
    let outs = super::sweep(&cfgs, |cfg| run_cell(cfg, sys));
    let mut t = Table::new(&[
        "mode", "completed", "fleet kJ", "J/query", "GPU-off s", "power-downs", "viol %",
    ]);
    let mut rows = Vec::new();
    for ((&consolidate, cfg), out) in modes.iter().zip(cfgs.iter()).zip(outs.iter()) {
        let label = if consolidate { "consolidate" } else { "static-on" };
        t.row(&[
            label.to_string(),
            out.completed_total().to_string(),
            num(out.energy.total_j() / 1e3),
            num(out.joules_per_query()),
            num(out.gpu_off_s),
            out.consolidations.to_string(),
            num(out.max_violation_frac(&cfg.tenants) * 100.0),
        ]);
        let mut row = fleet_row(label, out);
        if let Json::Obj(m) = &mut row {
            m.insert(
                "max_violation_frac".to_string(),
                Json::num(out.max_violation_frac(&cfg.tenants)),
            );
        }
        rows.push(row);
    }
    for line in t.render() {
        rep.row(&line);
    }
    if let Some(consol) = outs.get(1) {
        for ev in &consol.consolidation_events {
            rep.row(&format!(
                "  t={:.2}s {} GPU{} (retired {}, moved {})",
                crate::clock::to_secs(ev.at),
                if ev.powered_down { "power-down" } else { "wake" },
                ev.gpu,
                ev.retired,
                ev.moved
            ));
        }
    }
    let saved = 1.0 - outs[1].energy.total_j() / outs[0].energy.total_j().max(1e-12);
    rep.row(&format!("\nconsolidation energy saving: {:.1}%", 100.0 * saved));
    rep.data("consolidation", Json::Arr(rows));
    rep.data("consolidation_saving", Json::num(saved));

    rep.finish("energy")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(r: &Json, key: &str) -> f64 {
        r.get(key).unwrap().as_f64().unwrap()
    }

    /// One test, one `run()` — the sweep is heavy, so every assertion
    /// (paper bands, fleet gain, consolidation invariants) shares a
    /// single execution.
    #[test]
    fn energy_claims_hold_and_consolidation_saves_energy() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let data = doc.get("data").unwrap();

        // Paper bands (Fig 20/21 reproduced on integrated energy): the
        // model-average gains land in the fig20/fig21 band, and the
        // preprocessing-heaviest headline workload clears 3× outright.
        let avg_eff = f(data, "avg_perf_w_gain");
        assert!((2.0..8.0).contains(&avg_eff), "avg perf/W gain {avg_eff}");
        let avg_cost = f(data, "avg_cost_gain");
        assert!((2.0..8.0).contains(&avg_cost), "avg cost gain {avg_cost}");
        let citrinet = f(data, "citrinet_perf_w_gain");
        assert!(citrinet >= 3.0, "CitriNet perf/W gain {citrinet} below the 3x claim");

        // Fleet scale: the DPU design serves the same queries on at
        // least 3× less energy than host preprocessing.
        let fleet = f(data, "fleet_perf_w_gain");
        assert!(fleet >= 3.0, "fleet perf/W gain {fleet}");
        let rows = data.get("fleet").unwrap().as_arr().unwrap();
        assert_eq!(f(&rows[0], "completed"), f(&rows[1], "completed"), "unequal service");

        // Consolidation: at least one power-down, real off-time, less
        // energy at equal served count, and no SLA regression.
        let rows = data.get("consolidation").unwrap().as_arr().unwrap();
        let (base, consol) = (&rows[0], &rows[1]);
        assert!(f(consol, "consolidations") >= 1.0, "never powered a GPU down");
        assert!(f(consol, "gpu_off_s") > 0.0);
        assert_eq!(f(base, "gpu_off_s"), 0.0);
        assert_eq!(f(base, "completed"), f(consol, "completed"), "served count changed");
        assert!(
            f(consol, "energy_j") < f(base, "energy_j"),
            "consolidation did not reduce energy: {} vs {}",
            f(consol, "energy_j"),
            f(base, "energy_j")
        );
        assert!(
            f(consol, "max_violation_frac") <= f(base, "max_violation_frac") + 0.01,
            "consolidation hurt the SLA: {} vs {}",
            f(consol, "max_violation_frac"),
            f(base, "max_violation_frac")
        );
    }
}
