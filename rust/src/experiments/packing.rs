//! Multi-tenant packing vs fragmentation.
//!
//! Two questions, two sections:
//!
//! 1. **Inventory packing** (analytic, `mig::placement`): given a stream
//!    of slice requests over a small GPU inventory, how much requested
//!    capacity does naive first-fit admit versus fragmentation-aware
//!    best-fit-decreasing, and how many GPCs does each strand behind
//!    awkward remainders? (Ting et al., arXiv:2512.16099 motivates the
//!    metric.) A worked adversarial example plus a seeded randomized
//!    study.
//!
//! 2. **On-GPU slice assignment** (DES, `server::multi`): three tenants
//!    with skewed demand on one 1g.5gb(7x). A naive even split starves
//!    the hot tenant; demand-aware placement (`multi::place_tenants` —
//!    the same allocator the online reconfig controller uses) keeps every
//!    tenant inside its SLA.
//!
//! Expected qualitative outcome: best-fit admits ≥ first-fit with fewer
//! stranded GPCs; demand-aware placement cuts the hot tenant's tail and
//! violation rate versus the even split.

use crate::mig::placement::{adversarial_demo, pack, SliceAsk};
use crate::mig::ServiceModel;
use crate::prelude::*;
use crate::server::multi::{self, even_split, place_tenants, MultiConfig, TenantDemand};
use crate::util::bench::Reporter;
use crate::util::json::Json;
use crate::util::table::{num, Table};

/// Per-tenant SLA for the DES section, ms.
const SLA_MS: f64 = 25.0;

/// A random ask list: 5–10 instances drawn from the A100 profiles.
fn random_asks(seed: u64) -> Vec<SliceAsk> {
    let mut rng = Rng::new(0xACC ^ seed);
    let n = 5 + (rng.f64() * 6.0) as usize;
    (0..n)
        .map(|i| {
            let k = ((rng.f64() * Slice::PROFILES.len() as f64) as usize)
                .min(Slice::PROFILES.len() - 1);
            SliceAsk { tenant: i, slice: Slice::PROFILES[k] }
        })
        .collect()
}

pub fn run(sys: &PrebaConfig) -> Json {
    let mut rep = Reporter::new("Packing: fragmentation-aware placement vs naive baselines");

    // ---- Section 1: inventory packing (analytic). ----
    rep.section("worked example: 7 asks (small-first arrival order) on 2 GPUs");
    let mut t = Table::new(&["strategy", "admitted GPCs", "asked", "stranded", "frag %"]);
    let mut rows = Vec::new();
    for strategy in [PackStrategy::FirstFit, PackStrategy::BestFit] {
        let p = pack(&adversarial_demo(), 2, strategy);
        t.row(&[
            strategy.label().to_string(),
            p.admitted_gpcs().to_string(),
            p.asked_gpcs().to_string(),
            p.stranded_gpcs().to_string(),
            num(p.fragmentation() * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("strategy", Json::str(strategy.label())),
            ("admitted_gpcs", Json::num(p.admitted_gpcs() as f64)),
            ("asked_gpcs", Json::num(p.asked_gpcs() as f64)),
            ("stranded_gpcs", Json::num(p.stranded_gpcs() as f64)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("worked", Json::Arr(rows));

    rep.section("randomized study: 40 seeded ask lists on 2 GPUs");
    let seeds: Vec<u64> = (0..40).collect();
    let cells = super::sweep(&seeds, |&seed| {
        let asks = random_asks(seed);
        let ff = pack(&asks, 2, PackStrategy::FirstFit);
        let bf = pack(&asks, 2, PackStrategy::BestFit);
        (ff.admitted_frac(), bf.admitted_frac(), ff.stranded_gpcs(), bf.stranded_gpcs())
    });
    let n = cells.len() as f64;
    let ff_adm = cells.iter().map(|c| c.0).sum::<f64>() / n;
    let bf_adm = cells.iter().map(|c| c.1).sum::<f64>() / n;
    let ff_str = cells.iter().map(|c| c.2 as f64).sum::<f64>() / n;
    let bf_str = cells.iter().map(|c| c.3 as f64).sum::<f64>() / n;
    let bf_wins = cells.iter().filter(|c| c.1 >= c.0).count();
    let mut t = Table::new(&["strategy", "mean admitted %", "mean stranded GPCs"]);
    t.row(&["first-fit".into(), num(ff_adm * 100.0), num(ff_str)]);
    t.row(&["best-fit decreasing".into(), num(bf_adm * 100.0), num(bf_str)]);
    for line in t.render() {
        rep.row(&line);
    }
    rep.row(&format!("best-fit ≥ first-fit on {bf_wins}/{} instances", cells.len()));
    rep.data(
        "randomized",
        Json::obj(vec![
            ("ff_admitted_frac", Json::num(ff_adm)),
            ("bf_admitted_frac", Json::num(bf_adm)),
            ("ff_stranded", Json::num(ff_str)),
            ("bf_stranded", Json::num(bf_str)),
            ("bf_wins", Json::num(bf_wins as f64)),
            ("instances", Json::num(n)),
        ]),
    );

    // ---- Section 2: on-GPU assignment (DES). ----
    rep.section("3 skewed tenants on 1g.5gb(7x): even split vs demand-aware placement");
    let u = ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0);
    // Hot tenant wants ~3.5 slices' worth at the sizing target — the even
    // split's 3 slices run past sustained capacity, demand-aware's 4 stay
    // inside it.
    let demands = vec![
        TenantDemand { model: ModelId::MobileNet, rate_qps: 3.0 * u, sla_ms: SLA_MS },
        TenantDemand { model: ModelId::MobileNet, rate_qps: 1.1 * u, sla_ms: SLA_MS },
        TenantDemand { model: ModelId::MobileNet, rate_qps: 0.5 * u, sla_ms: SLA_MS },
    ];
    let requests = super::default_requests();
    let modes = [false, true]; // demand-aware?
    let sims = super::sweep(&modes, |&aware| {
        let tenants = if aware {
            place_tenants(&demands, MigConfig::Small7, 0.85).expect("placement")
        } else {
            even_split(&demands, MigConfig::Small7).expect("even split")
        };
        let alloc = tenants
            .iter()
            .map(|t| t.vgpus.to_string())
            .collect::<Vec<_>>()
            .join("/");
        let cfg = MultiConfig {
            mig: MigConfig::Small7,
            tenants,
            preproc: PreprocMode::Ideal,
            policy: PolicyKind::Dynamic,
            requests,
            seed: 0xAC4,
            warmup_frac: 0.1,
            reconfig: None,
        };
        (alloc, multi::run(&cfg, sys).expect("valid config"))
    });
    let outs: Vec<(bool, (String, multi::MultiOutcome))> =
        modes.iter().copied().zip(sims).collect();
    let mut t = Table::new(&["placement", "alloc", "worst p95 ms", "max viol %"]);
    let mut rows = Vec::new();
    for (aware, (alloc, out)) in &outs {
        let label = if *aware { "demand-aware" } else { "even split" };
        let viol = out
            .per_tenant
            .iter()
            .map(|(_, s)| s.sla_violation_frac(SLA_MS))
            .fold(0.0, f64::max);
        t.row(&[
            label.to_string(),
            alloc.to_string(),
            num(out.worst_p95_ms()),
            num(viol * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("placement", Json::str(label)),
            ("alloc", Json::str(alloc)),
            ("worst_p95_ms", Json::num(out.worst_p95_ms())),
            ("max_violation_frac", Json::num(viol)),
        ]));
    }
    for line in t.render() {
        rep.row(&line);
    }
    rep.data("assignment", Json::Arr(rows));
    rep.finish("packing")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_dominates_the_study_and_placement_rescues_the_hot_tenant() {
        crate::experiments::set_fast(true);
        let doc = run(&PrebaConfig::new());
        let data = doc.get("data").unwrap();

        // Worked example: exact numbers pinned by mig::placement's tests.
        let worked = data.get("worked").unwrap().as_arr().unwrap();
        let admitted = |s: &str| -> f64 {
            worked
                .iter()
                .find(|r| r.get("strategy").unwrap().as_str().unwrap().starts_with(s))
                .unwrap()
                .get("admitted_gpcs")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(admitted("best-fit") > admitted("first-fit"));

        // Randomized study: best-fit never loses on average.
        let rnd = data.get("randomized").unwrap();
        assert!(
            rnd.get("bf_admitted_frac").unwrap().as_f64()
                >= rnd.get("ff_admitted_frac").unwrap().as_f64()
        );

        // DES: demand-aware placement keeps the hot tenant inside the SLA
        // that the even split blows through (3.4 slices of demand on 3).
        let rows = data.get("assignment").unwrap().as_arr().unwrap();
        let get = |placement: &str, key: &str| -> f64 {
            rows.iter()
                .find(|r| r.get("placement").unwrap().as_str() == Some(placement))
                .unwrap()
                .get(key)
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(
            get("demand-aware", "worst_p95_ms") < 0.5 * get("even split", "worst_p95_ms"),
            "demand-aware {} vs even {}",
            get("demand-aware", "worst_p95_ms"),
            get("even split", "worst_p95_ms")
        );
        assert!(
            get("demand-aware", "max_violation_frac") < get("even split", "max_violation_frac")
        );
    }
}
