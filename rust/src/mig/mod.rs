//! NVIDIA MIG partition model + calibrated vGPU service-time model.
//!
//! `partition` encodes the A100's legal MIG geometries (paper Fig 2);
//! `service` gives per-vGPU model-execution time as a function of
//! (model, slice size, batch, audio length), calibrated so the paper's
//! measured Batch_knee / Time_knee values reproduce (see DESIGN.md §4).

pub mod partition;
pub mod planner;
pub mod service;

pub use partition::{MigConfig, Partition, Slice};
pub use service::ServiceModel;
