//! NVIDIA MIG partition model + calibrated vGPU service-time model.
//!
//! The layer map, bottom-up:
//!
//! * [`partition`] — the A100's legal MIG geometries (paper Fig 2): a
//!   [`Slice`] is one `Mg.Ngb` instance profile, a [`Partition`] a
//!   homogeneous split, and a [`GpuClass`] the per-GPU capacity of a
//!   (possibly heterogeneous) fleet inventory (A100 7-GPC vs A30-style
//!   4-GPC).
//! * [`service`] — per-vGPU model-execution time as a function of
//!   (model, slice size, batch, audio length), calibrated so the paper's
//!   measured Batch_knee / Time_knee values reproduce (provenance is
//!   documented on the calibration constants in [`crate::models`]).
//! * [`planner`] — offline partition recommendation for one SLA.
//! * [`placement`] — fragmentation-aware packing of slice asks onto a
//!   multi-GPU inventory (first-fit vs best-fit-decreasing).
//! * [`reconfig`] — the partition decision made *online*: windowed rate
//!   telemetry, hysteresis controller, amortized reconfig-cost model,
//!   and the cluster-scale planner that moves slices across GPUs
//!   (in-place reassignment vs paid migration).
//!
//! ```
//! use preba::mig::{MigConfig, Slice};
//!
//! // The paper's three characterized configurations all fit an A100.
//! for cfg in MigConfig::ALL {
//!     assert!(cfg.partition().fits_a100(), "{cfg}");
//! }
//! // 1 GPC + 20 GB is not a profile NVIDIA exposes.
//! assert!(!Slice::new(1, 20).is_legal());
//! ```

pub mod partition;
pub mod placement;
pub mod planner;
pub mod reconfig;
pub mod service;

pub use partition::{parse_fleet, GpuClass, MigConfig, Partition, Slice};
pub use placement::PackStrategy;
pub use reconfig::{
    validate_plan, ClusterReconfigController, ConsolidationAction, Plan, Planner, PlannerKind,
    ReconfigController, ReconfigPolicy, Relocation, SliceMove, TenantSpec,
};
pub use service::ServiceModel;
