//! NVIDIA MIG partition model + calibrated vGPU service-time model.
//!
//! `partition` encodes the A100's legal MIG geometries (paper Fig 2);
//! `service` gives per-vGPU model-execution time as a function of
//! (model, slice size, batch, audio length), calibrated so the paper's
//! measured Batch_knee / Time_knee values reproduce (see DESIGN.md §4).

//! `reconfig` turns the partition decision online (windowed rate
//! telemetry + hysteresis controller + amortized reconfig-cost model) and
//! `placement` packs slice requests onto a multi-GPU inventory with
//! fragmentation awareness.

pub mod partition;
pub mod placement;
pub mod planner;
pub mod reconfig;
pub mod service;

pub use partition::{MigConfig, Partition, Slice};
pub use placement::PackStrategy;
pub use reconfig::{
    ClusterReconfigController, Plan, ReconfigController, ReconfigPolicy, SliceMove, TenantSpec,
};
pub use service::ServiceModel;
