//! Calibrated vGPU service-time model.
//!
//! # Model
//!
//! Model-execution time of one batch on a vGPU with `g` GPCs is affine in
//! the batch size `b` once the slice is compute-bound:
//!
//! ```text
//! T(b) = t_ramp + b * t_samp
//! ```
//!
//! * `t_samp` — marginal time per batched sample at saturation. Pinned by
//!   the calibrated per-GPC plateau throughput:
//!   `t_samp(g) = 1 / (plateau_qps_per_gpc * g^(1-GAMMA))`. The `GAMMA`
//!   exponent models the well-documented efficiency loss of large slices
//!   on small-batch inference (paper Fig 5: the aggregate throughput of
//!   1g.5gb(7x) exceeds 7g.40gb(1x)); audio `t_samp` additionally scales
//!   linearly with input length (FLOPs per audio-second).
//! * `t_ramp` — batch-independent portion (kernel launches, weight
//!   traffic). Derived from the paper's measured knee:
//!   with the knee defined as the batch where throughput reaches
//!   `knee_frac` (=0.9) of plateau, `b/(t_ramp + b*t_samp) = f/t_samp`
//!   at `b = knee` gives `t_ramp = knee * t_samp * (1-f)/f = knee*t_samp/9`.
//!
//! Consequences (all measured by the profiler, not asserted):
//! * throughput `b/T(b)` saturates at the plateau while latency keeps
//!   growing linearly — the Fig 6 knee shape;
//! * for audio, `T(knee) = (10/9)*knee*t_samp ≈ Time_knee` independent of
//!   input length (Fig 15's ~35 ms observation) because `knee` is derived
//!   from `Time_knee` below;
//! * vision knees interpolate between the paper's measured 1g and 7g
//!   values with a power law in `g` (16→128 is 8× over 7× the GPCs, i.e.
//!   slightly super-linear).
//!
//! Tail dispersion: execution time samples multiply by a lognormal jitter
//! (σ≈0.05) so p95 sits above the mean as in real measurements.

use crate::models::{ModelKind, ModelSpec};
use crate::util::Rng;

/// Large-slice efficiency-loss exponent (see module docs).
pub const GAMMA: f64 = 0.12;

/// Throughput fraction of plateau that defines the knee.
pub const KNEE_FRAC: f64 = 0.90;

/// Lognormal sigma of execution-time jitter.
pub const JITTER_SIGMA: f64 = 0.05;

/// Service-time model for one (model, slice-size) pair.
#[derive(Debug, Clone)]
pub struct ServiceModel {
    /// GPCs in the vGPU.
    pub gpcs: usize,
    /// Marginal per-sample seconds at a 2.5 s audio length (audio) or
    /// fixed image size (vision).
    t_samp_ref: f64,
    /// Knee at the reference length.
    knee_ref: usize,
    /// Model kind (audio scales with length).
    kind: ModelKind,
    /// Audio Time_knee (s); drives length-dependent knees.
    time_knee_s: f64,
}

/// Reference audio length (s) for `t_samp_ref` (the calibration length).
pub const REF_AUDIO_S: f64 = 2.5;

impl ServiceModel {
    /// Build the calibrated model for `spec` on a `g`-GPC slice.
    pub fn new(spec: &ModelSpec, gpcs: usize) -> ServiceModel {
        assert!((1..=7).contains(&gpcs), "gpcs out of range");
        let g = gpcs as f64;
        let plateau_g = spec.plateau_qps_per_gpc * g.powf(1.0 - GAMMA);
        let t_samp_ref = 1.0 / plateau_g;
        let knee_ref = match spec.kind {
            ModelKind::Vision => {
                // Interpolate the paper's 1g / 7g knees with a power law.
                let k1 = spec.knee_1g.expect("vision knee_1g") as f64;
                let k7 = spec.knee_7g.expect("vision knee_7g") as f64;
                let alpha = (k7 / k1).ln() / 7f64.ln();
                (k1 * g.powf(alpha)).round().max(1.0) as usize
            }
            ModelKind::Audio => {
                // Knee derived from the constant Time_knee:
                // T(knee) = (10/9) * knee * t_samp = time_knee.
                let b = KNEE_FRAC * spec.time_knee_s / t_samp_ref;
                b.round().max(1.0) as usize
            }
        };
        ServiceModel { gpcs, t_samp_ref, knee_ref, kind: spec.kind, time_knee_s: spec.time_knee_s }
    }

    /// Marginal per-sample time for inputs of `len_s` seconds.
    pub fn t_samp(&self, len_s: f64) -> f64 {
        match self.kind {
            ModelKind::Vision => self.t_samp_ref,
            ModelKind::Audio => self.t_samp_ref * (len_s / REF_AUDIO_S).max(1e-3),
        }
    }

    /// Batch-independent ramp time for inputs of `len_s`.
    pub fn t_ramp(&self, len_s: f64) -> f64 {
        self.knee(len_s) as f64 * self.t_samp(len_s) * (1.0 - KNEE_FRAC) / KNEE_FRAC
    }

    /// Mean execution seconds of a batch of `b` inputs of `len_s` seconds.
    pub fn exec_secs(&self, b: usize, len_s: f64) -> f64 {
        assert!(b >= 1);
        self.t_ramp(len_s) + b as f64 * self.t_samp(len_s)
    }

    /// Execution seconds with lognormal tail jitter.
    pub fn exec_secs_jittered(&self, b: usize, len_s: f64, rng: &mut Rng) -> f64 {
        self.exec_secs(b, len_s) * rng.lognormal(0.0, JITTER_SIGMA)
    }

    /// The analytic Batch_knee for inputs of `len_s` seconds.
    pub fn knee(&self, len_s: f64) -> usize {
        match self.kind {
            ModelKind::Vision => self.knee_ref,
            ModelKind::Audio => {
                let b = KNEE_FRAC * self.time_knee_s / self.t_samp(len_s);
                b.round().max(1.0) as usize
            }
        }
    }

    /// Saturated throughput of this vGPU, queries/s, at `len_s`.
    pub fn plateau_qps(&self, len_s: f64) -> f64 {
        1.0 / self.t_samp(len_s)
    }

    /// Throughput (queries/s) when running back-to-back batches of size `b`.
    pub fn qps_at(&self, b: usize, len_s: f64) -> f64 {
        b as f64 / self.exec_secs(b, len_s)
    }

    /// "GPU utilization" of the slice at batch `b` — the fraction of
    /// plateau throughput achieved, matching how Fig 5 trends utilization
    /// with batch size.
    pub fn utilization(&self, b: usize, len_s: f64) -> f64 {
        self.qps_at(b, len_s) / self.plateau_qps(len_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    #[test]
    fn vision_knees_match_paper_at_1g_and_7g() {
        let cases = [
            (ModelId::MobileNet, 16, 128),
            (ModelId::SqueezeNet, 4, 32),
            (ModelId::SwinTransformer, 2, 16),
        ];
        for (m, k1, k7) in cases {
            assert_eq!(ServiceModel::new(m.spec(), 1).knee(0.0), k1, "{m} 1g");
            assert_eq!(ServiceModel::new(m.spec(), 7).knee(0.0), k7, "{m} 7g");
        }
    }

    #[test]
    fn knee_monotonic_in_gpcs() {
        for m in ModelId::ALL {
            let len = 2.5;
            let mut prev = 0;
            for g in 1..=7 {
                let k = ServiceModel::new(m.spec(), g).knee(len);
                assert!(k >= prev, "{m} g={g}");
                prev = k;
            }
        }
    }

    #[test]
    fn audio_latency_at_knee_is_time_knee_for_all_lengths() {
        for m in ModelId::AUDIO {
            for g in [1, 7] {
                let sm = ServiceModel::new(m.spec(), g);
                for len in [2.5, 5.0, 15.0, 25.0] {
                    let knee = sm.knee(len);
                    let t = sm.exec_secs(knee, len);
                    if knee >= 2 {
                        // Within rounding of 35 ms.
                        assert!(
                            (t - 0.035).abs() < 0.010,
                            "{m} g={g} len={len}: T(knee)={t}"
                        );
                    } else {
                        // knee == 1: the physical floor is the single-
                        // input execution time, which EXCEEDS Time_knee
                        // for long inputs on small slices (the yellow
                        // batch-1 cells at the top of paper Fig 14a).
                        assert!(t >= 0.020, "{m} g={g} len={len}: T(1)={t}");
                    }
                }
            }
        }
    }

    #[test]
    fn small_slices_aggregate_beats_full_gpu() {
        // Paper Fig 5: 1g.5gb(7x) aggregate plateau > 7g.40gb(1x).
        for m in ModelId::ALL {
            let len = 2.5;
            let agg_small = 7.0 * ServiceModel::new(m.spec(), 1).plateau_qps(len);
            let full = ServiceModel::new(m.spec(), 7).plateau_qps(len);
            assert!(agg_small > full, "{m}: {agg_small} <= {full}");
        }
    }

    #[test]
    fn throughput_saturates_latency_grows() {
        let sm = ServiceModel::new(ModelId::MobileNet.spec(), 1);
        let knee = sm.knee(0.0);
        let q_knee = sm.qps_at(knee, 0.0);
        let q_4x = sm.qps_at(knee * 4, 0.0);
        // <10% more throughput for 4x the batch...
        assert!(q_4x / q_knee < 1.10);
        // ...but ~4x the latency.
        let t_ratio = sm.exec_secs(knee * 4, 0.0) / sm.exec_secs(knee, 0.0);
        assert!(t_ratio > 3.0, "t_ratio={t_ratio}");
    }

    #[test]
    fn utilization_ramps_faster_on_small_slices() {
        // Paper Fig 5: fine-grained slices reach high utilization at small
        // batches.
        let m = ModelId::SqueezeNet.spec();
        let u1 = ServiceModel::new(m, 1).utilization(4, 0.0);
        let u7 = ServiceModel::new(m, 7).utilization(4, 0.0);
        assert!(u1 > u7, "{u1} <= {u7}");
        assert!(u1 >= 0.89); // knee batch => ~knee_frac utilization
    }

    #[test]
    fn jitter_is_unbiased_and_small() {
        let sm = ServiceModel::new(ModelId::CitriNet.spec(), 1);
        let mut rng = Rng::new(1);
        let base = sm.exec_secs(4, 2.5);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| sm.exec_secs_jittered(4, 2.5, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean / base - 1.0).abs() < 0.01, "mean ratio {}", mean / base);
    }
}
