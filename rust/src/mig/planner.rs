//! MIG partition planner — the operator-facing question the paper's
//! characterization enables (and the PARIS/ELSA [43] line of work
//! automates): *which partition should this model be served on?*
//!
//! For every homogeneous partition that fits the A100, the planner
//! evaluates the calibrated service model analytically:
//! * SLA-bounded per-slice throughput: the largest batch `b ≤ knee` whose
//!   execution time stays within the latency budget (after subtracting
//!   the batching wait `Time_queue`), times `b / T(b)`;
//! * aggregate = per-slice × slice count;
//! and returns the Pareto set over (throughput, latency).
//!
//! Analytic (no DES) so the CLI `preba plan` answers interactively; the
//! `capacity_planning` example cross-checks against simulation.

use crate::models::{ModelId, ModelKind};

use super::partition::Partition;
use super::service::ServiceModel;

/// One partition's evaluation.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub partition: Partition,
    /// Largest batch meeting the SLA (0 = infeasible).
    pub batch: usize,
    /// Aggregate SLA-bounded throughput, queries/s.
    pub qps: f64,
    /// Expected execution latency at that batch, ms.
    pub exec_ms: f64,
    /// End-to-end latency estimate (batching wait + execution), ms.
    pub e2e_ms: f64,
}

/// Evaluate every homogeneous partition for `model` under `sla_ms`
/// end-to-end p95 budget at input length `len_s` (0 for vision).
pub fn plan(model: ModelId, sla_ms: f64, len_s: f64) -> Vec<PlanPoint> {
    let spec = model.spec();
    let mut out = Vec::new();
    for partition in Partition::all_homogeneous() {
        let sm = ServiceModel::new(spec, partition.slice.gpcs);
        let knee = sm.knee(len_s);
        // Batching wait budget: PREBA sets Time_queue = Time_knee/n; an
        // SLA-aware deployment additionally caps the wait at a quarter of
        // the end-to-end budget so single-vGPU partitions don't spend the
        // whole SLA waiting to fill a batch.
        let time_queue_s =
            (sm.exec_secs(knee, len_s) / partition.count as f64).min(0.25 * sla_ms * 1e-3);
        let budget_s = sla_ms * 1e-3 - time_queue_s;
        // Largest batch within budget, capped at the knee (no throughput
        // benefit beyond it).
        let mut best = None;
        for b in 1..=knee {
            let t = sm.exec_secs(b, len_s) * 1.10; // p95 ≈ 1.1x mean
            if t <= budget_s {
                best = Some(b);
            }
        }
        let (batch, qps, exec_ms) = match best {
            Some(b) => {
                let t = sm.exec_secs(b, len_s);
                (b, partition.count as f64 * b as f64 / t, t * 1e3)
            }
            None => (0, 0.0, 0.0),
        };
        out.push(PlanPoint {
            partition,
            batch,
            qps,
            exec_ms,
            e2e_ms: exec_ms + time_queue_s * 1e3,
        });
    }
    out.sort_by(|a, b| b.qps.partial_cmp(&a.qps).unwrap());
    out
}

/// The best feasible partition (highest SLA-bounded throughput).
pub fn recommend(model: ModelId, sla_ms: f64, len_s: f64) -> Option<PlanPoint> {
    plan(model, sla_ms, len_s).into_iter().find(|p| p.batch > 0)
}

/// Default evaluation length for a model (mean LibriSpeech for audio).
pub fn default_len(model: ModelId) -> f64 {
    match model.kind() {
        ModelKind::Vision => 0.0,
        ModelKind::Audio => 10.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::MigConfig;

    #[test]
    fn loose_sla_prefers_fine_partitions() {
        // With a comfortable SLA, 1g.5gb(7x) has the highest aggregate
        // throughput (paper Fig 5's headline).
        for model in [ModelId::MobileNet, ModelId::SqueezeNet] {
            let best = recommend(model, 50.0, 0.0).unwrap();
            assert_eq!(
                best.partition,
                MigConfig::Small7.partition(),
                "{model}: {:?}",
                best
            );
        }
    }

    #[test]
    fn tight_sla_forces_bigger_slices_for_heavy_models() {
        // Swin at a very tight SLA: a 1g slice's single-input latency is
        // ~5.6 ms; at a 4 ms budget only bigger slices can serve.
        let points = plan(ModelId::SwinTransformer, 4.0, 0.0);
        let small = points
            .iter()
            .find(|p| p.partition == MigConfig::Small7.partition())
            .unwrap();
        assert_eq!(small.batch, 0, "1g should be infeasible: {small:?}");
        let best = recommend(ModelId::SwinTransformer, 4.0, 0.0);
        assert!(best.is_some(), "some partition must serve 4 ms");
        assert!(best.unwrap().partition.slice.gpcs > 1);
    }

    #[test]
    fn impossible_sla_yields_no_plan() {
        assert!(recommend(ModelId::ConformerDefault, 0.5, 25.0).is_none());
    }

    #[test]
    fn plan_is_sorted_and_covers_all_partitions() {
        let points = plan(ModelId::CitriNet, 60.0, 5.0);
        assert_eq!(points.len(), Partition::all_homogeneous().len());
        for w in points.windows(2) {
            assert!(w[0].qps >= w[1].qps);
        }
    }

    #[test]
    fn e2e_exceeds_exec_by_the_batching_wait() {
        for p in plan(ModelId::MobileNet, 30.0, 0.0) {
            if p.batch > 0 {
                assert!(p.e2e_ms > p.exec_ms);
            }
        }
    }
}
