//! Pluggable cluster-rebalance planning stack — the Reconfigurable
//! Machine Scheduling Problem (RMSP) solver tier of MIG-Serving
//! (arXiv:2109.11067) behind one [`Planner`] seam:
//!
//! * [`GreedyPlanner`] — the fast path: the existing deterministic
//!   worst-deficit-first heuristic
//!   ([`plan_cluster_moves_fleet_scaled`]), unchanged byte-for-byte.
//! * [`AnnealPlanner`] — the slow path: simulated annealing over legal
//!   single-slice swaps, **seeded from the greedy plan** so its
//!   objective can never be worse, budgeted by proposal count (not
//!   wall-clock) so plans stay deterministic at any `--jobs`.
//! * [`ExactPlanner`] — a small in-crate branch-and-bound solver for
//!   fleets up to ~16 GPUs: optimal over the swap move universe (donors
//!   above their need, gainers below theirs), with the anneal plan as
//!   incumbent and an admissible latency-mass bound for pruning. Above
//!   `max_gpus` it falls back to the anneal.
//!
//! All three consume the same borrowed [`PlanInstance`] and emit
//! [`SliceMove`] lists that replay cleanly through
//! [`super::validate_plan`]; plans are compared on [`plan_cost`] — the
//! controller's own units (latency mass over one cooldown plus the
//! amortized outage cost of the moves), lower is better.

use super::{
    plan_cluster_moves_fleet_scaled, predicted_p95_ms_gpcs_scaled, slices_for_rate_scaled,
    ReconfigPolicy, SliceMove, TenantSpec,
};
use crate::mig::{GpuClass, Slice};
use crate::util::rng::Rng;

/// Planner selection, threaded through [`ReconfigPolicy::planner`], the
/// `[reconfig] planner` TOML key and `preba cluster --planner`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlannerKind {
    /// Fast path: the deterministic worst-deficit-first heuristic.
    #[default]
    Greedy,
    /// Slow path: greedy-seeded simulated annealing (never worse than
    /// greedy on [`plan_cost`]).
    Anneal,
    /// Exact branch-and-bound for small fleets (≤ ~16 GPUs; anneal
    /// fallback above).
    Exact,
}

impl PlannerKind {
    pub const ALL: [PlannerKind; 3] =
        [PlannerKind::Greedy, PlannerKind::Anneal, PlannerKind::Exact];

    pub fn parse(s: &str) -> Option<PlannerKind> {
        match s {
            "greedy" => Some(PlannerKind::Greedy),
            "anneal" => Some(PlannerKind::Anneal),
            "exact" => Some(PlannerKind::Exact),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PlannerKind::Greedy => "greedy",
            PlannerKind::Anneal => "anneal",
            PlannerKind::Exact => "exact",
        }
    }

    /// The planner instance this kind selects, budgeted by `policy`.
    pub fn planner(self, policy: &ReconfigPolicy) -> Box<dyn Planner> {
        match self {
            PlannerKind::Greedy => Box::new(GreedyPlanner),
            PlannerKind::Anneal => Box::new(AnnealPlanner::budgeted(policy.anneal_iters)),
            PlannerKind::Exact => Box::new(ExactPlanner::default()),
        }
    }
}

/// One planning problem, borrowed from the controller: the same
/// arguments [`plan_cluster_moves_fleet_scaled`] takes, bundled so every
/// planner sees an identical instance.
#[derive(Debug, Clone, Copy)]
pub struct PlanInstance<'a> {
    pub tenants: &'a [TenantSpec],
    pub slices: &'a [Slice],
    pub rates: &'a [f64],
    /// Starting `alloc[gpu][tenant]` instance counts.
    pub alloc: &'a [Vec<usize>],
    pub fleet: &'a [GpuClass],
    pub policy: &'a ReconfigPolicy,
    /// Per-tenant curve-derived service-time scales (all-ones = flat).
    pub scales: &'a [f64],
}

/// Owning variant of [`PlanInstance`] so experiments, benches and tests
/// can build instances without juggling seven borrow lifetimes.
#[derive(Debug, Clone)]
pub struct OwnedInstance {
    pub tenants: Vec<TenantSpec>,
    pub slices: Vec<Slice>,
    pub rates: Vec<f64>,
    pub alloc: Vec<Vec<usize>>,
    pub fleet: Vec<GpuClass>,
    pub policy: ReconfigPolicy,
    pub scales: Vec<f64>,
}

impl OwnedInstance {
    pub fn as_instance(&self) -> PlanInstance<'_> {
        PlanInstance {
            tenants: &self.tenants,
            slices: &self.slices,
            rates: &self.rates,
            alloc: &self.alloc,
            fleet: &self.fleet,
            policy: &self.policy,
            scales: &self.scales,
        }
    }
}

/// A rebalance-planning algorithm: same instance in, a replayable
/// [`SliceMove`] list out. The controller's hysteresis/cooldown and
/// amortized-cost gates sit *outside* this seam, so swapping planners
/// can never change the no-thrash contract.
pub trait Planner {
    fn name(&self) -> &'static str;
    fn plan(&self, inst: &PlanInstance<'_>) -> Vec<SliceMove>;
}

/// Per-tenant slice needs of an instance (the controller's sizing rule).
pub fn plan_needs(inst: &PlanInstance<'_>) -> Vec<usize> {
    (0..inst.tenants.len())
        .map(|i| {
            slices_for_rate_scaled(
                &inst.tenants[i],
                inst.slices[i],
                inst.rates[i],
                inst.policy.target_util,
                inst.scales[i],
            )
        })
        .collect()
}

/// Replay `moves` over a copy of `alloc` (moves must be valid).
pub fn apply_moves(alloc: &[Vec<usize>], moves: &[SliceMove]) -> Vec<Vec<usize>> {
    let mut state = alloc.to_vec();
    for m in moves {
        state[m.gpu][m.from] -= 1;
        state[m.gpu][m.to] += 1;
    }
    state
}

/// The plan objective, lower is better: predicted per-tenant latency
/// mass over one cooldown (rate × p95, queue-seconds — the controller's
/// `saved_qs` currency) after the plan lands, plus the amortized outage
/// cost of the moves themselves (the controller's `cost_qs`). Move
/// costs are charged against the have-counts at each move's application
/// point, so the objective prices plans exactly as the commit gate
/// would. `moves` must replay cleanly over `inst.alloc`.
pub fn plan_cost(inst: &PlanInstance<'_>, moves: &[SliceMove]) -> f64 {
    let t = inst.tenants.len();
    let mut have: Vec<usize> = (0..t).map(|i| inst.alloc.iter().map(|g| g[i]).sum()).collect();
    let mut outage_qs = 0.0;
    for m in moves {
        let outage = m.outage_s(inst.policy);
        let displaced = inst.rates[m.from] / have[m.from].max(1) as f64
            + inst.rates[m.to] / (have[m.to] + 1) as f64;
        outage_qs += displaced * outage * outage;
        have[m.from] -= 1;
        have[m.to] += 1;
    }
    let mass_qs: f64 = (0..t)
        .map(|i| {
            let p95 = predicted_p95_ms_gpcs_scaled(
                &inst.tenants[i],
                inst.slices[i].gpcs,
                have[i],
                inst.rates[i],
                inst.scales[i],
            );
            inst.rates[i] * 1e-3 * p95 * inst.policy.cooldown_s
        })
        .sum();
    mass_qs + outage_qs
}

/// Turn a target allocation into a replayable move list: per GPU, pair
/// each destroyed instance with a created one (every search step is a
/// 1-for-1 swap, so counts balance per GPU), capacity-freeing pairings
/// first so every intermediate state stays within the class budget.
/// Migration flags are truthful at each move's application point.
/// `None` when the per-GPU deltas don't balance or no legal ordering
/// was found.
pub fn synthesize_moves(
    slices: &[Slice],
    fleet: &[GpuClass],
    from: &[Vec<usize>],
    target: &[Vec<usize>],
) -> Option<Vec<SliceMove>> {
    let t = slices.len();
    let mut moves = Vec::new();
    for g in 0..from.len() {
        let mut donors: Vec<usize> = Vec::new();
        let mut gainers: Vec<usize> = Vec::new();
        for i in 0..t {
            let (a, b) = (from[g][i], target[g][i]);
            for _ in b..a {
                donors.push(i);
            }
            for _ in a..b {
                gainers.push(i);
            }
        }
        if donors.len() != gainers.len() {
            return None;
        }
        let mut state: Vec<usize> = from[g].clone();
        let mut gpc_free = fleet[g]
            .gpcs
            .saturating_sub((0..t).map(|i| state[i] * slices[i].gpcs).sum());
        let mut mem_free = fleet[g]
            .mem_gb
            .saturating_sub((0..t).map(|i| state[i] * slices[i].mem_gb).sum());
        while !donors.is_empty() {
            // Pick the legal (donor, gainer) pair freeing the most GPCs;
            // ties break toward the lowest (donor, gainer) — deterministic.
            let mut best: Option<(i64, usize, usize)> = None;
            for &d in &donors {
                for &i in &gainers {
                    if d == i || state[d] == 0 {
                        continue;
                    }
                    if !(fleet[g].supports(&slices[i])
                        && gpc_free + slices[d].gpcs >= slices[i].gpcs
                        && mem_free + slices[d].mem_gb >= slices[i].mem_gb)
                    {
                        continue;
                    }
                    let freed = slices[i].gpcs as i64 - slices[d].gpcs as i64;
                    let key = (freed, d, i);
                    if best.map_or(true, |b| key < b) {
                        best = Some(key);
                    }
                }
            }
            let (_, d, i) = best?;
            let migration = state[i] == 0;
            state[d] -= 1;
            state[i] += 1;
            gpc_free = gpc_free + slices[d].gpcs - slices[i].gpcs;
            mem_free = mem_free + slices[d].mem_gb - slices[i].mem_gb;
            moves.push(SliceMove { gpu: g, from: d, to: i, migration });
            let dp = donors.iter().position(|&x| x == d).expect("donor present");
            donors.swap_remove(dp);
            let gp = gainers.iter().position(|&x| x == i).expect("gainer present");
            gainers.swap_remove(gp);
        }
    }
    Some(moves)
}

/// The fast path: [`plan_cluster_moves_fleet_scaled`] behind the trait,
/// byte-identical to calling it directly.
pub struct GreedyPlanner;

impl Planner for GreedyPlanner {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn plan(&self, inst: &PlanInstance<'_>) -> Vec<SliceMove> {
        plan_cluster_moves_fleet_scaled(
            inst.tenants,
            inst.slices,
            inst.rates,
            inst.alloc,
            inst.fleet,
            inst.policy,
            inst.scales,
        )
    }
}

/// The slow path: simulated annealing over legal single-slice swaps,
/// seeded from the greedy end state so the returned plan's
/// [`plan_cost`] is never above the greedy plan's. The budget is a
/// proposal count — wall-clock plays no part, so the plan is a pure
/// function of the instance and the fixed seed (byte-identical at any
/// `--jobs`). Swaps may drop a donor to its last instance but never to
/// zero (every tenant keeps a foothold).
pub struct AnnealPlanner {
    /// Proposal budget (legal or not, every proposal spends one).
    pub iters: usize,
    /// Fixed RNG seed — annealing is deterministic per instance.
    pub seed: u64,
}

impl AnnealPlanner {
    pub fn budgeted(iters: usize) -> AnnealPlanner {
        AnnealPlanner { iters, seed: 0x5EED_A11E_A1 }
    }

    /// Plan and report the proposals actually spent (`<= self.iters`) —
    /// the conformance suite pins the budget contract on this.
    pub fn plan_with_stats(&self, inst: &PlanInstance<'_>) -> (Vec<SliceMove>, usize) {
        let greedy = GreedyPlanner.plan(inst);
        let t = inst.tenants.len();
        let n_gpus = inst.alloc.len();
        if self.iters == 0 || n_gpus == 0 || t < 2 {
            return (greedy, 0);
        }
        let greedy_cost = plan_cost(inst, &greedy);
        let mut cur = apply_moves(inst.alloc, &greedy);
        let mut have: Vec<usize> = (0..t).map(|i| cur.iter().map(|g| g[i]).sum()).collect();
        let mut gpc_free: Vec<usize> = (0..n_gpus)
            .map(|g| {
                inst.fleet[g]
                    .gpcs
                    .saturating_sub((0..t).map(|i| cur[g][i] * inst.slices[i].gpcs).sum())
            })
            .collect();
        let mut mem_free: Vec<usize> = (0..n_gpus)
            .map(|g| {
                inst.fleet[g]
                    .mem_gb
                    .saturating_sub((0..t).map(|i| cur[g][i] * inst.slices[i].mem_gb).sum())
            })
            .collect();
        let mut cur_cost = greedy_cost;
        let mut best_moves = greedy;
        let mut best_cost = greedy_cost;
        let mut rng = Rng::new(self.seed);
        let t0 = 0.05 * greedy_cost.max(1e-9);
        let mut used = 0;
        for k in 0..self.iters {
            used = k + 1;
            let g = rng.below(n_gpus as u64) as usize;
            let d = rng.below(t as u64) as usize;
            let i = rng.below(t as u64) as usize;
            if d == i || cur[g][d] == 0 || have[d] <= 1 {
                continue;
            }
            let (sd, si) = (inst.slices[d], inst.slices[i]);
            if !(inst.fleet[g].supports(&si)
                && gpc_free[g] + sd.gpcs >= si.gpcs
                && mem_free[g] + sd.mem_gb >= si.mem_gb)
            {
                continue;
            }
            cur[g][d] -= 1;
            cur[g][i] += 1;
            let accepted = match synthesize_moves(inst.slices, inst.fleet, inst.alloc, &cur) {
                None => false,
                Some(moves) => {
                    let c = plan_cost(inst, &moves);
                    let temp = t0 * (1.0 - k as f64 / self.iters as f64);
                    let accept =
                        c <= cur_cost || rng.f64() < (-(c - cur_cost) / temp.max(1e-12)).exp();
                    if accept {
                        cur_cost = c;
                        if c < best_cost {
                            best_cost = c;
                            best_moves = moves;
                        }
                    }
                    accept
                }
            };
            if accepted {
                have[d] -= 1;
                have[i] += 1;
                gpc_free[g] = gpc_free[g] + sd.gpcs - si.gpcs;
                mem_free[g] = mem_free[g] + sd.mem_gb - si.mem_gb;
            } else {
                cur[g][d] += 1;
                cur[g][i] -= 1;
            }
        }
        (best_moves, used)
    }
}

impl Planner for AnnealPlanner {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn plan(&self, inst: &PlanInstance<'_>) -> Vec<SliceMove> {
        self.plan_with_stats(inst).0
    }
}

/// Exact branch-and-bound over the swap move universe: every move
/// donates from a tenant above its sized need to one below it (the
/// greedy's own universe), so move sequences terminate when deficits are
/// exhausted. The search starts from the better of the greedy and
/// anneal plans as incumbent and prunes on an admissible bound — move
/// costs are nonnegative and p95 is nonincreasing in slice count, so a
/// node's cheapest completion is its move cost so far plus each
/// tenant's latency mass at `max(have, need)` slices. Visited states
/// are dominance-pruned on move cost. Fleets above `max_gpus` fall
/// back to the anneal plan; exhausting `node_budget` returns the best
/// plan found (still never worse than greedy or anneal, which seed it).
pub struct ExactPlanner {
    /// Largest fleet branch-and-bound attempts (anneal fallback above).
    pub max_gpus: usize,
    /// Nodes expanded before settling for the incumbent.
    pub node_budget: usize,
}

impl Default for ExactPlanner {
    fn default() -> Self {
        ExactPlanner { max_gpus: 16, node_budget: 200_000 }
    }
}

impl ExactPlanner {
    fn key(state: &[Vec<usize>]) -> Vec<u32> {
        state.iter().flat_map(|g| g.iter().map(|&c| c as u32)).collect()
    }
}

impl Planner for ExactPlanner {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn plan(&self, inst: &PlanInstance<'_>) -> Vec<SliceMove> {
        let anneal = AnnealPlanner::budgeted(inst.policy.anneal_iters);
        if inst.alloc.len() > self.max_gpus {
            return anneal.plan(inst);
        }
        let greedy_moves = GreedyPlanner.plan(inst);
        let anneal_moves = anneal.plan(inst);
        let greedy_cost = plan_cost(inst, &greedy_moves);
        let anneal_cost = plan_cost(inst, &anneal_moves);
        let (mut best_moves, mut best_cost) = if anneal_cost <= greedy_cost {
            (anneal_moves, anneal_cost)
        } else {
            (greedy_moves, greedy_cost)
        };

        let t = inst.tenants.len();
        let n_gpus = inst.alloc.len();
        let need = plan_needs(inst);
        let p95 = |i: usize, n: usize| {
            predicted_p95_ms_gpcs_scaled(
                &inst.tenants[i],
                inst.slices[i].gpcs,
                n,
                inst.rates[i],
                inst.scales[i],
            )
        };
        let mass = |have: &[usize]| -> f64 {
            (0..t)
                .map(|i| inst.rates[i] * 1e-3 * p95(i, have[i]) * inst.policy.cooldown_s)
                .sum()
        };
        // Admissible completion bound: no tenant can end above
        // max(have, need) in this universe, and p95 only falls with
        // more slices, so this mass undershoots every reachable plan.
        let lb_mass = |have: &[usize]| -> f64 {
            (0..t)
                .map(|i| {
                    inst.rates[i] * 1e-3 * p95(i, have[i].max(need[i])) * inst.policy.cooldown_s
                })
                .sum()
        };

        struct Node {
            state: Vec<Vec<usize>>,
            have: Vec<usize>,
            move_cost: f64,
            moves: Vec<SliceMove>,
        }
        let root_have: Vec<usize> =
            (0..t).map(|i| inst.alloc.iter().map(|g| g[i]).sum()).collect();
        // The empty plan is itself a candidate — doing nothing can beat
        // any move list once outage costs are priced in.
        let root_cost = mass(&root_have);
        if root_cost < best_cost {
            best_cost = root_cost;
            best_moves = Vec::new();
        }
        let mut visited: std::collections::HashMap<Vec<u32>, f64> =
            std::collections::HashMap::new();
        visited.insert(Self::key(inst.alloc), 0.0);
        let mut stack = vec![Node {
            state: inst.alloc.to_vec(),
            have: root_have,
            move_cost: 0.0,
            moves: Vec::new(),
        }];
        let mut nodes = 0usize;
        while let Some(node) = stack.pop() {
            nodes += 1;
            if nodes > self.node_budget {
                break;
            }
            let gpc_free = |g: usize, s: &[Vec<usize>]| {
                inst.fleet[g]
                    .gpcs
                    .saturating_sub((0..t).map(|i| s[g][i] * inst.slices[i].gpcs).sum())
            };
            let mem_free = |g: usize, s: &[Vec<usize>]| {
                inst.fleet[g]
                    .mem_gb
                    .saturating_sub((0..t).map(|i| s[g][i] * inst.slices[i].mem_gb).sum())
            };
            for g in 0..n_gpus {
                for d in 0..t {
                    if node.have[d] <= need[d] || node.state[g][d] == 0 {
                        continue;
                    }
                    for i in 0..t {
                        if i == d || node.have[i] >= need[i] {
                            continue;
                        }
                        let (sd, si) = (inst.slices[d], inst.slices[i]);
                        if !(inst.fleet[g].supports(&si)
                            && gpc_free(g, &node.state) + sd.gpcs >= si.gpcs
                            && mem_free(g, &node.state) + sd.mem_gb >= si.mem_gb)
                        {
                            continue;
                        }
                        let migration = node.state[g][i] == 0;
                        let outage = if migration {
                            inst.policy.migration_s
                        } else {
                            inst.policy.repartition_s
                        };
                        let displaced = inst.rates[d] / node.have[d].max(1) as f64
                            + inst.rates[i] / (node.have[i] + 1) as f64;
                        let move_cost = node.move_cost + displaced * outage * outage;
                        let mut state = node.state.clone();
                        state[g][d] -= 1;
                        state[g][i] += 1;
                        let mut have = node.have.clone();
                        have[d] -= 1;
                        have[i] += 1;
                        if move_cost + lb_mass(&have) >= best_cost - 1e-12 {
                            continue;
                        }
                        let key = Self::key(&state);
                        if visited.get(&key).is_some_and(|&c| c <= move_cost + 1e-12) {
                            continue;
                        }
                        visited.insert(key, move_cost);
                        let mut moves = node.moves.clone();
                        moves.push(SliceMove { gpu: g, from: d, to: i, migration });
                        let total = move_cost + mass(&have);
                        if total < best_cost {
                            best_cost = total;
                            best_moves = moves.clone();
                        }
                        stack.push(Node { state, have, move_cost, moves });
                    }
                }
            }
        }
        best_moves
    }
}

#[cfg(test)]
mod tests {
    use super::super::validate_plan;
    use super::*;
    use crate::mig::GpuClass;
    use crate::models::ModelId;

    /// Two tenants on two A100s: tenant 0 over-provisioned, tenant 1
    /// starved — every planner must shift capacity toward tenant 1.
    fn rebalance_instance() -> OwnedInstance {
        let spec = || TenantSpec::new(ModelId::MobileNet, 40.0);
        let tenants = vec![spec(), spec()];
        let slices = vec![Slice::new(1, 5), Slice::new(1, 5)];
        let plateau =
            crate::mig::ServiceModel::new(ModelId::MobileNet.spec(), 1).plateau_qps(0.0);
        let rates = vec![0.2 * plateau, 3.0 * plateau];
        let alloc = vec![vec![5, 2], vec![2, 0]];
        OwnedInstance {
            tenants,
            slices,
            rates,
            alloc,
            fleet: vec![GpuClass::A100; 2],
            policy: ReconfigPolicy::default(),
            scales: vec![1.0; 2],
        }
    }

    #[test]
    fn greedy_planner_is_the_direct_call() {
        let own = rebalance_instance();
        let inst = own.as_instance();
        let via_trait = GreedyPlanner.plan(&inst);
        let direct = plan_cluster_moves_fleet_scaled(
            &own.tenants,
            &own.slices,
            &own.rates,
            &own.alloc,
            &own.fleet,
            &own.policy,
            &own.scales,
        );
        assert_eq!(via_trait, direct);
        assert!(!via_trait.is_empty(), "instance must demand a rebalance");
    }

    #[test]
    fn anneal_never_worse_and_budget_respected() {
        let own = rebalance_instance();
        let inst = own.as_instance();
        let greedy_cost = plan_cost(&inst, &GreedyPlanner.plan(&inst));
        let anneal = AnnealPlanner::budgeted(500);
        let (moves, used) = anneal.plan_with_stats(&inst);
        assert!(used <= 500);
        assert!(plan_cost(&inst, &moves) <= greedy_cost + 1e-9);
        let failed = vec![false; own.fleet.len()];
        validate_plan(&own.slices, &own.fleet, &failed, &own.alloc, &moves).unwrap();
        // Zero budget degenerates to the greedy plan exactly.
        let (g, used0) = AnnealPlanner::budgeted(0).plan_with_stats(&inst);
        assert_eq!(used0, 0);
        assert_eq!(g, GreedyPlanner.plan(&inst));
    }

    #[test]
    fn exact_never_worse_than_anneal() {
        let own = rebalance_instance();
        let inst = own.as_instance();
        let anneal_cost =
            plan_cost(&inst, &AnnealPlanner::budgeted(own.policy.anneal_iters).plan(&inst));
        let exact_moves = ExactPlanner::default().plan(&inst);
        assert!(plan_cost(&inst, &exact_moves) <= anneal_cost + 1e-9);
        let failed = vec![false; own.fleet.len()];
        validate_plan(&own.slices, &own.fleet, &failed, &own.alloc, &exact_moves).unwrap();
    }

    #[test]
    fn exact_falls_back_to_anneal_above_max_gpus() {
        let mut own = rebalance_instance();
        // Pad the fleet out past the branch-and-bound ceiling.
        while own.fleet.len() < 20 {
            own.fleet.push(GpuClass::A100);
            own.alloc.push(vec![0, 0]);
        }
        let inst = own.as_instance();
        let exact = ExactPlanner::default().plan(&inst);
        let anneal = AnnealPlanner::budgeted(own.policy.anneal_iters).plan(&inst);
        assert_eq!(exact, anneal);
    }

    #[test]
    fn synthesize_reproduces_a_swap_with_truthful_flags() {
        let own = rebalance_instance();
        let mut target = own.alloc.clone();
        // gpu1: tenant 0 gives one slice to tenant 1 (not resident -> migration).
        target[1][0] -= 1;
        target[1][1] += 1;
        let moves =
            synthesize_moves(&own.slices, &own.fleet, &own.alloc, &target).expect("legal target");
        assert_eq!(moves, vec![SliceMove { gpu: 1, from: 0, to: 1, migration: true }]);
        let failed = vec![false; own.fleet.len()];
        let end = validate_plan(&own.slices, &own.fleet, &failed, &own.alloc, &moves).unwrap();
        assert_eq!(end, target);
    }

    #[test]
    fn planner_kind_parses_and_labels() {
        for kind in PlannerKind::ALL {
            assert_eq!(PlannerKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(PlannerKind::parse("ilp"), None);
        assert_eq!(PlannerKind::default(), PlannerKind::Greedy);
    }
}
